"""Autopilot demo: a warren that reshapes itself under drifting load.

Builds a small ShardedWarren, then lets the closed-loop control plane
(``repro.dist.autopilot``) do everything an operator would otherwise do
by hand, on a fake clock so the whole "day" runs in seconds:

  * serve traffic -> the controller notices the hot group and splits it;
  * kill a replica -> anti-entropy re-syncs it back into lockstep;
  * stop traffic  -> the idle collection demotes to the static tier;

printing every structured Decision as it lands.  This is the same
Controller that ``repro.dist.elastic.autopilot(warren)`` runs on a real
interval timer in production — only the clock differs.

Run:  PYTHONPATH=src python examples/autopilot_demo.py
"""

import tempfile

from repro.core import ingest_documents
from repro.data.synth import doc_generator
from repro.dist.autopilot import (AntiEntropyPolicy, AutopilotConfig,
                                  ColdPolicy, Controller, Hysteresis,
                                  HotSplitPolicy)
from repro.dist.shard_router import ShardedWarren
from repro.dist.simharness import SimClock

QUERIES = ["school education student", "government law state",
           "stock money business", "vibration conductor wind"]


def main() -> None:
    static_root = tempfile.mkdtemp(prefix="autopilot-demo-")
    warren = ShardedWarren(n_shards=2, replicas=2, static_dir=static_root)
    ingest_documents(warren, doc_generator(7, 200, mean_len=30), batch=8)

    clock = SimClock()
    ctl = Controller.for_warren(warren, clock=clock, config=AutopilotConfig(
        split=HotSplitPolicy(p95_hot_ms=0.0, sustain_ticks=2, min_docs=1,
                             max_groups=3),
        cold=ColdPolicy(demote_after_ticks=2, merge_after_ticks=10 ** 6,
                        min_groups=1),
        anti_entropy=AntiEntropyPolicy(sustain_ticks=2),
        hysteresis=Hysteresis(cooldown_ticks=1, min_dwell_ticks=0),
        pool=None))

    def tick(serve: bool) -> None:
        if serve:
            with warren:
                for q in QUERIES:
                    warren.search(q, k=10)
        for d in ctl.tick():
            print(f"  {d.summary()}")
        clock.advance()

    print(f"day 1 — morning rush ({warren.n_shards} groups):")
    for _ in range(3):
        tick(serve=True)
    print(f"  -> {warren.n_shards} groups, routing epoch "
          f"{warren.routing.epoch}")

    print("day 1 — afternoon: replica (0, 1) dies:")
    warren.groups[0].mark_failed(1)
    for _ in range(4):
        tick(serve=True)
    print(f"  -> health {warren.health()}")

    print("day 1 — night: traffic stops:")
    for _ in range(4):
        tick(serve=False)
    print(f"  -> demoted: {[d is not None for d in warren.demoted()]}")

    print(f"\n{len(ctl.decisions)} decisions, "
          f"{sum(1 for d in ctl.decisions if d.outcome == 'applied')} "
          f"applied, 0 operator interventions")
    warren.close()


if __name__ == "__main__":
    main()

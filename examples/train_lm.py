"""End-to-end training driver: annotative-index-backed data → transformer.

The full pipeline: ingest a corpus into the dynamic index, run the dedup +
segmentation annotation stages, then train an LM whose batches are hydrated
from 'seg:' extents — with periodic checkpoints, an injected crash, and a
restart that resumes the exact batch stream.

    PYTHONPATH=src python examples/train_lm.py --steps 60            # smoke
    PYTHONPATH=src python examples/train_lm.py --preset 100m ...     # big
"""

import argparse
import dataclasses
import os
import tempfile
import time

import jax

from repro.core import DynamicIndex, Warren
from repro.data.pipeline import (IndexedCorpusLoader, ingest,
                                 mark_duplicates, segment)
from repro.data.synth import doc_generator
from repro.models import transformer as T
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig, run_with_restarts

PRESETS = {
    "smoke": T.TransformerConfig(
        name="lm-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=256, vocab=4096, dtype="float32", remat=False),
    "20m": T.TransformerConfig(
        name="lm-20m", n_layers=6, d_model=384, n_heads=6, n_kv_heads=2,
        head_dim=64, d_ff=1024, vocab=8192, dtype="float32", remat=False),
    "100m": T.TransformerConfig(
        name="lm-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        head_dim=64, d_ff=2048, vocab=16384, dtype="float32"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--docs", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--crash-at", type=int, default=None,
                    help="inject a failure to demo checkpoint/restart")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(),
                                             f"lm_ckpt_{os.getpid()}")

    # ---- stage 1-3: index-backed data pipeline ------------------------- #
    warren = Warren(DynamicIndex())
    t0 = time.time()
    n = ingest(warren, doc_generator(0, args.docs, mean_len=120))
    dups = mark_duplicates(warren)
    segs = segment(warren, window=args.seq, stride=args.seq // 2)
    print(f"pipeline: {n} docs, {dups} dups, {segs} segments "
          f"({time.time() - t0:.1f}s)")

    params = T.init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model {cfg.name}: {n_params / 1e6:.1f}M params")

    def make_trainer():
        loader = IndexedCorpusLoader(warren, vocab=cfg.vocab,
                                     batch=args.batch, seq_len=args.seq)
        tc = TrainerConfig(
            total_steps=args.steps, ckpt_every=max(args.steps // 4, 1),
            ckpt_dir=ckpt_dir, log_every=max(args.steps // 10, 1),
            opt=AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps))
        return Trainer(lambda p, b: T.loss_fn(p, b, cfg),
                       T.init_params(cfg, jax.random.PRNGKey(0)), tc, loader,
                       data_state_fn=loader.state,
                       data_restore_fn=loader.restore)

    t0 = time.time()
    trainer = run_with_restarts(make_trainer, fail_at=args.crash_at)
    dt = time.time() - t0
    if not trainer.metrics_log:      # resumed at/after total_steps
        print(f"nothing to do: checkpoint already at step {trainer.step}")
        return
    first, last = trainer.metrics_log[0], trainer.metrics_log[-1]
    print(f"trained {trainer.step} steps in {dt:.1f}s "
          f"({trainer.step / dt:.2f} steps/s)")
    print(f"loss {first['loss']:.3f} (step {first['step']}) -> "
          f"{last['loss']:.3f} (step {last['step']})")
    if first["step"] <= args.steps // 2:  # fresh-enough run to judge trend
        assert last["loss"] < first["loss"], "loss did not improve"
    print(f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()

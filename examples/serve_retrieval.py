"""Serving: batched first-stage retrieval from an annotative index,
plus two-tower candidate scoring (the learned-retrieval hand-off).

Shows the three scoring paths agreeing and their relative speed:
  1. lazy host engine (paper-faithful Cottontail-style),
  2. batched device scoring (vectorized τ/ρ + scatter-add),
  3. Block-Max Pallas kernel (interpret mode on CPU).

    PYTHONPATH=src python examples/serve_retrieval.py [--docs 2000]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DynamicIndex, Warren, build_block_impacts,
                        collection_stats, ingest_documents, score_blockmax,
                        score_bm25)
from repro.data.synth import doc_generator
from repro.kernels import bm25_blockmax_topk
from repro.train.serve import RetrievalServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--shards", type=int, default=1,
                    help="serve from N hash-partitioned index shards")
    ap.add_argument("--replicas", type=int, default=1,
                    help="replicas per shard group (quorum commits, "
                         "read failover)")
    ap.add_argument("--async-scatter", action="store_true",
                    help="with --shards: fan per-group reads out on the "
                         "ScatterGather worker pool and print the "
                         "scatter/score/merge breakdown")
    ap.add_argument("--tiered", action="store_true",
                    help="serve through the LSM-style tiered engine "
                         "(hot memtable + on-disk runs, background "
                         "compaction)")
    ap.add_argument("--demote-cold", action="store_true",
                    help="with --shards: demote every shard group to a "
                         "static run set after the build and show query "
                         "parity (a write promotes a group back)")
    ap.add_argument("--metrics-dump", metavar="PATH", default=None,
                    help="on exit, append the obs metrics snapshot to PATH "
                         "as a JSONL record and write the Prometheus text "
                         "exposition to PATH + '.prom'")
    ap.add_argument("--trace-slow", metavar="MS", type=float, default=None,
                    help="dump any request trace slower than MS milliseconds "
                         "to traces_slow.jsonl next to --metrics-dump (or "
                         "the cwd)")
    ap.add_argument("--admin-port", type=int, default=None,
                    help="serve the obs admin endpoint (/metrics, /routing, "
                         "/traces, /profile/cpu, ...) on this port for the "
                         "duration of the run (0 = ephemeral)")
    args = ap.parse_args()
    if args.trace_slow is not None:
        import os

        from repro import obs
        slow_path = os.path.join(
            os.path.dirname(args.metrics_dump) if args.metrics_dump else ".",
            "traces_slow.jsonl")
        obs.tracer().set_slow_dump(args.trace_slow, slow_path)
    if args.tiered and (args.shards > 1 or args.replicas > 1):
        ap.error("--tiered is the single-node engine; for sharded cold "
                 "storage use --shards N --demote-cold")

    tmpdir = None
    compactor = None
    if args.shards > 1 or args.replicas > 1:
        import tempfile

        from repro.dist.shard_router import ShardedWarren
        tmpdir = tempfile.TemporaryDirectory()
        warren = ShardedWarren(n_shards=args.shards, replicas=args.replicas,
                               static_dir=tmpdir.name,
                               async_scatter=args.async_scatter)
    elif args.tiered:
        import tempfile

        from repro.tiered import Compactor, TieredStore
        tmpdir = tempfile.TemporaryDirectory()
        store = TieredStore(tmpdir.name + "/tiered")
        compactor = Compactor(store, freeze_segments=3,
                              interval_s=0.01).start()
        warren = store.warren()
    else:
        warren = Warren(DynamicIndex())
    admin = None
    if args.admin_port is not None:
        from repro import obs
        admin = obs.AdminServer(
            port=args.admin_port,
            warren=warren if hasattr(warren, "describe_routing") else None,
            slo=obs.SLOMonitor()).start()
        print(f"admin endpoint: {admin.url()}")
    t0 = time.time()
    ingest_documents(warren, doc_generator(0, args.docs), batch=256)
    print(f"indexed {args.docs} docs in {time.time() - t0:.1f}s")
    if compactor is not None:
        compactor.stop(drain=True)   # hot tier -> immutable runs
        print(f"tiered state: {store.n_runs} runs, "
              f"{len(store.hot._segments)} hot segments "
              f"({store.metrics.summary()})")

    queries = ["vibration conductor wind", "school education student",
               "government law state", "stock money business"] * 4

    # 1. host engine
    with warren:
        stats = collection_stats(warren)
        t0 = time.time()
        host = [score_bm25(warren, q, k=10, stats=stats) for q in queries]
        t_host = time.time() - t0

    # 2. batched device serving (dynamic micro-batching server); over a
    # ShardedWarren this is the NATIVE scatter-gather path: one fan-out per
    # group per micro-batch, per-group device top-k, global k-way merge
    server = RetrievalServer(warren, k=10)
    t0 = time.time()
    handles = [server.batcher.submit(q) for q in queries]
    dev = [h.get(timeout=30) for h in handles]
    t_dev = time.time() - t0
    if args.shards > 1 or args.replicas > 1:
        print(f"sharded serving ({'async' if args.async_scatter else 'seq'} "
              f"scatter): {server.timing_summary()}")
    server.close()

    # 3. block-max kernel on one query
    with warren:
        terms = queries[0].split()
        bidx = build_block_impacts(warren, terms, block_size=128, stats=stats)
    t_max = max(len(t["di"]) for t in bidx.term_blocks)
    impacts = np.zeros((len(bidx.term_blocks), bidx.n_blocks, 128), np.float32)
    for ti, t in enumerate(bidx.term_blocks):
        impacts[ti, t["di"] // 128, t["di"] % 128] = t["imp"]
    bmax = impacts.max(axis=2)
    t0 = time.time()
    scores, ids = bm25_blockmax_topk(jnp.asarray(impacts), jnp.asarray(bmax),
                                     k=10)
    t_kernel = time.time() - t0

    # agreement
    host_top = {d for d, _ in host[0]}
    dev_top = {d for d, _ in dev[0]}
    kern_top = {int(bidx.doc_starts[i]) for i, s in
                zip(np.asarray(ids), np.asarray(scores)) if s > 0}
    print(f"top-10 agreement host/device: "
          f"{len(host_top & dev_top)}/10, host/kernel: "
          f"{len(host_top & kern_top)}/10")
    # replica failover: kill one replica of every group, answers unchanged
    if args.replicas > 1:
        with warren:
            before = warren.search(queries[0], k=10)
        for g in range(warren.n_shards):
            warren.mark_failed(g, g % args.replicas)
        with warren:
            after = warren.search(queries[0], k=10)
        same = [round(s, 9) for _, s in before] == \
               [round(s, 9) for _, s in after]
        print(f"failover (1 replica/group killed): scores identical={same}")
        for g in range(warren.n_shards):
            warren.resurrect(g, g % args.replicas)
    # cold-shard demotion: freeze every group to on-disk runs, answers
    # unchanged; the next write transparently promotes its group
    if args.demote_cold and args.shards > 1:
        with warren:
            before = warren.search(queries[0], k=10)
        for g in range(warren.n_shards):
            warren.demote_group(g)
        with warren:
            after = warren.search(queries[0], k=10)
        same = [round(s, 9) for _, s in before] == \
               [round(s, 9) for _, s in after]
        print(f"cold demotion ({warren.n_shards} groups -> static runs): "
              f"scores identical={same}")
        from repro.core import index_document as _idx
        with warren:
            warren.transaction()
            _idx(warren, "fresh hot document wind conductor", docid="dX")
            warren.commit()
        n_cold = sum(1 for d in warren.demoted() if d is not None)
        print(f"write-through promotion: {warren.n_shards - n_cold} group(s) "
              f"hot again, {n_cold} still cold")

    print(f"host engine      : {1e3 * t_host / len(queries):7.2f} ms/query")
    print(f"batched device   : {1e3 * t_dev / len(queries):7.2f} ms/query "
          f"(includes jit)")
    print(f"block-max kernel : {1e3 * t_kernel:7.2f} ms (interpret mode, "
          f"1 query)")
    if admin is not None:
        admin.close()
    if args.tiered:
        store.close()
    if args.shards > 1 or args.replicas > 1:
        warren.close()               # shuts the scatter pool, if any
    if tmpdir is not None:
        tmpdir.cleanup()
    if args.metrics_dump:
        from repro import obs
        from repro.obs import JsonlSink
        reg = obs.registry()
        JsonlSink(args.metrics_dump).write(reg)
        with open(args.metrics_dump + ".prom", "w") as fh:
            fh.write(reg.to_prometheus())
        print(f"metrics dumped to {args.metrics_dump} (+ .prom)")
    if args.trace_slow is not None:
        tr = obs.tracer()
        print(f"slow traces (> {args.trace_slow:g} ms): "
              f"{tr.n_slow_dumped} dumped to {slow_path}")


if __name__ == "__main__":
    main()

"""Knowledge graph over the annotative index (paper §2.5 + Conclusion).

Entities are JSON objects; subject-predicate-object triples are annotations;
the same index serves BM25 text retrieval AND graph traversal — the paper's
lifelogging/RAG vision: "ranked retrieval and structured queries to a
knowledge graph linked with the experiences".

    PYTHONPATH=src python examples/knowledge_graph.py
"""

from repro.core import DynamicIndex, GraphStore, Warren, score_bm25
from repro.core.json_store import value_of
from repro.core.query import solve
from repro.core.ranking import index_document


def main():
    w = Warren(DynamicIndex())
    g = GraphStore(w)

    # -- entities + triples ------------------------------------------- #
    with w:
        w.transaction()
        ent = {}
        for name, kind in [("Meryl Streep", "person"),
                           ("Best Actress", "award"),
                           ("The Iron Lady", "movie"),
                           ("Margaret Thatcher", "person"),
                           ("Kramer vs Kramer", "movie")]:
            ent[name] = g.add_node({"name": name, "kind": kind})
        remap = w.commit()
    ent = {k: (remap(a), remap(b)) for k, (a, b) in ent.items()}

    with w:
        w.transaction()
        g.add_triple(ent["Meryl Streep"][0], "won_award",
                     ent["Best Actress"][0])
        g.add_triple(ent["Meryl Streep"][0], "starred_in",
                     ent["The Iron Lady"][0])
        g.add_triple(ent["Meryl Streep"][0], "starred_in",
                     ent["Kramer vs Kramer"][0])
        g.add_triple(ent["The Iron Lady"][0], "depicts",
                     ent["Margaret Thatcher"][0])
        w.commit()

    # -- free text linked to the same address space -------------------- #
    with w:
        w.transaction()
        lo, hi = index_document(
            w, "watched a film about a british prime minister on the plane "
               "last weekend, outstanding lead performance", docid="diary1")
        remap2 = w.commit()
    lo = remap2(lo)
    with w:
        w.transaction()
        # link the diary entry to the movie entity (annotate-later!)
        g.add_edge("@mentions", lo, ent["The Iron Lady"][0])
        w.commit()

    # -- queries --------------------------------------------------------- #
    with w:
        print("movies starring Meryl Streep:")
        for addr in g.objects_of(ent["Meryl Streep"], "starred_in"):
            obj = g.containing_object(addr)
            t = solve("[:name:]", w)
            name = value_of(w, *[s[:2] for s in t
                                 if obj[0] <= s[0] <= obj[1]][0])
            print("  -", name)

        print("who does The Iron Lady depict?")
        for addr in g.objects_of(ent["The Iron Lady"], "depicts"):
            obj = g.containing_object(addr)
            names = [s for s in solve("[:name:]", w)
                     if obj[0] <= s[0] <= obj[1]]
            print("  -", value_of(w, *names[0][:2]))

        print("RAG hop: text search → mentioned entity → graph:")
        top = score_bm25(w, "film prime minister plane weekend", k=1)
        d_lo = top[0][0]
        doc = g.containing_object(d_lo) or (d_lo, d_lo)
        for dst in g.neighbors("@mentions", d_lo, d_lo + 50):
            movie = g.containing_object(dst)
            names = [s for s in solve("[:name:]", w)
                     if movie[0] <= s[0] <= movie[1]]
            movie_name = value_of(w, *names[0][:2])
            print(f"  diary entry mentions {movie_name!r}; its stars:")
            # reverse edge: who starred_in this movie
            rel = w.annotations("@rel:starred_in")
            for p, q, v in rel:
                if int(v) == movie[0]:
                    person = g.containing_object(int(p))
                    pn = [s for s in solve("[:name:]", w)
                          if person[0] <= s[0] <= person[1]]
                    print("   -", value_of(w, *pn[0][:2]))


if __name__ == "__main__":
    main()

"""Admin-plane demo and smoke: scrape a live warren's introspection API.

Builds a small ShardedWarren with an autopilot controller and an SLO
monitor, serves some traffic (so traces, latency histograms, and burn
gauges exist), demotes one group (so ``/tiered/runs`` has something to
say), then starts the :class:`repro.obs.AdminServer` and scrapes EVERY
endpoint, validating each response:

  * ``/metrics`` parses as Prometheus text 0.0.4 (cumulative histogram
    buckets, terminal ``+Inf`` equal to ``_count``);
  * ``/profile/cpu`` returns non-empty collapsed stacks;
  * ``/routing``, ``/traces``, ``/autopilot/decisions``, ``/slo``,
    ``/tiered/runs``, ``/healthz``, ``/readyz``, ``/metrics.json`` all
    answer 200 with well-formed JSON.

Exits non-zero on any failed check — this is the CI ``admin-smoke`` job.

Run:  PYTHONPATH=src python examples/admin_demo.py
"""

import json
import math
import sys
import tempfile
import threading
import urllib.request

from repro import obs
from repro.core import ingest_documents
from repro.data.synth import doc_generator
from repro.dist.autopilot import (AutopilotConfig, ColdPolicy, Controller,
                                  HotSplitPolicy, Hysteresis)
from repro.dist.shard_router import ShardedWarren
from repro.dist.simharness import SimClock

QUERIES = ["school education student", "government law state",
           "stock money business", "vibration conductor wind"]

failures = []


def check(name: str, ok: bool, detail: str = "") -> None:
    print(f"  {'PASS' if ok else 'FAIL'}  {name}" +
          (f" ({detail})" if detail else ""))
    if not ok:
        failures.append(name)


def get(url: str):
    with urllib.request.urlopen(url, timeout=30) as r:
        return r.status, r.read().decode()


def check_prometheus(text: str) -> None:
    """Format-0.0.4 conformance over the live scrape."""
    histograms = set()
    for line in text.strip().split("\n"):
        if line.startswith("# TYPE") and line.endswith("histogram"):
            histograms.add(line.split()[2])
    check("metrics: at least one histogram family", bool(histograms))
    # per histogram series: cumulative buckets end at +Inf == _count
    counts, infs = {}, {}
    for line in text.strip().split("\n"):
        if line.startswith("#"):
            continue
        metric, value = line.rsplit(" ", 1)
        name = metric.split("{")[0]
        base = name[:-7] if name.endswith("_bucket") else \
            name[:-6] if name.endswith("_count") else None
        if base not in histograms:
            continue
        series = metric.split("{")[1].rstrip("}") if "{" in metric else ""
        labels = tuple(sorted(p.rstrip('"') for p in series.split('",')
                              if p and not p.startswith('le="')))
        if name.endswith("_bucket") and 'le="+Inf"' in series:
            infs[(base, labels)] = float(value)
        elif name.endswith("_count"):
            counts[(base, labels)] = float(value)
    check("metrics: every histogram series has a +Inf bucket",
          set(counts) == set(infs),
          f"{len(counts)} series")
    check("metrics: +Inf bucket == _count everywhere",
          all(infs[k] == counts[k] for k in counts))


def main() -> int:
    obs.enable()
    static_root = tempfile.mkdtemp(prefix="admin-demo-")
    warren = ShardedWarren(n_shards=2, replicas=2, static_dir=static_root)
    ingest_documents(warren, doc_generator(7, 150, mean_len=30), batch=8)

    clock = SimClock()
    monitor = obs.SLOMonitor(clock=clock)
    ctl = Controller.for_warren(warren, clock=clock, slo_monitor=monitor,
                                config=AutopilotConfig(
        split=HotSplitPolicy(p95_hot_ms=0.0, sustain_ticks=2, min_docs=1,
                             max_groups=3),
        cold=ColdPolicy(demote_after_ticks=10 ** 6,
                        merge_after_ticks=10 ** 6),
        hysteresis=Hysteresis(cooldown_ticks=1, min_dwell_ticks=0),
        pool=None))

    # traffic -> traces + latency histograms + a split decision
    for _ in range(3):
        with warren:
            for q in QUERIES:
                warren.search(q, k=10)
        ctl.tick()
        clock.advance()
    warren.demote_group(0)                  # /tiered/runs has content

    # background load so /profile/cpu has stacks to sample
    stop = threading.Event()

    def load():
        while not stop.is_set():
            with warren:
                warren.search(QUERIES[0], k=10)

    loader = threading.Thread(target=load, name="load", daemon=True)
    loader.start()

    with obs.AdminServer(warren=warren, controller=ctl,
                         slo=monitor) as srv:
        print(f"admin endpoint: {srv.url()}")

        code, body = get(srv.url("/healthz"))
        check("/healthz", code == 200 and json.loads(body)["ok"] is True)

        code, body = get(srv.url("/readyz"))
        doc = json.loads(body)
        check("/readyz", code == 200 and doc["ready"] is True,
              f"epoch {doc.get('epoch')}")

        code, text = get(srv.url("/metrics"))
        check("/metrics answers", code == 200 and len(text) > 0)
        check_prometheus(text)
        check("/metrics: slo_burn_rate exported",
              "slo_burn_rate" in text)
        # ProfiledLock registers its series at construction, so the
        # group write locks show up even before any contention
        check("/metrics: lock contention family present",
              "lock_wait_ms" in text)

        code, body = get(srv.url("/metrics.json"))
        doc = json.loads(body)
        check("/metrics.json",
              code == 200 and "scatter_latency_ms" in doc["metrics"])

        code, body = get(srv.url("/routing"))
        doc = json.loads(body)
        check("/routing", code == 200 and doc["n_groups"] == warren.n_shards
              and all(g["ranges"] for g in doc["groups"].values()),
              f"{doc['n_groups']} groups, epoch {doc['epoch']}")

        code, body = get(srv.url("/traces"))
        traces = json.loads(body)["traces"]
        check("/traces", code == 200 and len(traces) > 0,
              f"{len(traces)} in ring")
        tid = traces[-1]["trace_id"]
        code, body = get(srv.url(f"/traces/{tid}"))
        check("/traces/<id>",
              code == 200 and json.loads(body)["tree"]["name"])

        code, body = get(srv.url("/autopilot/decisions?n=10"))
        doc = json.loads(body)
        check("/autopilot/decisions",
              code == 200 and doc["tick"] >= 3,
              f"{len(doc['decisions'])} decisions")

        code, body = get(srv.url("/tiered/runs"))
        doc = json.loads(body)
        check("/tiered/runs",
              code == 200 and doc["demoted_groups"],
              f"demoted: {sorted(doc['demoted_groups'])}")

        code, body = get(srv.url("/slo"))
        doc = json.loads(body)
        names = [s["name"] for s in doc["slos"]]
        check("/slo", code == 200 and "serving_p95" in names,
              f"slos: {names}")

        code, text = get(srv.url("/profile/cpu?seconds=0.5"))
        lines = [ln for ln in text.strip().split("\n") if ln]
        ok_fmt = bool(lines) and all(
            ln.rsplit(" ", 1)[1].isdigit() for ln in lines)
        check("/profile/cpu returns non-empty collapsed stacks",
              code == 200 and ok_fmt, f"{len(lines)} stacks")

    stop.set()
    loader.join(timeout=10.0)
    warren.close()

    if failures:
        print(f"\n{len(failures)} admin-smoke check(s) FAILED: {failures}")
        return 1
    print("\nall admin-smoke checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Concurrent ingestion pipeline + live retrieval (paper §5 / Fig. 7 shape).

Multiple appender threads ingest documents while annotation stages (dedup,
segmentation) run behind them in separate transactions, query threads serve
BM25+PRF continuously against consistent snapshots, and a deletion thread
erases old documents.  Everything happens on one fully dynamic index with
ACID transactions.

    PYTHONPATH=src python examples/rag_pipeline.py [--docs 400]
"""

import argparse
import threading
import time

from repro.core import (DynamicIndex, Warren, collection_stats, expand_query,
                        index_document, score_bm25)
from repro.data.pipeline import mark_duplicates, segment
from repro.data.synth import doc_generator


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=400)
    ap.add_argument("--writers", type=int, default=4)
    ap.add_argument("--readers", type=int, default=8)
    args = ap.parse_args()

    warren = Warren(DynamicIndex())
    docs = list(doc_generator(0, args.docs))
    per = len(docs) // args.writers
    stop = threading.Event()
    qps = [0]
    lock = threading.Lock()

    def appender(tid):
        wc = warren.clone()
        for docid, text in docs[tid * per:(tid + 1) * per]:
            with wc:
                wc.transaction()
                index_document(wc, text, docid=docid)
                wc.commit()

    def reader(tid):
        wc = warren.clone()
        queries = ["vibration conductor wind", "school education student",
                   "government law state", "stock money business"]
        while not stop.is_set():
            with wc:
                stats = collection_stats(wc)
                if stats.n_docs < 5:
                    continue
                q = queries[tid % len(queries)]
                weights = expand_query(wc, q, fb_docs=5, fb_terms=8,
                                       stats=stats)
                top = score_bm25(wc, "", k=10, weights=weights, stats=stats)
            with lock:
                qps[0] += 1

    def deleter():
        wc = warren.clone()
        while not stop.is_set():
            time.sleep(0.3)
            with wc:
                roots = wc.annotations(":")
                if len(roots) > args.docs // 2:
                    wc.transaction()
                    wc.erase(int(roots.starts[0]), int(roots.ends[0]))
                    wc.commit()

    t0 = time.time()
    writers = [threading.Thread(target=appender, args=(t,))
               for t in range(args.writers)]
    readers = [threading.Thread(target=reader, args=(t,))
               for t in range(args.readers)]
    del_t = threading.Thread(target=deleter)
    for t in writers + readers + [del_t]:
        t.start()
    for t in writers:
        t.join()
    ingest_s = time.time() - t0

    # annotation stages run AFTER ingestion in their own transactions —
    # the annotative-index superpower: index first, annotate later.
    n_dup = mark_duplicates(warren)
    n_seg = segment(warren, window=64, stride=32)
    stop.set()
    for t in readers + [del_t]:
        t.join()

    warren.index.merge_segments()
    with warren:
        n_docs = len(warren.annotations(":"))
        n_segs = len(warren.annotations("seg:"))
        top = score_bm25(warren, "aeolian vibration conductor", k=5)
        print(f"ingested {args.docs} docs in {ingest_s:.2f}s "
              f"({args.writers} writers), {n_dup} dups, {n_seg} segments")
        print(f"index now: {n_docs} docs, {n_segs} seg: annotations, "
              f"{qps[0]} BM25+PRF queries served concurrently")
        print(f"sample query top-5 scores: {[round(s, 2) for _, s in top]}")


if __name__ == "__main__":
    main()

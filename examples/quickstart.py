"""Quickstart: a JSON store on the annotative index (paper Fig. 4-6).

Builds a heterogeneous JSON collection, then runs the paper's Example
queries — containment algebra + aggregation — against the dynamic index.

    PYTHONPATH=src python examples/quickstart.py

With ``--tiered`` the collection is built through the LSM-style tiered
engine instead: the hot memtable is frozen into immutable on-disk runs
(build → demote → query), and every Example query is answered identically
from the merged hot+cold view.

    PYTHONPATH=src python examples/quickstart.py --tiered
"""

import argparse
import tempfile
import time

from repro.core import (DynamicIndex, Warren, add_json, annotate_dates,
                        value_of)
from repro.core.gcl import BothOf, ContainedIn, Containing, OneOf
from repro.data.synth import json_collection


def run_queries(w, quiet: bool = False):
    """The paper's Example queries; returns results for parity checks."""
    out = {}

    def show(line):
        if not quiet:
            print(line)

    with w:
        # Example 1: statistics over restaurant ratings
        ratings = [v for _, _, v in ContainedIn(
            w.hopper(":rating:"),
            w.hopper("Files/restaurant.json")).solutions()]
        out["ex1"] = (min(ratings), sum(ratings) / len(ratings), max(ratings))
        show(f"Example 1  SELECT MIN,AVG,MAX(rating) FROM restaurant -> "
             f"{out['ex1'][0]:.1f} / {out['ex1'][1]:.2f} / {out['ex1'][2]:.1f}")

        # Example 2: how many zips in New York?
        q = ContainedIn(Containing(w.hopper(":city:"), w.phrase("new york")),
                        w.hopper("Files/zips.json"))
        out["ex2"] = len(q.solutions())
        show(f"Example 2  COUNT(*) FROM zips WHERE city='NEW YORK' -> "
             f"{out['ex2']}")

        # Example 3: names of nanotech companies
        q = ContainedIn(
            w.hopper(":name:"),
            Containing(w.hopper("Files/companies.json"),
                       ContainedIn(Containing(w.hopper(":category_code:"),
                                              w.phrase("nanotech")),
                                   w.hopper("Files/companies.json"))))
        names = [value_of(w, int(p), int(qq)) for p, qq, _ in q.solutions()]
        out["ex3"] = names
        show(f"Example 3  companies WHERE category CONTAINS 'nanotech' -> "
             f"{len(names)} (e.g. {names[:3]})")

        # Example 4: titles OR authors from books
        q = ContainedIn(OneOf(w.hopper(":title:"), w.hopper(":authors:")),
                        w.hopper("Files/books.json"))
        out["ex4"] = len(q.solutions())
        show(f"Example 4  title, EXPLODE(authors) FROM books -> "
             f"{out['ex4']} fields")

        # Example 7: how many objects in the whole database?
        out["ex7"] = len(w.annotations(":"))
        show(f"Example 7  COUNT(*) FROM * -> {out['ex7']}")

        # Example 9: objects created in a specific year+month (any schema)
        q = Containing(w.hopper(":"),
                       BothOf(w.hopper("year=2008"), w.hopper("month=06")))
        out["ex9"] = len(q.solutions())
        show(f"Example 9  COUNT(*) FROM * WHERE created ~ 2008-06 -> "
             f"{out['ex9']}")
    return out


def build(w, data):
    t0 = time.time()
    with w:
        w.transaction()
        for name, objs in data.items():
            for obj in objs:
                add_json(w, obj, collection=f"Files/{name}.json")
        w.commit()
    n = sum(len(v) for v in data.values())
    print(f"indexed {n} JSON objects from {len(data)} subcollections "
          f"in {time.time() - t0:.2f}s\n")

    # post-hoc date unification (paper Examples 8/9): annotate, don't rewrite
    with w:
        w.transaction()
        n_dates = annotate_dates(w, [":created:", ":created_at:$date:",
                                     ":date:"])
        w.commit()
    print(f"annotated {n_dates} heterogeneous date fields\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiered", action="store_true",
                    help="build through the tiered engine and demote the "
                         "hot tier to on-disk runs before querying")
    args = ap.parse_args()
    data = json_collection(seed=0, scale=1.0)

    if not args.tiered:
        w = Warren(DynamicIndex())
        build(w, data)
        run_queries(w)
        return

    from repro.tiered import TieredStore
    with tempfile.TemporaryDirectory() as td:
        store = TieredStore(td + "/tiered")
        w = store.warren()
        build(w, data)
        hot_results = run_queries(w, quiet=True)     # served from memtable
        info = store.freeze()                        # demote: hot -> run
        print(f"froze hot tier -> {info.name} "
              f"({info.n_records} records, {info.n_features} features); "
              f"hot segments now: {len(store.hot._segments)}\n")
        cold_results = run_queries(w)                # served from the run
        assert cold_results == hot_results, "tier demotion changed answers"
        print(f"\nhot/cold parity: all {len(cold_results)} Example queries "
              f"identical before and after demotion")
        store.close()


if __name__ == "__main__":
    main()

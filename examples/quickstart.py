"""Quickstart: a JSON store on the annotative index (paper Fig. 4-6).

Builds a heterogeneous JSON collection, then runs the paper's Example
queries — containment algebra + aggregation — against the dynamic index.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

from repro.core import (DynamicIndex, Warren, add_json, annotate_dates,
                        value_of)
from repro.core.gcl import BothOf, ContainedIn, Containing, OneOf
from repro.data.synth import json_collection


def main():
    w = Warren(DynamicIndex())
    data = json_collection(seed=0, scale=1.0)

    t0 = time.time()
    with w:
        w.transaction()
        for name, objs in data.items():
            for obj in objs:
                add_json(w, obj, collection=f"Files/{name}.json")
        w.commit()
    n = sum(len(v) for v in data.values())
    print(f"indexed {n} JSON objects from {len(data)} subcollections "
          f"in {time.time() - t0:.2f}s\n")

    # post-hoc date unification (paper Examples 8/9): annotate, don't rewrite
    with w:
        w.transaction()
        n_dates = annotate_dates(w, [":created:", ":created_at:$date:",
                                     ":date:"])
        w.commit()
    print(f"annotated {n_dates} heterogeneous date fields\n")

    with w:
        # Example 1: statistics over restaurant ratings
        ratings = [v for _, _, v in ContainedIn(
            w.hopper(":rating:"),
            w.hopper("Files/restaurant.json")).solutions()]
        print(f"Example 1  SELECT MIN,AVG,MAX(rating) FROM restaurant -> "
              f"{min(ratings):.1f} / {sum(ratings)/len(ratings):.2f} / "
              f"{max(ratings):.1f}")

        # Example 2: how many zips in New York?
        q = ContainedIn(Containing(w.hopper(":city:"), w.phrase("new york")),
                        w.hopper("Files/zips.json"))
        print(f"Example 2  COUNT(*) FROM zips WHERE city='NEW YORK' -> "
              f"{len(q.solutions())}")

        # Example 3: names of nanotech companies
        q = ContainedIn(
            w.hopper(":name:"),
            Containing(w.hopper("Files/companies.json"),
                       ContainedIn(Containing(w.hopper(":category_code:"),
                                              w.phrase("nanotech")),
                                   w.hopper("Files/companies.json"))))
        names = [value_of(w, int(p), int(qq)) for p, qq, _ in q.solutions()]
        print(f"Example 3  companies WHERE category CONTAINS 'nanotech' -> "
              f"{len(names)} (e.g. {names[:3]})")

        # Example 4: titles OR authors from books
        q = ContainedIn(OneOf(w.hopper(":title:"), w.hopper(":authors:")),
                        w.hopper("Files/books.json"))
        print(f"Example 4  title, EXPLODE(authors) FROM books -> "
              f"{len(q.solutions())} fields")

        # Example 7: how many objects in the whole database?
        print(f"Example 7  COUNT(*) FROM * -> {len(w.annotations(':'))}")

        # Example 9: objects created in a specific year+month (any schema)
        q = Containing(w.hopper(":"),
                       BothOf(w.hopper("year=2008"), w.hopper("month=06")))
        print(f"Example 9  COUNT(*) FROM * WHERE created ~ 2008-06 -> "
              f"{len(q.solutions())}")


if __name__ == "__main__":
    main()

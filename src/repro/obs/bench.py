"""Schema-versioned benchmark emission — the persisted perf trajectory.

``emit()`` freezes the current registry snapshot into a ``BENCH_*.json``
file stamped with ``schema = "repro.bench/v1"`` and a *kind* (serving /
build / kernels / autopilot).  Committing those files turns git history into the
repo's performance trajectory: any PR that moves p95 scatter latency or
kernel roofline fraction shows up as a diff on a tracked file rather
than a silent regression.

``validate()`` checks a file against the schema — kind-specific required
metrics included — and returns a list of problems (empty = valid).  The
CLI form (``python -m repro.obs.bench validate PATH``) is what the CI
``obs-smoke`` job gates on.
"""

from __future__ import annotations

import json
import sys
import time
from typing import Dict, List, Optional, Tuple

from .registry import MetricsRegistry, registry, sanitize

SCHEMA = "repro.bench/v1"
KINDS = ("serving", "build", "kernels", "autopilot")

# Per-kind required metric families; histograms must carry percentiles.
REQUIRED: Dict[str, Tuple[str, ...]] = {
    "serving": ("serve_scatter_latency_ms", "serve_score_latency_ms",
                "serve_merge_latency_ms"),
    "build": ("build_docs_per_s",),
    "kernels": ("kernel_achieved_gflops", "kernel_phase_ms"),
    "autopilot": ("autopilot_actions_total", "autopilot_tick_ms",
                  "slo_burn_rate"),
}
_HIST_KEYS = ("count", "p50", "p95", "p99")


def emit(path: str, kind: str, extra: Optional[dict] = None,
         reg: Optional[MetricsRegistry] = None) -> dict:
    """Write a schema-versioned bench file from a registry snapshot."""
    if kind not in KINDS:
        raise ValueError(f"kind must be one of {KINDS}, got {kind!r}")
    reg = reg if reg is not None else registry()
    doc = {"schema": SCHEMA, "kind": kind, "created": time.time(),
           "metrics": reg.snapshot()}
    if extra:
        doc.update(extra)
    doc = sanitize(doc)
    problems = validate_doc(doc)
    if problems:
        raise ValueError("refusing to emit invalid bench file: "
                         + "; ".join(problems))
    with open(path, "w") as fh:
        json.dump(doc, fh, sort_keys=True, indent=2, allow_nan=False)
        fh.write("\n")
    return doc


def validate_doc(doc: object) -> List[str]:
    """Schema problems in an in-memory bench document (empty = valid)."""
    problems: List[str] = []
    if not isinstance(doc, dict):
        return ["top level is not a JSON object"]
    if doc.get("schema") != SCHEMA:
        problems.append(f"schema is {doc.get('schema')!r}, want {SCHEMA!r}")
    kind = doc.get("kind")
    if kind not in KINDS:
        problems.append(f"kind is {kind!r}, want one of {KINDS}")
    if not isinstance(doc.get("created"), (int, float)):
        problems.append("created timestamp missing or non-numeric")
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict):
        problems.append("metrics missing or not an object")
        return problems
    for name in REQUIRED.get(kind, ()):
        fam = metrics.get(name)
        if not isinstance(fam, dict) or not fam.get("series"):
            problems.append(f"required metric {name!r} missing or empty")
            continue
        if fam.get("type") == "histogram":
            for s in fam["series"]:
                for key in _HIST_KEYS:
                    if key not in s:
                        problems.append(
                            f"{name} series {s.get('labels')} lacks {key!r}")
                if s.get("count", 0) <= 0:
                    problems.append(
                        f"{name} series {s.get('labels')} has no samples")
    return problems


def validate(path: str) -> List[str]:
    """Schema problems in a bench file on disk (empty = valid)."""
    try:
        with open(path) as fh:
            doc = json.load(fh)
    except (OSError, ValueError) as e:
        return [f"unreadable: {e}"]
    return validate_doc(doc)


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if len(argv) != 2 or argv[0] != "validate":
        print("usage: python -m repro.obs.bench validate PATH",
              file=sys.stderr)
        return 2
    problems = validate(argv[1])
    if problems:
        for p in problems:
            print(f"INVALID: {p}", file=sys.stderr)
        return 1
    print(f"{argv[1]}: valid {SCHEMA}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Request tracing: contextvar-propagated spans with trace trees.

A :class:`Span` measures one named stage of one request; nesting follows
the *execution* context, not the thread: the active span lives in a
``contextvars.ContextVar``, and :class:`~repro.dist.parallel.ScatterGather`
captures the submitting context per fan-out item, so a span opened inside
a pool worker parents correctly under the span that was active where the
work was *submitted*.  One search through the native sharded server
therefore yields one tree::

    serve.batch
    ├── scatter{group=0}
    │   └── replica_read{group=0, replica=0}
    ├── scatter{group=1}
    │   └── replica_read{group=1, replica=1}
    ├── device_score
    └── merge

Completed traces (a root span plus all its descendants) land in a ring
buffer (:meth:`Tracer.traces`); traces slower than ``slow_ms`` are also
appended as JSON lines to the slow-trace sink — the "what was that p99
spike" artifact.  Span bodies run under ``with``, so an exception closes
the span (flagged ``error``) and still propagates.

Disabled mode returns a shared no-op context manager: one attribute check
and no allocation per ``span()`` call.
"""

from __future__ import annotations

import contextvars
import itertools
import json
import threading
import time
from collections import deque
from typing import Dict, List, Optional

from .rotate import RotatingJsonl

_CURRENT: "contextvars.ContextVar[Optional[Span]]" = contextvars.ContextVar(
    "repro_obs_span", default=None)

_ids = itertools.count(1)
_ids_lock = threading.Lock()


def _next_id() -> int:
    with _ids_lock:
        return next(_ids)


class Span:
    """One timed, labeled stage of a trace."""

    __slots__ = ("name", "labels", "trace_id", "span_id", "parent_id",
                 "start_ts", "_t0", "duration_s", "error", "_trace")

    def __init__(self, name: str, labels: Dict[str, object],
                 trace: "_Trace", parent: Optional["Span"]):
        self.name = name
        self.labels = labels
        self.trace_id = trace.trace_id
        self.span_id = _next_id()
        self.parent_id = parent.span_id if parent is not None else None
        self.start_ts = time.time()
        self._t0 = time.perf_counter()
        self.duration_s: Optional[float] = None
        self.error = False
        self._trace = trace

    @property
    def duration_ms(self) -> Optional[float]:
        return None if self.duration_s is None else 1e3 * self.duration_s

    def to_record(self) -> dict:
        return {"name": self.name, "labels": dict(self.labels),
                "trace_id": self.trace_id, "span_id": self.span_id,
                "parent_id": self.parent_id, "start_ts": self.start_ts,
                "duration_ms": self.duration_ms, "error": self.error}


class _Trace:
    """All spans of one request, collected across threads."""

    __slots__ = ("trace_id", "root", "_lock", "spans")

    def __init__(self):
        self.trace_id = _next_id()
        self.root: Optional[Span] = None
        self._lock = threading.Lock()
        self.spans: List[Span] = []

    def add(self, span: Span) -> None:
        with self._lock:
            if self.root is None:
                self.root = span
            self.spans.append(span)

    def tree(self) -> dict:
        """Nested dict form: {name, labels, duration_ms, children}."""
        with self._lock:
            spans = list(self.spans)
        children: Dict[Optional[int], List[Span]] = {}
        for s in spans:
            children.setdefault(s.parent_id, []).append(s)

        def node(s: Span) -> dict:
            kids = sorted(children.get(s.span_id, ()),
                          key=lambda c: c.start_ts)
            return {"name": s.name, "labels": dict(s.labels),
                    "duration_ms": s.duration_ms, "error": s.error,
                    "children": [node(c) for c in kids]}

        return node(self.root) if self.root is not None else {}

    def names(self) -> List[str]:
        with self._lock:
            return [s.name for s in self.spans]

    @property
    def duration_ms(self) -> Optional[float]:
        return self.root.duration_ms if self.root is not None else None

    def to_record(self) -> dict:
        with self._lock:
            spans = list(self.spans)
        return {"trace_id": self.trace_id,
                "root": self.root.name if self.root else None,
                "duration_ms": self.duration_ms,
                "spans": [s.to_record() for s in spans]}


class _NullSpanCtx:
    """Shared no-op for disabled tracing."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc) -> bool:
        return False


_NULL = _NullSpanCtx()


class _SpanCtx:
    __slots__ = ("_tracer", "_name", "_labels", "_span", "_token")

    def __init__(self, tracer: "Tracer", name: str, labels: dict):
        self._tracer = tracer
        self._name = name
        self._labels = labels

    def __enter__(self) -> Span:
        parent = _CURRENT.get()
        trace = parent._trace if parent is not None else _Trace()
        self._span = Span(self._name, self._labels, trace, parent)
        trace.add(self._span)
        self._token = _CURRENT.set(self._span)
        return self._span

    def __exit__(self, exc_type, exc, tb) -> bool:
        span = self._span
        span.duration_s = time.perf_counter() - span._t0
        span.error = exc_type is not None
        if exc_type is not None:
            # label the span with the exception type so errored spans are
            # greppable in dumps and visible in /traces; the exception
            # still propagates (we never swallow it)
            span.labels.setdefault("error", exc_type.__name__)
        _CURRENT.reset(self._token)
        if span.parent_id is None:           # root closed: trace complete
            self._tracer._finish(span._trace)
        return False


class Tracer:
    """Ring-buffer retention of completed traces + slow-trace JSONL dump."""

    def __init__(self, capacity: int = 128, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._ring: "deque[_Trace]" = deque(maxlen=capacity)
        self._slow_ms: Optional[float] = None
        self._slow_sink: Optional[RotatingJsonl] = None
        self.n_slow_dumped = 0

    # -- span creation ----------------------------------------------------- #
    def span(self, name: str, **labels):
        """Open a span under the execution-context's active span (or start
        a new trace).  Use as ``with tracer.span("merge", group=g):``."""
        if not self.enabled:
            return _NULL
        return _SpanCtx(self, name, labels)

    def current(self) -> Optional[Span]:
        return _CURRENT.get()

    # -- retention --------------------------------------------------------- #
    def _finish(self, trace: _Trace) -> None:
        with self._lock:
            self._ring.append(trace)
            slow_ms, sink = self._slow_ms, self._slow_sink
        # errored traces are always dump-eligible: a request that died is
        # at least as interesting as one that was merely slow
        errored = trace.root is not None and trace.root.error
        if (slow_ms is not None
                and ((trace.duration_ms or 0.0) >= slow_ms or errored)):
            rec = json.dumps(trace.to_record(), sort_keys=True)
            with self._lock:
                self.n_slow_dumped += 1
            if sink is not None:
                sink.write_line(rec)

    def traces(self) -> List[_Trace]:
        """Completed traces, oldest first (up to ring capacity)."""
        with self._lock:
            return list(self._ring)

    def last_trace(self, root: Optional[str] = None) -> Optional[_Trace]:
        """Most recent completed trace, optionally matching a root name."""
        with self._lock:
            ring = list(self._ring)
        for t in reversed(ring):
            if root is None or (t.root is not None and t.root.name == root):
                return t
        return None

    def trace_by_id(self, trace_id: int) -> Optional[_Trace]:
        """Completed trace with the given id, if still in the ring."""
        with self._lock:
            ring = list(self._ring)
        for t in reversed(ring):
            if t.trace_id == trace_id:
                return t
        return None

    def reset(self) -> None:
        with self._lock:
            self._ring.clear()
            self.n_slow_dumped = 0

    # -- slow-trace dump ---------------------------------------------------- #
    def set_slow_dump(self, threshold_ms: Optional[float],
                      path: Optional[str] = None,
                      max_bytes: int = 4 << 20, backups: int = 2) -> None:
        """Dump every trace slower than ``threshold_ms`` — and every
        errored trace, regardless of duration — as one JSON line appended
        to ``path`` (None threshold disables; None path counts slow
        traces without writing).  The dump is size-capped: it rotates at
        ``max_bytes`` keeping ``backups`` old files, so a server that
        runs for days cannot fill the disk with its own telemetry."""
        with self._lock:
            self._slow_ms = threshold_ms
            self._slow_sink = (RotatingJsonl(path, max_bytes=max_bytes,
                                             backups=backups)
                               if path is not None else None)


# -- process-global tracer -------------------------------------------------- #
_GLOBAL = Tracer()


def tracer() -> Tracer:
    return _GLOBAL


def span(name: str, **labels):
    """``with repro.obs.span("scatter", group=3): ...`` on the global
    tracer."""
    return _GLOBAL.span(name, **labels)

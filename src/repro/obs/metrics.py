"""Thread-safe metric primitives: Counter, Gauge, log-bucketed Histogram.

Each metric is a single time series; the labeled *families* that group them
("``scatter_latency_ms{group=3}``") live in :mod:`repro.obs.registry`.
Design constraints, in order:

* **Thread safety.**  Every mutation takes the metric's own lock; the
  serving paths hammer these from the ScatterGather pool, the MicroBatcher
  thread, and background compactors at once.  ``snapshot()`` takes the same
  lock, so a snapshot is a consistent point-in-time view of one series.
* **Disabled-mode fast path.**  Every mutator first checks the owning
  registry's ``enabled`` flag and returns before touching the lock — a
  disabled ``inc()``/``observe()`` costs one attribute load and a branch
  (~100 ns), which is what lets instrumentation stay compiled into the hot
  paths permanently instead of being stripped per-deployment.
* **Bounded memory.**  A histogram is a fixed array of log-spaced buckets
  (default: 20 per decade over [1e-3, 1e5], i.e. 1 µs to 100 s for
  millisecond-valued series, ~12 % relative resolution) plus count/sum/
  min/max.  Percentiles are exact up to bucket resolution: ``p95`` returns
  the geometric midpoint of the bucket holding the 95th-percentile sample.
"""

from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional, Sequence, Tuple


class _Enabled:
    """Stand-in owner for metrics constructed outside a registry."""

    enabled = True


_ALWAYS = _Enabled()


class Counter:
    """Monotonic counter (no decrements)."""

    kind = "counter"

    def __init__(self, _owner=_ALWAYS):
        self._owner = _owner
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        if not self._owner.enabled:
            return
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value

    def snapshot(self) -> dict:
        return {"value": self.value}

    def reset(self) -> None:
        with self._lock:
            self._value = 0


class Gauge:
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def __init__(self, _owner=_ALWAYS):
        self._owner = _owner
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        if not self._owner.enabled:
            return
        with self._lock:
            self._value = float(v)

    def add(self, v: float) -> None:
        if not self._owner.enabled:
            return
        with self._lock:
            self._value += float(v)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self) -> dict:
        return {"value": self.value}

    def reset(self) -> None:
        with self._lock:
            self._value = 0.0


class Histogram:
    """Log-bucketed histogram with exact-to-resolution percentiles.

    Bucket ``i`` (1-based) covers ``(lo·10^((i-1)/d), lo·10^(i/d)]`` with
    ``d = per_decade``; bucket 0 is the underflow (v ≤ lo, including zeros
    and negatives) and the last bucket the overflow.  ``percentile(p)``
    walks the cumulative counts and returns the geometric midpoint of the
    bucket where the p-quantile sample lives — within one bucket width
    (~12 % at the default resolution) of the exact order statistic.
    """

    kind = "histogram"
    PERCENTILES = (0.5, 0.95, 0.99)

    def __init__(self, lo: float = 1e-3, hi: float = 1e5,
                 per_decade: int = 20, _owner=_ALWAYS):
        if lo <= 0 or hi <= lo:
            raise ValueError("histogram needs 0 < lo < hi")
        self._owner = _owner
        self._lock = threading.Lock()
        self._lo = lo
        self._log_lo = math.log10(lo)
        self._per_decade = per_decade
        n = int(math.ceil((math.log10(hi) - self._log_lo) * per_decade))
        self._n = n
        self._counts = [0] * (n + 2)     # [0]=underflow, [n+1]=overflow
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def _bucket(self, v: float) -> int:
        if v <= self._lo:
            return 0
        i = 1 + int((math.log10(v) - self._log_lo) * self._per_decade)
        return min(i, self._n + 1)

    def _bucket_mid(self, i: int) -> float:
        """Geometric midpoint of bucket i (its representative value)."""
        if i <= 0:
            return self._lo
        if i > self._n:
            return 10 ** (self._log_lo + self._n / self._per_decade)
        return 10 ** (self._log_lo + (i - 0.5) / self._per_decade)

    def observe(self, v: float) -> None:
        if not self._owner.enabled:
            return
        v = float(v)
        b = self._bucket(v)
        with self._lock:
            self._counts[b] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def percentile(self, p: float) -> float:
        """Value at quantile ``p`` ∈ [0, 1], exact to bucket resolution;
        NaN when the histogram is empty."""
        with self._lock:
            return self._percentile_locked(p)

    def _bucket_le(self, i: int) -> float:
        """Inclusive upper bound of bucket i (+inf for the overflow)."""
        if i <= 0:
            return self._lo
        if i > self._n:
            return math.inf
        return 10 ** (self._log_lo + i / self._per_decade)

    def cumulative_buckets(self) -> List[Tuple[str, int]]:
        """Sparse cumulative ``(le, count)`` pairs in Prometheus histogram
        form: ascending upper bounds as strings, count cumulative from the
        underflow bucket up, terminated by ``("+Inf", total)`` (which by
        construction equals ``_count``).  Only buckets that hold samples
        are listed — the exposition stays small however wide the range."""
        with self._lock:
            counts = list(self._counts)
            total = self._count
        out: List[Tuple[str, int]] = []
        cum = 0
        for i, c in enumerate(counts):
            cum += c
            if c and i <= self._n:
                out.append((format(self._bucket_le(i), ".6g"), cum))
        out.append(("+Inf", total))
        return out

    # -- windowed reads (delta between two bucket snapshots) ------------- #
    def bucket_counts(self) -> List[int]:
        """Point-in-time copy of the raw bucket counts.  Pair with
        :meth:`percentile_since` to read *windowed* percentiles out of a
        cumulative histogram: take the counts at window start, then ask
        for the percentile of everything observed since."""
        with self._lock:
            return list(self._counts)

    def percentile_since(self, prev_counts: Optional[Sequence[int]],
                         p: float) -> float:
        """Percentile over the observations added since ``prev_counts``
        was captured with :meth:`bucket_counts` (``None`` = since the
        beginning).  NaN when the window holds no samples.  Exact to
        bucket resolution, like :meth:`percentile`."""
        with self._lock:
            cur = list(self._counts)
        if prev_counts is None:
            prev_counts = [0] * len(cur)
        if len(prev_counts) != len(cur):
            raise ValueError("bucket snapshot from a different histogram")
        delta = [c - q for c, q in zip(cur, prev_counts)]
        total = sum(delta)
        if total <= 0:
            return math.nan
        target = p * total
        seen = 0
        for i, c in enumerate(delta):
            seen += c
            if seen >= target and c > 0:
                return self._bucket_mid(i)
        return self._bucket_mid(len(delta) - 1)

    def over_threshold_since(self, prev_counts: Optional[Sequence[int]],
                             threshold: float) -> Tuple[int, int]:
        """``(bad, total)`` observation counts since ``prev_counts`` was
        captured with :meth:`bucket_counts` (``None`` = since the
        beginning), where *bad* counts the observations above
        ``threshold`` — the windowed error fraction SLO burn rates are
        built from.  Exact to bucket resolution: a bucket counts as bad
        iff its geometric midpoint exceeds the threshold."""
        with self._lock:
            cur = list(self._counts)
        if prev_counts is None:
            prev_counts = [0] * len(cur)
        if len(prev_counts) != len(cur):
            raise ValueError("bucket snapshot from a different histogram")
        bad = total = 0
        for i, (c, q) in enumerate(zip(cur, prev_counts)):
            d = c - q
            if d <= 0:
                continue
            total += d
            if self._bucket_mid(i) > threshold:
                bad += d
        return bad, total

    def _percentile_locked(self, p: float) -> float:
        if self._count == 0:
            return math.nan
        # clamp the percentile's representative to the observed range so
        # tiny samples don't report a bucket midpoint outside [min, max]
        target = p * self._count
        seen = 0
        for i, c in enumerate(self._counts):
            seen += c
            if seen >= target and c:
                mid = self._bucket_mid(i)
                return min(max(mid, self._min), self._max)
        return self._max

    def snapshot(self) -> dict:
        with self._lock:
            out = {"count": self._count, "sum": self._sum,
                   "min": self._min if self._count else math.nan,
                   "max": self._max if self._count else math.nan}
            for p in self.PERCENTILES:
                out[f"p{int(p * 100)}"] = self._percentile_locked(p)
            return out

    def reset(self) -> None:
        with self._lock:
            self._counts = [0] * len(self._counts)
            self._count = 0
            self._sum = 0.0
            self._min = math.inf
            self._max = -math.inf

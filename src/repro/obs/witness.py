"""Runtime lock witness — lockdep for the warren's ProfiledLocks.

The static analyzer (:mod:`repro.analysis`) proves ordering over the
acquisition graph it can *see*; the witness covers what static analysis
cannot — aliasing, dynamic dispatch, config-dependent paths — by
recording the per-thread acquisition order actually observed while the
tier-1 / stress suites (``REPRO_LOCK_WITNESS=1``) or the
day-in-the-life bench run.

Checks, per acquisition, against everything the thread already holds:

* **hierarchy** — an acquisition violating the declared rank order of
  ``analysis/lock_hierarchy.toml``;
* **cycle** — an observed edge ``A→B`` when ``B→…→A`` was already
  observed (the classic AB/BA inversion, across any two threads' whole
  history — neither thread has to actually deadlock for the witness to
  catch it);
* **ascending order** — two instances of an ``ascending`` lock class
  (the group-write rule) taken with a non-increasing order key;
* **same-class nesting** — two *instances* of a single-instance lock
  class nested (rank order cannot disambiguate them).

Violations are recorded, not raised mid-acquire (raising inside a lock
acquisition would corrupt the caller's unwind); the harness calls
:meth:`LockWitness.check` at teardown and fails the run.

Overhead: when no witness is installed, each ProfiledLock operation
pays one module-attribute load + ``is None`` test.  When installed, the
fast path is a thread-local list walk (typically 0–2 held frames) and
one dict lookup for an already-seen edge; graph mutation takes a lock
only for *never-seen* edges, which dry up after warmup.
"""

from __future__ import annotations

import threading
from typing import Dict, List, Optional, Tuple


class LockOrderViolation(AssertionError):
    """Raised by :meth:`LockWitness.check` when any violation was seen."""


class LockWitness:
    def __init__(self, ranks: Optional[Dict[str, int]] = None,
                 multi: Optional[Dict[str, str]] = None):
        self._ranks = dict(ranks or {})
        self._multi = dict(multi or {})
        # (src, dst) -> first-observed provenance "thread:src->dst"
        self._edges: Dict[Tuple[str, str], str] = {}
        self._graph: Dict[str, List[str]] = {}
        self._violations: List[str] = []
        self._mu = threading.Lock()
        self._tls = threading.local()

    # -- configuration ----------------------------------------------------- #
    @classmethod
    def from_hierarchy(cls, path: str) -> "LockWitness":
        """Build from ``analysis/lock_hierarchy.toml`` (lazy import — obs
        stays importable without the analysis package)."""
        from repro.analysis.config import Hierarchy
        h = Hierarchy.load(path)
        return cls(ranks={n: l.rank for n, l in h.levels.items()},
                   multi={n: l.multi for n, l in h.levels.items()})

    # -- per-thread state --------------------------------------------------- #
    def _stack(self) -> List[Tuple[str, Optional[int], int]]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    # -- the hooks ---------------------------------------------------------- #
    def note_acquire(self, name: str, order_key: Optional[int],
                     inst: int) -> None:
        st = self._stack()
        if any(f[2] == inst for f in st):
            # same instance re-entered (RLock) — ordering already decided
            st.append((name, order_key, inst))
            return
        tname = threading.current_thread().name
        for held_name, held_key, _ in st:
            if held_name == name:
                mode = self._multi.get(name, "none")
                if mode == "ascending":
                    if (order_key is not None and held_key is not None
                            and order_key <= held_key):
                        self._record(
                            f"ascending-order: {name!r} key {order_key} "
                            f"acquired after key {held_key} in thread "
                            f"{tname} — the ascending rule requires "
                            f"strictly increasing order keys")
                elif mode == "none":
                    self._record(
                        f"same-class-nesting: two instances of "
                        f"single-instance lock {name!r} nested in thread "
                        f"{tname}")
                continue
            self._edge(held_name, name, tname)
        st.append((name, order_key, inst))

    def note_release(self, name: str, inst: int) -> None:
        st = getattr(self._tls, "stack", None)
        if not st:
            return
        for i in range(len(st) - 1, -1, -1):
            if st[i][2] == inst:
                del st[i]
                return

    # -- graph -------------------------------------------------------------- #
    def _edge(self, a: str, b: str, tname: str) -> None:
        if (a, b) in self._edges:        # fast path: known-good edge
            return
        with self._mu:
            if (a, b) in self._edges:
                return
            ra, rb = self._ranks.get(a), self._ranks.get(b)
            if ra is not None and rb is not None and ra > rb:
                self._record_locked(
                    f"hierarchy: {b!r} (rank {rb}) acquired while {a!r} "
                    f"(rank {ra}) held in thread {tname} — declared "
                    f"order inverted")
            if self._reachable(b, a):
                self._record_locked(
                    f"cycle: observed {a!r}→{b!r} closes a cycle with "
                    f"the already-observed {b!r}→…→{a!r} (thread "
                    f"{tname}) — AB/BA inversion")
            self._edges[(a, b)] = tname
            self._graph.setdefault(a, []).append(b)

    def _reachable(self, src: str, dst: str) -> bool:
        seen = {src}
        frontier = [src]
        while frontier:
            nxt = []
            for n in frontier:
                for m in self._graph.get(n, ()):
                    if m == dst:
                        return True
                    if m not in seen:
                        seen.add(m)
                        nxt.append(m)
            frontier = nxt
        return False

    # -- violations --------------------------------------------------------- #
    def _record(self, msg: str) -> None:
        with self._mu:
            self._record_locked(msg)

    def _record_locked(self, msg: str) -> None:
        if len(self._violations) < 100:
            self._violations.append(msg)

    def violations(self) -> List[str]:
        with self._mu:
            return list(self._violations)

    def edges(self) -> Dict[Tuple[str, str], str]:
        with self._mu:
            return dict(self._edges)

    def check(self) -> None:
        """Raise :class:`LockOrderViolation` if anything was observed."""
        v = self.violations()
        if v:
            raise LockOrderViolation(
                f"{len(v)} lock-order violation(s) observed:\n  "
                + "\n  ".join(v))


# --------------------------------------------------------------------- #
# process-global installation
# --------------------------------------------------------------------- #
_active: Optional[LockWitness] = None


def install(witness: Optional[LockWitness] = None) -> LockWitness:
    """Install (and return) the process-global witness.  ProfiledLocks
    start reporting to it immediately."""
    global _active
    if witness is None:
        witness = LockWitness()
    _active = witness
    return witness


def uninstall() -> None:
    global _active
    _active = None


def active() -> Optional[LockWitness]:
    return _active

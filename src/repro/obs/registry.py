"""MetricsRegistry: labeled metric families + the two exporters.

One process-global registry (``repro.obs.registry()``) collects every
metric family in the system.  A *family* is one metric name with one type
and N labeled children — ``scatter_latency_ms{group=3}`` and
``scatter_latency_ms{group=7}`` are two series of one family.  Accessors
are get-or-create and return the live metric object, so instrumentation
sites call ``registry().counter("x", group=g)`` freely; the same
(name, labels) pair always yields the same object.

Exporters:

* ``JsonlSink`` appends one ``{"ts": ..., "metrics": snapshot}`` line per
  ``write()`` — the persisted perf-trajectory form consumed by
  ``BENCH_*.json`` emission and ``--metrics-dump``.
* ``to_prometheus()`` renders the text exposition format 0.0.4
  (histograms as cumulative ``_bucket{le=...}`` series plus
  ``_sum``/``_count``), for scraping or eyeballing.

``enabled`` gates every child metric's mutators (see
:mod:`repro.obs.metrics`): disabling the registry turns the whole
instrumentation sweep into ~100 ns no-ops without unhooking anything.
"""

from __future__ import annotations

import json
import math
import threading
import time
from typing import Dict, Optional, Tuple

from .metrics import Counter, Gauge, Histogram

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class _Family:
    __slots__ = ("kind", "help", "children")

    def __init__(self, kind: str, help: str):
        self.kind = kind
        self.help = help
        self.children: Dict[LabelKey, object] = {}


class MetricsRegistry:
    """Process-wide collection of labeled metric families."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._families: Dict[str, _Family] = {}

    # -- lifecycle -------------------------------------------------------- #
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        """Zero every series (families and label sets survive)."""
        with self._lock:
            fams = list(self._families.values())
        for fam in fams:
            for m in list(fam.children.values()):
                m.reset()

    # -- get-or-create accessors ------------------------------------------ #
    def _metric(self, cls, name: str, help: str, labels: dict, **kw):
        key = _label_key(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = self._families[name] = _Family(cls.kind, help)
            elif fam.kind != cls.kind:
                raise ValueError(
                    f"metric {name!r} is a {fam.kind}, not a {cls.kind}")
            m = fam.children.get(key)
            if m is None:
                m = fam.children[key] = cls(_owner=self, **kw)
            if help and not fam.help:
                fam.help = help
        return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._metric(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._metric(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "", lo: float = 1e-3,
                  hi: float = 1e5, per_decade: int = 20,
                  **labels) -> Histogram:
        return self._metric(Histogram, name, help, labels,
                            lo=lo, hi=hi, per_decade=per_decade)

    # -- export ------------------------------------------------------------ #
    def snapshot(self) -> dict:
        """Plain-dict view of every family: name → {type, help, series}."""
        with self._lock:
            fams = list(self._families.items())
        out = {}
        for name, fam in sorted(fams):
            series = []
            for key, m in sorted(fam.children.items()):
                series.append({"labels": dict(key), **m.snapshot()})
            out[name] = {"type": fam.kind, "help": fam.help,
                         "series": series}
        return out

    def series(self, name: str) -> List[Tuple[Dict[str, str], object]]:
        """Live ``(labels, metric)`` pairs of one family (empty when the
        family does not exist) — the read surface for consumers that need
        the metric *objects* (windowed reads, SLO burn computation), not
        a frozen snapshot."""
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                return []
            return [(dict(k), m) for k, m in sorted(fam.children.items())]

    def to_prometheus(self) -> str:
        """Prometheus text exposition (format 0.0.4).  Counters and gauges
        render verbatim; histograms follow the histogram type rules:
        cumulative ``_bucket{le="..."}`` series in ascending bound order
        with a terminal ``le="+Inf"`` equal to ``_count``, plus ``_sum``
        and ``_count``.  Label values are escaped per the spec."""
        def esc(v: str) -> str:
            return (str(v).replace("\\", "\\\\").replace('"', '\\"')
                    .replace("\n", "\\n"))

        def fmt_labels(labels: dict, extra: Optional[dict] = None) -> str:
            items = dict(labels)
            if extra:
                items.update(extra)
            if not items:
                return ""
            body = ",".join(f'{k}="{esc(v)}"'
                            for k, v in sorted(items.items()))
            return "{" + body + "}"

        def num(v) -> str:
            if isinstance(v, float) and math.isnan(v):
                return "NaN"
            return repr(float(v)) if isinstance(v, float) else str(v)

        with self._lock:
            fams = [(name, fam.kind, fam.help, sorted(fam.children.items()))
                    for name, fam in sorted(self._families.items())]
        lines = []
        for name, kind, help, children in fams:
            if help:
                lines.append(f"# HELP {name} {esc(help)}")
            lines.append(f"# TYPE {name} {kind}")
            for key, m in children:
                labels = dict(key)
                if kind == "histogram":
                    for le, cum in m.cumulative_buckets():
                        lines.append(
                            f"{name}_bucket{fmt_labels(labels, {'le': le})} "
                            f"{cum}")
                    lines.append(f"{name}_sum{fmt_labels(labels)} "
                                 f"{num(m.sum)}")
                    lines.append(f"{name}_count{fmt_labels(labels)} "
                                 f"{m.count}")
                else:
                    lines.append(f"{name}{fmt_labels(labels)} "
                                 f"{num(m.value)}")
        return "\n".join(lines) + "\n"


class JsonlSink:
    """Appends registry snapshots to a JSONL file, one line per write."""

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()

    def write(self, registry: MetricsRegistry,
              extra: Optional[dict] = None) -> dict:
        rec = {"ts": time.time(), "metrics": registry.snapshot()}
        if extra:
            rec.update(extra)
        rec = sanitize(rec)
        line = json.dumps(rec, sort_keys=True, allow_nan=False)
        with self._lock, open(self.path, "a") as fh:
            fh.write(line + "\n")
        return rec


def sanitize(obj):
    """NaN/inf → None, recursively — keeps every export strictly valid
    JSON (json.dumps would otherwise emit bare ``NaN`` tokens)."""
    if isinstance(obj, float) and not math.isfinite(obj):
        return None
    if isinstance(obj, dict):
        return {k: sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [sanitize(v) for v in obj]
    return obj


# -- process-global registry ------------------------------------------------ #
_GLOBAL = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-global registry every subsystem reports into."""
    return _GLOBAL

"""repro.obs — the unified telemetry plane.

Stdlib-only (no jax/numpy), so every subsystem — core, dist, tiered,
train — can import it without cycles or optional-dependency gates.

Three layers:

* metrics: thread-safe Counter / Gauge / log-bucketed Histogram in a
  process-global :func:`registry` of labeled families, exported as a
  plain-dict snapshot, JSONL lines, or Prometheus text.
* tracing: contextvar-propagated :func:`span` trees with a ring buffer
  and slow-trace JSONL dump (see :mod:`repro.obs.trace`).
* bench: schema-versioned ``BENCH_*.json`` emission + validation — the
  persisted perf trajectory (see :mod:`repro.obs.bench`).

Plus the live introspection plane on top: continuous profiling and lock
contention (:mod:`repro.obs.profile`), declared SLOs with multi-window
burn rates (:mod:`repro.obs.slo`), size-capped JSONL rotation
(:mod:`repro.obs.rotate`), and the HTTP admin server exposing all of it
(:mod:`repro.obs.server`).

Disable everything (both planes drop to ~100 ns no-ops) with
:func:`disable`; re-enable with :func:`enable`.
"""

from .metrics import Counter, Gauge, Histogram
from .registry import JsonlSink, MetricsRegistry, registry, sanitize
from .trace import Span, Tracer, span, tracer
from .bench import SCHEMA as BENCH_SCHEMA
from .bench import emit as emit_bench
from .bench import validate as validate_bench
from .rotate import RotatingJsonl
from .profile import ProfiledLock, SamplingProfiler, phase_timer, profile_for
from .slo import SLO, SLOMonitor, SLOSignalSource, default_slos
from .server import AdminServer
from .witness import LockOrderViolation, LockWitness
from .witness import active as witness_active
from .witness import install as install_witness
from .witness import uninstall as uninstall_witness


def enable() -> None:
    """Turn on metrics and tracing process-wide."""
    registry().enable()
    tracer().enabled = True


def disable() -> None:
    """Turn off metrics and tracing process-wide (near-zero overhead)."""
    registry().disable()
    tracer().enabled = False


__all__ = [
    "Counter", "Gauge", "Histogram",
    "JsonlSink", "MetricsRegistry", "registry", "sanitize",
    "Span", "Tracer", "span", "tracer",
    "BENCH_SCHEMA", "emit_bench", "validate_bench",
    "RotatingJsonl",
    "ProfiledLock", "SamplingProfiler", "phase_timer", "profile_for",
    "SLO", "SLOMonitor", "SLOSignalSource", "default_slos",
    "AdminServer",
    "LockOrderViolation", "LockWitness",
    "install_witness", "uninstall_witness", "witness_active",
    "enable", "disable",
]

"""Declared SLOs and multi-window burn-rate computation.

An :class:`SLO` declares what "good" means for one user-visible behavior;
the :class:`SLOMonitor` turns the cumulative metric families into
*windowed* bad-event fractions and reports them as **burn rates** — the
fraction of the error budget consumed per unit of budget, the signal a
production system pages on:

    burn = (bad events / total events in window) / (1 - objective)

``burn == 1`` means the window is eating budget exactly at the sustainable
rate; ``burn >> 1`` means the budget dies in hours.  Two windows guard
against both failure modes of single-window alerting: the *short* window
catches fast regressions quickly but flaps on blips, the *long* window is
stable but slow — requiring **both** to burn (``min`` across windows, the
Google SRE multi-window rule) fires fast on real sustained problems and
stays quiet on noise.  That min is what :class:`SLOSignalSource` feeds
the autopilot as ``GroupSignal.burn_rate``, so the hot-split policy can
trigger on sustained budget burn rather than one raw p95 spike.

Two SLO kinds:

* ``latency`` — over one histogram family (e.g.
  ``scatter_latency_ms{group}``): an observation is *bad* iff it exceeds
  ``threshold_ms`` (exact to bucket resolution, via
  ``Histogram.over_threshold_since`` — the same windowed-delta mechanism
  ``percentile_since`` uses).  Burn is computed per labeled series (so a
  ``group`` label yields per-group burns) and aggregated.
* ``ratio`` — over a good/bad counter pair (e.g. quorum commits vs
  quorum aborts): bad fraction = Δbad / (Δgood + Δbad).

Every computed burn is exported as the ``slo_burn_rate{slo,window}``
gauge family, so the admin server's ``/metrics`` and the BENCH trajectory
carry the same numbers the controller acts on.  Clock and windows are
injectable: the simulation harness runs the monitor on a ``SimClock``
with tick-denominated windows, deterministically.
"""

from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .registry import MetricsRegistry, registry

SeriesKey = Tuple[Tuple[str, str], ...]


@dataclass(frozen=True)
class SLO:
    """One declared objective.

    ``objective`` is the target good fraction (0.95 = "95 % of events
    good"); the error budget is ``1 - objective``.  ``latency`` SLOs name
    a histogram ``metric`` and a ``threshold_ms``; ``ratio`` SLOs name a
    ``good_metric``/``bad_metric`` counter pair.
    """

    name: str
    kind: str                     # "latency" | "ratio"
    objective: float
    metric: str = ""              # latency: histogram family
    threshold_ms: float = 0.0     # latency: good iff value <= threshold
    good_metric: str = ""         # ratio: success counter family
    bad_metric: str = ""          # ratio: failure counter family

    def __post_init__(self):
        if self.kind not in ("latency", "ratio"):
            raise ValueError(f"unknown SLO kind {self.kind!r}")
        if not 0.0 < self.objective < 1.0:
            raise ValueError("objective must be in (0, 1)")
        if self.kind == "latency" and not self.metric:
            raise ValueError("latency SLO needs a metric family")
        if self.kind == "ratio" and not (self.good_metric and
                                         self.bad_metric):
            raise ValueError("ratio SLO needs good and bad counters")

    @property
    def budget(self) -> float:
        return 1.0 - self.objective


def default_slos(serving_threshold_ms: float = 50.0) -> Tuple[SLO, ...]:
    """The warren's production SLO set: serving p95, quorum-commit
    success, compaction pause."""
    return (
        SLO(name="serving_p95", kind="latency", objective=0.95,
            metric="scatter_latency_ms",
            threshold_ms=serving_threshold_ms),
        SLO(name="quorum_commit", kind="ratio", objective=0.999,
            good_metric="txn_quorum_commit_total",
            bad_metric="txn_quorum_abort_total"),
        SLO(name="compaction_pause", kind="latency", objective=0.99,
            metric="compaction_pause_ms", threshold_ms=50.0),
    )


class SLOMonitor:
    """Multi-window burn-rate computation over cumulative families.

    ``tick()`` snapshots every SLO's underlying series, computes each
    window's burn against the history, exports the
    ``slo_burn_rate{slo,window}`` gauges, and retains the snapshot.
    Windows are ``(name, seconds)`` pairs against the injected ``clock``
    — wall seconds in production, sim-ticks under a ``SimClock``.  An
    empty window (no events) burns 0: no traffic is not an outage.
    """

    def __init__(self, slos: Optional[Sequence[SLO]] = None,
                 windows: Sequence[Tuple[str, float]] = (("short", 60.0),
                                                        ("long", 600.0)),
                 reg: Optional[MetricsRegistry] = None,
                 clock=time.monotonic):
        if not windows:
            raise ValueError("need at least one window")
        self.slos = tuple(slos) if slos is not None else default_slos()
        self.windows = tuple((str(n), float(s)) for n, s in windows)
        self.reg = reg if reg is not None else registry()
        self.clock = clock
        horizon = max(s for _, s in self.windows)
        self._horizon = 2.0 * horizon
        # per slo: deque of (ts, {series_key: state}); state is a bucket
        # count list (latency) or a (good, bad) value pair (ratio)
        self._hist: Dict[str, deque] = {s.name: deque() for s in self.slos}
        self._last: Dict[str, Dict[str, float]] = {}
        self._last_groups: Dict[str, Dict[str, float]] = {}

    # -- capture ----------------------------------------------------------- #
    def _capture(self, slo: SLO) -> Dict[SeriesKey, object]:
        if slo.kind == "latency":
            return {tuple(sorted(labels.items())): h.bucket_counts()
                    for labels, h in self.reg.series(slo.metric)}
        good = {tuple(sorted(labels.items())): c.value
                for labels, c in self.reg.series(slo.good_metric)}
        bad = {tuple(sorted(labels.items())): c.value
               for labels, c in self.reg.series(slo.bad_metric)}
        return {key: (good.get(key, 0), bad.get(key, 0))
                for key in set(good) | set(bad)}

    @staticmethod
    def _base_state(hist: deque, now: float,
                    window_s: float) -> Optional[Dict]:
        """The newest snapshot at least ``window_s`` old (the window's
        start), falling back to the oldest retained one."""
        base = None
        for ts, state in hist:
            if ts <= now - window_s:
                base = state
            else:
                break
        if base is None and hist:
            base = hist[0][1]
        return base

    def _bad_total(self, slo: SLO, base: Optional[Dict],
                   cur: Dict) -> Tuple[Dict[SeriesKey, Tuple[int, int]],
                                       int, int]:
        """Per-series and aggregate (bad, total) event deltas."""
        per: Dict[SeriesKey, Tuple[int, int]] = {}
        agg_bad = agg_total = 0
        for key, state in cur.items():
            prev = base.get(key) if base else None
            if slo.kind == "latency":
                # map the key back to the live histogram for the delta
                h = self.reg.histogram(slo.metric, **dict(key))
                b, t = h.over_threshold_since(prev, slo.threshold_ms)
            else:
                g0, b0 = prev if prev is not None else (0, 0)
                g1, b1 = state
                b = max(b1 - b0, 0)
                t = max(g1 - g0, 0) + b
            per[key] = (b, t)
            agg_bad += b
            agg_total += t
        return per, agg_bad, agg_total

    # -- the control-rate read --------------------------------------------- #
    def tick(self) -> Dict[str, Dict[str, float]]:
        """Compute every SLO's per-window burn, export the gauges, retain
        the snapshot.  Returns ``{slo: {window: burn}}``."""
        now = self.clock()
        report: Dict[str, Dict[str, float]] = {}
        for slo in self.slos:
            cur = self._capture(slo)
            hist = self._hist[slo.name]
            burns: Dict[str, float] = {}
            group_burns: Dict[str, List[float]] = {}
            for wname, wsecs in self.windows:
                base = self._base_state(hist, now, wsecs)
                per, bad, total = self._bad_total(slo, base, cur)
                burn = ((bad / total) / slo.budget) if total > 0 else 0.0
                burns[wname] = burn
                if self.reg.enabled:
                    self.reg.gauge(
                        "slo_burn_rate",
                        "windowed error-budget burn rate (1.0 = budget "
                        "consumed exactly at the sustainable rate)",
                        slo=slo.name, window=wname).set(burn)
                for key, (b, t) in per.items():
                    g = dict(key).get("group")
                    if g is None or t <= 0:
                        continue
                    group_burns.setdefault(g, []).append(
                        (b / t) / slo.budget)
            report[slo.name] = burns
            # sustained per-group burn: min across windows, like the
            # aggregate — a group must burn in EVERY window to register
            self._last_groups[slo.name] = {
                g: min(v) for g, v in group_burns.items()
                if len(v) == len(self.windows)}
            hist.append((now, cur))
            while hist and hist[0][0] < now - self._horizon:
                hist.popleft()
        self._last = report
        return report

    # -- reads -------------------------------------------------------------- #
    def burn(self, slo_name: str,
             window: Optional[str] = None) -> float:
        """Last computed burn for one SLO: a named window, or (default)
        the sustained burn — ``min`` across windows, the multi-window
        page rule.  NaN before the first ``tick``."""
        burns = self._last.get(slo_name)
        if not burns:
            return math.nan
        if window is not None:
            return burns.get(window, math.nan)
        return min(burns.values())

    def group_burns(self, slo_name: str) -> Dict[str, float]:
        """Last computed sustained burn per ``group`` label value (empty
        for SLOs whose series carry no group label)."""
        return dict(self._last_groups.get(slo_name, {}))

    def report(self) -> dict:
        """The full structure the admin server's ``/slo`` endpoint
        serves: declared objectives + last burns per window + per-group
        sustained burns."""
        out = []
        for slo in self.slos:
            out.append({
                "name": slo.name, "kind": slo.kind,
                "objective": slo.objective, "budget": slo.budget,
                "metric": slo.metric or None,
                "threshold_ms": (slo.threshold_ms
                                 if slo.kind == "latency" else None),
                "good_metric": slo.good_metric or None,
                "bad_metric": slo.bad_metric or None,
                "burn": self._last.get(slo.name, {}),
                "sustained_burn": self.burn(slo.name),
                "group_burns": self.group_burns(slo.name),
            })
        return {"windows": [{"name": n, "seconds": s}
                            for n, s in self.windows],
                "slos": out}


class SLOSignalSource:
    """SignalSource decorator feeding sustained SLO burn to the autopilot.

    Wraps any ``collect() -> [GroupSignal]`` source: each collect first
    ticks the monitor, then stamps every signal's ``burn_rate`` with the
    group's sustained burn for ``slo_name`` (falling back to the
    aggregate when the group has no series of its own).  The controller's
    ``HotSplitPolicy.burn_hot`` threshold then triggers splits on
    *sustained budget burn* instead of a raw latency spike.
    """

    def __init__(self, inner, monitor: SLOMonitor,
                 slo_name: str = "serving_p95"):
        if not any(s.name == slo_name for s in monitor.slos):
            raise ValueError(f"monitor declares no SLO named {slo_name!r}")
        self.inner = inner
        self.monitor = monitor
        self.slo_name = slo_name

    def collect(self):
        sigs = self.inner.collect()
        self.monitor.tick()
        per_group = self.monitor.group_burns(self.slo_name)
        agg = self.monitor.burn(self.slo_name)
        for s in sigs:
            s.burn_rate = per_group.get(str(s.group), agg)
        return sigs

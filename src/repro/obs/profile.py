"""Continuous profiling: wall-clock sampling, lock contention, kernel phases.

Three instruments, all cheap enough to leave on in production:

* :class:`SamplingProfiler` — a ``sys._current_frames``-based wall-clock
  sampler.  A daemon thread wakes every ``interval_s``, snapshots every
  thread's Python stack, and aggregates them as collapsed stacks
  (``frame;frame;frame count`` lines, the flamegraph input format).
  Sampling is GIL-serialized and allocation-free per live frame walk, so
  at the default 10 ms interval the overhead on the concurrent serving
  smoke is under 5 % (measured in docs/architecture.md §6).  The admin
  server's ``/profile/cpu?seconds=N`` endpoint runs one on demand.
* :class:`ProfiledLock` — wraps a ``threading.Lock``/``RLock`` and times
  only *contended* acquires into the ``lock_wait_ms{lock}`` histogram
  family: the uncontended path is one extra non-blocking ``acquire``
  attempt, so wrapping a hot lock costs nanoseconds until it actually
  blocks.  Wired onto the shard-group write locks, the rebalance lock,
  the MicroBatcher close lock, the tiered maintenance lock, the WAL
  durability lock, and the checkpoint filesystem lock.  When a
  :class:`~repro.obs.witness.LockWitness` is installed, every
  ProfiledLock acquire/release is also reported to it with the lock's
  profile name and optional ``order_key``, so the runtime lock-order
  checker sees exactly the locks the contention profiles see.
* :func:`phase_timer` — a context manager attributing device-kernel wall
  time to phases (host ``gather``/pack vs device ``compute``), feeding
  the ``kernel_phase_ms{kernel,phase}`` family that
  ``benchmarks/roofline.py --kernels`` reports and ``BENCH_kernels.json``
  persists — the DMA-vs-compute baseline the Pallas speed pass needs.
"""

from __future__ import annotations

import sys
import threading
import time
from contextlib import contextmanager
from typing import Dict, Optional

from .registry import registry
from . import witness as _witness


# --------------------------------------------------------------------- #
# wall-clock sampling profiler
# --------------------------------------------------------------------- #
class SamplingProfiler:
    """Collapsed-stack wall-clock sampler over ``sys._current_frames``.

    ``start()``/``stop()`` bracket a sampling window; ``collapsed()``
    returns the aggregate as flamegraph-compatible text (one
    ``name;name;name count`` line per distinct stack, root first).  The
    sampler thread skips itself and tags each stack with its thread name,
    so lock-wait parked threads, the MicroBatcher loop, and ScatterGather
    workers all show up as distinct towers.
    """

    def __init__(self, interval_s: float = 0.01, max_depth: int = 64):
        if interval_s <= 0:
            raise ValueError("interval_s must be positive")
        self.interval_s = interval_s
        self.max_depth = max_depth
        self._lock = threading.Lock()
        self._counts: Dict[str, int] = {}
        self._samples = 0
        self._stop: Optional[threading.Event] = None
        self._thread: Optional[threading.Thread] = None

    # -- sampling ---------------------------------------------------------- #
    def _walk(self, frame) -> str:
        parts = []
        depth = 0
        while frame is not None and depth < self.max_depth:
            code = frame.f_code
            parts.append(f"{code.co_name} ({code.co_filename.rsplit('/', 1)[-1]}"
                         f":{code.co_firstlineno})")
            frame = frame.f_back
            depth += 1
        parts.reverse()                     # root first, leaf last
        return ";".join(parts)

    def _sample_once(self, own_tid: int, names: Dict[int, str]) -> None:
        frames = sys._current_frames()
        stacks = []
        for tid, frame in frames.items():
            if tid == own_tid:
                continue
            name = names.get(tid, f"thread-{tid}")
            stacks.append(f"{name};{self._walk(frame)}")
        del frames                          # drop frame references promptly
        with self._lock:
            self._samples += 1
            for s in stacks:
                self._counts[s] = self._counts.get(s, 0) + 1

    def _run(self, stop: threading.Event) -> None:
        own_tid = threading.get_ident()
        while not stop.wait(self.interval_s):
            names = {t.ident: t.name for t in threading.enumerate()
                     if t.ident is not None}
            self._sample_once(own_tid, names)

    # -- lifecycle --------------------------------------------------------- #
    def start(self) -> "SamplingProfiler":
        if self._thread is not None:
            raise RuntimeError("profiler already running")
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run, args=(self._stop,),
            daemon=True, name="obs-sampler")
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._thread = None
        self._stop = None

    @property
    def samples(self) -> int:
        with self._lock:
            return self._samples

    # -- output ------------------------------------------------------------ #
    def collapsed(self) -> str:
        """Flamegraph-format collapsed stacks, hottest first."""
        with self._lock:
            items = sorted(self._counts.items(),
                           key=lambda kv: (-kv[1], kv[0]))
        return "\n".join(f"{stack} {count}" for stack, count in items)

    def reset(self) -> None:
        with self._lock:
            self._counts.clear()
            self._samples = 0


def profile_for(seconds: float, interval_s: float = 0.01) -> str:
    """Sample every thread for ``seconds`` and return collapsed stacks —
    the one-shot form behind ``/profile/cpu?seconds=N``."""
    prof = SamplingProfiler(interval_s=interval_s)
    prof.start()
    try:
        time.sleep(max(seconds, interval_s))
    finally:
        prof.stop()
    return prof.collapsed()


# --------------------------------------------------------------------- #
# instrumented locks
# --------------------------------------------------------------------- #
class ProfiledLock:
    """A Lock/RLock wrapper that histograms *contended* wait time.

    The fast path tries a non-blocking acquire first: uncontended use
    costs one extra C-level call and never touches the metrics plane.
    Only when the lock is actually held elsewhere does the wrapper time
    the blocking acquire into ``lock_wait_ms{lock=<name>}`` and count it
    in ``lock_contended_total{lock=<name>}``.  Supports the full lock
    protocol (``with``, ``acquire(blocking, timeout)``, ``release``), and
    wrapping an ``RLock`` keeps reentrancy (the non-blocking attempt of
    an already-owned RLock succeeds).

    When a :class:`repro.obs.witness.LockWitness` is installed, every
    acquire/release also reports to it with this lock's name and
    ``order_key`` (the ascending-order key for multi-instance lock
    classes, e.g. the shard group id for ``group_write``); with no
    witness installed the hook is one module-attribute load + ``is
    None`` test.
    """

    def __init__(self, name: str, lock=None, order_key: Optional[int] = None):
        self.name = name
        self.order_key = order_key
        self._lock = lock if lock is not None else threading.Lock()
        reg = registry()
        self._wait = reg.histogram(
            "lock_wait_ms",
            "time spent blocked on a contended hot lock", lock=name)
        self._contended = reg.counter(
            "lock_contended_total",
            "acquires that had to block", lock=name)

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        if self._lock.acquire(False):
            w = _witness._active
            if w is not None:
                w.note_acquire(self.name, self.order_key, id(self._lock))
            return True
        if not blocking:
            return False
        t0 = time.perf_counter()
        ok = self._lock.acquire(True, timeout)
        self._wait.observe(1e3 * (time.perf_counter() - t0))
        self._contended.inc()
        if ok:
            w = _witness._active
            if w is not None:
                w.note_acquire(self.name, self.order_key, id(self._lock))
        return ok

    def release(self) -> None:
        w = _witness._active
        if w is not None:
            w.note_release(self.name, id(self._lock))
        self._lock.release()

    def locked(self) -> bool:
        locked = getattr(self._lock, "locked", None)
        if locked is not None:
            return locked()
        # RLock has no locked(); probe without disturbing ownership
        if self._lock.acquire(False):
            self._lock.release()
            return False
        return True

    def __enter__(self) -> "ProfiledLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False

    def __repr__(self) -> str:                     # pragma: no cover
        return f"ProfiledLock({self.name!r}, {self._lock!r})"


# --------------------------------------------------------------------- #
# kernel phase attribution
# --------------------------------------------------------------------- #
@contextmanager
def phase_timer(kernel: str, phase: str):
    """Attribute a block's wall time to one kernel phase:
    ``kernel_phase_ms{kernel,phase}``.  Phases by convention: ``gather``
    (host-side packing / DMA staging) and ``compute`` (device dispatch +
    block-until-ready).  A disabled registry reduces this to two
    ``perf_counter`` calls."""
    reg = registry()
    if not reg.enabled:
        yield
        return
    t0 = time.perf_counter()
    try:
        yield
    finally:
        reg.histogram(
            "kernel_phase_ms",
            "device-kernel wall time by phase (gather=host pack/DMA "
            "staging, compute=dispatch+ready)",
            kernel=kernel, phase=phase,
        ).observe(1e3 * (time.perf_counter() - t0))

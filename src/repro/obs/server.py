"""The live introspection plane: a stdlib HTTP admin/debug server.

The paper's headline regime — a fully dynamic index under ACID
transactions with hundreds of concurrent readers and writers — cannot be
debugged from logs after the fact; you ask the *running* warren what it
is doing.  :class:`AdminServer` is that window: a
``ThreadingHTTPServer`` (stdlib only, daemon threads, ephemeral port by
default) serving read-only views of every observability surface:

    /healthz               liveness (the process answers)
    /readyz                readiness (the attached warren routes)
    /metrics               Prometheus text exposition (format 0.0.4)
    /metrics.json          full registry snapshot, sanitized JSON
    /traces                completed-trace ring: id, root, duration, error
    /traces/<id>           one trace: span tree + flat span records
    /routing               RoutingTable epoch/ranges + per-group state
    /autopilot/decisions   recent Decision records (?n=50)
    /tiered/runs           static-tier run sets (manifest + per-run info)
    /tiered/cache          block-cache occupancy + hit/miss/admission stats
    /slo                   declared SLOs + multi-window burn rates
    /profile/cpu?seconds=N on-demand wall-clock sampling profile
                           (collapsed stacks, flamegraph-compatible)

Every endpoint reads lock-free or through the same snapshot surfaces the
serving paths use — scraping ``/routing`` mid-rebalance never takes a
write lock, so the admin plane can never block writers (tier-1 asserts
this under a concurrent scrape storm with a split in flight).

Handlers never raise into the socket: failures become a JSON 500 with
the exception type, and ``log_message`` is silenced so the admin plane
does not spam the server's stderr.
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional
from urllib.parse import parse_qs, urlparse

from .profile import profile_for
from .registry import registry, sanitize
from .trace import tracer

PROFILE_MAX_SECONDS = 30.0


class AdminServer:
    """Admin endpoint over the process-global registry/tracer plus
    whatever subsystems are attached (all optional):

    * ``warren``     — a ShardedWarren (``/routing``, ``/readyz``)
    * ``controller`` — an autopilot Controller (``/autopilot/decisions``)
    * ``tiered``     — a TieredStore (``/tiered/runs``, ``/tiered/cache``);
      without one, a warren's demoted groups still report their run
      directories and ``/tiered/cache`` falls back to the process-default
      block cache
    * ``slo``        — an SLOMonitor (``/slo``)

    ``start()`` binds (port 0 = ephemeral) and serves on daemon threads;
    ``close()`` shuts the listener down.  Usable as a context manager.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 warren=None, controller=None, tiered=None, slo=None):
        self.host = host
        self._requested_port = port
        self.warren = warren
        self.controller = controller
        self.tiered = tiered
        self.slo = slo
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle --------------------------------------------------------- #
    def start(self) -> "AdminServer":
        if self._httpd is not None:
            raise RuntimeError("admin server already started")
        admin = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):
                admin._dispatch(self)

            def log_message(self, fmt, *args):   # silence per-request spam
                pass

        self._httpd = ThreadingHTTPServer(
            (self.host, self._requested_port), Handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, daemon=True,
            name="obs-admin")
        self._thread.start()
        return self

    @property
    def port(self) -> int:
        if self._httpd is None:
            raise RuntimeError("admin server not started")
        return self._httpd.server_address[1]

    def url(self, path: str = "/") -> str:
        return f"http://{self.host}:{self.port}{path}"

    def close(self) -> None:
        if self._httpd is None:
            return
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        self._httpd = None
        self._thread = None

    def __enter__(self) -> "AdminServer":
        return self.start() if self._httpd is None else self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # -- dispatch ----------------------------------------------------------- #
    def _dispatch(self, h: BaseHTTPRequestHandler) -> None:
        url = urlparse(h.path)
        path, query = url.path.rstrip("/") or "/", parse_qs(url.query)
        try:
            if path == "/healthz":
                self._json(h, {"ok": True})
            elif path == "/readyz":
                self._readyz(h)
            elif path == "/metrics":
                self._text(h, registry().to_prometheus(),
                           content_type="text/plain; version=0.0.4")
            elif path == "/metrics.json":
                self._json(h, {"metrics": registry().snapshot()})
            elif path == "/traces":
                self._traces(h)
            elif path.startswith("/traces/"):
                self._trace_one(h, path[len("/traces/"):])
            elif path == "/routing":
                self._routing(h)
            elif path == "/autopilot/decisions":
                self._decisions(h, query)
            elif path == "/tiered/runs":
                self._tiered_runs(h)
            elif path == "/tiered/cache":
                self._tiered_cache(h)
            elif path == "/slo":
                self._slo(h)
            elif path == "/profile/cpu":
                self._profile(h, query)
            else:
                self._json(h, {"error": f"no such endpoint {path!r}"},
                           status=404)
        except Exception as e:              # never raise into the socket
            try:
                self._json(h, {"error": f"{type(e).__name__}: {e}"},
                           status=500)
            except Exception:
                pass

    # -- response helpers --------------------------------------------------- #
    @staticmethod
    def _text(h, body: str, status: int = 200,
              content_type: str = "text/plain; charset=utf-8") -> None:
        data = body.encode("utf-8")
        h.send_response(status)
        h.send_header("Content-Type", content_type)
        h.send_header("Content-Length", str(len(data)))
        h.end_headers()
        h.wfile.write(data)

    @classmethod
    def _json(cls, h, obj, status: int = 200) -> None:
        cls._text(h, json.dumps(sanitize(obj), sort_keys=True, indent=1),
                  status=status, content_type="application/json")

    # -- endpoints ----------------------------------------------------------- #
    def _readyz(self, h) -> None:
        w = self.warren
        if w is None:
            self._json(h, {"ready": True, "warren": None})
            return
        try:
            table = w.routing
            self._json(h, {"ready": True, "epoch": table.epoch,
                           "groups": len(w.groups)})
        except Exception as e:
            self._json(h, {"ready": False,
                           "error": f"{type(e).__name__}: {e}"}, status=503)

    def _traces(self, h) -> None:
        out = []
        for t in tracer().traces():
            root = t.root
            out.append({
                "trace_id": t.trace_id,
                "root": root.name if root is not None else None,
                "duration_ms": t.duration_ms,
                "error": root.error if root is not None else False,
                "n_spans": len(t.spans),
            })
        self._json(h, {"traces": out})

    def _trace_one(self, h, ident: str) -> None:
        try:
            tid = int(ident)
        except ValueError:
            self._json(h, {"error": f"bad trace id {ident!r}"}, status=400)
            return
        t = tracer().trace_by_id(tid)
        if t is None:
            self._json(h, {"error": f"no trace {tid} in the ring"},
                       status=404)
            return
        self._json(h, {"trace": t.to_record(), "tree": t.tree()})

    def _routing(self, h) -> None:
        if self.warren is None:
            self._json(h, {"error": "no warren attached"}, status=404)
            return
        self._json(h, self.warren.describe_routing())

    def _decisions(self, h, query) -> None:
        if self.controller is None:
            self._json(h, {"error": "no controller attached"}, status=404)
            return
        try:
            n = int(query.get("n", ["50"])[0])
        except ValueError:
            n = 50
        ds = self.controller.decisions[-max(n, 0):]
        self._json(h, {"tick": self.controller.tick_count,
                       "decisions": [d.to_record() for d in ds]})

    def _tiered_runs(self, h) -> None:
        if self.tiered is not None:
            self._json(h, self.tiered.runs_info())
            return
        if self.warren is not None:
            demoted = {str(g): d
                       for g, d in enumerate(self.warren.demoted())
                       if d is not None}
            self._json(h, {"tiered": None, "demoted_groups": demoted})
            return
        self._json(h, {"error": "no tiered store or warren attached"},
                   status=404)

    def _tiered_cache(self, h) -> None:
        cache = getattr(self.tiered, "block_cache", None)
        if cache is None:
            from repro.tiered import default_block_cache
            cache = default_block_cache()
        self._json(h, cache.stats())

    def _slo(self, h) -> None:
        if self.slo is None:
            self._json(h, {"error": "no SLO monitor attached"}, status=404)
            return
        self._json(h, self.slo.report())

    def _profile(self, h, query) -> None:
        try:
            seconds = float(query.get("seconds", ["1.0"])[0])
        except ValueError:
            self._json(h, {"error": "seconds must be a number"},
                       status=400)
            return
        seconds = min(max(seconds, 0.05), PROFILE_MAX_SECONDS)
        self._text(h, profile_for(seconds) + "\n")

"""Size-capped JSONL sinks for long-running appenders.

The slow-trace dump and the autopilot decision log are append-only JSONL
files on servers that run for days — unbounded, they eventually fill the
disk and take the warren down with an observability artifact, the most
embarrassing possible outage.  :class:`RotatingJsonl` caps them: when an
append would push the live file past ``max_bytes`` the file rotates
(``path`` → ``path.1`` → … → ``path.N``, oldest dropped), so total disk
use is bounded by ``max_bytes * (backups + 1)`` no matter how long the
process lives.

Rotation is rename-based (atomic on POSIX) and serialized by the sink's
own lock; a reader following the live file sees whole lines only.
"""

from __future__ import annotations

import json
import os
import threading
from typing import Optional


class RotatingJsonl:
    """Append JSON records to ``path``, rotating at ``max_bytes``.

    ``write`` takes a JSON-serializable record (or a pre-encoded line via
    ``write_line``); the size check counts the encoded line, so a single
    oversized record still lands (in a fresh file) rather than being
    silently dropped.
    """

    def __init__(self, path: str, max_bytes: int = 4 << 20,
                 backups: int = 2):
        if max_bytes <= 0:
            raise ValueError("max_bytes must be positive")
        if backups < 0:
            raise ValueError("backups must be >= 0")
        self.path = path
        self.max_bytes = max_bytes
        self.backups = backups
        self._lock = threading.Lock()
        self._size: Optional[int] = None     # lazy: stat on first write

    # -- internals -------------------------------------------------------- #
    def _current_size(self) -> int:
        if self._size is None:
            try:
                self._size = os.path.getsize(self.path)
            except OSError:
                self._size = 0
        return self._size

    def _rotate(self) -> None:
        if self.backups == 0:
            try:
                os.remove(self.path)
            except OSError:
                pass
        else:
            for i in range(self.backups, 1, -1):
                src = f"{self.path}.{i - 1}"
                if os.path.exists(src):
                    os.replace(src, f"{self.path}.{i}")
            if os.path.exists(self.path):
                os.replace(self.path, f"{self.path}.1")
        self._size = 0

    # -- API --------------------------------------------------------------- #
    def write(self, record) -> None:
        """Encode ``record`` as one JSON line and append it."""
        self.write_line(json.dumps(record, sort_keys=True))

    def write_line(self, line: str) -> None:
        data = line + "\n"
        with self._lock:
            if self._current_size() + len(data) > self.max_bytes \
                    and self._current_size() > 0:
                self._rotate()
            with open(self.path, "a") as fh:
                fh.write(data)
            self._size = self._current_size() + len(data)

    def files(self) -> list:
        """Live file plus existing backups, newest first."""
        out = [self.path] if os.path.exists(self.path) else []
        for i in range(1, self.backups + 1):
            p = f"{self.path}.{i}"
            if os.path.exists(p):
                out.append(p)
        return out

"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel subpackage ships kernel.py (pl.pallas_call + BlockSpec),
ops.py (jit'd wrapper with a pure-jnp fallback), and ref.py (oracle).
Kernels target TPU and are validated in interpret mode on CPU; model code
takes a `use_pallas` flag (default off so the multi-pod dry-run lowers the
pure-jnp path).
"""

from .bm25_blockmax import bm25_blockmax_topk, bm25_topk_ref, pruned_fraction
from .embedding_bag import embedding_bag_padded, embedding_bag_ref, pad_ragged
from .gqa_decode import gqa_decode, gqa_decode_ref
from .interval_join import (contained_in_mask_ref, containing_mask_ref,
                            interval_join)

__all__ = [
    "bm25_blockmax_topk", "bm25_topk_ref", "pruned_fraction",
    "embedding_bag_padded", "embedding_bag_ref", "pad_ragged",
    "gqa_decode", "gqa_decode_ref",
    "contained_in_mask_ref", "containing_mask_ref", "interval_join",
]

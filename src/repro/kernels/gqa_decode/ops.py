"""Jit'd GQA decode attention with pallas/ref switch."""

import functools

import jax

from .kernel import gqa_decode_pallas
from .ref import gqa_decode_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret",
                                             "block_size"))
def gqa_decode(q, k, v, length, use_pallas: bool = True,
               interpret: bool = True, block_size: int = 512):
    """q [B, Hkv, G, D]; k/v [B, S, Hkv, D]; length [B] → [B, Hkv, G, D]."""
    if use_pallas:
        return gqa_decode_pallas(q, k, v, length, block_size=block_size,
                                 interpret=interpret)
    return gqa_decode_ref(q, k, v, length).astype(q.dtype)

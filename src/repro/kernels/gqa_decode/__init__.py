from .ops import gqa_decode
from .ref import gqa_decode_ref

__all__ = ["gqa_decode", "gqa_decode_ref"]

"""Flash-decoding GQA attention Pallas kernel.

Decode shape: one query token per sequence against a long KV cache — the
memory-bound regime of `decode_32k` / `long_500k`.  The kernel streams KV in
BS-sized tiles (grid innermost dim), maintaining the online-softmax running
max m, normalizer l, and accumulator in VMEM scratch; the G query heads
sharing one KV head are processed together so each KV tile is read once for
all of them (the GQA arithmetic-intensity win: G MACs per KV byte).

KV tiles beyond the valid `length` are skipped entirely with `@pl.when` —
the kernel's analogue of not launching work for unused cache (and on
hardware, of skipping the DMA).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, bs, scale):
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0, 0]
    base = j * bs

    @pl.when(base < length)
    def _():
        q = q_ref[0, 0]                    # [G, D]
        k = k_ref[0, :, 0, :]              # [BS, D]
        v = v_ref[0, :, 0, :]              # [BS, D]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [G, BS]
        pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_ref[...]                # [G, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)             # [G, BS]
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v.astype(jnp.float32), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nj - 1)
    def _():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


def gqa_decode_pallas(q, k, v, length, *, block_size: int = 512,
                      interpret: bool = True):
    """q [B, Hkv, G, D]; k/v [B, S, Hkv, D]; length [B] → [B, Hkv, G, D]."""
    b, hkv, g, d = q.shape
    s = k.shape[1]
    bs = min(block_size, s)
    n_blocks = -(-s // bs)
    s_pad = n_blocks * bs
    if s_pad != s:
        pad = ((0, 0), (0, s_pad - s), (0, 0), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    scale = 1.0 / (d ** 0.5)
    length2 = length.astype(jnp.int32).reshape(b, 1)

    kernel = functools.partial(_decode_kernel, bs=bs, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, hkv, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, h, j: (i, 0)),
            pl.BlockSpec((1, 1, g, d), lambda i, h, j: (i, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, d), lambda i, h, j: (i, j, h, 0)),
            pl.BlockSpec((1, bs, 1, d), lambda i, h, j: (i, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda i, h, j: (i, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(length2, q, k, v)

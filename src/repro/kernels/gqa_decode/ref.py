"""Pure-jnp oracle for GQA decode attention (one new token vs KV cache)."""

import jax.numpy as jnp


def gqa_decode_ref(q, k, v, length=None):
    """q [B, Hkv, G, D]; k/v [B, S, Hkv, D]; length [B] valid KV prefix.

    Returns [B, Hkv, G, D].
    """
    b, hkv, g, d = q.shape
    s = k.shape[1]
    scale = 1.0 / jnp.sqrt(d).astype(q.dtype)
    # scores [B, Hkv, G, S]
    scores = jnp.einsum("bhgd,bshd->bhgs", q, k) * scale
    if length is not None:
        pos = jnp.arange(s)[None, None, None, :]
        mask = pos < length[:, None, None, None]
        scores = jnp.where(mask, scores, -jnp.inf)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhgs,bshd->bhgd", p, v)

from .ops import bm25_blockmax_topk, pruned_fraction
from .ref import bm25_topk_ref

__all__ = ["bm25_blockmax_topk", "pruned_fraction", "bm25_topk_ref"]

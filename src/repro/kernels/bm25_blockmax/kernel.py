"""Block-Max BM25 Pallas TPU kernel (paper §2.2, Ding & Suel 2011 adapted).

TPU adaptation of Block-Max WAND (DESIGN §2): the CPU algorithm moves one
pivot pointer and skips compressed blocks; a TPU wants regular tiles.  The
doc space is cut into BS-doc blocks; per-(term, block) maxima live in a tiny
[T, NB] matrix.  A cheap pre-pass (ops.py) scores only the highest-UB blocks
to establish a top-k threshold θ; the kernel then sweeps all blocks and
*skips the scoring arithmetic* of any block whose upper bound Σ_t max_t is
≤ θ (`@pl.when`), writing -inf instead.  On hardware the same predicate
gates the HBM→VMEM DMA of the impact tile (manual async copy); functionally
both paths produce identical results, which is what this kernel validates.

The pruning is *conservative* (θ from a subset of true scores), so the
final top-k equals the exhaustive oracle exactly.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")


def _blockmax_kernel(theta_ref, bmax_ref, impacts_ref, o_ref):
    # theta [1,1]; bmax [T, 1] for this block; impacts [T, 1, BS]; out [1, BS]
    ub = jnp.sum(bmax_ref[...])
    theta = theta_ref[0, 0]

    # θ comes from a subset of true scores, so θ <= true kth-best; a block
    # at ub == θ may still hold a doc scoring exactly kth-best (the probe
    # pre-pass hits this whenever it scored the top block itself), so only
    # strictly-below blocks may be skipped.
    @pl.when(ub >= theta)
    def _():
        o_ref[...] = jnp.sum(impacts_ref[...], axis=0)

    @pl.when(ub < theta)
    def _():
        o_ref[...] = jnp.full_like(o_ref, NEG_INF)


def blockmax_scores_pallas(impacts, block_max, theta, *, interpret: bool = True):
    """impacts [T, NB, BS], block_max [T, NB], theta scalar → scores [NB, BS]
    with pruned blocks = -inf."""
    t, nb, bs = impacts.shape
    theta = jnp.asarray(theta, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _blockmax_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
            pl.BlockSpec((t, 1), lambda j: (0, j)),
            pl.BlockSpec((t, 1, bs), lambda j: (0, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, bs), jnp.float32),
        interpret=interpret,
    )(theta, block_max, impacts)

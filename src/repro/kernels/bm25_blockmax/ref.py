"""Pure-jnp oracle: exhaustive BM25 scoring over the block-impact layout."""

import jax
import jax.numpy as jnp


def bm25_score_ref(impacts):
    """impacts [T, NB, BS] → scores [NB * BS] (sum over terms, no pruning)."""
    return impacts.sum(axis=0).reshape(-1)


def bm25_topk_ref(impacts, k: int):
    scores = bm25_score_ref(impacts)
    return jax.lax.top_k(scores, k)

"""Jit'd Block-Max BM25 top-k: θ pre-pass + pruned kernel sweep + final top-k."""

import functools

import jax
import jax.numpy as jnp

from .kernel import blockmax_scores_pallas
from .ref import bm25_topk_ref


@functools.partial(jax.jit, static_argnames=("k", "use_pallas", "interpret",
                                             "probe_blocks"))
def bm25_blockmax_topk(impacts, block_max, k: int, use_pallas: bool = True,
                       interpret: bool = True, probe_blocks: int = None):
    """Top-k docs by BM25 with block-max pruning.

    impacts    [T, NB, BS] dense block-impact layout (0 where term absent)
    block_max  [T, NB]     per-(term, block) maxima
    Returns (scores [k], flat_doc_ids [k]); exact (pruning is conservative).
    """
    t, nb, bs = impacts.shape
    if not use_pallas:
        return bm25_topk_ref(impacts, k)

    # --- θ pre-pass: exactly score the highest-UB blocks ----------------- #
    probe = probe_blocks or max(1, min(nb, -(-k // bs) * 2))
    ub = block_max.sum(axis=0)                       # [NB]
    _, best_blocks = jax.lax.top_k(ub, probe)        # indices of probe blocks
    probe_imp = jnp.take(impacts, best_blocks, axis=1)   # [T, probe, BS]
    probe_scores = probe_imp.sum(axis=0).reshape(-1)     # [probe * BS]
    kth = jax.lax.top_k(probe_scores, min(k, probe * bs))[0][-1]
    theta = kth  # conservative: true kth-best is >= kth over a subset? No —
    # kth over a SUBSET is <= true kth-best, so pruning on it is safe.

    # --- pruned sweep ----------------------------------------------------- #
    scores = blockmax_scores_pallas(impacts, block_max, theta,
                                    interpret=interpret)  # [NB, BS]
    # pruned blocks carry -inf; clamp to the true score floor (impacts are
    # non-negative) so a top-k that spills past the last positive doc reads
    # 0 exactly like the exhaustive oracle
    scores = jnp.maximum(scores, 0.0)
    return jax.lax.top_k(scores.reshape(-1), k)


def pruned_fraction(block_max, theta) -> jnp.ndarray:
    """Diagnostic: fraction of blocks the kernel skips at threshold θ."""
    ub = block_max.sum(axis=0)
    return jnp.mean((ub < theta).astype(jnp.float32))

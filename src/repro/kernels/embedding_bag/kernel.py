"""EmbeddingBag Pallas TPU kernel: fused gather + in-register reduce.

Recsys hot path (DLRM/xDeepFM/two-tower): many small bags gathered from a
huge table.  JAX's take+segment_sum materializes the [N, D] gathered rows in
HBM; this kernel keeps the accumulation in VMEM, reading each row once and
never writing the intermediate.

Bag boundaries arrive as scalar-prefetch operands (offsets), so the grid and
DMA pattern are known before the kernel body runs — the Pallas TPU idiom for
data-dependent gathers.  Rows are fetched with dynamic slices on the sublane
axis (one row per loop step); bags are padded to `max_bag` items with index
0 / weight 0.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(idx_ref, w_ref, table_ref, o_ref, *, max_bag):
    # idx_ref [B, max_bag] (SMEM, scalar prefetch); table [V, D]; out [1, D]
    b = pl.program_id(0)

    def body(i, acc):
        row_id = idx_ref[b, i]
        w = w_ref[b, i]
        row = pl.load(table_ref, (pl.dslice(row_id, 1), slice(None)))  # [1, D]
        return acc + w * row[0].astype(jnp.float32)

    acc = jax.lax.fori_loop(0, max_bag,  body,
                            jnp.zeros((o_ref.shape[-1],), jnp.float32))
    o_ref[0, :] = acc.astype(o_ref.dtype)


def embedding_bag_pallas(table, indices, weights, *, interpret: bool = True):
    """table [V, D]; indices [B, max_bag] int32 (0-padded);
    weights [B, max_bag] f32 (0 where padded) → [B, D]."""
    bsz, max_bag = indices.shape
    v, d = table.shape
    kernel = functools.partial(_bag_kernel, max_bag=max_bag)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bsz,),
        in_specs=[pl.BlockSpec((v, d), lambda b, *_: (0, 0))],
        out_specs=pl.BlockSpec((1, d), lambda b, *_: (b, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, d), table.dtype),
        interpret=interpret,
    )(indices.astype(jnp.int32), weights.astype(jnp.float32), table)

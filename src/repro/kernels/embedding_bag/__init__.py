from .ops import embedding_bag_padded, pad_ragged
from .ref import embedding_bag_ref

__all__ = ["embedding_bag_padded", "pad_ragged", "embedding_bag_ref"]

"""Pure-jnp oracle: EmbeddingBag = gather + segment-sum (JAX has no native)."""

import jax
import jax.numpy as jnp


def embedding_bag_ref(table, indices, segment_ids, n_bags: int,
                      weights=None, combiner: str = "sum"):
    """table [V, D]; indices [N]; segment_ids [N] → [n_bags, D]."""
    rows = jnp.take(table, indices, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    out = jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)
    if combiner == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(indices, table.dtype),
                                  segment_ids, num_segments=n_bags)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out

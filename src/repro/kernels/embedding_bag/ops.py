"""Jit'd EmbeddingBag with pallas/ref switch and ragged→padded adapter."""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import embedding_bag_pallas
from .ref import embedding_bag_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def embedding_bag_padded(table, indices, weights, use_pallas: bool = False,
                         interpret: bool = True):
    """Padded-bag embedding lookup.

    table [V, D]; indices [B, L] (0-padded); weights [B, L] (0 on padding).
    The jnp path (default; used by the models and the dry-run) computes
    take + weighted sum; the Pallas path fuses gather and reduce.
    """
    if use_pallas:
        return embedding_bag_pallas(table, indices, weights,
                                    interpret=interpret)
    rows = jnp.take(table, indices, axis=0)           # [B, L, D]
    return jnp.einsum("bld,bl->bd", rows, weights.astype(table.dtype))


def pad_ragged(indices: np.ndarray, offsets: np.ndarray, max_bag: int):
    """Host adapter: CSR-style ragged bags → padded [B, max_bag] + weights."""
    b = len(offsets) - 1
    out = np.zeros((b, max_bag), dtype=np.int32)
    w = np.zeros((b, max_bag), dtype=np.float32)
    for i in range(b):
        lo, hi = offsets[i], min(offsets[i + 1], offsets[i] + max_bag)
        n = hi - lo
        out[i, :n] = indices[lo:hi]
        w[i, :n] = 1.0
    return out, w

from .ops import interval_join
from .ref import contained_in_mask_ref, containing_mask_ref

__all__ = ["interval_join", "contained_in_mask_ref", "containing_mask_ref"]

"""Containment-join Pallas TPU kernel.

TPU adaptation (DESIGN §2): the lazy engine's per-cursor galloping search is
pointer chasing — fast on a Xeon, serial on a TPU.  Binary search *could* be
vectorized, but data-dependent gathers are slow on the VPU.  Instead each
(A-tile × B-tile) pair is tested with a dense [TA, TB] comparison — pure
vector compares + reductions at ~arithmetic peak — and tiles of B whose
address range cannot overlap the A-tile are skipped via `@pl.when`
(block-level skipping: the same asymptotic win WAND gets from galloping,
at tile granularity).

Grid: (n_a_tiles, n_b_tiles), B innermost so the output tile accumulates in
place across B-tiles.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _join_kernel(a_s_ref, a_e_ref, b_s_ref, b_e_ref, o_ref, *, mode, pad):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    a_s = a_s_ref[...]          # [1, TA]
    a_e = a_e_ref[...]
    b_s = b_s_ref[...]          # [1, TB]
    b_e = b_e_ref[...]

    # tile-skip test: overlap of [min(a_s), max(a_e)] with [min(b_s), max(b_e)]
    a_valid = a_s != pad
    b_valid = b_s != pad
    a_lo = jnp.min(jnp.where(a_valid, a_s, pad))
    a_hi = jnp.max(jnp.where(a_valid, a_e, -pad))
    b_lo = jnp.min(jnp.where(b_valid, b_s, pad))
    b_hi = jnp.max(jnp.where(b_valid, b_e, -pad))
    # containment of a in b needs b_s <= a_s and a_e <= b_e: a B-tile is
    # relevant only if its span can bracket part of the A-tile span.
    relevant = (b_lo <= a_hi) & (b_hi >= a_lo)

    @pl.when(relevant)
    def _():
        if mode == "contained_in":
            cmp = (b_s[0][None, :] <= a_s[0][:, None]) & \
                  (a_e[0][:, None] <= b_e[0][None, :])
        else:  # containing
            cmp = (a_s[0][:, None] <= b_s[0][None, :]) & \
                  (b_e[0][None, :] <= a_e[0][:, None])
        cmp = cmp & b_valid[0][None, :] & a_valid[0][:, None]
        hit = jnp.any(cmp, axis=1).astype(jnp.int32)
        o_ref[...] = jnp.maximum(o_ref[...], hit[None, :])


def interval_join_pallas(a_s, a_e, b_s, b_e, *, mode: str = "contained_in",
                         tile_a: int = 256, tile_b: int = 256,
                         interpret: bool = True, pad: int = None):
    """Returns int32 mask[NA]: 1 where A[i] is contained in (contains) some B."""
    from repro.core.vectorized import PAD
    pad = int(PAD if pad is None else pad)
    na, nb = a_s.shape[0], b_s.shape[0]
    na_p = -(-na // tile_a) * tile_a
    nb_p = -(-nb // tile_b) * tile_b

    def padto(x, n):
        return jnp.pad(x, (0, n - x.shape[0]), constant_values=pad)[None, :]

    a_s2, a_e2 = padto(a_s, na_p), padto(a_e, na_p)
    b_s2, b_e2 = padto(b_s, nb_p), padto(b_e, nb_p)

    grid = (na_p // tile_a, nb_p // tile_b)
    out = pl.pallas_call(
        lambda *refs: _join_kernel(*refs, mode=mode, pad=pad),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_a), lambda i, j: (0, i)),
            pl.BlockSpec((1, tile_a), lambda i, j: (0, i)),
            pl.BlockSpec((1, tile_b), lambda i, j: (0, j)),
            pl.BlockSpec((1, tile_b), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, tile_a), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, na_p), jnp.int32),
        interpret=interpret,
    )(a_s2, a_e2, b_s2, b_e2)
    return out[0, :na]

"""Pure-jnp oracle for the containment-join kernel."""

import jax.numpy as jnp

from repro.core.vectorized import PAD


def contained_in_mask_ref(a_s, a_e, b_s, b_e):
    """mask[i] = A[i] ⊑ some B[j], via batched searchsorted (O(n log m))."""
    j = jnp.searchsorted(b_e, a_e, side="left")
    j = jnp.minimum(j, b_e.shape[0] - 1)
    ok = (b_e[j] >= a_e) & (b_s[j] <= a_s) & (b_s[j] != PAD)
    return (ok & (a_s != PAD)).astype(jnp.int32)


def containing_mask_ref(a_s, a_e, b_s, b_e):
    """mask[i] = A[i] ⊒ some B[j]."""
    j = jnp.searchsorted(b_s, a_s, side="left")
    j = jnp.minimum(j, b_s.shape[0] - 1)
    ok = (b_s[j] >= a_s) & (b_e[j] <= a_e) & (b_s[j] != PAD)
    return (ok & (a_s != PAD)).astype(jnp.int32)

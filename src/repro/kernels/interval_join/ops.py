"""Jit'd public wrapper for the containment-join kernel."""

import functools

import jax

from .kernel import interval_join_pallas
from .ref import contained_in_mask_ref, containing_mask_ref


@functools.partial(jax.jit, static_argnames=("mode", "use_pallas", "interpret"))
def interval_join(a_s, a_e, b_s, b_e, mode: str = "contained_in",
                  use_pallas: bool = True, interpret: bool = True):
    """Containment join over packed GC-lists; int32 mask over A."""
    if use_pallas:
        return interval_join_pallas(a_s, a_e, b_s, b_e, mode=mode,
                                    interpret=interpret)
    ref = contained_in_mask_ref if mode == "contained_in" else containing_mask_ref
    return ref(a_s, a_e, b_s, b_e)

"""Admission-controlled block cache for mmap-served static runs.

The cache sits between :class:`~repro.core.runfile.BlockRunReader` readers
and the mapped run files: keys are ``(device, inode, footer_crc, block)``
tuples, values are verified block payloads.  Capacity is in **bytes** and
the accounting is exact — every insert, evict, and pass-through is counted
under one lock, so the cache-invariant property tests can assert
``bytes == Σ len(entry)`` at any instant under concurrent readers.

Replacement is **segmented LRU** (probation + protected): a first hit
promotes an entry from probation to the protected segment (capped at
``protected_frac`` of capacity, overflow demotes back to probation MRU),
so one sequential scan cannot flush the hot working set.

Admission is **TinyLFU-style**: a count-min sketch of recent access
frequencies (4 rows, 8-bit counters, periodically halved so the window
ages) arbitrates every insert that would require an eviction — the
candidate must be *more* frequent than each victim it displaces, else the
candidate is rejected (``block_cache_admit_reject_total``) and the
resident blocks survive.  On skewed (Zipf) traces this beats plain LRU,
which is exactly the property test in ``tests/test_block_cache.py``.

**Pinning**: readers pin the blocks of an extent while assembling it and
bulk streams (compaction, run slicing) bypass admission entirely
(``admit=False``), so maintenance never thrashes serving.  Pinned entries
are never evicted and never demoted.

Capacity edge modes: ``capacity_bytes=0`` disables storage entirely (every
access is a pass-through miss); ``capacity_bytes=None`` is unbounded.
Read results are bit-identical across all three modes — the cache can only
ever change *where* a verified block payload comes from.
"""

from __future__ import annotations

import os
import threading
from collections import OrderedDict
from typing import Dict, Optional

import numpy as np

from repro import obs

DEFAULT_CAPACITY = 64 * 1024 * 1024


class _Entry:
    __slots__ = ("value", "nbytes", "pins", "protected")

    def __init__(self, value: bytes):
        self.value = value
        self.nbytes = len(value)
        self.pins = 0
        self.protected = False


class _FrequencySketch:
    """Count-min sketch with periodic aging (the TinyLFU frequency
    estimator): 4 salted rows of 8-bit counters, all halved every
    ``sample_period`` increments so stale popularity decays."""

    ROWS = 4
    CAP = 255

    def __init__(self, width: int = 8192, sample_period: int = 65536):
        self.width = width
        self.sample_period = sample_period
        self._rows = np.zeros((self.ROWS, width), dtype=np.uint8)
        self._ops = 0

    def _slots(self, key):
        h = hash(key)
        for r in range(self.ROWS):
            yield r, (h ^ (0x9E3779B9 * (r + 1))) % self.width

    def add(self, key) -> None:
        for r, i in self._slots(key):
            if self._rows[r, i] < self.CAP:
                self._rows[r, i] += 1
        self._ops += 1
        if self._ops >= self.sample_period:
            self._rows >>= 1            # age the window
            self._ops = 0

    def estimate(self, key) -> int:
        return min(int(self._rows[r, i]) for r, i in self._slots(key))


class BlockCache:
    """Byte-capacity segmented-LRU block cache with TinyLFU admission."""

    def __init__(self, capacity_bytes: Optional[int] = DEFAULT_CAPACITY,
                 protected_frac: float = 0.8,
                 sketch_width: int = 8192):
        if capacity_bytes is not None and capacity_bytes < 0:
            raise ValueError("capacity_bytes must be >= 0 or None")
        self.capacity = capacity_bytes
        self.protected_frac = protected_frac
        self._lock = threading.Lock()
        self._entries: Dict[object, _Entry] = {}
        self._probation: "OrderedDict[object, None]" = OrderedDict()
        self._protected: "OrderedDict[object, None]" = OrderedDict()
        self._bytes = 0
        self._protected_bytes = 0
        self._sketch = _FrequencySketch(width=sketch_width)
        # exact local tallies (obs counters mirror them when enabled)
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.admit_rejects = 0

    # -- metrics -------------------------------------------------------- #
    def _note(self, kind: str, n: int = 1) -> None:
        reg = obs.registry()
        if not reg.enabled:
            return
        if kind == "hit":
            reg.counter("block_cache_hit_total",
                        "block cache hits").inc(n)
        elif kind == "miss":
            reg.counter("block_cache_miss_total",
                        "block cache misses (loaded from mmap)").inc(n)
        elif kind == "evict":
            reg.counter("block_cache_evict_total",
                        "blocks evicted by the segmented LRU").inc(n)
        elif kind == "admit_reject":
            reg.counter("block_cache_admit_reject_total",
                        "inserts rejected by TinyLFU admission").inc(n)
        reg.gauge("block_cache_bytes",
                  "resident block cache bytes").set(self._bytes)

    # -- core ----------------------------------------------------------- #
    def get(self, key) -> Optional[bytes]:
        with self._lock:
            self._sketch.add(key)
            e = self._entries.get(key)
            if e is None:
                return None
            self.hits += 1
            self._touch(key, e)
            self._note("hit")
            return e.value

    def get_or_load(self, key, loader, admit: bool = True) -> bytes:
        """Return the cached payload for ``key``, loading (and, by
        default, inserting) it on a miss.  ``admit=False`` is the bulk
        streaming mode: the loaded value is returned but never stored and
        never competes with resident entries."""
        got = self.get(key)
        if got is not None:
            return got
        value = loader()
        with self._lock:
            self.misses += 1
            self._note("miss")
            if admit and self.capacity != 0:
                self._put_locked(key, value)
            e = self._entries.get(key)
            return e.value if e is not None else value

    def _touch(self, key, e: _Entry) -> None:
        """Segmented-LRU hit path: probation -> protected promotion."""
        if e.protected:
            self._protected.move_to_end(key)
            return
        del self._probation[key]
        e.protected = True
        self._protected[key] = None
        self._protected_bytes += e.nbytes
        cap = self._protected_cap()
        if cap is None:
            return
        # overflow demotes the protected LRU back to probation MRU
        while self._protected_bytes > cap:
            victim = self._first_unpinned(self._protected)
            if victim is None or victim == key:
                break
            ve = self._entries[victim]
            del self._protected[victim]
            ve.protected = False
            self._probation[victim] = None
            self._protected_bytes -= ve.nbytes

    def _protected_cap(self) -> Optional[int]:
        if self.capacity is None:
            return None
        return int(self.capacity * self.protected_frac)

    def _first_unpinned(self, seg: "OrderedDict[object, None]"):
        for key in seg:                  # LRU -> MRU
            if self._entries[key].pins == 0:
                return key
        return None

    def _put_locked(self, key, value: bytes) -> None:
        if key in self._entries:
            return                       # raced with another loader
        nbytes = len(value)
        if self.capacity is not None:
            if nbytes > self.capacity:
                self.admit_rejects += 1
                self._note("admit_reject")
                return
            cand_freq = self._sketch.estimate(key)
            while self._bytes + nbytes > self.capacity:
                victim = self._first_unpinned(self._probation)
                if victim is None:
                    victim = self._first_unpinned(self._protected)
                if victim is None:       # everything resident is pinned
                    self.admit_rejects += 1
                    self._note("admit_reject")
                    return
                # TinyLFU: the newcomer must beat every block it displaces
                if self._sketch.estimate(victim) >= cand_freq:
                    self.admit_rejects += 1
                    self._note("admit_reject")
                    return
                self._evict_locked(victim)
        e = _Entry(value)
        self._entries[key] = e
        self._probation[key] = None
        self._bytes += nbytes

    def _evict_locked(self, key) -> None:
        e = self._entries.pop(key)
        if e.protected:
            del self._protected[key]
            self._protected_bytes -= e.nbytes
        else:
            del self._probation[key]
        self._bytes -= e.nbytes
        self.evictions += 1
        self._note("evict")

    # -- pinning -------------------------------------------------------- #
    def pin(self, key) -> None:
        with self._lock:
            e = self._entries.get(key)
            if e is not None:
                e.pins += 1

    def unpin(self, key) -> None:
        with self._lock:
            e = self._entries.get(key)
            if e is not None and e.pins > 0:
                e.pins -= 1

    # -- introspection --------------------------------------------------- #
    def invalidate(self) -> None:
        """Drop every unpinned entry (tests; capacity reconfiguration)."""
        with self._lock:
            for key in [k for k, e in self._entries.items() if e.pins == 0]:
                e = self._entries.pop(key)
                (self._protected if e.protected
                 else self._probation).pop(key, None)
                self._bytes -= e.nbytes
                if e.protected:
                    self._protected_bytes -= e.nbytes

    @property
    def bytes(self) -> int:
        with self._lock:
            return self._bytes

    def stats(self) -> dict:
        """The ``/tiered/cache`` admin document."""
        with self._lock:
            lookups = self.hits + self.misses
            return {
                "capacity_bytes": self.capacity,
                "bytes": self._bytes,
                "protected_bytes": self._protected_bytes,
                "entries": len(self._entries),
                "pinned": sum(1 for e in self._entries.values() if e.pins),
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "admit_rejects": self.admit_rejects,
                "hit_rate": (self.hits / lookups) if lookups else 0.0,
            }

    def check_accounting(self) -> None:
        """Assert the exact-bytes invariant (property-test hook)."""
        with self._lock:
            total = sum(e.nbytes for e in self._entries.values())
            prot = sum(e.nbytes for e in self._entries.values()
                       if e.protected)
            assert total == self._bytes, (total, self._bytes)
            assert prot == self._protected_bytes, (prot,
                                                   self._protected_bytes)
            assert set(self._entries) == (set(self._probation)
                                          | set(self._protected))
            assert not (set(self._probation) & set(self._protected))


# --------------------------------------------------------------------- #
_default_lock = threading.Lock()
_default: Optional[BlockCache] = None


def default_block_cache() -> BlockCache:
    """The process-wide cache every TieredStore/StaticWarren shares unless
    given its own; capacity from ``REPRO_BLOCK_CACHE_BYTES`` (default
    64 MiB)."""
    global _default
    with _default_lock:
        if _default is None:
            cap = int(os.environ.get("REPRO_BLOCK_CACHE_BYTES",
                                     DEFAULT_CAPACITY))
            _default = BlockCache(capacity_bytes=cap)
        return _default


def set_default_block_cache(cache: Optional[BlockCache]) -> None:
    global _default
    with _default_lock:
        _default = cache

"""Tiered storage engine: hot dynamic memtable + immutable static runs.

The paper's index is two-faced — a fully dynamic ACID index and an
immutable on-disk static layout — and this module connects them LSM-style:

  writes  →  hot tier: one :class:`~repro.core.index.DynamicIndex` with a
             WAL (``wal.log``) and size-tiered segment auto-merge
  freeze  →  committed hot segments become an immutable *run* directory
             (``static.write_run``), published by a new manifest version,
             and only then detached from the hot tier
  merge   →  overlapping runs fold into one (``static.merge_runs``),
             GC'ing erased records
  reads   →  a :class:`TieredSnapshot` pins a (runs, hot-snapshot) pair;
             per-feature views k-way merge run lists + the hot list in
             sequence order and filter by the union of every tier's
             tombstones — exactly the single-index ``Snapshot`` semantics

The only stop-the-world window is the view swap (a tuple assignment plus
``detach_segments``), measured and reported as compaction pause time.
Crash safety: the run is durable and the manifest swapped *before* the hot
tier forgets the segments, and the WAL is compacted only after that — every
crash point recovers to the latest-good manifest plus the WAL's committed
transactions, with already-frozen segments deduplicated at open.
"""

from __future__ import annotations

import os
import threading
import time
from typing import Dict, List, Optional, Tuple

from repro import obs
from repro.core.annotation import AnnotationList, merge_lists, union_intervals
from repro.core.faults import fault_point
from repro.core.featurizer import Featurizer, JsonFeaturizer
from repro.core.gcl import GCLNode, Phrase, Term
from repro.core.index import (DynamicIndex, Segment, Snapshot, Transaction,
                              _filter_erased, erased_overlaps, tokens_sources,
                              translate_sources)
from repro.core.static import (StaticIndex, merge_runs, run_bytes, slice_run,
                               write_carrier_run, write_run)
from repro.core.tokenizer import Tokenizer, Utf8Tokenizer

from .cache import BlockCache, default_block_cache
from .compaction import CompactionMetrics, LeveledPolicy
from .manifest import Manifest, ManifestStore, RunInfo


class StaticRun:
    """One immutable on-disk run: a StaticIndex plus its manifest record."""

    def __init__(self, index: StaticIndex, info: RunInfo, directory: str):
        self.index = index
        self.info = info
        self.directory = directory

    @staticmethod
    def open(directory: str, info: RunInfo,
             tokenizer: Optional[Tokenizer] = None,
             featurizer: Optional[Featurizer] = None,
             block_cache: Optional[BlockCache] = None) -> "StaticRun":
        return StaticRun(StaticIndex(directory, tokenizer, featurizer,
                                     block_cache=block_cache),
                         info, directory)

    def annotations(self, fval: int) -> AnnotationList:
        return self.index.annotations(fval)

    @property
    def erased(self) -> AnnotationList:
        return self.index.erased

    @property
    def content(self):
        return self.index.content

    def close(self) -> None:
        self.index.close()


def replace_info_nbytes(run: StaticRun) -> RunInfo:
    """A run's info with ``nbytes`` measured from disk — fills the size in
    for runs recorded by pre-leveling manifests (legacy ``nbytes=0``)."""
    from dataclasses import replace
    return replace(run.info, nbytes=run_bytes(run.directory))


def _sort_runs(runs) -> Tuple[StaticRun, ...]:
    """Recency order for the k-way merge: deepest level first (oldest
    data), then ascending sequence within a level, hot tier last — so on
    exact interval ties the newest write wins, exactly the single-index
    semantics, even when leveled compaction leaves interleaved levels."""
    return tuple(sorted(runs, key=lambda r: (-r.info.level, r.info.seq_lo,
                                             r.info.run_id)))


class TieredSnapshot:
    """A consistent read view over N runs + (optionally) a hot snapshot.

    Merge semantics match the single-index :class:`Snapshot` exactly: lists
    are merged in sequence order (runs deepest-level-first then ascending
    sequence — see :func:`_sort_runs` — hot last, so on exact interval ties
    the newest write wins) and filtered by the coalescing union of every
    tier's erased intervals, so tombstones in any tier hide annotations and
    content in every other tier.
    """

    def __init__(self, runs: Tuple[StaticRun, ...], hot: Optional[Snapshot]):
        self.runs = runs
        self.hot = hot
        pieces = [r.erased for r in runs]
        if hot is not None:
            pieces.append(hot.erased)
        self.erased = union_intervals(pieces)
        self._cache: Dict[int, AnnotationList] = {}
        self._cache_lock = threading.Lock()

    def max_seqnum(self) -> int:
        seq = max((r.info.seq_hi for r in self.runs), default=-1)
        if self.hot is not None:
            seq = max(seq, max((s.seqnum for s in self.hot.segments),
                               default=-1))
        return seq

    # -- Idx ------------------------------------------------------------ #
    def annotations(self, fval: int) -> AnnotationList:
        with self._cache_lock:
            got = self._cache.get(fval)
        if got is not None:
            return got
        pieces = [r.annotations(fval) for r in self.runs]
        if self.hot is not None:
            pieces.append(self.hot.annotations(fval))
        merged = _filter_erased(merge_lists(pieces), self.erased)
        with self._cache_lock:
            self._cache[fval] = merged
        return merged

    def hopper(self, fval: int) -> Term:
        return Term(self.annotations(fval))

    # -- Txt ------------------------------------------------------------ #
    def _content_sources(self):
        """Non-empty content stores of every tier, in address order."""
        out = [r.content for r in self.runs if r.content.records()]
        if self.hot is not None:
            out.extend(s.content for s in self.hot.segments
                       if s.content.records())
        out.sort(key=lambda c: c.span()[0])
        return out

    def translate(self, p: int, q: int) -> Optional[str]:
        if erased_overlaps(self.erased, p, q):
            return None
        return translate_sources(self._content_sources(), p, q)

    def tokens(self, p: int, q: int) -> Optional[List[str]]:
        if erased_overlaps(self.erased, p, q):
            return None
        return tokens_sources(self._content_sources(), p, q)


# --------------------------------------------------------------------- #
class TieredStore:
    """The tiered engine: hot DynamicIndex + runs + manifest + WAL.

    Directory layout::

        <root>/wal.log              hot-tier transaction log
        <root>/runs/run_<id>/       immutable static runs
        <root>/MANIFEST-<v>.json    versioned manifests (latest-good wins)
    """

    def __init__(self, directory: str,
                 tokenizer: Optional[Tokenizer] = None,
                 featurizer: Optional[Featurizer] = None,
                 auto_merge_threshold: Optional[int] = 8,
                 durable: bool = True,
                 block_cache: Optional[BlockCache] = None):
        self.directory = directory
        self.tokenizer = tokenizer or Utf8Tokenizer()
        self.featurizer = featurizer or JsonFeaturizer()
        self.block_cache = (block_cache if block_cache is not None
                            else default_block_cache())
        os.makedirs(directory, exist_ok=True)
        self.manifests = ManifestStore(directory)
        m = self.manifests.load_latest_good()
        if m is None:
            m = Manifest.initial()
        self.manifests.gc(m)        # torn runs from a crash never resurface
        self._manifest = m
        self._runs: Tuple[StaticRun, ...] = _sort_runs(
            StaticRun.open(self.manifests.run_path(i.name), i,
                           self.tokenizer, self.featurizer,
                           block_cache=self.block_cache)
            for i in m.runs)
        wal = os.path.join(directory, "wal.log") if durable else None
        if wal is not None and os.path.exists(wal):
            hot = DynamicIndex.recover(wal, self.tokenizer, self.featurizer)
        else:
            hot = DynamicIndex(self.tokenizer, self.featurizer, log_path=wal)
        hot.auto_merge_threshold = auto_merge_threshold
        # idempotent crash recovery: a crash after manifest publish but
        # before WAL compaction leaves frozen segments in the WAL too —
        # the manifest wins, the WAL copies are dropped
        if m.frozen_upto >= 0 and hot.detach_segments(m.frozen_upto):
            hot.compact_log()
        with hot._addr_lock:
            hot._next_addr = max(hot._next_addr, m.next_addr)
            hot._next_seq = max(hot._next_seq, m.next_seq)
        self.hot = hot
        self._view_lock = threading.Lock()
        # contention-profiled (lock_wait_ms{lock="tiered_maint"}): freeze
        # vs compact vs demote racing is exactly what /metrics should show
        self._maint_lock = obs.ProfiledLock("tiered_maint",
                                            threading.RLock())
        self.metrics = CompactionMetrics()

    # -- views ------------------------------------------------------------ #
    @property
    def manifest(self) -> Manifest:
        return self._manifest

    @property
    def n_runs(self) -> int:
        return len(self._runs)

    def snapshot(self) -> TieredSnapshot:
        with self._view_lock:
            return TieredSnapshot(self._runs, self.hot.snapshot())

    def warren(self) -> "TieredWarren":
        return TieredWarren(self)

    def runs_info(self) -> dict:
        """The static tier as the admin server's ``/tiered/runs`` serves
        it: manifest position plus one record per live run."""
        with self._view_lock:
            m, runs = self._manifest, self._runs
        levels: Dict[int, int] = {}
        for r in runs:
            levels[r.info.level] = levels.get(r.info.level, 0) + 1
        return {
            "manifest": {"version": m.version,
                         "frozen_upto": m.frozen_upto},
            "n_runs": len(runs),
            "levels": {str(k): v for k, v in sorted(levels.items())},
            "cache": self.block_cache.stats(),
            "runs": [{
                "run_id": r.info.run_id, "name": r.info.name,
                "directory": r.directory,
                "level": r.info.level, "nbytes": r.info.nbytes,
                "seq_lo": r.info.seq_lo, "seq_hi": r.info.seq_hi,
                "addr_lo": r.info.addr_lo, "addr_hi": r.info.addr_hi,
                "n_records": r.info.n_records,
                "n_features": r.info.n_features,
            } for r in runs],
        }

    # -- freeze: hot tier -> new run -------------------------------------- #
    def freeze(self) -> Optional[RunInfo]:
        """Fold every committed hot segment into a new immutable run.

        Readers are never blocked: the run is written and the manifest
        published while the hot tier keeps serving; the swap (run in, hot
        segments out) is a single short critical section against
        ``snapshot()``.  Returns the new run's info, or None when the hot
        tier had nothing committed.
        """
        with self._maint_lock, obs.span("tiered.freeze"):
            hot = self.hot
            hot.merge_segments()       # size-tiered auto-merge, freeze path
            s = hot.max_committed_seq()
            # never advance frozen_upto past a readied-but-uncommitted
            # transaction: its seqnum is below later commits, and a reopen
            # would otherwise discard its recovered segment as "already
            # frozen".  Seqnums are allocated monotonically at ready(), so
            # no new pending transaction can appear at or below ``s``.
            with hot._durable_lock:
                pending_min = min(hot._pending, default=None)
            if pending_min is not None:
                s = min(s, pending_min - 1)
            if s < 0:
                return None
            hot.set_merge_fence(s)     # stabilize the frozen set
            try:
                with hot._publish_lock:
                    segs = tuple(x for x in hot._segments if x.seqnum <= s)
                if not segs:
                    return None
                m = self._manifest
                name = f"run_{m.next_run_id:08d}"
                meta = write_run(segs, self.manifests.run_path(name))
                info = RunInfo.from_meta(m.next_run_id, name, meta)
                with hot._addr_lock:
                    next_addr, next_seq = hot._next_addr, hot._next_seq
                new_m = m.successor(frozen_upto=max(m.frozen_upto, s),
                                    next_run_id=m.next_run_id + 1,
                                    next_addr=next_addr, next_seq=next_seq,
                                    runs=list(m.runs) + [info])
                self.manifests.publish(new_m)   # durable BEFORE hot mutates
                run = StaticRun.open(self.manifests.run_path(name), info,
                                     self.tokenizer, self.featurizer,
                                     block_cache=self.block_cache)
                t0 = time.perf_counter()
                with self._view_lock:
                    self._runs = _sort_runs(self._runs + (run,))
                    hot.detach_segments(s)
                self.metrics.note_freeze(time.perf_counter() - t0)
                self._manifest = new_m
                self._gauge_runs()
            finally:
                hot.set_merge_fence(-1)
            hot.compact_log()          # WAL forgets the frozen segments
            return info

    # -- merge: N runs -> 1 (full, bottom-level) -------------------------- #
    def compact_runs(self, min_runs: int = 2) -> Optional[RunInfo]:
        """Merge every live run into one bottom-level run, GC'ing erased
        records.  No-op below ``min_runs``.  The drain/final-compaction
        path; steady-state maintenance uses :meth:`compact_level`.  Pinned
        snapshots keep serving the victim runs (postings and content reach
        the unlinked file through its still-open mmap)."""
        with self._maint_lock, obs.span("tiered.merge"):
            victims = self._runs
            if len(victims) < max(2, min_runs):
                return None
            out_level = max(v.info.level for v in victims)
            ordered = sorted(victims,
                             key=lambda r: (-r.info.level, r.info.seq_lo,
                                            r.info.run_id))
            m = self._manifest
            name = f"run_{m.next_run_id:08d}"
            meta = merge_runs([v.directory for v in ordered],
                              self.manifests.run_path(name))
            info = RunInfo.from_meta(m.next_run_id, name, meta,
                                     level=out_level)
            new_m = m.successor(next_run_id=m.next_run_id + 1,
                                runs=[info])
            self.manifests.publish(new_m)
            run = StaticRun.open(self.manifests.run_path(name), info,
                                 self.tokenizer, self.featurizer,
                                 block_cache=self.block_cache)
            t0 = time.perf_counter()
            with self._view_lock:
                self._runs = (run,)
            self.metrics.note_merge(time.perf_counter() - t0)
            self._manifest = new_m
            # victims are dropped, not closed: snapshots pinning them keep
            # serving, and each run's fd closes when its last reference
            # dies (StaticIndex.__del__)
            self.manifests.gc(new_m)
            self._gauge_runs()
            return info

    # -- leveled, overlap-aware compaction -------------------------------- #
    def compact_level(self, policy: Optional[LeveledPolicy] = None
                      ) -> Optional[RunInfo]:
        """One leveled compaction step (see :class:`LeveledPolicy`): fold
        the picked victims into one run at the output level.  Erased
        records are GC'd only when the output lands on the bottom level
        (no surviving run is deeper); upper-level merges keep them so the
        reclaim happens once, at the bottom.  Returns the new run's info,
        or None when no level is over target."""
        policy = policy or LeveledPolicy()
        with self._maint_lock, obs.span("tiered.compact_level"):
            runs = self._runs
            infos = [r.info if r.info.nbytes
                     else replace_info_nbytes(r) for r in runs]
            picked = policy.pick(infos)
            if picked is None:
                return None
            victims_info, out_level = picked
            victim_ids = {i.run_id for i in victims_info}
            vmap = {r.info.run_id: r for r in runs}
            victims = [vmap[i.run_id] for i in victims_info]
            survivors = [i for i in self._manifest.runs
                         if i.run_id not in victim_ids]
            gc = not any(i.level > out_level for i in survivors)
            m = self._manifest
            name = f"run_{m.next_run_id:08d}"
            meta = merge_runs([v.directory for v in victims],
                              self.manifests.run_path(name), gc_records=gc)
            info = RunInfo.from_meta(m.next_run_id, name, meta,
                                     level=out_level)
            new_m = m.successor(next_run_id=m.next_run_id + 1,
                                runs=survivors + [info])
            self.manifests.publish(new_m)
            run = StaticRun.open(self.manifests.run_path(name), info,
                                 self.tokenizer, self.featurizer,
                                 block_cache=self.block_cache)
            t0 = time.perf_counter()
            with self._view_lock:
                self._runs = _sort_runs(
                    tuple(r for r in self._runs
                          if r.info.run_id not in victim_ids) + (run,))
            self.metrics.note_merge(time.perf_counter() - t0)
            self._manifest = new_m
            self.manifests.gc(new_m)
            self._gauge_runs()
            return info

    def _gauge_runs(self) -> None:
        """Publish the static tier's size after a run-set swap."""
        reg = obs.registry()
        if not reg.enabled:
            return
        with self._view_lock:
            runs = self._runs
        total = 0
        for r in runs:
            try:
                for fn in os.listdir(r.directory):
                    total += os.path.getsize(os.path.join(r.directory, fn))
            except OSError:
                pass
        reg.gauge("tiered_runs", "live static runs").set(len(runs))
        reg.gauge("tiered_run_bytes",
                  "on-disk bytes across live static runs").set(total)
        by_level: Dict[int, int] = {}
        for r in runs:
            by_level[r.info.level] = by_level.get(r.info.level, 0) + 1
        for level, n in by_level.items():
            reg.gauge("tiered_level_runs",
                      "live static runs per compaction level",
                      level=str(level)).set(n)

    def close(self) -> None:
        for run in self._runs:
            try:
                run.close()
            except OSError:
                pass
        self.hot._log.close()


# --------------------------------------------------------------------- #
class _SnapshotReads:
    """The shared Warren read surface: ``start()`` (subclass-provided) pins
    a :class:`TieredSnapshot` in ``self._snapshot`` and every read
    delegates to it, so TieredWarren and StaticWarren cannot diverge."""

    _snapshot: Optional[TieredSnapshot] = None

    def end(self) -> None:
        self._snapshot = None

    def __enter__(self):
        self.start()
        return self

    def __exit__(self, *exc) -> bool:
        self.end()
        return False

    def featurize(self, feature: str) -> int:
        return self.featurizer.featurize(feature)

    def annotations(self, feature) -> AnnotationList:
        self._require_started()
        fval = feature if isinstance(feature, int) else self.featurize(feature)
        return self._snapshot.annotations(fval)

    def hopper(self, feature) -> Term:
        self._require_started()
        fval = feature if isinstance(feature, int) else self.featurize(feature)
        return self._snapshot.hopper(fval)

    def translate(self, p: int, q: int) -> Optional[str]:
        self._require_started()
        return self._snapshot.translate(p, q)

    def tokens(self, p: int, q: int) -> Optional[List[str]]:
        self._require_started()
        return self._snapshot.tokens(p, q)

    def phrase(self, text: str) -> GCLNode:
        self._require_started()
        words = self.tokenizer.split(text)
        terms = [self.hopper(w) for w in words]
        if not terms:
            return Term(AnnotationList.empty())
        return terms[0] if len(terms) == 1 else Phrase(terms)

    def _require_started(self) -> None:
        if self._snapshot is None:
            raise RuntimeError("warren access outside start()/end()")


class TieredWarren(_SnapshotReads):
    """The exact Warren surface over a TieredStore (paper Fig. 3 lifecycle:
    clone/start/end/transaction/ready/commit/abort + Idx/Txt reads), with
    reads k-way merged across the hot tier and every static run."""

    def __init__(self, store: TieredStore):
        self.store = store
        self._snapshot = None
        self._txn: Optional[Transaction] = None

    @property
    def index(self) -> DynamicIndex:
        return self.store.hot

    @property
    def tokenizer(self) -> Tokenizer:
        return self.store.tokenizer

    @property
    def featurizer(self) -> Featurizer:
        return self.store.featurizer

    # -- lifecycle ------------------------------------------------------ #
    def clone(self) -> "TieredWarren":
        return TieredWarren(self.store)

    def start(self) -> None:
        if self._snapshot is not None:
            raise RuntimeError("already started")
        self._snapshot = self.store.snapshot()

    def __exit__(self, *exc) -> bool:
        if self._txn is not None and self._txn._state in ("open", "ready"):
            self._txn.abort()
            self._txn = None
        self.end()
        return False

    # -- transactions (hot tier) ---------------------------------------- #
    def transaction(self) -> None:
        self._require_started()
        if self._txn is not None:
            raise RuntimeError("transaction already active on this warren")
        self._txn = self.store.hot.transaction()

    def append(self, text: str) -> Tuple[int, int]:
        return self._require_txn().append(text)

    def annotate(self, feature, p: int, q: int, v: float = 0.0,
                 v_is_address: bool = False) -> None:
        self._require_txn().annotate(feature, p, q, v,
                                     v_is_address=v_is_address)

    def erase(self, p: int, q: int) -> None:
        self._require_txn().erase(p, q)

    def ready(self) -> None:
        self._require_txn().ready()

    def commit(self):
        txn = self._require_txn()
        txn.commit()
        self._txn = None
        return txn.remap

    def abort(self) -> None:
        self._require_txn().abort()
        self._txn = None

    def _require_txn(self) -> Transaction:
        if self._txn is None:
            raise RuntimeError("no active transaction")
        return self._txn


# --------------------------------------------------------------------- #
# Cold demotion: a whole DynamicIndex <-> a static run set + manifest.
# --------------------------------------------------------------------- #
def demote_index(index: DynamicIndex, directory: str) -> Manifest:
    """Freeze an entire DynamicIndex into a static run set + manifest
    (the cold form of a ShardedWarren replica group).  Safe to re-demote
    into the same directory: versions increase, old runs are GC'd.

    Emits ``tiered_demote_total`` and a ``tiered.demote`` span — the
    demotion half of the lifecycle signal pair the autopilot's cold
    policy acts through (``tiered_promote_total`` is the other half)."""
    reg = obs.registry()
    if reg.enabled:
        reg.counter("tiered_demote_total",
                    "groups frozen to static run sets").inc()
    with obs.span("tiered.demote", directory=directory):
        return _demote_index(index, directory)


def _demote_index(index: DynamicIndex, directory: str) -> Manifest:
    ms = ManifestStore(directory)
    prev = ms.load_latest_good() or Manifest.initial()
    with index._durable_lock:
        if index._pending:
            raise RuntimeError(
                "demote_index with in-flight (readied) transactions — "
                "commit or abort them first")
    with index._publish_lock:
        segs = index._segments
    with index._addr_lock:
        next_addr, next_seq = index._next_addr, index._next_seq
    runs: List[RunInfo] = []
    next_run_id = prev.next_run_id
    if segs:
        name = f"run_{next_run_id:08d}"
        meta = write_run(segs, ms.run_path(name))
        runs.append(RunInfo.from_meta(next_run_id, name, meta))
        next_run_id += 1
    m = prev.successor(frozen_upto=max(prev.frozen_upto, next_seq - 1),
                       next_run_id=next_run_id,
                       next_addr=next_addr, next_seq=next_seq, runs=runs)
    ms.publish(m)
    ms.gc(m)
    return m


def resurrect_index(directory: str, tokenizer: Optional[Tokenizer] = None,
                    featurizer: Optional[Featurizer] = None,
                    n: int = 1) -> List[DynamicIndex]:
    """Rebuild ``n`` lockstep DynamicIndex replicas from a demoted run set,
    streaming each run back through the durable ``Segment.to_record`` form
    so every replica owns its state.  Emits ``tiered_promote_total`` —
    the promotion half of the demotion lifecycle signal pair."""
    reg = obs.registry()
    if reg.enabled:
        reg.counter("tiered_promote_total",
                    "groups rebuilt hot from static run sets").inc()
    ms = ManifestStore(directory)
    m = ms.load_latest_good()
    if m is None:
        raise FileNotFoundError(f"no manifest in {directory}")
    records = []
    # deepest level first, then ascending sequence — recency order, so
    # resurrected segments keep last-wins semantics on exact ties
    for info in sorted(m.runs, key=lambda i: (-i.level, i.seq_lo, i.run_id)):
        si = StaticIndex(ms.run_path(info.name), tokenizer, featurizer)
        records.append(si.to_segment().to_record())
        si.close()
    out = []
    for _ in range(max(1, n)):
        idx = DynamicIndex(tokenizer, featurizer, log_path=None)
        idx._segments = tuple(Segment.from_record(r) for r in records)
        idx._version = 1
        idx._next_addr = m.next_addr
        idx._next_seq = m.next_seq
        out.append(idx)
    return out


def merge_demoted(dst_dir: str, src_dir: str) -> Manifest:
    """Ship one demoted group's runs into another by *manifest*: copy the
    source's immutable run directories file-level into the destination's
    run set (fresh run ids, no record decoding) and publish a successor
    manifest covering both — the cold half of live shard rebalancing.

    Crash safety follows the manifest invariants: runs are copied before
    the successor is published, so a crash mid-copy leaves orphan run
    directories that the next open garbage-collects, and the destination
    keeps recovering to its previous latest-good manifest.  The source
    directory is left untouched (the caller retires the group and may
    delete it once nothing pins its manifest).  Sequence ranges of the two
    groups may overlap; that is safe — their address ranges are disjoint,
    so exact-interval conflicts between the run sets are impossible, and
    allocation floors take the pairwise max.
    """
    import shutil
    from dataclasses import replace as _replace

    dms, sms = ManifestStore(dst_dir), ManifestStore(src_dir)
    dm = dms.load_latest_good()
    sm = sms.load_latest_good()
    if dm is None or sm is None:
        raise FileNotFoundError("merge_demoted needs a manifest on both "
                                f"sides ({dst_dir!r}, {src_dir!r})")
    runs = list(dm.runs)
    next_id = dm.next_run_id
    # idempotent retry: a crashed earlier attempt may have already
    # published some of the source's runs into the destination manifest
    already = {(r.seq_lo, r.seq_hi, r.addr_lo, r.addr_hi, r.n_records,
                r.n_features) for r in dm.runs}
    for info in sm.runs:
        if (info.seq_lo, info.seq_hi, info.addr_lo, info.addr_hi,
                info.n_records, info.n_features) in already:
            continue
        name = f"run_{next_id:08d}"
        target = dms.run_path(name)
        if os.path.exists(target):
            # orphan from a crashed earlier attempt (copied but never
            # published, so next_run_id never advanced): replace it, don't
            # collide — retries must succeed without manual cleanup
            shutil.rmtree(target)
        shutil.copytree(sms.run_path(info.name), target)
        runs.append(_replace(info, run_id=next_id, name=name))
        next_id += 1
    new = dm.successor(frozen_upto=max(dm.frozen_upto, sm.frozen_upto),
                       next_run_id=next_id,
                       next_addr=max(dm.next_addr, sm.next_addr),
                       next_seq=max(dm.next_seq, sm.next_seq),
                       runs=runs)
    dms.publish(new)
    dms.gc(new)     # any remaining orphans from crashed attempts
    return new


_SPLIT_CEILING = 1 << 62     # default upper fence for the moved window


def split_demoted(src_dir: str, keep_dir: str, moved_dir: str,
                  lo: int, hi: int = _SPLIT_CEILING,
                  keep_next_addr: Optional[int] = None,
                  moved_next_addr: Optional[int] = None,
                  tokenizer: Optional[Tokenizer] = None,
                  featurizer: Optional[Featurizer] = None
                  ) -> Tuple[Manifest, Manifest]:
    """Split one demoted run set at the address window ``[lo, hi)`` into
    two fresh run sets — **without promoting or decoding** the cold group.
    ``moved_dir`` receives the window; ``keep_dir`` its complement (a
    group may own several address ranges, so the keep side is not
    contiguous).

    Every run wholly on one side is copied file-level; a run straddling
    the window is cut by :func:`~repro.core.static.slice_run` (postings
    masked by start address, content shipped as raw footer-index extents,
    no decompression).  Both sides receive the source's *full* tombstone
    union — a tombstone recorded in a keep-side run may cover moved-side
    addresses and vice versa — via the sliced runs' erased override plus
    an erased-carrier run for any side that only got whole-run copies.

    Crash safety: the source directory is never touched; both sides are
    built fresh and published (keep side first); ``split.shipped`` fires
    after both are durable.  A crash mid-build leaves the source
    latest-good and partial side directories for the caller to discard.
    Allocation floors: each side's manifest records the floor the caller
    assigns (``*_next_addr``, default the source's own floor — safe,
    allocation is monotone, but the routing layer should hand the side
    that lost the cursor a fresh stripe base).
    """
    from dataclasses import replace as _replace

    sms = ManifestStore(src_dir)
    sm = sms.load_latest_good()
    if sm is None:
        raise FileNotFoundError(f"no manifest in {src_dir}")
    erased_pieces = []
    for info in sm.runs:
        si = StaticIndex(sms.run_path(info.name), tokenizer, featurizer)
        erased_pieces.append(si.erased)
        si.close()
    erased = union_intervals(erased_pieces)

    def build_side(directory: str, moved_side: bool,
                   next_addr: int) -> Manifest:
        import shutil
        ms = ManifestStore(directory)
        runs: List[RunInfo] = []
        next_id = 0
        carried_erased = False
        for info in sm.runs:
            inside = lo <= info.addr_lo and info.addr_hi < hi
            outside = info.addr_hi < lo or info.addr_lo >= hi
            name = f"run_{next_id:08d}"
            target = ms.run_path(name)
            if os.path.exists(target):       # leftover of a crashed build
                shutil.rmtree(target)
            if inside if moved_side else outside:
                # wholly on this side: raw file-level copy, no slicing
                shutil.copytree(sms.run_path(info.name), target)
                runs.append(_replace(info, run_id=next_id, name=name))
                next_id += 1
            elif outside if moved_side else inside:
                continue                     # wholly on the other side
            else:
                meta = slice_run(sms.run_path(info.name), target, lo, hi,
                                 erased_override=erased,
                                 invert=not moved_side)
                if meta is None:
                    continue
                runs.append(RunInfo.from_meta(next_id, name, meta,
                                              level=info.level))
                carried_erased = True
                next_id += 1
        if not carried_erased and len(erased):
            # whole-run copies only: ship the tombstone union separately
            name = f"run_{next_id:08d}"
            meta = write_carrier_run(ms.run_path(name), erased)
            runs.append(RunInfo.from_meta(next_id, name, meta))
            next_id += 1
        m = Manifest.initial().successor(
            frozen_upto=sm.frozen_upto, next_run_id=next_id,
            next_addr=next_addr, next_seq=sm.next_seq, runs=runs)
        ms.publish(m)
        ms.gc(m)
        return m

    keep_m = build_side(keep_dir, False,
                        keep_next_addr if keep_next_addr is not None
                        else sm.next_addr)
    moved_m = build_side(moved_dir, True,
                         moved_next_addr if moved_next_addr is not None
                         else sm.next_addr)
    fault_point("split.shipped")
    return keep_m, moved_m


class StaticWarren(_SnapshotReads):
    """Read-only Warren surface over a demoted run set (no hot tier).

    Clones share the loaded runs; ``start`` pins a runs-only
    :class:`TieredSnapshot`.  Writes are structurally impossible — the
    owner (a shard router) promotes the group back to dynamic first.
    """

    def __init__(self, directory: str,
                 tokenizer: Optional[Tokenizer] = None,
                 featurizer: Optional[Featurizer] = None,
                 _shared: Optional[tuple] = None,
                 block_cache: Optional[BlockCache] = None):
        self.directory = directory
        self.tokenizer = tokenizer or Utf8Tokenizer()
        self.featurizer = featurizer or JsonFeaturizer()
        if _shared is not None:
            self.manifest, self._runs = _shared
        else:
            cache = (block_cache if block_cache is not None
                     else default_block_cache())
            ms = ManifestStore(directory)
            m = ms.load_latest_good()
            if m is None:
                raise FileNotFoundError(f"no manifest in {directory}")
            self.manifest = m
            self._runs = _sort_runs(
                StaticRun.open(ms.run_path(i.name), i, self.tokenizer,
                               self.featurizer, block_cache=cache)
                for i in m.runs)
        self._snapshot = None

    @property
    def index(self) -> "StaticWarren":
        return self

    def max_seqnum(self) -> int:
        return max((r.info.seq_hi for r in self._runs), default=-1)

    def clone(self) -> "StaticWarren":
        return StaticWarren(self.directory, self.tokenizer, self.featurizer,
                            _shared=(self.manifest, self._runs))

    def start(self) -> None:
        if self._snapshot is not None:
            raise RuntimeError("already started")
        self._snapshot = TieredSnapshot(self._runs, None)

    def close(self) -> None:
        for r in self._runs:
            try:
                r.close()
            except OSError:
                pass

"""Background compaction for the tiered store.

The :class:`Compactor` runs freeze/compact maintenance on its own thread:

  * when the hot tier accumulates ``freeze_segments`` committed segments
    (or ``freeze_records`` content records), it is frozen into a new L0 run
    — which first triggers the hot tier's size-tiered segment auto-merge,
    so run writes stay one-segment cheap;
  * runs are folded down **leveled**: freshly frozen runs pile up at L0;
    when L0 reaches :attr:`LeveledPolicy.l0_trigger` every L0 run (plus the
    L1 runs its address range overlaps) merges into one L1 run; a deeper
    level whose total bytes exceed its geometric target sheds its
    least-overlapping run into the next level.  Erased content records are
    GC'd only when the output lands on the bottom level — upper-level
    merges defer the reclaim, classic leveled doctrine (Munro et al.,
    PAPERS.md).  Tombstones themselves are never dropped (annotative
    semantics: later transactions may annotate erased ranges).

Readers never block: they pin a (runs, hot-snapshot) view; the only
mutual-exclusion window is the view swap, whose duration is recorded in
:class:`CompactionMetrics` as pause time (the LSM "write stall" figure the
``benchmarks/build_throughput.py --tiered`` mode reports).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro import obs

from .manifest import RunInfo


@dataclass(frozen=True)
class LeveledPolicy:
    """Leveled, overlap-aware compaction targets.

    ``l0_trigger`` L0 runs force an L0→L1 fold (all of L0 — L0 runs
    overlap by construction, so partial folds would corrupt recency);
    level ``i >= 1`` holds up to ``base_bytes * ratio**(i-1)`` bytes, and
    an over-target level sheds the run with the *least* byte-overlap into
    ``i+1`` (minimizing write amplification), expanded to the closure of
    next-level runs its address range overlaps so within-level runs stay
    address-disjoint.
    """

    l0_trigger: int = 4
    base_bytes: int = 4 * 1024 * 1024
    ratio: int = 8
    max_level: int = 6

    def target_bytes(self, level: int) -> int:
        return self.base_bytes * (self.ratio ** max(0, level - 1))

    @staticmethod
    def _overlaps(a: RunInfo, lo: int, hi: int) -> bool:
        return a.addr_lo <= hi and lo <= a.addr_hi

    @classmethod
    def _closure(cls, victims: List[RunInfo],
                 next_level: Sequence[RunInfo]) -> List[RunInfo]:
        """Expand ``victims`` with every next-level run overlapping their
        combined address range, to a fixpoint (adding a run widens the
        range, which can overlap further adjacent runs)."""
        out = list(victims)
        pool = [r for r in next_level]
        changed = True
        while changed:
            changed = False
            lo = min(r.addr_lo for r in out)
            hi = max(r.addr_hi for r in out)
            for r in list(pool):
                if cls._overlaps(r, lo, hi):
                    out.append(r)
                    pool.remove(r)
                    changed = True
        return out

    def pick(self, infos: Sequence[RunInfo]
             ) -> Optional[Tuple[List[RunInfo], int]]:
        """Choose a compaction: ``(victims, output_level)`` or None.

        ``victims`` come back merge-ordered (deepest level first, then
        ascending sequence) so the k-way merge preserves recency on exact
        interval ties."""
        by_level: Dict[int, List[RunInfo]] = {}
        for i in infos:
            by_level.setdefault(i.level, []).append(i)
        chosen: Optional[List[RunInfo]] = None
        out_level = 0
        l0 = by_level.get(0, [])
        if len(l0) >= self.l0_trigger:
            chosen = self._closure(list(l0), by_level.get(1, []))
            out_level = 1
        else:
            for level in sorted(k for k in by_level if k >= 1):
                if level >= self.max_level:
                    continue
                runs = by_level[level]
                if sum(r.nbytes for r in runs) <= self.target_bytes(level):
                    continue
                nxt = by_level.get(level + 1, [])

                def overlap_bytes(r: RunInfo) -> int:
                    return sum(n.nbytes for n in nxt
                               if self._overlaps(n, r.addr_lo, r.addr_hi))

                victim = min(runs, key=lambda r: (overlap_bytes(r),
                                                  r.seq_lo, r.run_id))
                chosen = self._closure([victim], nxt)
                out_level = level + 1
                break
        if chosen is None or len(chosen) < 1:
            return None
        if len(chosen) == 1 and chosen[0].level == out_level:
            return None                    # nothing would change
        chosen.sort(key=lambda i: (-i.level, i.seq_lo, i.run_id))
        return chosen, out_level


@dataclass
class CompactionMetrics:
    """Counters + pause samples, shared by manual and background paths.

    Pause samples also feed the ``compaction_pause_ms{kind}`` registry
    histogram, so the LSM write-stall distribution shows up next to the
    serving percentiles in every snapshot."""
    n_freezes: int = 0
    n_merges: int = 0
    pause_s: List[float] = field(default_factory=list)

    def note_freeze(self, pause: float) -> None:
        self.n_freezes += 1
        self.pause_s.append(pause)
        self._observe("freeze", pause)

    def note_merge(self, pause: float) -> None:
        self.n_merges += 1
        self.pause_s.append(pause)
        self._observe("merge", pause)

    @staticmethod
    def _observe(kind: str, pause: float) -> None:
        reg = obs.registry()
        if reg.enabled:
            reg.histogram("compaction_pause_ms",
                          "reader-visible view-swap stall per freeze/merge",
                          kind=kind).observe(1e3 * pause)

    @property
    def total_pause_s(self) -> float:
        return float(sum(self.pause_s))

    @property
    def max_pause_s(self) -> float:
        return float(max(self.pause_s, default=0.0))

    def summary(self) -> str:
        return (f"{self.n_freezes} freezes, {self.n_merges} merges, "
                f"pause total {1e3 * self.total_pause_s:.2f} ms, "
                f"max {1e3 * self.max_pause_s:.3f} ms")


class Compactor:
    """Background freeze + leveled-compaction loop over one
    :class:`TieredStore`.  ``max_runs`` doubles as the L0 trigger when no
    explicit :class:`LeveledPolicy` is given (back-compat with the old
    full-merge knob)."""

    def __init__(self, store, freeze_segments: int = 4,
                 freeze_records: int = 4096, max_runs: int = 4,
                 interval_s: float = 0.05,
                 policy: Optional[LeveledPolicy] = None):
        self.store = store
        self.freeze_segments = freeze_segments
        self.freeze_records = freeze_records
        self.max_runs = max_runs
        self.policy = policy or LeveledPolicy(l0_trigger=max(2, max_runs))
        self.interval_s = interval_s
        self._stop = threading.Event()
        self._thread: threading.Thread = None

    # -- policy ----------------------------------------------------------- #
    def _hot_pressure(self) -> bool:
        hot = self.store.hot
        with hot._publish_lock:
            segs = hot._segments
        if len(segs) >= self.freeze_segments:
            return True
        return sum(len(s.content.records()) for s in segs) \
            >= self.freeze_records

    def run_once(self) -> bool:
        """One maintenance pass; returns True when any work was done."""
        did = False
        if self._hot_pressure():
            did = self.store.freeze() is not None
        did = self.store.compact_level(self.policy) is not None or did
        return did

    # -- thread ----------------------------------------------------------- #
    def start(self) -> "Compactor":
        if self._thread is not None:
            raise RuntimeError("compactor already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="tiered-compactor")
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.run_once()
            except Exception:   # pragma: no cover - keep the loop alive
                import traceback
                traceback.print_exc()

    def stop(self, drain: bool = True) -> None:
        """Stop the thread; with ``drain`` run one final freeze plus a full
        bottom-level merge so the on-disk state reflects everything
        committed in one GC'd run."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None
        if drain:
            self.store.freeze()
            if self.store.n_runs > self.max_runs:
                self.store.compact_runs()

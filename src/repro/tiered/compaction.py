"""Background compaction for the tiered store.

The :class:`Compactor` runs freeze/merge maintenance on its own thread:

  * when the hot tier accumulates ``freeze_segments`` committed segments
    (or ``freeze_records`` content records), it is frozen into a new run —
    which first triggers the hot tier's size-tiered segment auto-merge, so
    run writes stay one-segment cheap;
  * when the run count exceeds ``max_runs``, every run is merged into one,
    GC'ing erased records.

Readers never block: they pin a (runs, hot-snapshot) view; the only
mutual-exclusion window is the view swap, whose duration is recorded in
:class:`CompactionMetrics` as pause time (the LSM "write stall" figure the
``benchmarks/build_throughput.py --tiered`` mode reports).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import List

from repro import obs


@dataclass
class CompactionMetrics:
    """Counters + pause samples, shared by manual and background paths.

    Pause samples also feed the ``compaction_pause_ms{kind}`` registry
    histogram, so the LSM write-stall distribution shows up next to the
    serving percentiles in every snapshot."""
    n_freezes: int = 0
    n_merges: int = 0
    pause_s: List[float] = field(default_factory=list)

    def note_freeze(self, pause: float) -> None:
        self.n_freezes += 1
        self.pause_s.append(pause)
        self._observe("freeze", pause)

    def note_merge(self, pause: float) -> None:
        self.n_merges += 1
        self.pause_s.append(pause)
        self._observe("merge", pause)

    @staticmethod
    def _observe(kind: str, pause: float) -> None:
        reg = obs.registry()
        if reg.enabled:
            reg.histogram("compaction_pause_ms",
                          "reader-visible view-swap stall per freeze/merge",
                          kind=kind).observe(1e3 * pause)

    @property
    def total_pause_s(self) -> float:
        return float(sum(self.pause_s))

    @property
    def max_pause_s(self) -> float:
        return float(max(self.pause_s, default=0.0))

    def summary(self) -> str:
        return (f"{self.n_freezes} freezes, {self.n_merges} merges, "
                f"pause total {1e3 * self.total_pause_s:.2f} ms, "
                f"max {1e3 * self.max_pause_s:.3f} ms")


class Compactor:
    """Background freeze/merge loop over one :class:`TieredStore`."""

    def __init__(self, store, freeze_segments: int = 4,
                 freeze_records: int = 4096, max_runs: int = 4,
                 interval_s: float = 0.05):
        self.store = store
        self.freeze_segments = freeze_segments
        self.freeze_records = freeze_records
        self.max_runs = max_runs
        self.interval_s = interval_s
        self._stop = threading.Event()
        self._thread: threading.Thread = None

    # -- policy ----------------------------------------------------------- #
    def _hot_pressure(self) -> bool:
        hot = self.store.hot
        with hot._publish_lock:
            segs = hot._segments
        if len(segs) >= self.freeze_segments:
            return True
        return sum(len(s.content.records()) for s in segs) \
            >= self.freeze_records

    def run_once(self) -> bool:
        """One maintenance pass; returns True when any work was done."""
        did = False
        if self._hot_pressure():
            did = self.store.freeze() is not None
        if self.store.n_runs > self.max_runs:
            did = self.store.compact_runs() is not None or did
        return did

    # -- thread ----------------------------------------------------------- #
    def start(self) -> "Compactor":
        if self._thread is not None:
            raise RuntimeError("compactor already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="tiered-compactor")
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.run_once()
            except Exception:   # pragma: no cover - keep the loop alive
                import traceback
                traceback.print_exc()

    def stop(self, drain: bool = True) -> None:
        """Stop the thread; with ``drain`` run one final freeze+merge so the
        on-disk state reflects everything committed."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None
        if drain:
            self.store.freeze()
            if self.store.n_runs > self.max_runs:
                self.store.compact_runs()

"""repro.tiered — LSM-style tiered storage for the annotative index.

  manifest      versioned atomic-JSON manifests with latest-good recovery
  store         TieredStore / TieredSnapshot / TieredWarren / StaticWarren
                + demote_index / resurrect_index (cold shard demotion)
  compaction    background Compactor + pause-time metrics

A TieredWarren exposes the exact Warren surface over a hot DynamicIndex
memtable plus N immutable on-disk static runs; freezes and merges run in
the background without blocking pinned readers.
"""

from .compaction import CompactionMetrics, Compactor
from .manifest import Manifest, ManifestCorrupt, ManifestStore, RunInfo
from .store import (StaticRun, StaticWarren, TieredSnapshot, TieredStore,
                    TieredWarren, demote_index, resurrect_index)

__all__ = [
    "CompactionMetrics", "Compactor", "Manifest", "ManifestCorrupt",
    "ManifestStore", "RunInfo", "StaticRun", "StaticWarren",
    "TieredSnapshot", "TieredStore", "TieredWarren", "demote_index",
    "resurrect_index",
]

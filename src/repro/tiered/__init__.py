"""repro.tiered — LSM-style tiered storage for the annotative index.

  manifest      versioned atomic-JSON manifests with latest-good recovery
  store         TieredStore / TieredSnapshot / TieredWarren / StaticWarren
                + demote_index / resurrect_index (cold shard demotion)
                + merge_demoted / split_demoted (manifest-shipping
                rebalance of cold groups, sliced run sets — no promotion)
  compaction    background Compactor + LeveledPolicy + pause-time metrics
  cache         BlockCache: byte-capacity segmented-LRU with TinyLFU
                admission, shared by every mmap'd v2 run reader

Semantics.  A :class:`TieredWarren` exposes the *exact* Warren surface
over a hot :class:`~repro.core.index.DynamicIndex` memtable plus N
immutable on-disk static runs.  Every read pins a
:class:`TieredSnapshot` — an immutable (runs, hot-snapshot) pair — and
per-feature views k-way merge run lists with the hot list in sequence
order, filtered by the coalescing union of every tier's tombstones, so a
tiered index is bit-identical to the single dynamic index holding the same
committed transactions.  Writes only ever touch the hot tier; ``freeze``
folds committed hot segments into a new run and ``merge`` folds runs
together, both in the background.

Invariants the rest of the system leans on:

* **Readers never block.**  The only stop-the-world window in a freeze or
  merge is the view swap (a tuple assignment + ``detach_segments``),
  measured and reported as compaction pause time.  Pinned snapshots keep
  serving their run tuple and segment tuple forever — run file handles
  stay valid past unlink (POSIX), content is resident.
* **The manifest is the commit point.**  A run is durable on disk *before*
  the manifest version naming it is published (tmp + fsync + atomic
  rename), and the hot tier forgets frozen segments only *after* the
  publish; the WAL is compacted last.  Every crash point therefore
  recovers to latest-good manifest + WAL replay, with already-frozen
  segments deduplicated at open and orphan run directories GC'd.
* **Erasure is a point-set.**  Tombstones merge as a coalescing interval
  union across *all* tiers — an erase recorded in any tier hides content
  and annotations in every other tier, and survives run merges.
* **Levels order recency.**  Leveled compaction keeps runs address-
  disjoint within each level ``>= 1``; the read path merges deepest level
  first, then ascending sequence, hot tier last, so exact-interval ties
  still resolve newest-wins.  Erased content records are GC'd only when a
  merge lands on the bottom level; tombstones are never dropped.

Failure model: fail-stop with durable media.  Torn manifest writes are
detected by crc and skipped (latest-good wins); a run directory missing
files invalidates exactly the manifests naming it; the WAL tolerates a
torn tail frame.  There is no partial-visibility state: a crashed freeze
either never published (hot tier still owns the data) or published (the
run owns it and the WAL copy is dropped at open).
"""

from repro.core.runfile import RunCorruption

from .cache import BlockCache, default_block_cache, set_default_block_cache
from .compaction import CompactionMetrics, Compactor, LeveledPolicy
from .manifest import Manifest, ManifestCorrupt, ManifestStore, RunInfo
from .store import (StaticRun, StaticWarren, TieredSnapshot, TieredStore,
                    TieredWarren, demote_index, merge_demoted,
                    resurrect_index, split_demoted)

__all__ = [
    "BlockCache", "CompactionMetrics", "Compactor", "LeveledPolicy",
    "Manifest", "ManifestCorrupt", "ManifestStore", "RunCorruption",
    "RunInfo", "StaticRun", "StaticWarren", "TieredSnapshot", "TieredStore",
    "TieredWarren", "default_block_cache", "demote_index", "merge_demoted",
    "resurrect_index", "set_default_block_cache", "split_demoted",
]

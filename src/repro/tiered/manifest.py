"""Versioned manifest for the tiered storage engine.

One JSON file per version (``MANIFEST-<v>.json``), written tmp + fsync +
atomic rename — the same pattern as ``dist/checkpoint.py``.  A manifest
records the live immutable runs (with their sequence and address-stripe
coverage), the highest seqnum folded out of the hot tier, and the address /
sequence allocation floors, plus a crc over its own payload so a torn write
is detected at load time.

Recovery (:meth:`ManifestStore.load_latest_good`) walks versions newest
first and returns the first manifest that (a) parses, (b) passes its crc,
and (c) whose run directories are all intact on disk — so a crash *between
a run write and the manifest swap* simply falls back to the previous
version, and the orphaned run directory is garbage-collected on the next
open (:meth:`ManifestStore.gc`).  Readers pin a manifest version by holding
the run tuple it described; published manifests are immutable.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import zlib
from dataclasses import asdict, dataclass, field, replace
from typing import Dict, List, Optional

from repro.core.faults import fault_point

_MANIFEST_RE = re.compile(r"^MANIFEST-(\d{8})\.json$")


@dataclass(frozen=True)
class RunInfo:
    """One immutable on-disk run, as recorded by the manifest.

    ``level`` and ``nbytes`` power the leveled compaction policy; they
    default so manifests written before PR 10 still load (all runs at L0,
    sizes re-measured lazily)."""
    run_id: int
    name: str            # directory name under <root>/runs/
    seq_lo: int
    seq_hi: int
    addr_lo: int
    addr_hi: int
    n_records: int
    n_features: int
    level: int = 0       # 0 = freshly frozen; deeper = older, bigger
    nbytes: int = 0      # on-disk size at write time (0: unknown/legacy)

    @staticmethod
    def from_meta(run_id: int, name: str, meta: dict,
                  level: int = 0) -> "RunInfo":
        """From a ``write_run``/``merge_runs``/``slice_run`` meta record."""
        return RunInfo(run_id=run_id, name=name,
                       seq_lo=int(meta["seq_lo"]), seq_hi=int(meta["seq_hi"]),
                       addr_lo=int(meta["addr_lo"]),
                       addr_hi=int(meta["addr_hi"]),
                       n_records=int(meta["n_records"]),
                       n_features=int(meta["n_features"]),
                       level=int(level),
                       nbytes=int(meta.get("nbytes", 0)))


@dataclass(frozen=True)
class Manifest:
    version: int
    frozen_upto: int     # max seqnum folded into runs (-1: nothing frozen)
    next_run_id: int
    next_addr: int       # address-allocation floor at publish time
    next_seq: int        # seqnum-allocation floor at publish time
    runs: List[RunInfo] = field(default_factory=list)
    extra: Dict = field(default_factory=dict)

    @staticmethod
    def initial() -> "Manifest":
        return Manifest(version=0, frozen_upto=-1, next_run_id=0,
                        next_addr=0, next_seq=0)

    def successor(self, **changes) -> "Manifest":
        return replace(self, version=self.version + 1, **changes)

    # -- (de)serialization ------------------------------------------------ #
    def to_json(self) -> str:
        body = asdict(self)
        payload = json.dumps(body, sort_keys=True)
        return json.dumps({"crc": zlib.crc32(payload.encode()),
                           "manifest": body}, sort_keys=True)

    @staticmethod
    def from_json(text: str) -> "Manifest":
        obj = json.loads(text)
        body = obj["manifest"]
        payload = json.dumps(body, sort_keys=True)
        if zlib.crc32(payload.encode()) != obj.get("crc"):
            raise ValueError("manifest crc mismatch (torn write)")
        runs = [RunInfo(**r) for r in body.pop("runs")]
        return Manifest(runs=runs, **body)


class ManifestCorrupt(RuntimeError):
    """No manifest version on disk is intact."""


class ManifestStore:
    """Publishes and recovers manifest versions under one root directory."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.runs_dir = os.path.join(directory, "runs")
        self.keep = keep
        os.makedirs(self.runs_dir, exist_ok=True)
        for name in os.listdir(directory):       # torn tmp files from a crash
            if ".tmp-" in name:
                try:
                    os.unlink(os.path.join(directory, name))
                except OSError:
                    pass

    def run_path(self, name: str) -> str:
        return os.path.join(self.runs_dir, name)

    def _versions(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            m = _MANIFEST_RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def _run_intact(self, info: RunInfo) -> bool:
        path = self.run_path(info.name)
        # v2 block runs carry one file; legacy v1 runs key off meta.msgpack
        return (os.path.exists(os.path.join(path, "run.aix2"))
                or os.path.exists(os.path.join(path, "meta.msgpack")))

    # -- recovery --------------------------------------------------------- #
    def load_latest_good(self) -> Optional[Manifest]:
        """Newest manifest that parses, passes crc, and names only intact
        run directories; None when no manifest exists at all."""
        versions = self._versions()
        for v in reversed(versions):
            path = os.path.join(self.directory, f"MANIFEST-{v:08d}.json")
            try:
                with open(path) as fh:
                    m = Manifest.from_json(fh.read())
            except (OSError, ValueError, KeyError, TypeError):
                continue
            if all(self._run_intact(r) for r in m.runs):
                return m
        if versions:
            raise ManifestCorrupt(
                f"{len(versions)} manifest versions in {self.directory}, "
                "none intact")
        return None

    # -- publish ---------------------------------------------------------- #
    def publish(self, manifest: Manifest) -> None:
        """Durably write one manifest version (tmp + fsync + atomic rename),
        then drop versions older than the retention window."""
        final = os.path.join(self.directory,
                             f"MANIFEST-{manifest.version:08d}.json")
        tmp = f"{final}.tmp-{os.getpid()}"
        with open(tmp, "w") as fh:
            fh.write(manifest.to_json())
            fh.flush()
            os.fsync(fh.fileno())
        fault_point("manifest.written")
        os.replace(tmp, final)
        fault_point("manifest.published")
        for v in self._versions()[:-self.keep]:
            try:
                os.unlink(os.path.join(self.directory,
                                       f"MANIFEST-{v:08d}.json"))
            except OSError:
                pass

    # -- garbage collection ----------------------------------------------- #
    def gc(self, live: Manifest) -> List[str]:
        """Remove run directories not referenced by ``live`` (orphans from a
        crash between run write and manifest swap, or victims of a finished
        compaction).  Readers pinning an older manifest keep serving: a
        run's mmap and file handles stay valid after unlink (POSIX
        semantics), so lazily decoded blocks remain readable."""
        referenced = {r.name for r in live.runs}
        removed = []
        for name in sorted(os.listdir(self.runs_dir)):
            if name in referenced:
                continue
            path = os.path.join(self.runs_dir, name)
            shutil.rmtree(path, ignore_errors=True)
            removed.append(name)
        return removed

"""repro.dist — the distributed serving/training layer.

  compression   int8 gradient compression with error feedback
  checkpoint    atomic versioned checkpoints (train state + dynamic index)
  elastic       mesh shrink / pytree reshard on device loss
  sharding      param/batch/cache sharding policies for the meshes
  shard_router  ShardedWarren: hash-partitioned index serving with a
                versioned RoutingTable (address ranges + routing epochs)
  rebalance     live shard rebalancing: split/merge replica groups by
                streaming segments, without pausing writers
  parallel      ScatterGather worker pool + serving time breakdown
  autopilot     closed-loop control plane: Controller + policies that
                drive split/merge/demote/re-sync from live signals
  simharness    deterministic day-in-the-life simulation (SimClock,
                SimCluster, DriftingWorkload) for tests and benchmarks

Submodules are imported lazily so that pulling in one (e.g. compression,
jax-only) never drags the whole index stack along.
"""

import importlib

_SUBMODULES = ("compression", "checkpoint", "elastic", "sharding",
               "shard_router", "parallel", "rebalance", "autopilot",
               "simharness")

_LAZY_NAMES = {
    "ShardedWarren": "shard_router",
    "RoutingTable": "shard_router",
    "CheckpointManager": "checkpoint",
    "ScatterGather": "parallel",
    "ScatterTimings": "parallel",
    "Rebalancer": "rebalance",
    "RebalanceStats": "rebalance",
    "Controller": "autopilot",
    "AutopilotConfig": "autopilot",
    "Decision": "autopilot",
    "GroupSignal": "autopilot",
    "SimClock": "simharness",
    "SimCluster": "simharness",
    "DriftingWorkload": "simharness",
}

__all__ = list(_SUBMODULES) + list(_LAZY_NAMES)


def __getattr__(name):
    if name in _SUBMODULES:
        return importlib.import_module(f".{name}", __name__)
    if name in _LAZY_NAMES:
        mod = importlib.import_module(f".{_LAZY_NAMES[name]}", __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

"""repro.dist — the distributed serving/training layer.

  compression   int8 gradient compression with error feedback
  checkpoint    atomic versioned checkpoints (train state + dynamic index)
  elastic       mesh shrink / pytree reshard on device loss
  sharding      param/batch/cache sharding policies for the meshes
  shard_router  ShardedWarren: hash-partitioned index serving

Submodules are imported lazily so that pulling in one (e.g. compression,
jax-only) never drags the whole index stack along.
"""

import importlib

_SUBMODULES = ("compression", "checkpoint", "elastic", "sharding",
               "shard_router")

__all__ = list(_SUBMODULES) + ["ShardedWarren", "CheckpointManager"]


def __getattr__(name):
    if name in _SUBMODULES:
        return importlib.import_module(f".{name}", __name__)
    if name == "ShardedWarren":
        return importlib.import_module(".shard_router", __name__).ShardedWarren
    if name == "CheckpointManager":
        return importlib.import_module(".checkpoint", __name__).CheckpointManager
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

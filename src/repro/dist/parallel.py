"""Scatter-gather execution for sharded serving and background migration.

Semantics.  :class:`ScatterGather` is a small worker pool that fans
per-group read closures out concurrently and gathers results in input
order.  It is the engine behind ``ShardedWarren``'s async scatter:
``annotations``, ``global_stats``, ``search`` (both scatter phases) and
``search_gcl`` hand it one closure per shard group instead of looping on
the caller thread.  Each closure runs the group's full replica-failover
protocol (``_group_read``) inside the worker, so a replica dying
mid-scatter fails over exactly as it would on the sequential path —
workers touch disjoint per-group state, which is what makes the fan-out
safe.  The same ``map`` fan-out hosts a live shard migration's bulk
segment streaming (``repro.dist.rebalance``), so rebalancing work runs on
pool workers rather than a serving thread.

Failure model and invariants:

* **Run-all-then-raise.**  ``run``/``map`` let every closure finish before
  re-raising the *first* failure in input order — per-group side effects
  (failover marks, read-warren re-pins) are never torn mid-scatter, and a
  caller observing an exception knows every group reached a settled state.
* **Caller participation.**  The caller thread executes the first closure
  itself: a fan-out never leaves the caller idle, costs one fewer wakeup,
  and a 1-item scatter degrades to a plain call.
* **Close is graceful, not fatal.**  A closed pool (or a ``close`` racing
  a fan-out) degrades to the caller-thread loop — holders never need to
  guard fan-outs on pool lifetime, and no submitted work is dropped.
* **No ordering between items.**  Closures of one fan-out may run in any
  order and concurrently; correctness must come from the closures touching
  disjoint state (per-group reads do; anything else must lock).

:class:`ScatterTimings` is the thread-safe scatter/score/merge time
accumulator the serving paths report their per-query breakdown through.
"""

from __future__ import annotations

import contextvars
import os
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.obs import registry


class ScatterTimings:
    """Thread-safe accumulator for the serving-path time breakdown.

    ``scatter``  fan-out reads (per-group stats + annotation lists)
    ``score``    per-group packing + device/host scoring
    ``merge``    the global k-way merge of per-group top-k lists

    Every ``add`` also feeds the per-query breakdown into the obs
    histograms (``serve_{scatter,score,merge}_latency_ms{site=...}``),
    which carry the percentiles; the struct itself keeps only running
    sums for its human-readable ``summary``.  Because one instance is
    shared across every clone of a warren (via ``_ctx``), the sums are
    *windowed*: ``window()`` returns the delta since the last call and
    bumps ``epoch``, so long-lived servers report per-window rates
    instead of lifetime averages.
    """

    def __init__(self, site: str = "warren.search"):
        self._lock = threading.Lock()
        self.site = site
        self.epoch = 0
        self.scatter_s = 0.0
        self.score_s = 0.0
        self.merge_s = 0.0
        self.queries = 0
        reg = registry()
        self._h_scatter = reg.histogram(
            "serve_scatter_latency_ms",
            "per-query scatter (fan-out read) time", site=site)
        self._h_score = reg.histogram(
            "serve_score_latency_ms",
            "per-query pack + device/host scoring time", site=site)
        self._h_merge = reg.histogram(
            "serve_merge_latency_ms",
            "per-query global k-way merge time", site=site)

    def reset(self) -> None:
        """Zero the window sums and bump the epoch marker."""
        with self._lock:
            self.scatter_s = self.score_s = self.merge_s = 0.0
            self.queries = 0
            self.epoch += 1

    def add(self, scatter: float = 0.0, score: float = 0.0,
            merge: float = 0.0, queries: int = 1) -> None:
        with self._lock:
            self.scatter_s += scatter
            self.score_s += score
            self.merge_s += merge
            self.queries += queries
        self._h_scatter.observe(1e3 * scatter)
        self._h_score.observe(1e3 * score)
        self._h_merge.observe(1e3 * merge)

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            return {"scatter_s": self.scatter_s, "score_s": self.score_s,
                    "merge_s": self.merge_s, "queries": self.queries,
                    "epoch": self.epoch}

    def window(self) -> Dict[str, float]:
        """Snapshot the current window, then reset it (epoch += 1)."""
        with self._lock:
            out = {"scatter_s": self.scatter_s, "score_s": self.score_s,
                   "merge_s": self.merge_s, "queries": self.queries,
                   "epoch": self.epoch}
            self.scatter_s = self.score_s = self.merge_s = 0.0
            self.queries = 0
            self.epoch += 1
        return out

    def summary(self) -> str:
        s = self.snapshot()
        q = max(s["queries"], 1)
        total = s["scatter_s"] + s["score_s"] + s["merge_s"]
        return (f"{s['queries']} queries — scatter "
                f"{1e3 * s['scatter_s'] / q:.2f} score "
                f"{1e3 * s['score_s'] / q:.2f} merge "
                f"{1e3 * s['merge_s'] / q:.2f} ms/query "
                f"(total {1e3 * total / q:.2f})")


class ScatterGather:
    """Worker pool for ordered per-group fan-out.

    A closed (or single-item) scatter degrades to the caller-thread loop,
    so holders never have to guard their fan-outs on pool lifetime.  The
    pool is elastic: ``resize`` swaps in a new worker width on a live pool
    (the autopilot drives this as the group count changes) without
    dropping or blocking in-flight fan-outs.
    """

    def __init__(self, workers: Optional[int] = None):
        self.workers = workers if workers else min(16, os.cpu_count() or 4)
        self._pool = ThreadPoolExecutor(max_workers=self.workers,
                                        thread_name_prefix="scatter")
        self._lifecycle = threading.Lock()   # serializes resize/close
        self._closed = False

    def resize(self, workers: int) -> None:
        """Grow or shrink the worker count on a LIVE pool.

        A fresh executor with the new width is published first and the old
        one is retired with ``shutdown(wait=False)`` — already-submitted
        work keeps running on the old threads until done, so in-flight
        fan-outs always complete; only *new* fan-outs land on the new
        width.  A ``run`` that raced the swap and submitted into the
        retired executor falls back to running those thunks inline (the
        same degrade path ``close`` uses).  No-op when the requested width
        matches or the pool is closed.
        """
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        with self._lifecycle:
            if self._closed or workers == self.workers:
                return
            old = self._pool
            self._pool = ThreadPoolExecutor(max_workers=workers,
                                            thread_name_prefix="scatter")
            self.workers = workers
            old.shutdown(wait=False)
        reg = registry()
        if reg.enabled:
            reg.gauge("scatter_pool_workers",
                      "current ScatterGather worker count").set(workers)

    def run(self, thunks: Sequence[Callable[[], Any]]) -> List[Any]:
        """Run thunks concurrently; results in input order.

        The caller thread participates (it runs the first thunk itself
        while workers take the rest), so a fan-out never leaves the caller
        idle and costs one fewer wakeup.  Every thunk runs to completion
        before the first exception (in input order) is re-raised, so
        per-group side effects — failover marks, read-warren swaps — are
        never torn mid-scatter.
        """
        if self._closed or len(thunks) <= 1:
            return [t() for t in thunks]
        futures = []
        for t in thunks[1:]:
            # One context copy per thunk: trace spans opened inside the
            # worker parent under the span active at submission, and a
            # Context can only run one callable at a time.
            ctx = contextvars.copy_context()
            try:
                futures.append(self._pool.submit(ctx.run, t))
            except RuntimeError:          # close() raced the fan-out: the
                futures.append(t)         # unsubmitted tail runs inline
        first: Optional[BaseException] = None
        try:
            head = thunks[0]()
        except BaseException as e:
            first, head = e, None
        out: List[Any] = [head]
        for f in futures:
            try:
                out.append(f() if callable(f) else f.result())
            except BaseException as e:
                if first is None:
                    first = e
                out.append(None)
        if first is not None:
            raise first
        return out

    def map(self, fn: Callable[[Any], Any], items: Sequence[Any]) -> List[Any]:
        return self.run([lambda it=it: fn(it) for it in items])

    def close(self) -> None:
        with self._lifecycle:
            self._closed = True
            self._pool.shutdown(wait=False)

    def __enter__(self) -> "ScatterGather":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

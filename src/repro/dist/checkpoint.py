"""Atomic, versioned checkpointing for train state *and* the dynamic index.

Layout (one directory per manager):

  step_00000020/state.msgpack    flattened pytree leaves (raw array bytes)
  step_00000020/MANIFEST         json: {"step", "crc", "nbytes"}
  index_00000020.log             annotative-index snapshot in the normal
                                 transaction-log format (Segment.to_record
                                 frames + commit markers), so recovery is
                                 just DynamicIndex.recover()

Writes land in a tmp name and are published with an atomic rename, so a
reader never sees a partial checkpoint.  Restores verify the manifest crc;
``restore_latest_good`` walks backwards past corrupt/torn checkpoints to
the newest intact one.  ``async_write=True`` serializes to host memory
synchronously (donation-safe) and does the file I/O on a worker thread.
"""

from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
import zlib
from typing import Any, List, Optional

import jax
import msgpack
import numpy as np

from repro import obs

_STEP_RE = re.compile(r"^step_(\d{8})$")
_INDEX_RE = re.compile(r"^(.+)_(\d{8})\.log$")
_ROUTING_RE = re.compile(r"^(.+)_(\d{8})\.routing\.json$")


class CheckpointCorrupt(RuntimeError):
    """On-disk damage: torn write, bad crc, unreadable payload."""


class CheckpointShapeMismatch(RuntimeError):
    """Intact checkpoint whose structure doesn't match the restore target
    (e.g. the model or optimizer config changed).  Deliberately NOT skipped
    by restore_latest_good — silently restarting from step 0 is worse."""


# ------------------------------------------------------------------ #
# leaf serialization: raw bytes + dtype string (bf16 via ml_dtypes)
# ------------------------------------------------------------------ #
def _pack_leaf(leaf) -> dict:
    if isinstance(leaf, (bool, int, float)):
        return {"k": "py", "v": leaf}
    arr = np.asarray(leaf)            # device -> host copy (donation-safe)
    return {"k": "nd", "d": str(arr.dtype), "s": list(arr.shape),
            "b": arr.tobytes()}


def _unpack_leaf(rec: dict):
    if rec["k"] == "py":
        return rec["v"]
    return np.frombuffer(rec["b"], dtype=np.dtype(rec["d"])
                         ).reshape(rec["s"]).copy()


def _serialize(tree) -> bytes:
    leaves = jax.tree.leaves(tree)
    return msgpack.packb({"n": len(leaves),
                          "leaves": [_pack_leaf(l) for l in leaves]},
                         use_bin_type=True)


def _deserialize(payload: bytes, like):
    obj = msgpack.unpackb(payload, raw=False)
    flat, treedef = jax.tree.flatten(like)
    if obj["n"] != len(flat):
        raise CheckpointShapeMismatch(
            f"checkpoint has {obj['n']} leaves, expected {len(flat)} — "
            "did the model/optimizer config change since it was written?")
    return treedef.unflatten([_unpack_leaf(r) for r in obj["leaves"]])


# ------------------------------------------------------------------ #
class CheckpointManager:
    """Versioned save/restore with retention and latest-good recovery."""

    def __init__(self, directory: str, keep: Optional[int] = 3,
                 async_write: bool = True):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        for name in os.listdir(directory):    # torn writes from a crash
            if ".tmp-" in name:
                path = os.path.join(directory, name)
                try:
                    (shutil.rmtree if os.path.isdir(path)
                     else os.unlink)(path)
                except OSError:
                    pass
        self._fs_lock = obs.ProfiledLock("checkpoint_fs")
        self._q: Optional["queue.Queue"] = None
        self._write_error: Optional[BaseException] = None
        if async_write:
            self._q = queue.Queue()
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # -- listing ---------------------------------------------------- #
    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.directory, name,
                                                 "MANIFEST")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save -------------------------------------------------------- #
    def save(self, step: int, tree, block: bool = False) -> None:
        payload = _serialize(tree)      # host copy happens HERE, synchronously
        if self._q is None:
            self._write(step, payload)
            return
        self._q.put((step, payload))
        if block:
            self.wait()

    def wait(self) -> None:
        """Block until all queued async writes are durable.

        Raises if any queued write failed — a caller that asked for a
        durable checkpoint must not be told it has one.
        """
        if self._q is not None:
            self._q.join()
        if self._write_error is not None:
            err, self._write_error = self._write_error, None
            raise RuntimeError("async checkpoint write failed") from err

    def _drain(self):
        while True:
            step, payload = self._q.get()
            try:
                self._write(step, payload)
            except Exception as e:      # keep the worker alive, keep the error
                self._write_error = e
            finally:
                self._q.task_done()

    def _write(self, step: int, payload: bytes) -> None:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = f"{final}.tmp-{os.getpid()}-{threading.get_ident()}"
        with self._fs_lock:
            os.makedirs(tmp, exist_ok=True)
            with open(os.path.join(tmp, "state.msgpack"), "wb") as fh:
                fh.write(payload)
                fh.flush()
                os.fsync(fh.fileno())
            manifest = {"step": step, "crc": zlib.crc32(payload),
                        "nbytes": len(payload)}
            with open(os.path.join(tmp, "MANIFEST"), "w") as fh:
                json.dump(manifest, fh)
                fh.flush()
                os.fsync(fh.fileno())
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)       # atomic publish
            self._gc()

    def _gc(self) -> None:
        if self.keep is None:
            return
        steps = self.all_steps()
        for step in steps[:-self.keep] if len(steps) > self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{step:08d}"),
                          ignore_errors=True)
            for name in os.listdir(self.directory):
                m = _INDEX_RE.match(name) or _ROUTING_RE.match(name)
                if m and int(m.group(2)) == step:
                    os.unlink(os.path.join(self.directory, name))

    # -- restore ------------------------------------------------------ #
    def restore(self, step: int, like):
        d = os.path.join(self.directory, f"step_{step:08d}")
        try:
            with open(os.path.join(d, "MANIFEST")) as fh:
                manifest = json.load(fh)
            with open(os.path.join(d, "state.msgpack"), "rb") as fh:
                payload = fh.read()
        except (OSError, json.JSONDecodeError) as e:
            raise CheckpointCorrupt(f"step {step}: {e}") from e
        if zlib.crc32(payload) != manifest.get("crc"):
            raise CheckpointCorrupt(f"step {step}: crc mismatch")
        return _deserialize(payload, like)

    def restore_latest_good(self, like):
        """Newest intact checkpoint as (step, state); (None, None) if none.

        Corrupt or torn checkpoints are skipped, not fatal — the pod-loss
        recovery path must make progress off whatever survived.
        """
        for step in reversed(self.all_steps()):
            try:
                return step, self.restore(step, like)
            except CheckpointCorrupt:
                continue
        return None, None

    # -- the dynamic index ------------------------------------------- #
    def save_index(self, step: int, index, name: str = "index") -> str:
        """Snapshot a DynamicIndex as a compacted transaction log."""
        from repro.core.log import TransactionLog

        with index._publish_lock:
            segments = index._segments
        records = []
        for seg in segments:
            records.append(seg.to_record())
            records.append({"t": "commit", "seq": seg.seqnum})
        final = os.path.join(self.directory, f"{name}_{step:08d}.log")
        tmp = f"{final}.tmp-{os.getpid()}"
        log = TransactionLog(tmp)
        for rec in records:
            log.append(rec, sync=False)
        log.close()
        with self._fs_lock:
            os.replace(tmp, final)
        return final

    def restore_index(self, step: int, name: str = "index",
                      tokenizer=None, featurizer=None,
                      log_path: Optional[str] = None):
        """Rebuild a DynamicIndex from its snapshot log (or None).

        The restored index logs to ``log_path`` (in-memory when None) —
        never back into the checkpoint file itself.
        """
        from repro.core.index import DynamicIndex
        from repro.core.log import TransactionLog

        path = os.path.join(self.directory, f"{name}_{step:08d}.log")
        if not os.path.exists(path):
            return None
        index = DynamicIndex.recover(path, tokenizer=tokenizer,
                                     featurizer=featurizer)
        index._log.close()
        index._log = TransactionLog(log_path)
        return index

    def restore_index_replicas(self, step: int, name: str = "index",
                               n: int = 1, tokenizer=None, featurizer=None,
                               log_path: Optional[str] = None) -> List:
        """Fan one index snapshot out to ``n`` independent replicas.

        The snapshot log is recovered from disk once; siblings are deep
        copies through the durable segment form (``Segment.to_record`` /
        ``from_record``), so every replica owns its segments and content
        stores — no shared mutable state, and no repeated log replay.
        Raises FileNotFoundError when the snapshot is absent: a replicated
        restore must not silently hand back an empty group.

        ``log_path`` names the transaction log of the FIRST replica only
        and is rejected for n > 1 — replicas sharing one append log would
        interleave duplicate-seqnum frames and double-replay on recovery;
        give each sibling its own log after restore instead.
        """
        from repro.core.index import DynamicIndex, Segment

        if log_path is not None and n > 1:
            raise ValueError(
                "log_path with n > 1 would share one transaction log "
                "across replicas; attach per-replica logs after restore")
        first = self.restore_index(step, name=name, tokenizer=tokenizer,
                                   featurizer=featurizer, log_path=log_path)
        if first is None:
            raise FileNotFoundError(
                f"no index snapshot {name!r} at step {step} "
                f"in {self.directory}")
        replicas = [first]
        for _ in range(max(1, n) - 1):
            idx = DynamicIndex(first.tokenizer, first.featurizer,
                               log_path=None)
            idx._segments = tuple(Segment.from_record(s.to_record())
                                  for s in first._segments)
            idx._version = 1
            idx._next_addr = first._next_addr
            idx._next_seq = first._next_seq
            replicas.append(idx)
        return replicas

    # -- shard routing ------------------------------------------------- #
    def save_routing(self, step: int, record: dict,
                     name: str = "routing") -> str:
        """Persist a ShardedWarren routing record (routing-table ranges,
        epochs, write groups, per-group allocation floors) next to the
        step's shard snapshots — tmp + fsync + atomic rename, with a crc
        so a torn write reads as absent, not as a wrong topology."""
        body = json.dumps(record, sort_keys=True)
        payload = json.dumps({"crc": zlib.crc32(body.encode()),
                              "routing": record}, sort_keys=True)
        final = os.path.join(self.directory, f"{name}_{step:08d}.routing.json")
        tmp = f"{final}.tmp-{os.getpid()}"
        with open(tmp, "w") as fh:
            fh.write(payload)
            fh.flush()
            os.fsync(fh.fileno())
        with self._fs_lock:
            os.replace(tmp, final)
        return final

    def restore_routing(self, step: int,
                        name: str = "routing") -> Optional[dict]:
        """The routing record saved at ``step``; None only when the file
        is absent (a legacy checkpoint, which restores with the striped
        default).  A present-but-torn record raises CheckpointCorrupt —
        silently falling back to striped routing would misroute every
        address a rebalance ever moved."""
        path = os.path.join(self.directory, f"{name}_{step:08d}.routing.json")
        if not os.path.exists(path):
            return None
        try:
            with open(path) as fh:
                obj = json.load(fh)
            record = obj["routing"]
            body = json.dumps(record, sort_keys=True)
        except (OSError, ValueError, KeyError, TypeError) as e:
            raise CheckpointCorrupt(
                f"step {step}: unreadable routing record: {e}") from e
        if zlib.crc32(body.encode()) != obj.get("crc"):
            raise CheckpointCorrupt(
                f"step {step}: routing record crc mismatch (torn write)")
        return record

    def index_steps(self, name: str = "index") -> List[int]:
        steps = []
        for fn in os.listdir(self.directory):
            m = _INDEX_RE.match(fn)
            if m and m.group(1) == name:
                steps.append(int(m.group(2)))
        return sorted(steps)

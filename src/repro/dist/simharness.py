"""Deterministic day-in-the-life simulation harness for the autopilot.

Everything the :class:`~repro.dist.autopilot.Controller` touches is
behind three injectable seams — SignalSource, Actuator, clock — and this
module provides the simulated side of each:

* :class:`SimClock` — a manually-advanced monotonic clock.  Nothing in
  the harness (or in the controller) reads the wall clock or sleeps, so
  a simulated "day" of drifting traffic runs in milliseconds and every
  run with the same seed produces byte-identical decision sequences.
* :class:`SimCluster` — a virtual sharded warren: groups own disjoint
  key ranges ``[lo, hi)`` of the unit interval, carry doc counts and
  per-replica seqnum high-water marks, and cost reads with a linear
  latency model (``p95 = base_ms + ms_per_doc * docs``) — the simplest
  model in which splitting a hot group visibly flattens its p95.  It is
  simultaneously the controller's SignalSource (``collect``) and its
  Actuator (``split``/``merge``/``demote``/``resync``), and it can
  inject :class:`~repro.dist.rebalance.RebalanceAborted` on demand to
  exercise the backoff path without a real migration race.
* :class:`DriftingWorkload` — a seeded Zipf-over-topics query stream
  whose hot spot migrates at phase boundaries: topic ``i`` lives at a
  fixed key position, ranks are Zipf(s)-weighted, and every
  ``phase_ticks`` ticks the whole topic→key mapping rotates by an
  irrational stride, so yesterday's cold range becomes today's hot one.
  This is the "day in the life" the benchmark and the tier-1 tests both
  replay.

The harness lives under ``src/`` (not ``tests/``) deliberately: the
``benchmarks/day_in_the_life.py`` driver and the examples import it via
the normal package path, and ``tests/_sim.py`` layers canned scenarios
on top.
"""

from __future__ import annotations

import bisect
import math
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro import obs
from repro.dist.autopilot import GroupSignal
from repro.dist.rebalance import RebalanceAborted


class SimClock:
    """Manually-advanced monotonic clock; pass the instance itself as the
    controller's ``clock`` (it is callable)."""

    def __init__(self, start: float = 0.0, step: float = 1.0):
        self._now = float(start)
        self.step = float(step)

    def __call__(self) -> float:
        return self._now

    @property
    def now(self) -> float:
        return self._now

    def advance(self, dt: Optional[float] = None) -> float:
        self._now += self.step if dt is None else float(dt)
        return self._now


@dataclass
class SimGroup:
    """One virtual shard group: a key range, its committed docs, and a
    replica seqnum/health vector."""

    gid: int
    lo: float
    hi: float
    docs: int = 0
    demoted: bool = False
    retired: bool = False
    seqs: List[int] = field(default_factory=list)
    alive: List[bool] = field(default_factory=list)


class SimCluster:
    """A virtual warren that is both SignalSource and Actuator.

    Reads routed via :meth:`route` accrue per-group read counts for the
    next ``collect``; writes via :meth:`ingest` grow the owner's doc
    count and advance its live, non-diverged replica seqnums.  Latency is
    modeled, not measured: a group serving any reads reports
    ``p95 = base_ms + ms_per_doc * docs`` — linear in resident docs, so
    hot-spot growth raises p95 and a split halves it.

    ``actions`` records every applied actuator call as a tuple, the
    ground truth tests compare against the controller's Decision log.
    Failure injection: :meth:`kill` / :meth:`diverge` a replica,
    :meth:`inject_aborts` to make the next N calls of one action kind
    raise ``RebalanceAborted``.
    """

    def __init__(self, replicas: int = 2, docs: int = 0,
                 base_ms: float = 2.0, ms_per_doc: float = 0.05,
                 observe_latency: bool = False):
        self.replicas = replicas
        self.base_ms = base_ms
        self.ms_per_doc = ms_per_doc
        # observe_latency feeds each routed read's modeled latency into
        # the real scatter_latency_ms{group} histograms, so an
        # obs.SLOMonitor can compute burn rates over simulated traffic
        self.observe_latency = observe_latency
        self._lat_hists: Dict[int, obs.Histogram] = {}
        self.groups: List[SimGroup] = [SimGroup(
            gid=0, lo=0.0, hi=1.0, docs=docs,
            seqs=[0] * replicas, alive=[True] * replicas)]
        self.actions: List[Tuple] = []
        self._reads: Dict[int, int] = {}
        self._writes: Dict[int, int] = {}
        self._diverged: Set[Tuple[int, int]] = set()
        self._abort_next: Dict[str, int] = {}
        # non-adjacent merges park the absorbed key range here
        self._extra_ranges: Dict[int, List[Tuple[float, float]]] = {}

    # -- topology queries ------------------------------------------------ #
    def active(self) -> List[SimGroup]:
        return [g for g in self.groups if not g.retired]

    def owner(self, key: float) -> SimGroup:
        k = key % 1.0
        for g in self.active():
            if g.lo <= k < g.hi:
                return g
            for lo, hi in self._extra_ranges.get(g.gid, ()):
                if lo <= k < hi:
                    return g
        raise KeyError(f"no group owns key {k}")   # pragma: no cover

    def total_docs(self) -> int:
        return sum(g.docs for g in self.active())

    # -- traffic --------------------------------------------------------- #
    def route(self, keys: Sequence[float]) -> None:
        observe = self.observe_latency and obs.registry().enabled
        for k in keys:
            g = self.owner(k)
            self._reads[g.gid] = self._reads.get(g.gid, 0) + 1
            if observe:
                h = self._lat_hists.get(g.gid)
                if h is None:
                    h = obs.registry().histogram(
                        "scatter_latency_ms",
                        "per-group scatter fan-out latency",
                        group=g.gid)
                    self._lat_hists[g.gid] = h
                h.observe(self.base_ms + self.ms_per_doc * g.docs)

    def ingest(self, keys: Sequence[float]) -> None:
        for k in keys:
            g = self.owner(k)
            g.docs += 1
            self._writes[g.gid] = self._writes.get(g.gid, 0) + 1
            for r in range(len(g.seqs)):
                if g.alive[r] and (g.gid, r) not in self._diverged:
                    g.seqs[r] += 1

    # -- SignalSource ----------------------------------------------------- #
    def collect(self) -> List[GroupSignal]:
        out = []
        for g in self.groups:
            reads = self._reads.get(g.gid, 0)
            p95 = (self.base_ms + self.ms_per_doc * g.docs
                   if reads > 0 else math.nan)
            out.append(GroupSignal(
                group=g.gid, docs=0 if g.retired else g.docs, p95_ms=p95,
                reads=reads, writes=self._writes.get(g.gid, 0),
                demoted=g.demoted, retired=g.retired,
                replica_seqs=tuple(g.seqs), alive=tuple(g.alive)))
        self._reads.clear()
        self._writes.clear()
        return out

    # -- Actuator ---------------------------------------------------------- #
    def _maybe_abort(self, kind: str, group: int) -> None:
        n = self._abort_next.get(kind, 0)
        if n > 0:
            self._abort_next[kind] = n - 1
            raise RebalanceAborted(f"injected {kind} abort on group {group}")

    def split(self, group: int) -> int:
        self._maybe_abort("split", group)
        g = self.groups[group]
        if g.retired:
            raise ValueError(f"group {group} is retired")
        new_gid = len(self.groups)
        mid = (g.lo + g.hi) / 2.0
        moved = g.docs // 2
        ng = SimGroup(gid=new_gid, lo=mid, hi=g.hi, docs=moved,
                      demoted=False, retired=False,
                      seqs=list(g.seqs), alive=[True] * len(g.alive))
        g.hi, g.docs, g.demoted = mid, g.docs - moved, False
        self.groups.append(ng)
        self.actions.append(("split", group, new_gid))
        return new_gid

    def merge(self, dest: int, source: int) -> None:
        self._maybe_abort("merge", source)
        d, s = self.groups[dest], self.groups[source]
        if d.retired or s.retired:
            raise ValueError("merge with retired group")
        d.docs += s.docs
        # the dest takes over the source's key range (ranges need not be
        # adjacent in the sim; ownership is what matters)
        if s.hi == d.lo:
            d.lo = s.lo
        elif d.hi == s.lo:
            d.hi = s.hi
        else:
            self._extra_ranges.setdefault(dest, []).append((s.lo, s.hi))
        s.retired, s.docs = True, 0
        for rng in self._extra_ranges.pop(source, []):
            self._extra_ranges.setdefault(dest, []).append(rng)
        self.actions.append(("merge", dest, source))

    def demote(self, group: int) -> None:
        g = self.groups[group]
        if g.retired or g.demoted:
            raise ValueError(f"group {group} cannot demote")
        g.demoted = True
        self.actions.append(("demote", group))

    def resync(self, group: int, replica: int) -> None:
        self._maybe_abort("resync", group)
        g = self.groups[group]
        live = [q for q, a in zip(g.seqs, g.alive) if a]
        g.seqs[replica] = max(live, default=0)
        g.alive[replica] = True
        self._diverged.discard((group, replica))
        self.actions.append(("resync", group, replica))

    # -- failure injection -------------------------------------------------- #
    def kill(self, group: int, replica: int) -> None:
        self.groups[group].alive[replica] = False

    def diverge(self, group: int, replica: int, lag: int = 1) -> None:
        g = self.groups[group]
        g.seqs[replica] = max(0, g.seqs[replica] - lag)
        self._diverged.add((group, replica))

    def inject_aborts(self, kind: str, n: int) -> None:
        self._abort_next[kind] = self._abort_next.get(kind, 0) + n


class DriftingWorkload:
    """Seeded Zipf-over-topics query stream with hot-spot migration.

    ``topics`` fixed points on the unit interval receive Zipf(s)-ranked
    traffic; every ``phase_ticks`` ticks the rank→position mapping
    rotates by the golden-ratio stride, migrating the hot spot into what
    was a cold key range.  ``tick_keys()`` returns one tick's
    ``(read_keys, write_keys)`` and advances the phase — fully
    deterministic for a given seed.
    """

    STRIDE = 0.6180339887498949    # frac(golden ratio): maximally mixing

    def __init__(self, seed: int = 0, topics: int = 64,
                 reads_per_tick: int = 200, writes_per_tick: int = 0,
                 zipf_s: float = 1.2, phase_ticks: int = 40):
        self.rng = random.Random(seed)
        self.topics = topics
        self.reads_per_tick = reads_per_tick
        self.writes_per_tick = writes_per_tick
        self.phase_ticks = phase_ticks
        self.tick = 0
        w = [1.0 / (r ** zipf_s) for r in range(1, topics + 1)]
        total = sum(w)
        self._cum, acc = [], 0.0
        for x in w:
            acc += x / total
            self._cum.append(acc)

    @property
    def phase(self) -> int:
        return self.tick // self.phase_ticks if self.phase_ticks else 0

    def _topic_key(self, rank: int) -> float:
        # rank 0 is the hottest topic; its key position jumps each phase
        return ((rank / self.topics) + self.phase * self.STRIDE) % 1.0

    def _sample_rank(self) -> int:
        return bisect.bisect_left(self._cum, self.rng.random())

    def tick_keys(self) -> Tuple[List[float], List[float]]:
        reads = [self._topic_key(self._sample_rank())
                 for _ in range(self.reads_per_tick)]
        writes = [self._topic_key(self._sample_rank())
                  for _ in range(self.writes_per_tick)]
        self.tick += 1
        return reads, writes

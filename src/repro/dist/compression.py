"""Gradient compression for cross-pod collectives: int8 quantization with
error-feedback residuals (1-bit-Adam / EF-SGD style).

Each leaf is quantized independently against its own max-abs scale:

    scale = max|g + r| / 127          (one f32 per leaf)
    q     = round((g + r) / scale)    (int8)
    r'    = (g + r) - q * scale       (the rounding error, carried)

Carrying the residual makes the compressed stream unbiased over time, so
the *averaged* update converges even though any single step moves by at
most one quantization step.  All ops are jit- and shard_map-safe;
``cross_pod_reduce_compressed`` is the drop-in replacement for a plain
``psum`` of gradients over the pod axis: quantize locally, reduce, and
keep the quantization error on-device for the next step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_residual(tree):
    """Zero error-feedback residuals shaped like the gradient pytree."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), tree)


def _quantize_leaf(g, r):
    x = g.astype(jnp.float32) + r
    scale = jnp.max(jnp.abs(x)) / 127.0
    safe = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(x / safe), -127, 127).astype(jnp.int8)
    new_r = x - q.astype(jnp.float32) * scale
    return q, scale, new_r


def compress_with_feedback(grads, residual):
    """Quantize grads+residual; returns (int8 tree, scale tree, residual')."""
    out = jax.tree.map(_quantize_leaf, grads, residual)
    q = jax.tree.map(lambda o: o[0], out, is_leaf=lambda o: isinstance(o, tuple))
    s = jax.tree.map(lambda o: o[1], out, is_leaf=lambda o: isinstance(o, tuple))
    r = jax.tree.map(lambda o: o[2], out, is_leaf=lambda o: isinstance(o, tuple))
    return q, s, r


def decompress(q, scales):
    """Dequantize an int8 tree back to f32."""
    return jax.tree.map(lambda qi, si: qi.astype(jnp.float32) * si, q, scales)


def compression_ratio(tree) -> float:
    """Wire bytes of the compressed form relative to f32 (per-leaf scale)."""
    num = sum(l.size * 1 + 4 for l in jax.tree.leaves(tree))
    den = sum(l.size * 4 for l in jax.tree.leaves(tree))
    return num / max(den, 1)


def cross_pod_reduce_compressed(grads, residual, axis_name: str = "pod"):
    """Mean-reduce gradients over ``axis_name`` with a compressed payload.

    Call inside shard_map/pmap.  The scale is agreed globally first (pmax
    of each pod's max-abs — a scalar), every pod quantizes against it, and
    the psum moves *int16* instead of f32: half the collective bytes, with
    headroom to sum 256 pods of int8-range values without overflow.  Error
    feedback stays local, against the shared scale.  Returns
    (reduced grads, residual').
    """
    n = jax.lax.psum(1, axis_name)

    def reduce_leaf(g, r):
        x = g.astype(jnp.float32) + r
        scale = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name) / 127.0
        safe = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
        q = jnp.clip(jnp.round(x / safe), -127, 127).astype(jnp.int16)
        total = jax.lax.psum(q, axis_name)       # 2-byte payload on the wire
        new_r = x - q.astype(jnp.float32) * scale
        return total.astype(jnp.float32) * scale / n, new_r

    out = jax.tree.map(reduce_leaf, grads, residual)
    is_pair = lambda o: isinstance(o, tuple)
    reduced = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
    new_res = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
    return reduced, new_res

"""ShardedWarren: hash-partitioned, replicated serving over K shard groups.

Each *logical shard* is a :class:`ReplicaGroup` of R lockstep
:class:`DynamicIndex` replicas.  Which group owns which committed address
is decided by a versioned :class:`RoutingTable`: a sorted set of disjoint
address ranges, each tagged with its owning group.  A fresh warren starts
with the classic striped table (group g owns [g*STRIPE, (g+1)*STRIPE)), and
live rebalancing (:mod:`repro.dist.rebalance`) publishes successor tables —
splitting one group's range at a document boundary, retagging a merged
group's ranges, granting fresh stripes for new allocations — each with a
monotonically increasing *epoch*.

Routing epochs and read consistency: every read session (``start``) pins
ONE table version and one read warren per group, and accepts the pinned set
only if each group's ``epoch`` matches what the table expects — a
rebalance bumps the group epoch *before* rewriting replica state and
publishes the successor table *after*, so a session can never pair a
post-swap group state with a pre-swap table (or vice versa).  Pinned
sessions keep serving their immutable snapshots across a swap; the next
``start`` (or a mid-session failover that trips the epoch check) re-pins
against the current table.  Session reads stay monotonic: the per-group
seqnum high-water mark is keyed by (group, epoch) and the swap only
publishes once the destination holds everything the source committed.

Write path: a ShardedWarren transaction fans out into per-group
transactions, opened lazily; inside a group every live replica stages the
same operations, so deterministic transaction building keeps replicas in
address lockstep.  All *appends* of one transaction land on one group
(chosen by hashing the first appended document over the table's
``write_groups``), which keeps the transaction's staging-address space
consistent; annotations and erases on committed addresses route to their
owners through the *current* table.  Commit is a two-phase *quorum*
commit across the touched groups: phase 1 durably readies the transaction
on every live replica of every group, holding each group's write lock in
ascending group order (no deadlocks, and a replica can never be resurrected
mid-window) — if any group readies fewer than ⌈(R+1)/2⌉ replicas the whole
cross-shard transaction aborts cleanly (:class:`QuorumError`); phase 2
publishes on every readied replica that is still live.  A replica whose
ready/commit raises is failed in place (fail-stop) so the survivors stay
consistent.  A transaction staged against a group that a rebalance rewrote
before phase 1 is *re-staged*, not lost: the warren keeps the logical op
list and transparently replays it against the current topology
(:class:`RouteEpochError` is internal retry fuel, surfaced only if the
topology refuses to settle).

Read path: the class exposes the exact Warren surface (start/end/
transaction/annotations/hopper/translate/phrase/…) by k-way merging
per-group annotation lists served from the *first live replica* of each
group, with automatic failover to a sibling when a replica is marked failed
(or raises :class:`ReplicaFailure`).  ``search`` is the scatter-gather fast
path: global collection statistics are reduced first, each group scores its
own documents with the *global* BM25 parameters, and a k-way merge yields
the global top-k — identical scores to a single index even with R-1
replicas of every group dead, before or after any number of rebalances.

Async scatter: with ``async_scatter=True`` (or ``set_async_scatter``) the
per-group fan-outs of ``annotations``/``global_stats``/``search``/
``search_gcl`` run on a shared :class:`~repro.dist.parallel.ScatterGather`
worker pool instead of a sequential caller-thread loop; per-group replica
failover runs unchanged inside each worker, results are merged in group
order, and ``timings`` accumulates the scatter/score/merge breakdown.
The pool, the timings, and the routing table are shared by every clone of
the warren family.

Failed replicas re-join via ``resurrect``: the lagging replica's state is
rebuilt by streaming the durable segment form (``Segment.to_record``) from
a healthy sibling under the group write lock, restoring address lockstep.

Cold demotion (``demote_group``): a whole replica group can be frozen into
a static run set + manifest (``repro.tiered.demote_index``) — its replicas
drop their in-memory segments and reads are served from the on-disk runs
through a read-only :class:`~repro.tiered.StaticWarren`.  The first write
touching a demoted group transparently *promotes* it back.  A group merged
away by a rebalance is *retired*: it stays addressable (health, checkpoint,
resurrect all keep working) but owns no address range, takes no appends,
and serves empty reads — so group ids stay dense and stable forever.
"""

from __future__ import annotations

import bisect
import heapq
import itertools
import os
import re
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.core import ranking
from repro.dist.parallel import ScatterGather, ScatterTimings
from repro.core.annotation import AnnotationList, merge_lists
from repro.core.featurizer import Featurizer, JsonFeaturizer, murmur64a
from repro.core.gcl import GCLNode, Phrase, Term
from repro.core.index import DynamicIndex, Segment, Transaction
from repro.core.tokenizer import Tokenizer, Utf8Tokenizer
from repro.core.warren import Warren

STRIPE = 1 << 44          # address stripe per shard group (>> any index size)


def shard_of(addr: int) -> int:
    """Owning shard group of a committed address under the *striped*
    layout (addr // STRIPE) — exact for any warren that has never been
    rebalanced; rebalanced warrens route through their RoutingTable."""
    return int(addr) // STRIPE


def route_text(text: str, n_shards: int) -> int:
    """Stable hash partition for appends."""
    return int(murmur64a(text.encode()) % n_shards)


class ReplicaFailure(RuntimeError):
    """A replica cannot serve; readers fail over, writers fail it in place."""


class QuorumError(RuntimeError):
    """Phase 1 readied fewer than ⌈(R+1)/2⌉ replicas of some group; the
    whole cross-shard transaction was aborted cleanly (nothing published)."""


class RouteEpochError(RuntimeError):
    """A transaction was staged against a group that a rebalance rewrote
    before phase 1 could run.  ``ShardedWarren.commit``/``ready`` catch
    this internally and transparently re-stage the logical op list against
    the current routing table; it surfaces only when the topology keeps
    changing faster than the retry budget."""

    def __init__(self, group: int):
        super().__init__(f"shard group {group}: routing epoch changed "
                         "under a staged transaction")
        self.group = group


class _RouteEpochChanged(Exception):
    """Internal reader-side signal: the pinned table went stale mid-read;
    the session refreshes its view and retries the operation."""


# --------------------------------------------------------------------- #
class RoutingTable:
    """Immutable, versioned map from address ranges to shard groups.

    ``ranges``        sorted disjoint ``(lo, hi, gid)`` triples (hi exclusive)
    ``write_groups``  gids that accept appends (retired groups drop out)
    ``group_epochs``  per-gid expected :class:`ReplicaGroup` epoch — the
                      handshake that keeps read sessions consistent across
                      a rebalance swap (see module docstring)
    ``epoch``         monotonic table version; bumped by every successor
    """

    __slots__ = ("epoch", "ranges", "write_groups", "group_epochs", "_los")

    def __init__(self, epoch: int, ranges: Tuple[Tuple[int, int, int], ...],
                 write_groups: Tuple[int, ...],
                 group_epochs: Tuple[int, ...]):
        rs = tuple(sorted(tuple(r) for r in ranges))
        for (alo, ahi, _), (blo, _, _) in zip(rs, rs[1:]):
            if blo < ahi:
                raise ValueError("routing ranges overlap")
        if not write_groups:
            raise ValueError("routing table with no writable group")
        self.epoch = epoch
        self.ranges = rs
        self.write_groups = tuple(write_groups)
        self.group_epochs = tuple(group_epochs)
        self._los = [r[0] for r in rs]

    @staticmethod
    def striped(n_groups: int) -> "RoutingTable":
        """The initial layout: group g owns [g*STRIPE, (g+1)*STRIPE)."""
        return RoutingTable(
            0, tuple((g * STRIPE, (g + 1) * STRIPE, g)
                     for g in range(n_groups)),
            tuple(range(n_groups)), (0,) * n_groups)

    @property
    def n_groups(self) -> int:
        return len(self.group_epochs)

    def owner(self, addr: int) -> Optional[int]:
        """gid owning ``addr``, or None when no range covers it."""
        i = bisect.bisect_right(self._los, int(addr)) - 1
        if i < 0:
            return None
        lo, hi, gid = self.ranges[i]
        return gid if addr < hi else None

    def range_containing(self, addr: int) -> Optional[Tuple[int, int, int]]:
        i = bisect.bisect_right(self._los, int(addr)) - 1
        if i >= 0 and addr < self.ranges[i][1]:
            return self.ranges[i]
        return None

    def ranges_of(self, gid: int) -> List[Tuple[int, int]]:
        return [(lo, hi) for lo, hi, g in self.ranges if g == gid]

    def fresh_stripe(self) -> Tuple[int, int]:
        """An untouched stripe above every routed range (new allocations
        after a split land here, so address spaces never collide)."""
        top = max((hi for _, hi, _ in self.ranges), default=0)
        lo = -(-top // STRIPE) * STRIPE
        return (lo, lo + STRIPE)

    def successor(self, ranges=None, write_groups=None,
                  group_epochs=None) -> "RoutingTable":
        return RoutingTable(
            self.epoch + 1,
            tuple(ranges) if ranges is not None else self.ranges,
            tuple(write_groups) if write_groups is not None
            else self.write_groups,
            tuple(group_epochs) if group_epochs is not None
            else self.group_epochs)

    # -- durable form (checkpointing) ----------------------------------- #
    def to_record(self) -> dict:
        return {"epoch": self.epoch,
                "ranges": [list(r) for r in self.ranges],
                "write_groups": list(self.write_groups),
                "group_epochs": list(self.group_epochs)}

    @staticmethod
    def from_record(rec: dict) -> "RoutingTable":
        return RoutingTable(int(rec["epoch"]),
                            tuple(tuple(r) for r in rec["ranges"]),
                            tuple(rec["write_groups"]),
                            tuple(rec["group_epochs"]))


# --------------------------------------------------------------------- #
class ReplicaGroup:
    """R lockstep DynamicIndex replicas of one logical shard.

    ``alive`` is the fail-stop health vector shared by every clone of the
    owning ShardedWarren.  ``write_lock`` serializes phase-1+2 of quorum
    commits against each other, against ``resurrect``, and against the
    rebalancer's swap window — readers never take it.  ``epoch`` counts
    rebalance rewrites of this group's state (splits trim it, merges grow
    or retire it); it is the group half of the RoutingTable handshake.
    """

    def __init__(self, group_id: int, replicas: List[DynamicIndex]):
        self.group_id = group_id
        self.replicas = replicas
        self.alive = [True] * len(replicas)
        # contention-profiled (lock_wait_ms{lock="group_write"}): commits,
        # swaps, and resurrections queueing here is the first thing to
        # look at when write p95 moves
        # order_key: the lock witness enforces ascending group-id
        # acquisition across groups (the multi-shard commit discipline)
        self.write_lock = obs.ProfiledLock("group_write", threading.RLock(),
                                           order_key=group_id)
        self.epoch = 0
        self.retired = False                 # merged away: empty, addressable
        self.demoted: Optional[str] = None   # run-set directory when cold
        self.static = None                   # StaticWarren serving the runs

    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    @property
    def quorum(self) -> int:
        """⌈(R+1)/2⌉: a strict majority of the group."""
        return len(self.replicas) // 2 + 1

    def live(self) -> List[int]:
        return [r for r, a in enumerate(self.alive) if a]

    def first_alive(self) -> int:
        for r, a in enumerate(self.alive):
            if a:
                return r
        raise ReplicaFailure(
            f"shard group {self.group_id}: no live replica")

    def mark_failed(self, replica: int) -> None:
        self.alive[replica] = False

    # -- control-plane signals (read by repro.dist.autopilot) --------- #
    def replica_seqnums(self) -> List[int]:
        """Per-replica committed seqnum high-water mark (-1 = empty).

        Under the fail-stop model live replicas are in lockstep, so any
        spread between *live* marks is divergence the autopilot's
        anti-entropy policy schedules a re-sync for.  Dead replicas report
        their last published mark; a demoted group's replicas report -1
        (their state lives in the run set, not hot segments)."""
        out = []
        for idx in self.replicas:
            with idx._publish_lock:
                segs = idx._segments
            out.append(max((s.seqnum for s in segs), default=-1))
        return out

    def doc_count(self) -> int:
        """Committed (non-erased) document count of this group — the
        skew signal hot-split policies balance on.  Served from the first
        live replica (or the static run set when demoted); retired groups
        count zero."""
        from repro.core.ranking import DOC_FEATURE

        if self.retired:
            return 0
        if self.demoted is not None:
            st = self.static
            if st is not None:
                w = st.clone()
                w.start()
                try:
                    return len(w.annotations(DOC_FEATURE))
                finally:
                    w.end()
        w = Warren(self.replicas[self.first_alive()])
        w.start()
        try:
            return len(w.annotations(DOC_FEATURE))
        finally:
            w.end()

    # -- cold demotion ----------------------------------------------- #
    def demote(self, directory: str) -> None:
        """Freeze this group into a static run set + manifest and drop the
        replicas' in-memory segments; reads switch to the on-disk runs.
        Pinned reader snapshots keep serving their old segment tuples."""
        from repro.tiered import StaticWarren, demote_index

        with self.write_lock:
            if self.demoted is not None:
                return
            if self.retired:
                raise ValueError(
                    f"shard group {self.group_id} is retired (merged away)")
            src = self.replicas[self.first_alive()]
            demote_index(src, directory)
            # publish the cold read path BEFORE wiping the replicas:
            # lock-free readers check ``demoted`` first, so at every
            # instant they see either the intact replicas or the runs —
            # never an empty shard; and a StaticWarren failure here leaves
            # the group fully hot
            self.static = StaticWarren(directory, src.tokenizer,
                                       src.featurizer)
            self.demoted = directory
            for dst in self.replicas:
                with dst._publish_lock:
                    dst._segments = ()
                    dst._version += 1
                    dst._trim_cache()

    def promote(self) -> None:
        """Resurrect a demoted group: rebuild every replica from the run
        set (``Segment.to_record`` streams) at the recorded address and
        sequence floors, restoring lockstep; all replicas re-join live."""
        from repro.tiered import resurrect_index

        with self.write_lock:
            if self.demoted is None:
                return
            tok = self.replicas[0].tokenizer
            feat = self.replicas[0].featurizer
            fresh = resurrect_index(self.demoted, tok, feat,
                                    n=len(self.replicas))
            for dst, src in zip(self.replicas, fresh):
                with dst._publish_lock:
                    dst._segments = src._segments
                    dst._version += 1
                    dst._next_addr = src._next_addr
                    dst._next_seq = src._next_seq
                    dst._trim_cache()
            self.alive = [True] * len(self.replicas)
            # clear demoted FIRST: lock-free readers check it before
            # dereferencing static (pinned static clones keep serving —
            # their run file handles close when the last reference dies)
            self.demoted = None
            self.static = None

    def resurrect(self, replica: int) -> None:
        """Re-join a failed replica by streaming segments from a healthy
        sibling (durable ``Segment.to_record`` form), restoring lockstep."""
        with self.write_lock:
            if self.demoted is not None:   # cold group: resurrect = promote
                self.promote()
                return
            if self.alive[replica]:
                return
            src = self.replicas[self.first_alive()]
            dst = self.replicas[replica]
            with src._publish_lock:
                segments = src._segments
                next_addr, next_seq = src._next_addr, src._next_seq
            copies = tuple(Segment.from_record(s.to_record())
                           for s in segments)
            with dst._publish_lock:
                dst._segments = copies
                dst._version += 1
                dst._next_addr = next_addr
                dst._next_seq = next_seq
                dst._trim_cache()
            self.alive[replica] = True


class _GroupTxn:
    """One logical-shard transaction fanned out onto live replicas.

    Staging is per-replica (negative addresses, no side effects until
    ready), so replicas that die mid-transaction are simply skipped and
    replicas resurrected mid-transaction catch up by replaying the staged
    operation list at phase 1 — both without breaking lockstep.  The
    group's rebalance epoch is captured at open; phase 1 refuses to ready
    onto a group the rebalancer rewrote in between (RouteEpochError — the
    warren re-stages the whole transaction against the new topology).
    """

    def __init__(self, group: ReplicaGroup):
        self.group = group
        if group.demoted is not None:    # first write wakes a cold group
            group.promote()
        self.epoch0 = group.epoch
        self.txns: Dict[int, Transaction] = {}
        self.ops: List[Tuple] = []       # replay log for late joiners
        for r in group.live():
            self.txns[r] = group.replicas[r].transaction()
        if not self.txns:
            raise ReplicaFailure(
                f"shard group {group.group_id}: no live replica for writes")

    # -- staged operations (fan out to live replicas) -------------------- #
    def _apply(self, op: Tuple, txn: Transaction):
        kind = op[0]
        if kind == "append":
            return txn.append(op[1])
        if kind == "annotate":
            return txn.annotate(*op[1:])
        return txn.erase(*op[1:])

    def _fan_out(self, op: Tuple):
        self.ops.append(op)
        out = None
        for r in list(self.txns):
            if not self.group.alive[r]:
                # the replica missed this op: discard its staging so a
                # resurrected replica rebuilds via the phase-1 replay
                # instead of readying a torn partial transaction
                self.txns.pop(r).abort()
                continue
            res = self._apply(op, self.txns[r])
            if out is None:
                out = res
        if out is None and op[0] == "append":
            raise ReplicaFailure(
                f"shard group {self.group.group_id}: no live replica")
        return out

    def append(self, text: str) -> Tuple[int, int]:
        return self._fan_out(("append", text))

    def annotate(self, feature, p: int, q: int, v: float,
                 v_is_address: bool) -> None:
        self._fan_out(("annotate", feature, p, q, v, v_is_address))

    def erase(self, p: int, q: int) -> None:
        self._fan_out(("erase", p, q))

    # -- two-phase quorum commit ------------------------------------------ #
    def quorum_ready(self, hook: Optional[Callable] = None) -> int:
        """Phase 1 on this group; returns the number of readied replicas.

        Caller holds ``group.write_lock``.  Replicas resurrected since the
        transaction opened get the staged ops replayed first; replicas
        whose ready() raises are failed in place so the address space of
        the surviving replicas stays in lockstep.
        """
        if self.group.epoch != self.epoch0:
            raise RouteEpochError(self.group.group_id)
        if self.group.demoted is not None:
            # the group was demoted between this transaction opening and
            # its commit: promote it back (restoring every replica from the
            # run set) so phase 1 publishes onto real state, not the wiped
            # replicas of a cold group
            self.group.promote()
        for r in self.group.live():          # late joiners (resurrected)
            if r not in self.txns:
                txn = self.group.replicas[r].transaction()
                try:
                    for op in self.ops:
                        self._apply(op, txn)
                except Exception:
                    self.group.mark_failed(r)
                    continue
                self.txns[r] = txn
        ready = 0
        for r, txn in self.txns.items():
            if not self.group.alive[r]:
                continue
            if hook is not None:
                hook(self.group.group_id, r)
            if not self.group.alive[r]:      # the hook may have killed it
                continue
            try:
                if txn._state == "open":
                    txn.ready()
                if txn._state == "ready":
                    ready += 1
            except Exception:
                self.group.mark_failed(r)
        return ready

    def commit_live(self):
        """Phase 2: publish on every live, readied replica.

        Returns (remap, error): the staging→permanent remap of the first
        replica that published (they are identical by lockstep), or
        (None, err) when no replica could publish.
        """
        remap, err = None, None
        for r, txn in self.txns.items():
            if not self.group.alive[r] or txn._state != "ready":
                continue
            try:
                txn.commit()
            except Exception as e:
                err = err or e
                self.group.mark_failed(r)
                continue
            if remap is None:
                remap = txn.remap
        return remap, err

    def abort(self) -> None:
        for txn in self.txns.values():
            if txn._state in ("open", "ready"):
                try:
                    txn.abort()
                except Exception:
                    pass


# --------------------------------------------------------------------- #
class _ShardedIndexView:
    """Facade matching the bits of DynamicIndex callers poke at."""

    def __init__(self, groups: List[ReplicaGroup], tokenizer, featurizer):
        self._groups = groups
        self.tokenizer = tokenizer
        self.featurizer = featurizer

    @property
    def _segments(self) -> tuple:
        out = []
        for g in self._groups:
            if g.demoted is not None or g.retired:  # cold/retired: no hot segs
                continue
            out.extend(g.replicas[g.first_alive()]._segments)
        return tuple(out)

    def merge_segments(self, upto: Optional[int] = None) -> None:
        # compaction is deterministic, so live replicas stay equivalent
        for g in self._groups:
            with g.write_lock:
                if g.demoted is not None or g.retired:
                    continue
                for r in g.live():
                    g.replicas[r].merge_segments(upto)


class ShardedWarren:
    """K×R replicated shard groups with the single-Warren lifecycle surface."""

    def __init__(self, n_shards: int = 4, replicas: int = 1,
                 tokenizer: Optional[Tokenizer] = None,
                 featurizer: Optional[Featurizer] = None,
                 log_dir: Optional[str] = None,
                 static_dir: Optional[str] = None,
                 async_scatter: bool = False,
                 scatter_workers: Optional[int] = None,
                 _shards: Optional[List[DynamicIndex]] = None,
                 _groups: Optional[List[ReplicaGroup]] = None,
                 _table: Optional[RoutingTable] = None,
                 _hooks: Optional[dict] = None,
                 _shared: Optional[dict] = None):
        self.tokenizer = tokenizer or Utf8Tokenizer()
        self.featurizer = featurizer or JsonFeaturizer()
        self.static_dir = static_dir     # default root for cold demotion
        if _groups is not None:
            self.groups = _groups
        elif _shards is not None:        # back-compat: bare index list
            self.groups = [ReplicaGroup(g, [idx])
                           for g, idx in enumerate(_shards)]
        else:
            if replicas < 1:
                raise ValueError("replicas must be >= 1")
            self.groups = []
            for g in range(n_shards):
                reps = []
                for r in range(replicas):
                    path = (f"{log_dir}/shard{g:02d}r{r}.log"
                            if log_dir is not None else None)
                    idx = DynamicIndex(self.tokenizer, self.featurizer,
                                       log_path=path)
                    idx._next_addr = g * STRIPE
                    reps.append(idx)
                self.groups.append(ReplicaGroup(g, reps))
        # scatter pool + serving timings + the routing table, shared by
        # every clone so a runtime toggle, a breakdown read, or a rebalance
        # swap is seen by the whole family
        if _shared is not None:
            self._ctx = _shared
        else:
            self._ctx = {
                "scatter": (ScatterGather(scatter_workers)
                            if async_scatter else None),
                "timings": ScatterTimings(),
                "table": _table or RoutingTable.striped(len(self.groups)),
                "rebalance_lock": obs.ProfiledLock("rebalance"),
            }
        self.index = _ShardedIndexView(self.groups, self.tokenizer,
                                       self.featurizer)
        # test/ops hooks, shared across clones:
        #   "on_ready"(group_id, replica)  — phase 1, before each ready()
        #   "mid_commit"(warren, group_id) — between phase 1 and phase 2
        #   "mid_migration"(warren, stage, group_id) — rebalance checkpoints
        self.hooks: dict = _hooks if _hooks is not None else {}
        self._started = False
        self._table: Optional[RoutingTable] = None   # pinned per session
        self._read: Dict[int, Tuple[Optional[int], Warren]] = {}
        # monotonic session reads: highest segment seqnum this clone has
        # served per group, keyed by the group epoch it was observed under;
        # failover never steps behind it
        self._hwm: Dict[int, Tuple[int, int]] = {}
        self._txn_open: Dict[int, _GroupTxn] = {}    # group -> fan-out txn
        self._txn_ops: List[Tuple] = []              # logical op replay log
        self._txn_active = False
        self._txn_ready = False
        self._held: List[int] = []                   # group locks held
        self._append_shard: Optional[int] = None

    # -- replica lifecycle ------------------------------------------------ #
    def mark_failed(self, group: int, replica: int) -> None:
        """Fail-stop a replica: it stops serving reads and taking writes."""
        self.groups[group].mark_failed(replica)

    def resurrect(self, group: int, replica: int) -> None:
        """Re-sync a failed replica from a healthy sibling and re-join it."""
        self.groups[group].resurrect(replica)

    def health(self) -> List[List[bool]]:
        return [list(g.alive) for g in self.groups]

    # -- control-plane signals (read by repro.dist.autopilot) ------------ #
    def group_doc_counts(self) -> List[int]:
        """Committed document count per group (0 for retired groups)."""
        return [g.doc_count() for g in self.groups]

    def group_seqnums(self) -> List[List[int]]:
        """Per-group, per-replica committed seqnum high-water marks."""
        return [g.replica_seqnums() for g in self.groups]

    def describe_routing(self) -> dict:
        """JSON-able view of the CURRENT routing table and per-group
        state — the admin server's ``/routing`` payload.  Reads only
        lock-free fields plus the replicas' publish locks (for seqnums),
        never a group write lock, so a scrape mid-rebalance cannot block
        writers; the epoch pair makes a torn read visible instead."""
        table = self._ctx["table"]
        groups = {}
        for g, grp in enumerate(self.groups):
            groups[str(g)] = {
                "epoch": grp.epoch,
                "table_epoch": table.group_epochs[g]
                if g < len(table.group_epochs) else None,
                "retired": grp.retired,
                "demoted": grp.demoted,
                "alive": list(grp.alive),
                "n_replicas": grp.n_replicas,
                "replica_seqnums": grp.replica_seqnums(),
                "ranges": [[lo, hi] for lo, hi in table.ranges_of(g)],
            }
        return {"epoch": table.epoch,
                "write_groups": list(table.write_groups),
                "n_groups": len(self.groups),
                "groups": groups}

    # -- cold demotion ----------------------------------------------------- #
    def _group_static_dir(self, group: int,
                          directory: Optional[str]) -> str:
        if directory is not None:
            return directory
        if self.static_dir is None:
            raise ValueError("demote_group needs a directory (or construct "
                             "the ShardedWarren with static_dir=...)")
        return os.path.join(self.static_dir, f"group{group:02d}")

    def demote_group(self, group: int,
                     directory: Optional[str] = None) -> str:
        """Demote a cold replica group to an on-disk static run set; reads
        keep working (served from the runs), the next write promotes it."""
        d = self._group_static_dir(group, directory)
        self.groups[group].demote(d)
        return d

    def promote_group(self, group: int) -> None:
        """Rebuild a demoted group's replicas from its static run set."""
        self.groups[group].promote()

    def demoted(self) -> List[Optional[str]]:
        """Per group: the run-set directory when demoted, else None."""
        return [g.demoted for g in self.groups]

    # -- lifecycle ------------------------------------------------------ #
    @property
    def n_shards(self) -> int:
        return len(self.groups)

    @property
    def replicas(self) -> int:
        return max(g.n_replicas for g in self.groups)

    @property
    def shards(self) -> List[DynamicIndex]:
        """Primary replica per group (callers wanting one index per shard)."""
        return [g.replicas[0] for g in self.groups]

    @property
    def routing(self) -> RoutingTable:
        """The family's CURRENT routing table (sessions pin their own)."""
        return self._ctx["table"]

    def clone(self) -> "ShardedWarren":
        return ShardedWarren(tokenizer=self.tokenizer,
                             featurizer=self.featurizer, _groups=self.groups,
                             static_dir=self.static_dir, _hooks=self.hooks,
                             _shared=self._ctx)

    # -- async scatter ----------------------------------------------------- #
    @property
    def async_scatter(self) -> bool:
        return self._ctx["scatter"] is not None

    @property
    def timings(self) -> ScatterTimings:
        """Scatter/score/merge breakdown of every ``search`` in the family."""
        return self._ctx["timings"]

    @property
    def scatter_pool(self) -> Optional[ScatterGather]:
        """The family's ScatterGather pool when async scatter is enabled."""
        return self._ctx["scatter"]

    def set_async_scatter(self, enabled: bool,
                          workers: Optional[int] = None) -> None:
        """Toggle pool-based scatter for this warren and all its clones."""
        pool = self._ctx["scatter"]
        if enabled and pool is None:
            self._ctx["scatter"] = ScatterGather(workers)
        elif not enabled and pool is not None:
            self._ctx["scatter"] = None
            pool.close()

    def close(self) -> None:
        """Shut down the scatter pool (reads fall back to sequential)."""
        self.set_async_scatter(False)

    def map_groups(self, fn) -> List:
        """Apply ``fn(warren)`` to every group's serving replica, in group
        order of this session's pinned routing table, with per-group replica
        failover; fanned out on the scatter pool when async scatter is
        enabled, else a caller-thread loop.  If a rebalance swap lands
        mid-fan-out, the session refreshes its pinned view and retries —
        readers are never aborted by a topology change."""
        self._require_started()
        for _ in range(8):
            table = self._table
            gids = range(table.n_groups)
            pool = self._ctx["scatter"]
            try:
                if pool is not None and table.n_groups > 1:
                    return pool.run([(lambda g=g: self._scatter_read(g, fn))
                                     for g in gids])
                return [self._scatter_read(g, fn) for g in gids]
            except _RouteEpochChanged:
                self._refresh_view()
        raise ReplicaFailure("routing table kept changing mid-read")

    def _scatter_read(self, group: int, fn):
        """One group's leg of a fan-out: a ``scatter`` span plus the
        per-group latency histogram around the failover-protected read."""
        reg = obs.registry()
        with obs.span("scatter", group=group):
            t0 = time.perf_counter()
            try:
                return self._group_read(group, fn)
            finally:
                if reg.enabled:
                    reg.histogram(
                        "scatter_latency_ms",
                        "per-group fan-out read time (failover included)",
                        group=group,
                    ).observe(1e3 * (time.perf_counter() - t0))

    def start(self) -> None:
        if self._started:
            raise RuntimeError("already started")
        self._pin_view()
        self._started = True

    def _pin_view(self, settle: float = 5.0) -> None:
        """Pin (table, per-group read warren) pairs that agree on every
        group's epoch.  The rebalancer bumps a group's epoch before
        rewriting its state and publishes the successor table after, so a
        full set of matching pins is a consistent cut of the family."""
        deadline = time.monotonic() + settle
        while True:
            table = self._ctx["table"]
            read: Dict[int, Tuple[Optional[int], Warren]] = {}
            ok = True
            try:
                for gid in range(table.n_groups):
                    grp = self.groups[gid]
                    if grp.epoch != table.group_epochs[gid]:
                        ok = False
                        break
                    read[gid] = self._start_read(grp)
                    if grp.epoch != table.group_epochs[gid]:
                        ok = False
                        break
            except Exception:
                for _, w in read.values():
                    w.end()
                raise
            if ok and self._ctx["table"] is table:
                self._table, self._read = table, read
                return
            for _, w in read.values():
                w.end()
            if time.monotonic() > deadline:
                raise ReplicaFailure(
                    "routing table swap did not settle within the pin window")
            time.sleep(0.0005)

    def _refresh_view(self) -> None:
        """Drop the pinned view and re-pin against the current table (used
        when a failover trips over a rebalance swap mid-session).  Data
        monotonicity is preserved: a swap only publishes once its successor
        state holds every commit the session may have observed."""
        for _, w in self._read.values():
            w.end()
        self._read = {}
        self._pin_view()

    def _start_read(self, group: ReplicaGroup,
                    catchup: float = 2.0) -> Tuple[Optional[int], Warren]:
        """Start a read warren on a live replica whose snapshot has caught
        up to this clone's high-water seqnum for the group.

        Per-group commits are serialized under the group write lock, so a
        replica's published segments form a seqnum-ordered prefix; a
        snapshot at max-seq ≥ the high-water mark therefore contains every
        transaction this session has already observed (monotonic session
        reads — failover mid-publish can never step backwards).  A replica
        still publishing catches up within the commit window, hence the
        brief bounded wait.  The mark is keyed by the group's rebalance
        epoch: a rebalance renumbers or re-homes segments, but only ever
        publishes supersets of the committed data, so resetting the mark at
        an epoch boundary keeps session reads monotonic in *data*.
        """
        gid = group.group_id
        epoch = group.epoch
        got = self._hwm.get(gid)
        floor = got[1] if got is not None and got[0] == epoch else -1
        last: Optional[Exception] = None
        deadline = time.monotonic() + catchup
        while True:
            st = group.static if group.demoted is not None else None
            if st is not None:           # snapshot: promote() may race
                w = st.clone()
                w.start()
                seq = w.max_seqnum()
                if seq >= floor:
                    self._hwm[gid] = (epoch, seq)
                    return (None, w)     # None: static, no replica number
                w.end()                  # promote+commit+demote raced; retry
            for r in group.live():
                w = Warren(group.replicas[r])
                try:
                    w.start()
                except Exception as e:   # failover past a broken replica
                    group.mark_failed(r)
                    last = e
                    continue
                seq = max((s.seqnum for s in w._snapshot.segments),
                          default=-1)
                if seq >= floor:
                    self._hwm[gid] = (epoch, seq)
                    return (r, w)
                w.end()                  # stale: publish in flight; retry
            if not group.live():
                raise ReplicaFailure(
                    f"shard group {gid}: no live replica") from last
            if time.monotonic() > deadline:
                raise ReplicaFailure(
                    f"shard group {gid}: no live replica caught up to "
                    f"seq {floor}")
            time.sleep(0.0005)

    def end(self) -> None:
        for _, w in self._read.values():
            w.end()
        self._read = {}
        self._table = None
        self._started = False

    def __enter__(self) -> "ShardedWarren":
        self.start()
        return self

    def __exit__(self, *exc) -> bool:
        if self._txn_active:
            self._abort_locked()
        self.end()
        return False

    # -- transactions ---------------------------------------------------- #
    def transaction(self) -> None:
        self._require_started()
        if self._txn_active:
            raise RuntimeError("transaction already active on this warren")
        self._txn_active = True

    def _reset_txn(self) -> None:
        self._txn_open = {}
        self._txn_ops = []
        self._txn_active = False
        self._txn_ready = False
        self._append_shard = None

    def _txn_group(self, group: int) -> _GroupTxn:
        if not self._txn_active:
            raise RuntimeError("no active transaction")
        if self._txn_ready:
            raise RuntimeError("transaction already readied")
        gt = self._txn_open.get(group)
        if gt is None:
            gt = _GroupTxn(self.groups[group])
            self._txn_open[group] = gt
        return gt

    def _route_addr(self, p: int) -> int:
        if p < 0:                      # staging address -> the append group
            if self._append_shard is None:
                raise RuntimeError("staging address with no appends")
            return self._append_shard
        gid = self._ctx["table"].owner(p)
        if gid is None:
            raise ValueError(f"address {p} is outside every routed range")
        return gid

    def append(self, text: str) -> Tuple[int, int]:
        if self._append_shard is None:
            wg = self._ctx["table"].write_groups
            self._append_shard = wg[route_text(text, len(wg))]
        self._txn_ops.append(("append", text))
        return self._txn_group(self._append_shard).append(text)

    def annotate(self, feature, p: int, q: int, v: float = 0.0,
                 v_is_address: bool = False) -> None:
        group = self._route_addr(p)
        if v_is_address and v < 0 and group != self._append_shard:
            raise ValueError("staging-valued annotation on a foreign shard")
        self._txn_ops.append(("annotate", feature, p, q, v, v_is_address))
        self._txn_group(group).annotate(feature, p, q, v, v_is_address)

    def erase(self, p: int, q: int) -> None:
        self._txn_ops.append(("erase", p, q))
        self._txn_group(self._route_addr(p)).erase(p, q)

    # -- two-phase quorum commit ------------------------------------------ #
    def _acquire_locks(self) -> None:
        for g in sorted(self._txn_open):     # ascending order: deadlock-free
            self.groups[g].write_lock.acquire()
            self._held.append(g)

    def _release_locks(self) -> None:
        for g in reversed(self._held):
            self.groups[g].write_lock.release()
        self._held = []

    def _phase1(self) -> None:
        """Quorum-ready every touched group or raise QuorumError."""
        hook = self.hooks.get("on_ready")
        t0 = time.perf_counter()
        try:
            for g in sorted(self._txn_open):
                gt = self._txn_open[g]
                ok = gt.quorum_ready(hook=hook)
                if ok < gt.group.quorum:
                    reg = obs.registry()
                    if reg.enabled:
                        reg.counter(
                            "txn_quorum_abort_total",
                            "cross-shard transactions aborted because a "
                            "touched group could not ready a quorum").inc()
                    raise QuorumError(
                        f"shard group {g}: {ok}/{gt.group.n_replicas} "
                        f"replicas ready, quorum is {gt.group.quorum}")
        finally:
            reg = obs.registry()
            if reg.enabled:
                reg.histogram(
                    "txn_quorum_wait_ms",
                    "phase-1 time to durably ready a quorum of every "
                    "touched group",
                ).observe(1e3 * (time.perf_counter() - t0))

    def _restage(self) -> None:
        """Re-stage the logical op list against the current routing table
        after a rebalance rewrote a touched group (staging addresses only
        depend on op order, so the replay reproduces them exactly)."""
        ops = self._txn_ops
        for gt in self._txn_open.values():
            gt.abort()
        self._release_locks()
        self._txn_open = {}
        self._txn_ops = []
        self._append_shard = None
        for op in ops:
            if op[0] == "append":
                self.append(op[1])
            elif op[0] == "annotate":
                self.annotate(*op[1:])
            else:
                self.erase(*op[1:])

    def _ready_with_restage(self) -> None:
        """Acquire locks + phase 1, transparently re-staging (bounded) when
        a rebalance swap rewrote a touched group under the staged txn."""
        for _ in range(4):
            self._acquire_locks()
            try:
                self._phase1()
                return
            except RouteEpochError:
                self._restage()          # releases the locks; retry
            except Exception:
                self._abort_locked()
                raise
        self._abort_locked()
        raise RouteEpochError(-1)

    def ready(self) -> None:
        """Phase 1 now; the group write locks stay held until commit()/
        abort() so replicas cannot drift between the phases."""
        if not self._txn_active:
            raise RuntimeError("no active transaction")
        if self._txn_ready:
            raise RuntimeError("transaction already readied")
        self._ready_with_restage()
        self._txn_ready = True

    def commit(self):
        """Two-phase quorum commit across every group this transaction
        touched; raises QuorumError (cleanly aborted) when any group cannot
        ready a majority of its replicas."""
        if not self._txn_active:
            raise RuntimeError("no active transaction")
        if not self._txn_ready:
            self._ready_with_restage()
        mid = self.hooks.get("mid_commit")
        if mid is not None:
            for g in sorted(self._txn_open):
                mid(self, g)
        append_remap = None
        failed: Optional[BaseException] = None
        reg = obs.registry()
        try:
            for g in sorted(self._txn_open):   # phase 2: publish
                remap, err = self._txn_open[g].commit_live()
                if remap is None:              # every replica of g failed —
                    failed = failed or err or RuntimeError(  # ready records
                        f"shard group {g}: no replica published")  # durable
                else:
                    if reg.enabled:
                        reg.counter("shard_write_total",
                                    "group transactions published",
                                    group=g).inc()
                    if g == self._append_shard:
                        append_remap = remap
        finally:
            self._release_locks()
            self._reset_txn()
        if failed is not None:
            raise RuntimeError(
                "partial cross-shard commit: some groups published, the "
                "rest are recoverable from their ready records") from failed
        if reg.enabled:
            # the success half of the quorum-commit SLO ratio
            # (bad = txn_quorum_abort_total, incremented at phase 1)
            reg.counter("txn_quorum_commit_total",
                        "cross-shard transactions fully published").inc()
        return append_remap if append_remap is not None else (lambda a: a)

    def abort(self) -> None:
        if not self._txn_active:
            raise RuntimeError("no active transaction")
        self._abort_locked()

    def _abort_locked(self) -> None:
        for gt in self._txn_open.values():
            gt.abort()
        self._release_locks()
        self._reset_txn()

    # -- reads (merged across groups, replica failover) -------------------- #
    def _repin(self, group: int) -> None:
        """Re-pin one group's read warren mid-session, unless the pinned
        table went stale under a rebalance (then the whole view refreshes)."""
        grp = self.groups[group]
        if grp.epoch != self._table.group_epochs[group]:
            raise _RouteEpochChanged()
        self._read[group] = self._start_read(grp)
        if grp.epoch != self._table.group_epochs[group]:
            raise _RouteEpochChanged()

    def _group_read(self, group: int, fn):
        """Run ``fn(warren)`` on the group's serving replica, failing over
        to a live sibling when the replica was marked failed or raises
        ReplicaFailure."""
        grp = self.groups[group]
        reg = obs.registry()
        if reg.enabled:
            reg.counter("shard_read_total", "group reads served",
                        group=group).inc()
        for _ in range(grp.n_replicas + 1):
            r, w = self._read[group]
            if r is None:                # static read over a demoted group
                with obs.span("replica_read", group=group, replica="static"):
                    return fn(w)
            if not grp.alive[r]:
                self._repin(group)
                continue
            try:
                with obs.span("replica_read", group=group, replica=r):
                    return fn(w)
            except ReplicaFailure:
                grp.mark_failed(r)
                if reg.enabled:
                    reg.counter("shard_failover_total",
                                "reads that failed over to a sibling",
                                group=group).inc()
                self._repin(group)
        raise ReplicaFailure(f"shard group {group}: failover exhausted")

    def _routed_read(self, p: int, fn):
        """Point read on the group owning address ``p`` (session table),
        refreshing the view when a rebalance swap lands mid-read."""
        self._require_started()
        for _ in range(8):
            gid = self._table.owner(p)
            if gid is None:
                return None
            try:
                return self._group_read(gid, fn)
            except _RouteEpochChanged:
                self._refresh_view()
        raise ReplicaFailure("routing table kept changing mid-read")

    def featurize(self, feature: str) -> int:
        return self.featurizer.featurize(feature)

    def annotations(self, feature) -> AnnotationList:
        self._require_started()
        fval = feature if isinstance(feature, int) else self.featurize(feature)
        return merge_lists(self.map_groups(lambda w: w.annotations(fval)))

    def hopper(self, feature) -> Term:
        return Term(self.annotations(feature))

    def translate(self, p: int, q: int) -> Optional[str]:
        return self._routed_read(p, lambda w: w.translate(p, q))

    def tokens(self, p: int, q: int) -> Optional[List[str]]:
        return self._routed_read(p, lambda w: w.tokens(p, q))

    def phrase(self, text: str) -> GCLNode:
        self._require_started()
        words = self.tokenizer.split(text)
        terms = [self.hopper(w) for w in words]
        if not terms:
            return Term(AnnotationList.empty())
        return terms[0] if len(terms) == 1 else Phrase(terms)

    # -- scatter-gather serving ------------------------------------------- #
    def global_stats(self) -> ranking.CollectionStats:
        """Cross-group collection statistics (one pass, reduced).

        Concatenated per-group vectors are re-sorted by document start
        address: group order stops matching address order once a rebalance
        has split or merged ranges, and downstream scoring binary-searches
        ``doc_starts``."""
        self._require_started()
        per = self.map_groups(ranking.collection_stats)
        n_docs = sum(s.n_docs for s in per)
        total_len = sum(float(s.doc_lens.sum()) for s in per)
        avgdl = total_len / n_docs if n_docs else 1.0
        starts = np.concatenate([s.doc_starts for s in per])
        ends = np.concatenate([s.doc_ends for s in per])
        lens = np.concatenate([s.doc_lens for s in per])
        if len(starts) and not np.all(starts[:-1] <= starts[1:]):
            order = np.argsort(starts, kind="stable")
            starts, ends, lens = starts[order], ends[order], lens[order]
        return ranking.CollectionStats(n_docs, avgdl, starts, ends, lens)

    def search(self, query: str, k: int = 10, k1: float = 0.9,
               b: float = 0.4) -> List[Tuple[int, float]]:
        """Scatter-gather BM25: per-group top-k + global k-way merge.

        Global document frequencies and avgdl make per-group scores exactly
        the single-index scores, so the merged top-k is exact — from any
        live replica of each group, before or after any rebalance.
        """
        self._require_started()
        t0 = time.perf_counter()
        terms = list(dict.fromkeys(ranking.ranking_tokens(query)))
        fvals = [ranking.TF_PREFIX + ranking.porter_stem(t) for t in terms]
        # scatter 1: per-group stats + term lists (one replica per group)
        gathered = self.map_groups(
            lambda w: (ranking.collection_stats(w),
                       [w.annotations(f) for f in fvals]))
        per = [s for s, _ in gathered]
        lists = [l for _, l in gathered]
        n_groups = len(gathered)
        n_docs = sum(s.n_docs for s in per)
        if n_docs == 0:
            self.timings.add(scatter=time.perf_counter() - t0)
            return []
        total_len = sum(float(s.doc_lens.sum()) for s in per)
        avgdl = total_len / n_docs
        # reduce document frequencies
        dfs = [sum(len(lists[gi][ti]) for gi in range(n_groups))
               for ti in range(len(terms))]
        t1 = time.perf_counter()

        # scatter 2: score each group with the GLOBAL idf/avgdl
        def score_group(gi: int) -> List[Tuple[float, int]]:
            stats = per[gi]
            if stats.n_docs == 0:
                return []
            acc = np.zeros(stats.n_docs)
            for ti in range(len(terms)):
                lst = lists[gi][ti]
                if len(lst) == 0 or dfs[ti] == 0:
                    continue
                idf = ranking._bm25_idf(n_docs, dfs[ti])
                di, imp = ranking._impacts_with_avgdl(lst, stats, idf,
                                                      avgdl, k1, b)
                np.add.at(acc, di, imp)
            kk = min(k, stats.n_docs)
            top = np.argpartition(-acc, kk - 1)[:kk]
            # order ties by doc index (= ascending address), so every run
            # is sorted by the merge key below
            top = top[np.lexsort((top, -acc[top]))]
            return [(float(acc[i]), int(stats.doc_starts[i]))
                    for i in top if acc[i] > 0]

        pool = self._ctx["scatter"]
        if pool is not None and n_groups > 1:
            per_group_topk = pool.map(score_group, range(n_groups))
        else:
            per_group_topk = [score_group(g) for g in range(n_groups)]
        t2 = time.perf_counter()
        # gather: lazy k-way merge of per-group results; ties at equal
        # scores resolve by address, matching the single-index argsort
        merged = heapq.merge(*per_group_topk, key=lambda t: (-t[0], t[1]))
        out = [(d, s) for s, d in itertools.islice(merged, k)]
        t3 = time.perf_counter()
        self.timings.add(scatter=t1 - t0, score=t2 - t1, merge=t3 - t2)
        return out

    def search_gcl(self, query_text: str, limit: int = 1000) -> List:
        """Scatter-gather structural query: solve per group, concatenate.

        Exact when query solutions don't cross group boundaries — true for
        any query over intra-document structure, since a document lives
        wholly inside one group (rebalance pivots are document boundaries).
        """
        from repro.core.query import solve
        self._require_started()
        per = self.map_groups(lambda w: solve(query_text, w, limit=limit))
        out = [sol for group_sols in per for sol in group_sols]
        out.sort()
        return out[:limit]

    # -- fault tolerance --------------------------------------------------- #
    def checkpoint(self, manager, step: int) -> None:
        """Snapshot one live replica per group through a CheckpointManager
        (replicas are lockstep-identical, so one copy per group suffices),
        plus the routing table and per-group allocation floors.  A demoted
        group is materialized transiently from its run set so the
        checkpoint stays a complete, self-contained shard family.  Retired
        groups checkpoint as empty snapshots — they stay addressable.
        Consistency: the snapshot loop runs under the family's rebalance
        lock (a split/merge landing between two group snapshots would tear
        the checkpoint across two topologies) AND under every group's
        write lock at once, acquired in ascending order — the same
        discipline quorum commits use — so a cross-shard transaction can
        never be half-captured (its annotations in one group's snapshot,
        the content they reference missing from another's)."""
        with self._ctx["rebalance_lock"]:
            for group in self.groups:          # ascending id order
                group.write_lock.acquire()
            try:
                floors = []
                for g, group in enumerate(self.groups):
                    if group.demoted is not None:
                        from repro.tiered import resurrect_index
                        src = resurrect_index(group.demoted, self.tokenizer,
                                              self.featurizer, n=1)[0]
                    else:
                        src = group.replicas[group.first_alive()]
                    manager.save_index(step, src, name=f"shard{g:02d}")
                    floors.append({"next_addr": int(src._next_addr),
                                   "next_seq": int(src._next_seq),
                                   "retired": bool(group.retired)})
                manager.save_routing(step, {
                    "table": self._ctx["table"].to_record(),
                    "groups": floors})
            finally:
                for group in reversed(self.groups):
                    group.write_lock.release()

    @staticmethod
    def restore(manager, step: int, tokenizer: Optional[Tokenizer] = None,
                featurizer: Optional[Featurizer] = None,
                replicas: int = 1) -> "ShardedWarren":
        """Rebuild from per-group snapshot logs at ``step``, fanning each
        group's snapshot out to ``replicas`` independent copies.

        When the checkpoint carries a routing record (any warren
        checkpointed since rebalancing landed), the routing table, group
        epochs, retirement flags, and exact allocation floors are restored
        with it; legacy checkpoints fall back to the striped table.  A gap
        in the group set (a torn multi-shard checkpoint) is an error,
        never a silent truncation — a missing middle group would corrupt
        routing for every later group.
        """
        from repro.dist.checkpoint import CheckpointCorrupt

        routing = manager.restore_routing(step)
        present = set()
        for fn in os.listdir(manager.directory):
            m = re.match(r"^shard(\d+)_(\d{8})\.log$", fn)
            if m and int(m.group(2)) == step:
                present.add(int(m.group(1)))
        if not present:
            raise FileNotFoundError(f"no shard snapshots at step {step}")
        n_expected = (RoutingTable.from_record(routing["table"]).n_groups
                      if routing is not None else max(present) + 1)
        missing = set(range(n_expected)) - present
        if missing:
            raise CheckpointCorrupt(
                f"step {step} is missing shard snapshots {sorted(missing)} "
                f"of {n_expected}")
        tokenizer = tokenizer or Utf8Tokenizer()
        featurizer = featurizer or JsonFeaturizer()
        table = (RoutingTable.from_record(routing["table"])
                 if routing is not None else None)
        groups: List[ReplicaGroup] = []
        for g in range(n_expected):
            reps = manager.restore_index_replicas(
                step, name=f"shard{g:02d}", n=replicas,
                tokenizer=tokenizer, featurizer=featurizer)
            if routing is not None:
                floors = routing["groups"][g]
                for idx in reps:
                    idx._next_addr = int(floors["next_addr"])
                    idx._next_seq = int(floors["next_seq"])
            else:
                for idx in reps:
                    # legacy (pre-routing) checkpoints are striped by
                    # construction; a group whose recovered addresses fall
                    # outside its stripe can only come from a rebalanced
                    # family whose routing record was lost — refuse loudly
                    # instead of silently misrouting the moved addresses
                    if idx._next_addr > 0 and \
                            shard_of(idx._next_addr - 1) != g:
                        raise CheckpointCorrupt(
                            f"shard {g} snapshot holds addresses outside "
                            f"its stripe but step {step} has no routing "
                            "record — rebalanced checkpoint missing its "
                            "routing file")
                    idx._next_addr = max(idx._next_addr, g * STRIPE)
            grp = ReplicaGroup(g, reps)
            if routing is not None:
                grp.epoch = table.group_epochs[g]
                grp.retired = bool(routing["groups"][g].get("retired"))
            groups.append(grp)
        return ShardedWarren(tokenizer=tokenizer, featurizer=featurizer,
                             _groups=groups, _table=table)

    # -- internals --------------------------------------------------------- #
    def _require_started(self) -> None:
        if not self._started:
            raise RuntimeError("warren access outside start()/end()")

"""ShardedWarren: hash-partitioned serving over K dynamic index shards.

Each shard is a full :class:`DynamicIndex` owning a disjoint *address
stripe* (shard i allocates permanent addresses in [i*STRIPE, (i+1)*STRIPE)),
so a global address names its owning shard — reads route by ``addr //
STRIPE`` and committed cross-shard annotations just work.

Write path: a ShardedWarren transaction fans out into per-shard
transactions, opened lazily.  All *appends* of one transaction land on one
shard (chosen by hashing the first appended document), which keeps the
transaction's staging-address space consistent; annotations and erases on
committed addresses route to their owners.  Commit is two-phase across the
touched shards: ready() everywhere, then commit() everywhere — each shard's
own transaction log provides per-shard durability.

Read path: the class exposes the exact Warren surface (start/end/
transaction/annotations/hopper/translate/phrase/…) by k-way merging
per-shard annotation lists, so every existing caller — ``score_bm25``,
``collection_stats``, ``RetrievalServer``, the GCL engine — runs sharded
with zero call-site changes.  ``search`` is the scatter-gather fast path:
global collection statistics (document counts, lengths, per-term document
frequencies) are reduced across shards first, each shard scores its own
documents with the *global* BM25 parameters and returns its top-k, and a
k-way merge yields the global top-k — identical scores to a single index.
"""

from __future__ import annotations

import heapq
import os
import re
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import ranking
from repro.core.annotation import AnnotationList, merge_lists
from repro.core.featurizer import Featurizer, JsonFeaturizer, murmur64a
from repro.core.gcl import GCLNode, Phrase, Term
from repro.core.index import DynamicIndex
from repro.core.tokenizer import Tokenizer, Utf8Tokenizer
from repro.core.warren import Warren

STRIPE = 1 << 44          # address stripe per shard (>> any index size)


def shard_of(addr: int) -> int:
    """Owning shard of a committed (non-negative) address."""
    return int(addr) // STRIPE


def route_text(text: str, n_shards: int) -> int:
    """Stable hash partition for appends."""
    return int(murmur64a(text.encode()) % n_shards)


class _ShardedIndexView:
    """Facade matching the bits of DynamicIndex callers poke at."""

    def __init__(self, shards: List[DynamicIndex], tokenizer, featurizer):
        self._shards = shards
        self.tokenizer = tokenizer
        self.featurizer = featurizer

    @property
    def _segments(self) -> tuple:
        out = []
        for s in self._shards:
            out.extend(s._segments)
        return tuple(out)

    def merge_segments(self, upto: Optional[int] = None) -> None:
        for s in self._shards:
            s.merge_segments(upto)


class ShardedWarren:
    """K-shard warren with the single-Warren lifecycle surface."""

    def __init__(self, n_shards: int = 4,
                 tokenizer: Optional[Tokenizer] = None,
                 featurizer: Optional[Featurizer] = None,
                 log_dir: Optional[str] = None,
                 _shards: Optional[List[DynamicIndex]] = None):
        self.tokenizer = tokenizer or Utf8Tokenizer()
        self.featurizer = featurizer or JsonFeaturizer()
        if _shards is not None:
            self.shards = _shards
        else:
            self.shards = []
            for i in range(n_shards):
                path = (f"{log_dir}/shard{i:02d}.log"
                        if log_dir is not None else None)
                idx = DynamicIndex(self.tokenizer, self.featurizer,
                                   log_path=path)
                idx._next_addr = i * STRIPE
                self.shards.append(idx)
        self.n_shards = len(self.shards)
        self.index = _ShardedIndexView(self.shards, self.tokenizer,
                                       self.featurizer)
        self._warrens = [Warren(s) for s in self.shards]
        self._started = False
        self._txn_open: Dict[int, Warren] = {}   # shard -> warren with txn
        self._txn_active = False
        self._append_shard: Optional[int] = None

    # -- lifecycle ------------------------------------------------------ #
    def clone(self) -> "ShardedWarren":
        return ShardedWarren(tokenizer=self.tokenizer,
                             featurizer=self.featurizer, _shards=self.shards)

    def start(self) -> None:
        if self._started:
            raise RuntimeError("already started")
        for w in self._warrens:
            w.start()
        self._started = True

    def end(self) -> None:
        for w in self._warrens:
            w.end()
        self._started = False

    def __enter__(self) -> "ShardedWarren":
        self.start()
        return self

    def __exit__(self, *exc) -> bool:
        if self._txn_active:
            for w in self._txn_open.values():
                if w._txn is not None and w._txn._state in ("open", "ready"):
                    w.abort()
            self._reset_txn()
        self.end()
        return False

    # -- transactions ---------------------------------------------------- #
    def transaction(self) -> None:
        self._require_started()
        if self._txn_active:
            raise RuntimeError("transaction already active on this warren")
        self._txn_active = True

    def _reset_txn(self) -> None:
        self._txn_open = {}
        self._txn_active = False
        self._append_shard = None

    def _txn_warren(self, shard: int) -> Warren:
        if not self._txn_active:
            raise RuntimeError("no active transaction")
        w = self._txn_open.get(shard)
        if w is None:
            w = self._warrens[shard]
            w.transaction()
            self._txn_open[shard] = w
        return w

    def _route_addr(self, p: int) -> int:
        if p < 0:                      # staging address -> the append shard
            if self._append_shard is None:
                raise RuntimeError("staging address with no appends")
            return self._append_shard
        return shard_of(p)

    def append(self, text: str) -> Tuple[int, int]:
        if self._append_shard is None:
            self._append_shard = route_text(text, self.n_shards)
        return self._txn_warren(self._append_shard).append(text)

    def annotate(self, feature, p: int, q: int, v: float = 0.0,
                 v_is_address: bool = False) -> None:
        shard = self._route_addr(p)
        if v_is_address and v < 0 and shard != self._append_shard:
            raise ValueError("staging-valued annotation on a foreign shard")
        self._txn_warren(shard).annotate(feature, p, q, v,
                                         v_is_address=v_is_address)

    def erase(self, p: int, q: int) -> None:
        self._txn_warren(self._route_addr(p)).erase(p, q)

    def ready(self) -> None:
        for w in self._txn_open.values():
            w.ready()

    def commit(self):
        """Two-phase commit across every shard this transaction touched."""
        if not self._txn_active:
            raise RuntimeError("no active transaction")
        opened = list(self._txn_open.values())
        try:
            for w in opened:                   # phase 1: all durable-ready
                if w._txn is not None and w._txn._state == "open":
                    w.ready()
        except Exception:
            self.abort()                       # nothing published yet
            raise
        append_w = (self._txn_open.get(self._append_shard)
                    if self._append_shard is not None else None)
        append_remap = None
        failed = None
        for w in opened:                       # phase 2: publish
            try:
                remap = w.commit()
            except Exception as e:             # keep going: every shard's
                failed = failed or e           # ready record is durable, so
                continue                       # recovery can replay it
            if w is append_w:
                append_remap = remap
        self._reset_txn()
        if failed is not None:
            raise RuntimeError(
                "partial cross-shard commit: some shards published, the "
                "rest are recoverable from their ready records") from failed
        return append_remap if append_remap is not None else (lambda a: a)

    def abort(self) -> None:
        if not self._txn_active:
            raise RuntimeError("no active transaction")
        for w in self._txn_open.values():
            w.abort()
        self._reset_txn()

    # -- reads (merged across shards) ------------------------------------- #
    def featurize(self, feature: str) -> int:
        return self.featurizer.featurize(feature)

    def annotations(self, feature) -> AnnotationList:
        self._require_started()
        fval = feature if isinstance(feature, int) else self.featurize(feature)
        return merge_lists([w.annotations(fval) for w in self._warrens])

    def hopper(self, feature) -> Term:
        return Term(self.annotations(feature))

    def translate(self, p: int, q: int) -> Optional[str]:
        self._require_started()
        return self._warrens[shard_of(p)].translate(p, q)

    def tokens(self, p: int, q: int) -> Optional[List[str]]:
        self._require_started()
        return self._warrens[shard_of(p)].tokens(p, q)

    def phrase(self, text: str) -> GCLNode:
        self._require_started()
        words = self.tokenizer.split(text)
        terms = [self.hopper(w) for w in words]
        if not terms:
            return Term(AnnotationList.empty())
        return terms[0] if len(terms) == 1 else Phrase(terms)

    # -- scatter-gather serving ------------------------------------------- #
    def global_stats(self) -> ranking.CollectionStats:
        """Cross-shard collection statistics (one pass, reduced)."""
        self._require_started()
        per = [ranking.collection_stats(w) for w in self._warrens]
        n_docs = sum(s.n_docs for s in per)
        total_len = sum(float(s.doc_lens.sum()) for s in per)
        avgdl = total_len / n_docs if n_docs else 1.0
        return ranking.CollectionStats(
            n_docs, avgdl,
            np.concatenate([s.doc_starts for s in per]),
            np.concatenate([s.doc_ends for s in per]),
            np.concatenate([s.doc_lens for s in per]))

    def search(self, query: str, k: int = 10, k1: float = 0.9,
               b: float = 0.4) -> List[Tuple[int, float]]:
        """Scatter-gather BM25: per-shard top-k + global k-way merge.

        Global document frequencies and avgdl make per-shard scores exactly
        the single-index scores, so the merged top-k is exact.
        """
        self._require_started()
        per = [ranking.collection_stats(w) for w in self._warrens]
        n_docs = sum(s.n_docs for s in per)
        if n_docs == 0:
            return []
        total_len = sum(float(s.doc_lens.sum()) for s in per)
        avgdl = total_len / n_docs
        terms = list(dict.fromkeys(ranking.ranking_tokens(query)))
        fvals = [ranking.TF_PREFIX + ranking.porter_stem(t) for t in terms]
        # scatter 1: per-shard term lists; reduce document frequencies
        lists = [[w.annotations(f) for f in fvals] for w in self._warrens]
        dfs = [sum(len(lists[si][ti]) for si in range(self.n_shards))
               for ti in range(len(terms))]
        # scatter 2: score each shard with the GLOBAL idf/avgdl
        per_shard_topk: List[List[Tuple[float, int]]] = []
        for si, stats in enumerate(per):
            if stats.n_docs == 0:
                per_shard_topk.append([])
                continue
            local = ranking.CollectionStats(stats.n_docs, avgdl,
                                            stats.doc_starts, stats.doc_ends,
                                            stats.doc_lens)
            acc = np.zeros(stats.n_docs)
            for ti in range(len(terms)):
                lst = lists[si][ti]
                if len(lst) == 0 or dfs[ti] == 0:
                    continue
                idf = ranking._bm25_idf(n_docs, dfs[ti])
                di, imp = ranking._impacts(lst, local, idf, k1, b)
                np.add.at(acc, di, imp)
            kk = min(k, stats.n_docs)
            top = np.argpartition(-acc, kk - 1)[:kk]
            top = top[np.argsort(-acc[top], kind="stable")]
            per_shard_topk.append(
                [(float(acc[i]), int(stats.doc_starts[i]))
                 for i in top if acc[i] > 0])
        # gather: k-way merge of per-shard results
        merged = heapq.merge(*per_shard_topk, key=lambda t: -t[0])
        return [(d, s) for s, d in list(merged)[:k]]

    def search_gcl(self, query_text: str, limit: int = 1000) -> List:
        """Scatter-gather structural query: solve per shard, concatenate.

        Exact when query solutions don't cross shard stripes — true for any
        query over intra-document structure, since a document lives wholly
        inside one shard.
        """
        from repro.core.query import solve
        self._require_started()
        out = []
        for w in self._warrens:
            out.extend(solve(query_text, w, limit=limit))
        out.sort()
        return out[:limit]

    # -- fault tolerance --------------------------------------------------- #
    def checkpoint(self, manager, step: int) -> None:
        """Snapshot every shard through a CheckpointManager."""
        for i, idx in enumerate(self.shards):
            manager.save_index(step, idx, name=f"shard{i:02d}")

    @staticmethod
    def restore(manager, step: int, tokenizer: Optional[Tokenizer] = None,
                featurizer: Optional[Featurizer] = None) -> "ShardedWarren":
        """Rebuild from per-shard snapshot logs at ``step``.

        A gap in the shard set (a torn multi-shard checkpoint) is an error,
        never a silent truncation — addresses route by shard number, so a
        missing middle shard would corrupt routing for every later shard.
        """
        from repro.dist.checkpoint import CheckpointCorrupt

        present = set()
        for fn in os.listdir(manager.directory):
            m = re.match(r"^shard(\d+)_(\d{8})\.log$", fn)
            if m and int(m.group(2)) == step:
                present.add(int(m.group(1)))
        if not present:
            raise FileNotFoundError(f"no shard snapshots at step {step}")
        missing = set(range(max(present) + 1)) - present
        if missing:
            raise CheckpointCorrupt(
                f"step {step} is missing shard snapshots {sorted(missing)} "
                f"of {max(present) + 1}")
        tokenizer = tokenizer or Utf8Tokenizer()
        featurizer = featurizer or JsonFeaturizer()
        shards: List[DynamicIndex] = []
        for i in sorted(present):
            idx = manager.restore_index(step, name=f"shard{i:02d}",
                                        tokenizer=tokenizer,
                                        featurizer=featurizer)
            idx._next_addr = max(idx._next_addr, i * STRIPE)
            shards.append(idx)
        return ShardedWarren(tokenizer=tokenizer, featurizer=featurizer,
                             _shards=shards)

    # -- internals --------------------------------------------------------- #
    def _require_started(self) -> None:
        if not self._started:
            raise RuntimeError("warren access outside start()/end()")

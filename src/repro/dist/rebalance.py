"""Live shard rebalancing: stream segments between replica groups without
pausing writers.

``Rebalancer`` reshapes a running :class:`~repro.dist.shard_router.
ShardedWarren` — splitting one replica group into two, or merging two into
one — while writers keep committing and readers keep serving.  Both
operations follow the same three-phase protocol:

  1. **Freeze + bulk stream.**  The source group's committed segments are
     snapshotted at a freeze seqnum (``max_committed_seq``) and streamed to
     the destination in the durable ``Segment.to_record`` form — the same
     stream replica resurrection and cold-demotion recovery use.  A merge
     fence (``set_merge_fence``) pins the source's segment set so a
     concurrent auto-merge cannot collapse segments across the freeze
     watermark mid-stream.  Readers and writers are untouched: the source
     keeps serving and committing.
  2. **Tail catch-up.**  Commits that landed above the freeze seqnum are
     replayed from the source's published segment sequence in bounded
     rounds (each round streams the new tail and advances the watermark),
     until the tail is small.
  3. **Atomic swap.**  Under the source group's write lock — the only
     writer stall, measured and reported as ``RebalanceStats.swap_s`` —
     the final tail is streamed, the group states are rewritten, and a
     successor :class:`~repro.dist.shard_router.RoutingTable` is published
     with a bumped epoch.  Group epochs are bumped *before* the state
     rewrite and the table *after*, so read sessions can never pair a
     pre-swap table with post-swap state (see the shard_router module
     docstring); sessions pinned to the old table keep serving their
     immutable snapshots.  In-flight transactions staged against the old
     topology are re-staged transparently by ``ShardedWarren.commit``.

**Split** partitions the source's committed address range at a *document
boundary* (the median content-record address by default): annotations and
content move with the side owning their start address — the rule cross-
shard routing already uses — and both sides receive an *erased-carrier*
segment holding the group's full tombstone union, because a tombstone may
be recorded in a segment that lands wholly on the other side (erasure is a
point-set over addresses; losing a tombstone would resurrect erased
content).  The destination inherits the upper half of the split range; the
side whose allocation cursor landed in the moved range is granted a fresh
address stripe, so address spaces never collide.

**Merge** streams the absorbed group's segments into the surviving group
with their sequence numbers rebased above the survivor's (preserving the
absorbed group's internal order, so exact-interval tie-breaks are
unchanged; cross-group ties are impossible — address ranges are disjoint).
The absorbed group is *retired*: still addressable (health, checkpoint,
resurrect), but it owns no ranges, takes no appends, and serves empty.

**Demoted groups** rebalance by shipping *runs* instead of records.  A
cold merge copies the absorbed group's immutable run directories
file-level into the survivor's run set and publishes a successor manifest
(:func:`repro.tiered.merge_demoted`); a cold split ships **sliced run
sets** (:func:`repro.tiered.split_demoted`): runs wholly on one side of
the pivot are copied file-level, straddlers are cut by footer-index
extents (postings masked by start address, content moved as raw
compressed payloads), and both sides carry the full tombstone union — in
neither direction is the group promoted or a record decoded.

Failure model: fail-stop, same as the router.  If the source group loses
its last live replica (or is demoted/retired under the migration), the
operation raises :class:`RebalanceAborted` — the routing table is never
published partially, the destination group is discarded, and a retry after
``resurrect`` starts clean.  Nothing the rebalancer does is visible to
readers or writers until the single atomic table publish.
"""

from __future__ import annotations

import os
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro import obs
from repro.core.annotation import union_intervals
from repro.core.index import (DynamicIndex, Segment, erased_carrier,
                              partition_segment)
from repro.core.log import TransactionLog
from repro.dist.shard_router import (ReplicaFailure, ReplicaGroup,
                                     RoutingTable, ShardedWarren)


class RebalanceError(RuntimeError):
    """The requested rebalance is invalid (unknown/retired group, no
    document boundary to split at, ...)."""


class RebalanceAborted(RebalanceError):
    """The migration could not complete (source group lost all replicas,
    was demoted/retired mid-stream).  The routing table was NOT changed:
    no successor was published, the destination group was discarded, and
    the warren keeps serving exactly as before.  Retry after repair."""


@dataclass
class RebalanceStats:
    """One completed rebalance, as measured."""
    kind: str                       # "split" | "merge" | "merge-demoted"
    source: int
    dest: int
    epoch: int                      # routing epoch published by the swap
    freeze_seq: int = -1
    pivot: Optional[int] = None
    segments_streamed: int = 0
    catchup_rounds: int = 0
    copy_s: float = 0.0             # bulk stream (no locks held)
    catchup_s: float = 0.0          # tail rounds (no locks held)
    swap_s: float = 0.0             # THE writer stall: lock-held window

    def summary(self) -> str:
        return (f"{self.kind} {self.source}->{self.dest} epoch {self.epoch}: "
                f"{self.segments_streamed} segments streamed "
                f"(copy {1e3 * self.copy_s:.1f} ms, "
                f"{self.catchup_rounds} catch-up rounds "
                f"{1e3 * self.catchup_s:.1f} ms), writer stall "
                f"{1e3 * self.swap_s:.2f} ms")


_FENCE_ALL = 1 << 62        # merge fence high enough to pin every segment


class Rebalancer:
    """Online split/merge of a ShardedWarren's replica groups.

    One migration runs at a time per warren family (the shared
    ``rebalance_lock``); serving is never paused.  Bulk streaming fans out
    over the warren's ScatterGather pool when one is available (or the
    ``pool`` argument), so migration work never runs on a serving thread.
    """

    def __init__(self, warren: ShardedWarren, pool=None):
        self.warren = warren
        self.pool = pool if pool is not None else warren.scatter_pool
        self.history: List[RebalanceStats] = []

    @property
    def last_stats(self) -> Optional[RebalanceStats]:
        return self.history[-1] if self.history else None

    def _record(self, stats: RebalanceStats) -> None:
        """Append to history and publish the migration to the registry —
        the swap stall is the one number a rebalance can hurt serving by."""
        self.history.append(stats)
        reg = obs.registry()
        if reg.enabled:
            reg.counter("rebalance_total", "completed migrations",
                        kind=stats.kind).inc()
            reg.histogram("rebalance_swap_stall_ms",
                          "writer stall of the atomic swap window"
                          ).observe(1e3 * stats.swap_s)

    def _record_abort(self, kind: str) -> None:
        """Count an aborted migration — the signal the autopilot's
        backoff policy watches (the routing table was left untouched)."""
        reg = obs.registry()
        if reg.enabled:
            reg.counter("rebalance_aborted_total",
                        "migrations aborted with the table unchanged",
                        kind=kind).inc()

    # ------------------------------------------------------------------ #
    def _hook(self, stage: str, gid: int) -> None:
        hook = self.warren.hooks.get("mid_migration")
        if hook is not None:
            hook(self.warren, stage, gid)

    def _group(self, gid: int) -> ReplicaGroup:
        if not 0 <= gid < len(self.warren.groups):
            raise RebalanceError(f"no shard group {gid}")
        grp = self.warren.groups[gid]
        if grp.retired:
            raise RebalanceError(f"shard group {gid} is retired")
        return grp

    def _serving_index(self, grp: ReplicaGroup) -> DynamicIndex:
        try:
            return grp.replicas[grp.first_alive()]
        except ReplicaFailure as e:
            raise RebalanceAborted(
                f"shard group {grp.group_id} lost every replica "
                "mid-migration; routing table unchanged") from e

    def _stream(self, segments, transform) -> List:
        """Stream segments through the durable record form, applying
        ``transform(Segment) -> Optional[Segment]`` to each copy."""
        def one(seg):
            return transform(Segment.from_record(seg.to_record()))
        if self.pool is not None and len(segments) > 1:
            out = self.pool.map(one, segments)
        else:
            out = [one(s) for s in segments]
        return [s for s in out if s is not None]

    def _bulk_and_catchup(self, grp: ReplicaGroup, transform,
                          out: List[Segment]) -> Tuple[int, int, int, int,
                                                       float, float]:
        """The shared lock-free migration prefix: snapshot the source at a
        freeze seqnum, bulk-stream its committed segments through
        ``transform`` into ``out``, then replay the tail committed above
        the watermark in bounded catch-up rounds.  Returns
        ``(freeze_seq, streamed_watermark, n_streamed, rounds, copy_s,
        catchup_s)``; the final (under-lock) tail is the caller's job."""
        src_idx = self._serving_index(grp)
        with src_idx._publish_lock:
            segs0 = src_idx._segments
        freeze_seq = max((s.seqnum for s in segs0), default=-1)
        t0 = time.perf_counter()
        with obs.span("bulk_copy", group=grp.group_id):
            out.extend(self._stream(segs0, transform))
        streamed, n_streamed = freeze_seq, len(segs0)
        copy_s = time.perf_counter() - t0
        self._hook("after_copy", grp.group_id)
        t0 = time.perf_counter()
        rounds = 0
        with obs.span("catchup", group=grp.group_id):
            for _ in range(8):
                src_idx = self._serving_index(grp)
                with src_idx._publish_lock:
                    segs = src_idx._segments
                tail = [s for s in segs if s.seqnum > streamed]
                if not tail:
                    break
                rounds += 1
                out.extend(self._stream(tail, transform))
                streamed = max(s.seqnum for s in tail)
                n_streamed += len(tail)
                if len(tail) <= 2:
                    break
        catchup_s = time.perf_counter() - t0
        self._hook("before_swap", grp.group_id)
        return freeze_seq, streamed, n_streamed, rounds, copy_s, catchup_s

    # ------------------------------------------------------------------ #
    def split_group(self, source: int,
                    pivot: Optional[int] = None) -> int:
        """Split ``source`` into two groups; returns the new group's id.

        The new group owns the source range's upper half ``[pivot, hi)``
        (``pivot`` defaults to the median committed document boundary) and
        starts with the same replica count.  Writers keep committing
        throughout; the only stall is the routing-table swap.
        """
        w = self.warren
        with w._ctx["rebalance_lock"]:
            grp = self._group(source)
            if grp.demoted is not None:
                # cold split: ship sliced run sets (footer-index
                # subranges) — the group is never promoted or decoded
                table: RoutingTable = w._ctx["table"]
                try:
                    with obs.span("rebalance.split", source=source,
                                  demoted=True):
                        return self._split_demoted_locked(grp, table, pivot)
                except RebalanceAborted:
                    self._record_abort("split-demoted")
                    raise
            table: RoutingTable = w._ctx["table"]
            for idx in grp.replicas:
                idx.set_merge_fence(_FENCE_ALL)
            try:
                with obs.span("rebalance.split", source=source):
                    return self._split_locked(grp, table, pivot)
            except RebalanceAborted:
                self._record_abort("split")
                raise
            finally:
                for idx in grp.replicas:
                    idx.set_merge_fence(-1)

    def _split_demoted_locked(self, grp: ReplicaGroup, table: RoutingTable,
                              pivot: Optional[int]) -> int:
        """Split a *cold* group by shipping sliced run sets
        (:func:`repro.tiered.split_demoted`): runs wholly on one side of
        the pivot are copied file-level, straddlers are cut by
        footer-index extents, and both sides carry the full tombstone
        union — no promotion, no record decoding.  Cold groups take no
        writes (a write would promote, and promotion needs the write lock
        we hold), so holding the lock across the file I/O stalls no one.
        """
        from repro.core.static import StaticIndex
        from repro.tiered import ManifestStore, StaticWarren, split_demoted

        w = self.warren
        source = grp.group_id
        # pivot: the median document (record) boundary, read footer-only
        ms = ManifestStore(grp.demoted)
        sm = ms.load_latest_good()
        if sm is None:
            raise RebalanceAborted(
                f"shard group {source} has no latest-good manifest in "
                f"{grp.demoted!r}; routing table unchanged")
        los: List[int] = []
        for info in sm.runs:
            si = StaticIndex(ms.run_path(info.name), w.tokenizer,
                             w.featurizer)
            los.extend(lo for lo, _ in si.record_bounds())
            si.close()
        los.sort()
        if pivot is None:
            if len(los) < 2:
                raise RebalanceError(
                    f"shard group {source} has {len(los)} documents — "
                    "nothing to split")
            pivot = los[len(los) // 2]
        rng = table.range_containing(pivot)
        if rng is None or rng[2] != source:
            raise RebalanceError(
                f"pivot {pivot} is not inside a range owned by group "
                f"{source}")
        rlo, rhi, _ = rng
        if pivot <= rlo:
            raise RebalanceError(f"pivot {pivot} at/below range base {rlo}")

        new_gid = len(w.groups)
        tok, feat = w.tokenizer, w.featurizer
        fresh = table.fresh_stripe()
        cursor = sm.next_addr
        moved_alloc = pivot <= cursor < rhi
        keep_dir = f"{grp.demoted}.e{grp.epoch + 1}.keep"
        moved_dir = f"{grp.demoted}.e{grp.epoch + 1}.moved"

        t0 = time.perf_counter()
        with obs.span("swap", group=source), grp.write_lock:
            if grp.demoted is None or grp.retired:
                raise RebalanceAborted(
                    f"shard group {source} was promoted/retired "
                    "mid-migration; routing table unchanged")
            grp.epoch += 1                    # BEFORE any state rewrite
            try:
                keep_m, moved_m = split_demoted(
                    grp.demoted, keep_dir, moved_dir, pivot, rhi,
                    keep_next_addr=fresh[0] if moved_alloc else cursor,
                    moved_next_addr=cursor if moved_alloc else fresh[0],
                    tokenizer=tok, featurizer=feat)
                keep_static = StaticWarren(keep_dir, tok, feat)
                moved_static = StaticWarren(moved_dir, tok, feat)
            except BaseException:
                # the file I/O failed AFTER the epoch bump: publish a
                # same-topology successor so the epoch handshake re-syncs
                # and the group keeps serving its untouched run set; the
                # partially-built side directories are discarded
                import shutil
                shutil.rmtree(keep_dir, ignore_errors=True)
                shutil.rmtree(moved_dir, ignore_errors=True)
                epochs = list(table.group_epochs)
                epochs[source] = grp.epoch
                w._ctx["table"] = table.successor(group_epochs=epochs)
                raise
            # source keeps the complement side; pinned static clones keep
            # serving the old run set (their mmaps outlive the swap)
            grp.static = keep_static
            grp.demoted = keep_dir
            dest_replicas = [DynamicIndex(tok, feat, log_path=None)
                             for _ in range(grp.n_replicas)]
            dest_grp = ReplicaGroup(new_gid, dest_replicas)
            dest_grp.demoted = moved_dir
            dest_grp.static = moved_static
            w.groups.append(dest_grp)
            ranges = [r for r in table.ranges if r != rng]
            ranges += [(rlo, pivot, source), (pivot, rhi, new_gid),
                       (fresh[0], fresh[1],
                        source if moved_alloc else new_gid)]
            epochs = list(table.group_epochs) + [0]
            epochs[source] = grp.epoch
            w._ctx["table"] = table.successor(   # publish: swap complete
                ranges=ranges,
                write_groups=table.write_groups + (new_gid,),
                group_epochs=epochs)
        swap_s = time.perf_counter() - t0
        self._record(RebalanceStats(
            kind="split-demoted", source=source, dest=new_gid,
            epoch=w._ctx["table"].epoch, pivot=pivot,
            segments_streamed=len(moved_m.runs), swap_s=swap_s))
        return new_gid

    def _split_locked(self, grp: ReplicaGroup, table: RoutingTable,
                      pivot: Optional[int]) -> int:
        w = self.warren
        source = grp.group_id
        src_idx = self._serving_index(grp)
        with src_idx._publish_lock:
            segs0 = src_idx._segments       # pivot selection only; the
            # freeze snapshot itself is taken inside _bulk_and_catchup

        # choose the pivot: a committed document (record) boundary
        los = sorted(r.lo for s in segs0 for r in s.content.records())
        if pivot is None:
            if len(los) < 2:
                raise RebalanceError(
                    f"shard group {source} has {len(los)} documents — "
                    "nothing to split")
            pivot = los[len(los) // 2]
        rng = table.range_containing(pivot)
        if rng is None or rng[2] != source:
            raise RebalanceError(
                f"pivot {pivot} is not inside a range owned by group "
                f"{source}")
        rlo, rhi, _ = rng
        if pivot <= rlo:
            raise RebalanceError(f"pivot {pivot} at/below range base {rlo}")

        new_gid = len(w.groups)
        tok, feat = w.tokenizer, w.featurizer
        dest_replicas = [DynamicIndex(tok, feat, log_path=None)
                         for _ in range(grp.n_replicas)]
        for d in dest_replicas:
            d.auto_merge_threshold = src_idx.auto_merge_threshold
        # log-backed source family: the destination must get durable logs
        # too (in the same directory), else the moved half would survive in
        # NO log once the source compacts its own
        src_log = src_idx._log.path
        dest_log_dir = os.path.dirname(src_log) if src_log else None

        # 1+2. bulk stream + tail catch-up (no locks), partitioning each
        # segment at the pivot: the inside half moves, the outside stays
        move_segs: List[Segment] = []
        keep_segs: List[Segment] = []

        def _partition_into(seg: Segment) -> Optional[Segment]:
            inside, outside = partition_segment(seg, pivot, rhi)
            if outside is not None:
                keep_segs.append(outside)
            return inside

        (freeze_seq, streamed, n_streamed, rounds, copy_s,
         catchup_s) = self._bulk_and_catchup(grp, _partition_into, move_segs)

        # 3. atomic swap: the only writer stall
        t0 = time.perf_counter()
        with obs.span("swap", group=source), grp.write_lock:
            if grp.demoted is not None or grp.retired:
                raise RebalanceAborted(
                    f"shard group {source} was demoted/retired "
                    "mid-migration; routing table unchanged")
            src_idx = self._serving_index(grp)
            with src_idx._publish_lock:
                segs_now = src_idx._segments
            tail = [s for s in segs_now if s.seqnum > streamed]
            if tail:
                move_segs.extend(self._stream(tail, _partition_into))
                n_streamed += len(tail)
            max_seq = max((s.seqnum for s in segs_now), default=-1)
            erased_full = union_intervals([s.erased for s in segs_now])
            with src_idx._addr_lock:
                src_next_addr = src_idx._next_addr
                src_next_seq = src_idx._next_seq
            keep_final = list(keep_segs)
            move_final = list(move_segs)
            if len(erased_full):
                keep_final.append(erased_carrier(max_seq, rlo, erased_full))
                move_final.append(erased_carrier(max_seq, pivot, erased_full))
            keep_final.sort(key=lambda s: s.seqnum)
            move_final.sort(key=lambda s: s.seqnum)
            fresh = table.fresh_stripe()
            moved_alloc = pivot <= src_next_addr < rhi

            grp.epoch += 1                    # BEFORE any state rewrite
            for dst in grp.replicas:
                with dst._publish_lock:
                    dst._segments = tuple(keep_final)
                    dst._version += 1
                    dst._trim_cache()
                if moved_alloc:
                    with dst._addr_lock:
                        dst._next_addr = fresh[0]
            for d in dest_replicas:
                d._segments = tuple(move_final)
                d._version = 1
                d._next_addr = src_next_addr if moved_alloc else fresh[0]
                d._next_seq = src_next_seq
            dest_grp = ReplicaGroup(new_gid, dest_replicas)
            w.groups.append(dest_grp)

            ranges = [r for r in table.ranges if r != rng]
            ranges += [(rlo, pivot, source), (pivot, rhi, new_gid),
                       (fresh[0], fresh[1],
                        source if moved_alloc else new_gid)]
            epochs = list(table.group_epochs) + [0]
            epochs[source] = grp.epoch
            w._ctx["table"] = table.successor(   # publish: swap complete
                ranges=ranges,
                write_groups=table.write_groups + (new_gid,),
                group_epochs=epochs)
        swap_s = time.perf_counter() - t0

        if dest_log_dir is not None:
            # log-backed family: the destination gets its own per-replica
            # logs (same directory), written durably BEFORE the source
            # compacts the moved half out of its logs — a crash in between
            # leaves the moved documents in both log sets (at-least-once;
            # the routing record arbitrates ownership at recovery), never
            # in zero.  Done only after the swap succeeded, so an aborted
            # migration leaves no log files behind.
            for r, d in enumerate(dest_replicas):
                d._log.close()
                d._log = TransactionLog(os.path.join(
                    dest_log_dir, f"shard{new_gid:02d}r{r}.log"))
                d.compact_log()
        for idx in grp.replicas:      # durable logs forget the moved half
            idx.compact_log()
        stats = RebalanceStats(
            kind="split", source=source, dest=new_gid,
            epoch=w._ctx["table"].epoch, freeze_seq=freeze_seq, pivot=pivot,
            segments_streamed=n_streamed, catchup_rounds=rounds,
            copy_s=copy_s, catchup_s=catchup_s, swap_s=swap_s)
        self._record(stats)
        return new_gid

    # ------------------------------------------------------------------ #
    def merge_groups(self, dest: int, source: int) -> None:
        """Fold ``source`` into ``dest``; ``source`` is retired (empty but
        addressable) and its address ranges re-home to ``dest``.  Writers
        keep committing throughout; the only stall is the swap window."""
        w = self.warren
        if dest == source:
            raise RebalanceError("merge of a group with itself")
        with w._ctx["rebalance_lock"]:
            dgrp, sgrp = self._group(dest), self._group(source)
            table: RoutingTable = w._ctx["table"]
            if dgrp.demoted is not None and sgrp.demoted is not None:
                try:
                    with obs.span("rebalance.merge", source=source,
                                  dest=dest, demoted=True):
                        self._merge_demoted_locked(dgrp, sgrp, table)
                except RebalanceAborted:
                    self._record_abort("merge-demoted")
                    raise
                return
            # mixed hot/cold: promote the cold side, then merge hot
            if dgrp.demoted is not None:
                dgrp.promote()
            if sgrp.demoted is not None:
                sgrp.promote()
            for idx in sgrp.replicas:
                idx.set_merge_fence(_FENCE_ALL)
            try:
                with obs.span("rebalance.merge", source=source, dest=dest):
                    self._merge_locked(dgrp, sgrp, table)
            except RebalanceAborted:
                self._record_abort("merge")
                raise
            finally:
                for idx in sgrp.replicas:
                    idx.set_merge_fence(-1)

    def _merge_locked(self, dgrp: ReplicaGroup, sgrp: ReplicaGroup,
                      table: RoutingTable) -> None:
        w = self.warren
        dest, source = dgrp.group_id, sgrp.group_id
        # 1+2. bulk stream + tail catch-up (no locks); the absorbed group's
        # segments travel whole (unsliced), so their erased intervals and
        # internal tie order travel with them
        copies: List[Segment] = []
        (freeze_seq, streamed, n_streamed, rounds, copy_s,
         catchup_s) = self._bulk_and_catchup(sgrp, lambda s: s, copies)

        # 3. atomic swap under BOTH groups' locks (ascending id order —
        #    the same discipline quorum commits use, so no deadlocks)
        t0 = time.perf_counter()
        first, second = sorted([dgrp, sgrp], key=lambda g: g.group_id)
        with obs.span("swap", group=source), \
                first.write_lock, second.write_lock:
            if (dgrp.demoted is not None or sgrp.demoted is not None
                    or dgrp.retired or sgrp.retired):
                raise RebalanceAborted(
                    "a group was demoted/retired mid-merge; "
                    "routing table unchanged")
            dst_idx = self._serving_index(dgrp)
            src_idx = self._serving_index(sgrp)
            with src_idx._publish_lock:
                segs_now = src_idx._segments
            tail = [s for s in segs_now if s.seqnum > streamed]
            if tail:
                copies.extend(self._stream(tail, lambda s: s))
                n_streamed += len(tail)
            # rebase the absorbed sequence numbers above the survivor's,
            # preserving their relative order (tie-breaks intact; cross-
            # group exact ties are impossible — disjoint addresses)
            copies.sort(key=lambda s: s.seqnum)
            with dst_idx._addr_lock:
                seq_base = dst_idx._next_seq
            for i, c in enumerate(copies):
                c.seqnum = seq_base + i
            new_next_seq = seq_base + len(copies)

            dgrp.epoch += 1                   # BEFORE any state rewrite
            sgrp.epoch += 1
            for dst in dgrp.replicas:
                with dst._publish_lock:
                    merged = sorted(list(dst._segments) + copies,
                                    key=lambda s: s.seqnum)
                    dst._segments = tuple(merged)
                    dst._version += 1
                    dst._trim_cache()
                with dst._addr_lock:
                    dst._next_seq = new_next_seq
            for idx in sgrp.replicas:
                with idx._publish_lock:
                    idx._segments = ()
                    idx._version += 1
                    idx._trim_cache()
            sgrp.retired = True

            ranges = tuple((lo, hi, dest if gid == source else gid)
                           for lo, hi, gid in table.ranges)
            epochs = list(table.group_epochs)
            epochs[dest], epochs[source] = dgrp.epoch, sgrp.epoch
            w._ctx["table"] = table.successor(   # publish: swap complete
                ranges=ranges,
                write_groups=tuple(g for g in table.write_groups
                                   if g != source),
                group_epochs=epochs)
        swap_s = time.perf_counter() - t0

        for idx in dgrp.replicas + sgrp.replicas:
            idx.compact_log()
        self._record(RebalanceStats(
            kind="merge", source=source, dest=dest,
            epoch=w._ctx["table"].epoch, freeze_seq=freeze_seq,
            segments_streamed=n_streamed, catchup_rounds=rounds,
            copy_s=copy_s, catchup_s=catchup_s, swap_s=swap_s))

    def _merge_demoted_locked(self, dgrp: ReplicaGroup, sgrp: ReplicaGroup,
                              table: RoutingTable) -> None:
        """Merge two *cold* groups by shipping run manifests — the absorbed
        group's immutable run directories are copied file-level into the
        survivor's run set; no segment records are decoded and neither
        group is promoted.  Cold groups take no writes (a write would
        promote, and promotion needs the write lock we hold), so holding
        both locks across the file copies stalls no one."""
        from repro.tiered import StaticWarren, merge_demoted

        w = self.warren
        dest, source = dgrp.group_id, sgrp.group_id
        t0 = time.perf_counter()
        first, second = sorted([dgrp, sgrp], key=lambda g: g.group_id)
        with obs.span("swap", group=source), \
                first.write_lock, second.write_lock:
            if dgrp.demoted is None or sgrp.demoted is None:
                raise RebalanceAborted(
                    "a group was promoted mid-merge; retry")
            dgrp.epoch += 1                   # BEFORE any state rewrite —
            sgrp.epoch += 1                   # same handshake as hot merge
            try:
                shipped = len(merge_demoted(dgrp.demoted,
                                            sgrp.demoted).runs) \
                    - len(dgrp.static.manifest.runs)
                dgrp.static = StaticWarren(dgrp.demoted, w.tokenizer,
                                           w.featurizer)
            except BaseException:
                # the file I/O failed AFTER the epoch bumps: publish a
                # same-topology successor table so the epoch handshake
                # re-syncs and both groups keep serving; retry is safe
                # (merge_demoted skips runs already shipped)
                epochs = list(table.group_epochs)
                epochs[dest], epochs[source] = dgrp.epoch, sgrp.epoch
                w._ctx["table"] = table.successor(group_epochs=epochs)
                raise
            sgrp.retired = True
            sgrp.demoted = None
            sgrp.static = None
            ranges = tuple((lo, hi, dest if gid == source else gid)
                           for lo, hi, gid in table.ranges)
            epochs = list(table.group_epochs)
            epochs[dest], epochs[source] = dgrp.epoch, sgrp.epoch
            w._ctx["table"] = table.successor(
                ranges=ranges,
                write_groups=tuple(g for g in table.write_groups
                                   if g != source),
                group_epochs=epochs)
        swap_s = time.perf_counter() - t0
        self._record(RebalanceStats(
            kind="merge-demoted", source=source, dest=dest,
            epoch=w._ctx["table"].epoch, segments_streamed=shipped,
            swap_s=swap_s))

    # ------------------------------------------------------------------ #
    def split_group_async(self, source: int,
                          pivot: Optional[int] = None) -> Future:
        """Run ``split_group`` off the caller's thread; returns a Future
        resolving to the new group id.  Always a dedicated thread, never
        the scatter pool: the migration fans its own segment streaming
        onto the pool, so running the outer job there too could occupy
        the last worker and deadlock the stream behind itself."""
        fut: Future = Future()

        def run():
            try:
                fut.set_result(self.split_group(source, pivot))
            except BaseException as e:
                fut.set_exception(e)

        threading.Thread(target=run, daemon=True,
                         name="rebalance-split").start()
        return fut

"""Autopilot: the closed-loop control plane over the rebalancing mechanism.

Every *mechanism* for reshaping a running :class:`~repro.dist.shard_router.
ShardedWarren` already exists — live split/merge (``repro.dist.rebalance``),
cold demotion/promotion (``repro.tiered``), fail-stop ``mark_failed``/
``resurrect`` — and the telemetry plane (``repro.obs``) exposes the
signals.  This module is the part that *decides*: a :class:`Controller`
that watches per-group signals and autonomously keeps the warren balanced
under drifting, skewed traffic.

Architecture — three narrow interfaces, so the same controller runs
against a live warren in production and against a deterministic
simulation in tier-1:

* **SignalSource** — ``collect() -> [GroupSignal]``.  One
  :class:`GroupSignal` per shard group: committed doc count, windowed p95
  scatter latency, reads/writes in the window, per-replica seqnum
  high-water marks, demoted/retired flags.  :class:`WarrenSignals` reads
  a live warren (doc counts from the groups, windowed p95 via
  ``Histogram.percentile_since`` over the cumulative
  ``scatter_latency_ms{group}`` family); the simulation harness
  (``repro.dist.simharness``) synthesizes streams from a seeded workload.
* **Actuator** — ``split``/``merge``/``demote``/``resync``.
  :class:`WarrenActuator` drives the real ``Rebalancer`` and warren;
  the simulator applies actions to its virtual cluster.  Failures
  surface as :class:`~repro.dist.rebalance.RebalanceAborted`, which the
  controller absorbs with capped exponential backoff — it never wedges,
  and never holds any lock itself (locking is the mechanism layer's job).
* **Clock** — every timestamp comes from an injectable ``clock()``
  callable (default ``time.monotonic``).  The controller itself never
  sleeps; pacing belongs to the caller (``spawn`` for production, plain
  ``tick()`` loops in tests and benchmarks).  Tier-1 therefore runs the
  full control loop with a fake clock and asserts *exact* decision
  sequences.

Policies are frozen dataclasses (:class:`HotSplitPolicy`,
:class:`ColdPolicy`, :class:`AntiEntropyPolicy`) under a shared
:class:`Hysteresis` envelope.  Hysteresis is what makes the loop
trustworthy: a per-group **cooldown** after any action (so a split can
never be immediately reverted by a merge of the same group), a global
**min-dwell** after any action (the warren settles before the next
decision), and a **bounded action budget** per sliding window.  These are
mechanical properties of ``_plan`` — the property test in
``tests/test_autopilot.py`` checks them over arbitrary signal streams.

Every decision — applied, aborted, or failed — is recorded as a
structured :class:`Decision` (optionally appended to a JSONL decision
log) and counted in the ``autopilot_*`` metric families; each control
cycle runs under an ``autopilot.tick`` span.
"""

from __future__ import annotations

import json
import math
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro import obs
from repro.dist.rebalance import RebalanceAborted, Rebalancer

# --------------------------------------------------------------------- #
# signals
# --------------------------------------------------------------------- #


@dataclass
class GroupSignal:
    """One shard group's control inputs for one tick.

    ``p95_ms`` is the *windowed* p95 per-group scatter latency (NaN when
    the window holds no samples); ``reads``/``writes`` likewise count the
    window, not the lifetime.  ``replica_seqs`` are per-replica committed
    seqnum high-water marks and ``alive`` the fail-stop health vector —
    the anti-entropy inputs.
    """

    group: int
    docs: int = 0
    p95_ms: float = math.nan
    reads: int = 0
    writes: int = 0
    demoted: bool = False
    retired: bool = False
    replica_seqs: Tuple[int, ...] = ()
    alive: Tuple[bool, ...] = ()
    # sustained SLO burn rate (min across monitor windows), stamped by an
    # obs.SLOSignalSource wrapper; NaN when no SLO monitor is attached
    burn_rate: float = math.nan


class WarrenSignals:
    """SignalSource over a live ShardedWarren + the metrics registry.

    Doc counts and replica seqnums come straight from the groups;
    latency and read/write rates are *windowed* reads of the cumulative
    registry families (``scatter_latency_ms{group}``,
    ``shard_read_total{group}``, ``shard_write_total{group}``): each
    ``collect`` snapshots the histogram bucket counts and counter values
    and reports the delta since the previous ``collect``.  With the
    registry disabled the latency/rate fields degrade to NaN/0 and the
    controller still balances on doc-count skew.
    """

    def __init__(self, warren):
        self.warren = warren
        self._prev_buckets: Dict[int, List[int]] = {}
        self._prev_reads: Dict[int, int] = {}
        self._prev_writes: Dict[int, int] = {}

    def collect(self) -> List[GroupSignal]:
        reg = obs.registry()
        out: List[GroupSignal] = []
        for g, grp in enumerate(self.warren.groups):
            docs = grp.doc_count()
            seqs = tuple(grp.replica_seqnums())
            h = reg.histogram("scatter_latency_ms",
                              "per-group fan-out read time "
                              "(failover included)", group=g)
            p95 = h.percentile_since(self._prev_buckets.get(g), 0.95)
            self._prev_buckets[g] = h.bucket_counts()
            rc = reg.counter("shard_read_total", group=g).value
            wc = reg.counter("shard_write_total", group=g).value
            reads = rc - self._prev_reads.get(g, 0)
            writes = wc - self._prev_writes.get(g, 0)
            self._prev_reads[g], self._prev_writes[g] = rc, wc
            out.append(GroupSignal(
                group=g, docs=docs, p95_ms=p95, reads=reads, writes=writes,
                demoted=grp.demoted is not None, retired=grp.retired,
                replica_seqs=seqs, alive=tuple(grp.alive)))
        return out


class ScriptedSignals:
    """SignalSource replaying a canned per-tick schedule (tests and the
    benchmark's injected-stream scenarios).  Holds the last tick's
    signals once the script runs out."""

    def __init__(self, ticks: Sequence[Sequence[GroupSignal]]):
        if not ticks:
            raise ValueError("ScriptedSignals needs at least one tick")
        self._ticks = [list(t) for t in ticks]
        self._i = 0

    def collect(self) -> List[GroupSignal]:
        sigs = self._ticks[min(self._i, len(self._ticks) - 1)]
        self._i += 1
        return list(sigs)


# --------------------------------------------------------------------- #
# actuators
# --------------------------------------------------------------------- #
class WarrenActuator:
    """Actuator driving the real mechanisms on a live ShardedWarren."""

    def __init__(self, warren, rebalancer: Optional[Rebalancer] = None):
        self.warren = warren
        self.rebalancer = rebalancer if rebalancer is not None \
            else Rebalancer(warren)

    def split(self, group: int) -> int:
        return self.rebalancer.split_group(group)

    def merge(self, dest: int, source: int) -> None:
        self.rebalancer.merge_groups(dest, source)

    def demote(self, group: int) -> None:
        self.warren.demote_group(group)

    def resync(self, group: int, replica: int) -> None:
        """Anti-entropy re-sync: a replica that diverged while marked
        alive is outside the fail-stop model — fail it in place first,
        then stream it back from a healthy sibling.  A replica already
        marked dead resurrects directly."""
        grp = self.warren.groups[group]
        if grp.alive[replica]:
            grp.mark_failed(replica)
        self.warren.resurrect(group, replica)


# --------------------------------------------------------------------- #
# policies
# --------------------------------------------------------------------- #
@dataclass(frozen=True)
class HotSplitPolicy:
    """Split a group that is *sustainedly* hot: windowed p95 scatter
    latency at/above ``p95_hot_ms``, or doc count at/above ``skew_ratio``
    times the mean of the other active groups, or sustained SLO burn rate
    at/above ``burn_hot``, for ``sustain_ticks`` consecutive ticks.
    Groups below ``min_docs`` never split (nothing to partition) and the
    warren never grows past ``max_groups``.

    ``burn_hot`` defaults to +inf (disabled): burn only drives splits
    when an :class:`repro.obs.SLOSignalSource` stamps
    ``GroupSignal.burn_rate`` and the operator opts in — burn 1.0 means
    the error budget is being consumed exactly at the sustainable rate,
    so thresholds slightly above 1 page on real sustained burn."""

    p95_hot_ms: float = 50.0
    skew_ratio: float = 3.0
    min_docs: int = 8
    sustain_ticks: int = 3
    max_groups: int = 16
    burn_hot: float = math.inf


@dataclass(frozen=True)
class ColdPolicy:
    """Demote, then merge away, groups that go idle (LRU-style).  A group
    with at most ``idle_reads`` reads per tick accrues idle ticks; at
    ``demote_after_ticks`` it is frozen to its static run set, at
    ``merge_after_ticks`` it is folded into the smallest other active
    group.  The warren never shrinks below ``min_groups`` active groups,
    and only groups at or below ``merge_max_docs`` are merge candidates
    (merging a huge group would re-create the hot spot)."""

    idle_reads: int = 0
    demote_after_ticks: int = 6
    merge_after_ticks: int = 10
    min_groups: int = 2
    merge_max_docs: int = 1 << 30


@dataclass(frozen=True)
class AntiEntropyPolicy:
    """Schedule a re-sync for a replica whose committed seqnum high-water
    mark trails its group's live maximum by more than ``max_seq_lag`` for
    ``sustain_ticks`` consecutive ticks — divergence the fail-stop model
    does not explain — and for dead replicas (plain resurrection)."""

    max_seq_lag: int = 0
    sustain_ticks: int = 2
    resync_dead: bool = True


@dataclass(frozen=True)
class Hysteresis:
    """The flap-guard envelope around every policy.

    * ``cooldown_ticks``: after an applied action touching a group, no
      further action may touch that group (or, for a split, the new
      group) for this many ticks — a split can provably not be reverted
      by a merge inside the window.
    * ``min_dwell_ticks``: after *any* attempted action, no action of any
      kind for this many ticks — the warren (and the windowed signals)
      settle before the next decision.
    * ``max_actions_per_window`` / ``window_ticks``: a hard budget on
      attempted actions inside any sliding window of ``window_ticks``
      ticks — total control activity is bounded no matter what the
      signals do.
    """

    cooldown_ticks: int = 5
    min_dwell_ticks: int = 2
    window_ticks: int = 20
    max_actions_per_window: int = 4


@dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff after an aborted/failed action on a
    group: attempt ``k`` blocks the group for
    ``min(cap_ticks, base_ticks * 2**(k-1))`` ticks."""

    base_ticks: int = 1
    cap_ticks: int = 8


@dataclass(frozen=True)
class PoolPolicy:
    """Keep the family's ScatterGather pool sized to the active group
    count (one worker per group leg, clamped) via ``resize``."""

    min_workers: int = 2
    max_workers: int = 16


@dataclass(frozen=True)
class AutopilotConfig:
    split: HotSplitPolicy = HotSplitPolicy()
    cold: ColdPolicy = ColdPolicy()
    anti_entropy: AntiEntropyPolicy = AntiEntropyPolicy()
    hysteresis: Hysteresis = Hysteresis()
    retry: RetryPolicy = RetryPolicy()
    pool: Optional[PoolPolicy] = PoolPolicy()
    max_actions_per_tick: int = 1


# --------------------------------------------------------------------- #
# decisions
# --------------------------------------------------------------------- #
@dataclass
class Decision:
    """One structured control decision — the replayable audit record.

    ``kind``     "split" | "merge" | "demote" | "resync"
    ``group``    the acted-on group (merge: the absorbed source)
    ``target``   split: the new gid (filled after the act); merge: the
                 surviving dest; resync: the replica; demote: None
    ``outcome``  "applied" | "aborted" (RebalanceAborted, table
                 unchanged) | "failed" (unexpected actuator error)
    """

    tick: int
    t: float
    kind: str
    group: int
    target: Optional[int] = None
    reason: str = ""
    outcome: str = "planned"
    detail: str = ""

    def to_record(self) -> dict:
        return {"tick": self.tick, "t": self.t, "kind": self.kind,
                "group": self.group, "target": self.target,
                "reason": self.reason, "outcome": self.outcome,
                "detail": self.detail}

    def summary(self) -> str:
        tgt = "" if self.target is None else f"->{self.target}"
        return (f"[tick {self.tick}] {self.kind} group {self.group}{tgt} "
                f"{self.outcome}: {self.reason}")


# --------------------------------------------------------------------- #
# the controller
# --------------------------------------------------------------------- #
class Controller:
    """The closed control loop: collect signals, plan under hysteresis,
    act, record.  One ``tick()`` is one full cycle; the controller holds
    no locks and never sleeps (see the module docstring)."""

    def __init__(self, signals, actuator,
                 config: Optional[AutopilotConfig] = None,
                 clock: Callable[[], float] = time.monotonic,
                 pool=None, decision_log: Optional[str] = None):
        self.signals = signals
        self.actuator = actuator
        self.config = config if config is not None else AutopilotConfig()
        self.clock = clock
        self.pool = pool
        self.decision_log = decision_log
        self._log_sink: Optional[obs.RotatingJsonl] = None
        self.decisions: List[Decision] = []
        self._tick = 0
        self._hot: Dict[int, int] = {}           # group -> hot streak
        self._idle: Dict[int, int] = {}          # group -> idle streak
        self._lag: Dict[Tuple[int, int], int] = {}   # (group, replica)
        self._cooldown_until: Dict[int, int] = {}    # group -> last blocked tick
        self._backoff: Dict[int, Tuple[int, int]] = {}  # group -> (attempts, until)
        self._last_action_tick = -(1 << 30)
        self._action_ticks: deque = deque()      # attempted-action ticks

    @staticmethod
    def for_warren(warren, rebalancer: Optional[Rebalancer] = None,
                   config: Optional[AutopilotConfig] = None,
                   clock: Callable[[], float] = time.monotonic,
                   decision_log: Optional[str] = None,
                   slo_monitor=None) -> "Controller":
        """The production wiring: live signals + live actuator + the
        family's scatter pool (for PoolPolicy autoscaling).  Passing an
        ``obs.SLOMonitor`` wraps the signal source in an
        ``obs.SLOSignalSource`` so every GroupSignal carries its
        sustained serving-SLO burn rate (see HotSplitPolicy.burn_hot)."""
        signals = WarrenSignals(warren)
        if slo_monitor is not None:
            signals = obs.SLOSignalSource(signals, slo_monitor)
        return Controller(signals,
                          WarrenActuator(warren, rebalancer),
                          config=config, clock=clock,
                          pool=warren.scatter_pool,
                          decision_log=decision_log)

    @property
    def tick_count(self) -> int:
        return self._tick

    # -- the control cycle --------------------------------------------- #
    def tick(self) -> List[Decision]:
        """One control cycle; returns the decisions attempted this tick
        (possibly empty).  Never raises on mechanism failures — aborts
        and errors become Decision outcomes with backoff."""
        t0 = self.clock()
        with obs.span("autopilot.tick", tick=self._tick):
            sigs = self.signals.collect()
            planned = self._plan(sigs)
            for d in planned:
                self._act(d)
                self.decisions.append(d)
                self._append_log(d)
            self._autoscale_pool(sigs)
        reg = obs.registry()
        if reg.enabled:
            reg.histogram("autopilot_tick_ms",
                          "control-cycle duration").observe(
                              1e3 * (self.clock() - t0))
            reg.gauge("autopilot_groups",
                      "active (non-retired) shard groups").set(
                          sum(1 for s in sigs if not s.retired))
            reg.counter("autopilot_ticks_total", "control cycles run").inc()
            for d in planned:
                reg.counter("autopilot_actions_total",
                            "control actions attempted",
                            kind=d.kind, outcome=d.outcome).inc()
        self._tick += 1
        return planned

    def spawn(self, interval_s: float) -> threading.Event:
        """Run ``tick`` on a daemon thread every ``interval_s`` seconds
        (wall clock); returns the stop event.  A tick that raises (a
        signal-source bug, not a mechanism failure — those become
        Decision outcomes) is counted and the loop keeps going."""
        stop = threading.Event()

        def loop():
            while not stop.wait(interval_s):
                try:
                    self.tick()
                except Exception:
                    reg = obs.registry()
                    if reg.enabled:
                        reg.counter("autopilot_tick_errors_total",
                                    "ticks that raised").inc()

        threading.Thread(target=loop, daemon=True,
                         name="autopilot").start()
        return stop

    # -- planning (pure: signals + controller state -> decisions) ------- #
    def _plan(self, sigs: List[GroupSignal]) -> List[Decision]:
        cfg = self.config
        hys = cfg.hysteresis
        tick = self._tick
        active = [s for s in sigs if not s.retired]
        self._update_streaks(sigs, active)

        # global dwell: the warren settles after ANY attempted action
        if tick <= self._last_action_tick + hys.min_dwell_ticks:
            return []
        planned: List[Decision] = []
        for cand in self._candidates(active):
            if len(planned) >= cfg.max_actions_per_tick:
                break
            if not self._window_budget_ok(tick, len(planned)):
                break
            touched = [cand.group] + (
                [cand.target] if cand.kind == "merge" else [])
            if any(self._blocked(g, tick) for g in touched):
                continue
            planned.append(cand)
        return planned

    def _update_streaks(self, sigs: List[GroupSignal],
                        active: List[GroupSignal]) -> None:
        split, cold, ae = (self.config.split, self.config.cold,
                           self.config.anti_entropy)
        live_gids = {s.group for s in active}
        for key in [g for g in self._hot if g not in live_gids]:
            self._hot.pop(key, None)
            self._idle.pop(key, None)
        for s in active:
            hot = False
            if s.docs >= split.min_docs:
                if s.p95_ms == s.p95_ms and s.p95_ms >= split.p95_hot_ms:
                    hot = True
                others = [o.docs for o in active if o.group != s.group]
                if others and s.docs >= split.skew_ratio * \
                        max(1.0, sum(others) / len(others)):
                    hot = True
                # sustained SLO budget burn (NaN-safe: NaN != NaN)
                if s.burn_rate == s.burn_rate and \
                        s.burn_rate >= split.burn_hot:
                    hot = True
            self._hot[s.group] = self._hot.get(s.group, 0) + 1 if hot else 0
            idle = s.reads <= cold.idle_reads
            self._idle[s.group] = (self._idle.get(s.group, 0) + 1
                                   if idle else 0)
            # anti-entropy: lag of each replica vs the live maximum
            live_seqs = [q for q, a in zip(s.replica_seqs, s.alive) if a]
            top = max(live_seqs, default=-1)
            for r, (seq, alive) in enumerate(zip(s.replica_seqs, s.alive)):
                diverged = (alive and seq < top - ae.max_seq_lag) or \
                    (not alive and ae.resync_dead)
                key = (s.group, r)
                self._lag[key] = self._lag.get(key, 0) + 1 if diverged else 0

    def _candidates(self, active: List[GroupSignal]) -> List[Decision]:
        """Every policy's eligible actions, in priority order: re-sync
        (repair before reshaping) > split (hot spots hurt now) > demote >
        merge.  Deterministic: ties break by group id."""
        cfg = self.config
        tick, now = self._tick, self.clock()
        by_gid = {s.group: s for s in active}
        out: List[Decision] = []

        ae = cfg.anti_entropy
        for (g, r), streak in sorted(self._lag.items()):
            if streak >= ae.sustain_ticks and g in by_gid \
                    and not by_gid[g].demoted:
                s = by_gid[g]
                dead = r < len(s.alive) and not s.alive[r]
                out.append(Decision(
                    tick=tick, t=now, kind="resync", group=g, target=r,
                    reason=("replica dead" if dead else
                            f"replica seq {s.replica_seqs[r]} trails live "
                            f"max {max(q for q, a in zip(s.replica_seqs, s.alive) if a)} "
                            f"beyond lag {ae.max_seq_lag}")
                    + f" for {streak} ticks"))

        sp = cfg.split
        if len(active) < sp.max_groups:
            hot = [s for s in active
                   if self._hot.get(s.group, 0) >= sp.sustain_ticks]
            for s in sorted(hot, key=lambda s: (-s.docs, s.group)):
                why = f"p95 {s.p95_ms:.1f} ms, {s.docs} docs"
                if s.burn_rate == s.burn_rate and \
                        s.burn_rate >= sp.burn_hot:
                    why += f", burn {s.burn_rate:.2f}"
                out.append(Decision(
                    tick=tick, t=now, kind="split", group=s.group,
                    reason=f"hot for {self._hot[s.group]} ticks ({why})"))

        cold = cfg.cold
        idle = sorted(((self._idle.get(s.group, 0), s) for s in active),
                      key=lambda t: (-t[0], t[1].group))
        for streak, s in idle:
            if streak >= cold.merge_after_ticks \
                    and len(active) > cold.min_groups \
                    and s.docs <= cold.merge_max_docs:
                dest = self._merge_dest(active, s.group)
                if dest is not None:
                    out.append(Decision(
                        tick=tick, t=now, kind="merge", group=s.group,
                        target=dest,
                        reason=f"idle for {streak} ticks "
                               f"({s.docs} docs) -> group {dest}"))
                    continue
            if streak >= cold.demote_after_ticks and not s.demoted \
                    and s.docs > 0:
                out.append(Decision(
                    tick=tick, t=now, kind="demote", group=s.group,
                    reason=f"idle for {streak} ticks ({s.docs} docs)"))
        return out

    def _merge_dest(self, active: List[GroupSignal],
                    source: int) -> Optional[int]:
        """Smallest other active group that is not itself blocked —
        folding cold data into the least-loaded survivor."""
        tick = self._tick
        best = None
        for s in sorted(active, key=lambda s: (s.docs, s.group)):
            if s.group == source or self._blocked(s.group, tick):
                continue
            best = s.group
            break
        return best

    def _blocked(self, group: int, tick: int) -> bool:
        if tick <= self._cooldown_until.get(group, -(1 << 30)):
            return True
        bo = self._backoff.get(group)
        return bo is not None and tick <= bo[1]

    def _window_budget_ok(self, tick: int, planned_now: int) -> bool:
        hys = self.config.hysteresis
        while self._action_ticks and \
                self._action_ticks[0] <= tick - hys.window_ticks:
            self._action_ticks.popleft()
        return (len(self._action_ticks) + planned_now
                < hys.max_actions_per_window)

    # -- acting ---------------------------------------------------------- #
    def _act(self, d: Decision) -> None:
        hys, retry = self.config.hysteresis, self.config.retry
        tick = self._tick
        self._action_ticks.append(tick)          # attempts consume budget
        self._last_action_tick = tick
        try:
            if d.kind == "split":
                d.target = self.actuator.split(d.group)
            elif d.kind == "merge":
                self.actuator.merge(d.target, d.group)
            elif d.kind == "demote":
                self.actuator.demote(d.group)
            elif d.kind == "resync":
                self.actuator.resync(d.group, d.target)
            else:                                # pragma: no cover
                raise ValueError(f"unknown decision kind {d.kind!r}")
        except RebalanceAborted as e:
            d.outcome, d.detail = "aborted", str(e)
            self._note_failure(d.group, tick, retry)
            return
        except Exception as e:
            d.outcome, d.detail = "failed", f"{type(e).__name__}: {e}"
            self._note_failure(d.group, tick, retry)
            return
        d.outcome = "applied"
        self._backoff.pop(d.group, None)
        touched = {d.group}
        if d.kind in ("split", "merge") and d.target is not None:
            touched.add(d.target)
        if d.kind == "resync":
            self._lag[(d.group, d.target)] = 0
        for g in touched:
            self._cooldown_until[g] = tick + hys.cooldown_ticks
            self._hot[g] = 0
            self._idle[g] = 0

    def _note_failure(self, group: int, tick: int,
                      retry: RetryPolicy) -> None:
        attempts = self._backoff.get(group, (0, 0))[0] + 1
        delay = min(retry.cap_ticks,
                    retry.base_ticks * (2 ** (attempts - 1)))
        self._backoff[group] = (attempts, tick + delay)

    def _autoscale_pool(self, sigs: List[GroupSignal]) -> None:
        pp = self.config.pool
        if pp is None or self.pool is None:
            return
        n_active = sum(1 for s in sigs if not s.retired)
        target = max(pp.min_workers, min(pp.max_workers, n_active))
        if target != self.pool.workers:
            self.pool.resize(target)

    # -- decision log ---------------------------------------------------- #
    def _append_log(self, d: Decision) -> None:
        if self.decision_log is None:
            return
        if self._log_sink is None or \
                self._log_sink.path != self.decision_log:
            self._log_sink = obs.RotatingJsonl(self.decision_log)
        self._log_sink.write(d.to_record())

"""Sharding policies for the production meshes (used by launch/dryrun).

The policy is divisibility-driven rather than name-driven so it covers all
three families (LM / GNN / recsys) and every mesh in ``launch/mesh.py``:
each axis group ("model" first, then the data axes under FSDP) is greedily
assigned to the largest not-yet-sharded dimension it divides evenly.  That
yields Megatron-style layouts on the LM stacks (vocab- or ff-sharded
matmuls) and row-sharded embedding tables on recsys, while odd-shaped
leaves (norm vectors, biases) fall back to replication on that axis.
"""

from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Every mesh axis except the tensor-parallel one ("model")."""
    return tuple(a for a in mesh.axis_names if a != "model")


def _axes_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def leaf_sharding(mesh: Mesh, leaf, groups) -> NamedSharding:
    """Greedy assignment of axis groups to divisible dims (largest first)."""
    shape = tuple(getattr(leaf, "shape", ()))
    spec = [None] * len(shape)
    for axes in groups:
        size = _axes_size(mesh, axes)
        if size <= 1:
            continue
        best = None
        for d in range(len(shape)):
            if spec[d] is None and shape[d] > 0 and shape[d] % size == 0:
                if best is None or shape[d] > shape[best]:
                    best = d
        if best is not None:
            spec[best] = axes if len(axes) > 1 else axes[0]
    return NamedSharding(mesh, P(*spec))


def _tree_sharding(mesh: Mesh, params, groups):
    return jax.tree.map(lambda l: leaf_sharding(mesh, l, groups), params)


# -- per-family policies ------------------------------------------------ #
def lm_param_sharding(mesh: Mesh, params, fsdp: bool = False):
    groups = [("model",)] + ([data_axes(mesh)] if fsdp else [])
    return _tree_sharding(mesh, params, groups)


def gnn_param_sharding(mesh: Mesh, params):
    return _tree_sharding(mesh, params, [("model",)])


def recsys_param_sharding(mesh: Mesh, params):
    # embedding tables are the big leaves -> row-sharded over "model"
    return _tree_sharding(mesh, params, [("model",)])


def recsys_batch_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P(data_axes(mesh)))


def opt_state_sharding(param_sharding):
    """AdamW moments follow the params; the step counter is replicated."""
    mesh = jax.tree.leaves(param_sharding)[0].mesh
    return {"mu": param_sharding, "nu": param_sharding,
            "step": NamedSharding(mesh, P())}


def lm_cache_sharding(mesh: Mesh, batch: int, long_context: bool = False):
    """KV cache [L, B, S, Hkv, Dh]: batch-sharded normally; for batch-1
    long-context decode the *sequence* dim is sharded instead (the 500k
    cell's sequence-sharded KV)."""
    dp = data_axes(mesh)
    if long_context or batch % _axes_size(mesh, dp) != 0:
        kv = NamedSharding(mesh, P(None, None, dp, None, None))
        length = NamedSharding(mesh, P())
    else:
        kv = NamedSharding(mesh, P(None, dp, None, None, None))
        length = NamedSharding(mesh, P(dp))
    return {"k": kv, "v": kv, "length": length}

"""Elastic fault tolerance: repartition state when the device mesh changes.

When a pod (or a slice of one) drops out, the scheduler hands back fewer
devices.  Recovery is: pick a new mesh shape (``shrink_mesh``), rebuild the
mesh (``launch.mesh.make_mesh_from_sizes``), restore the latest-good
checkpoint, and move every pytree leaf onto its new sharding (``reshard``).
Index shards are repartitioned the same way (``repartition_shards``): the
surviving shard count changes, documents re-route by the same hash, so a
ShardedWarren rebuilt with fewer shards serves identical results.
"""

from __future__ import annotations

from typing import Dict, List

import jax


def reshard(tree, shardings):
    """Move/repartition every leaf of ``tree`` onto ``shardings``.

    ``shardings`` is a matching pytree of ``jax.sharding.Sharding`` (or a
    single sharding applied to all leaves).  jax.device_put handles
    resharding committed arrays across meshes, including host transfers.
    """
    if isinstance(shardings, jax.sharding.Sharding):
        return jax.tree.map(lambda l: jax.device_put(l, shardings), tree)
    return jax.tree.map(lambda l, s: jax.device_put(l, s), tree, shardings)


def shrink_mesh(sizes: Dict[str, int], lost_devices: int,
                preserve: str = "model") -> Dict[str, int]:
    """New mesh axis sizes after losing ``lost_devices`` devices.

    Policy: tensor-parallel width (``preserve``) is never touched — param
    layouts and compiled kernels assume it.  The largest remaining axis is
    halved (keeping power-of-two shapes restartable from FSDP checkpoints)
    until the mesh fits in the surviving device count.
    """
    new = dict(sizes)
    total = 1
    for v in new.values():
        total *= v
    budget = total - lost_devices
    if budget < 1:
        raise ValueError(f"lost {lost_devices} of {total} devices")

    def prod():
        p = 1
        for v in new.values():
            p *= v
        return p

    while prod() > budget:
        candidates = [a for a, v in new.items() if a != preserve and v > 1]
        if not candidates:
            raise ValueError(
                f"cannot shrink {sizes} into {budget} devices while "
                f"preserving axis {preserve!r}")
        axis = max(candidates, key=lambda a: new[a])
        new[axis] //= 2
    return new


def repartition_shards(shard_docs: List[List], k_new: int,
                       route=None) -> List[List]:
    """Redistribute per-shard item lists onto ``k_new`` shards.

    ``route(item, k) -> shard`` defaults to stable hashing of the item's
    repr; items already on the right shard stay put (minimal movement when
    k_new == k_old).
    """
    if route is None:
        def route(item, k):
            import hashlib
            h = hashlib.blake2b(repr(item).encode(), digest_size=8)
            return int.from_bytes(h.digest(), "big") % k
    out: List[List] = [[] for _ in range(k_new)]
    for items in shard_docs:
        for item in items:
            out[route(item, k_new)].append(item)
    return out


def repartition_replica_groups(group_docs: List[List], k_new: int,
                               replicas: int = 1,
                               route=None) -> List[List[List]]:
    """Repartition *whole replica groups* onto ``k_new`` logical shards.

    ``group_docs`` holds one item list per current shard group (replicas of
    a group are lockstep-identical, so one list describes the whole group).
    Items are re-routed with the same stable hash as ``repartition_shards``,
    then every new group's list is fanned out to ``replicas`` copies —
    replicas always move together, a group is never split across shards.

    Returns ``k_new`` groups, each a list of ``replicas`` identical item
    lists (independent list objects, matching the independent per-replica
    indexes they describe).
    """
    if replicas < 1:
        raise ValueError("replicas must be >= 1")
    flat = repartition_shards(group_docs, k_new, route)
    return [[list(items) for _ in range(replicas)] for items in flat]

"""Elastic capacity: repartition state when the mesh or shard count changes.

Two distinct paths live here, for two distinct failure/scale modes:

* **Offline repartition** (mesh shrink): when a pod (or a slice of one)
  drops out, the scheduler hands back fewer devices.  Recovery is: pick a
  new mesh shape (``shrink_mesh``), rebuild the mesh
  (``launch.mesh.make_mesh_from_sizes``), restore the latest-good
  checkpoint, and move every pytree leaf onto its new sharding
  (``reshard``).  Index shards are repartitioned the same way
  (``repartition_shards`` / ``repartition_replica_groups``): document
  lists re-route by a stable hash and the warren is *rebuilt* — correct,
  but the collection is offline while it happens.  This stays the right
  tool when the serving processes themselves are gone.
* **Live rebalance** (capacity change under load): ``split_shard_group``
  and ``merge_shard_groups`` reshape a *running* ShardedWarren through
  :class:`repro.dist.rebalance.Rebalancer` — segments stream to the new
  topology in the durable ``Segment.to_record`` form while writers keep
  committing, and the only stall is the routing-table swap.

Repartition invariants: the output always has exactly ``k_new`` groups —
a shard left unpopulated by the hash is returned as an *empty, addressable*
group, never dropped, because group ids are positional (a missing middle
group would shift every later group's identity).  Routing is deterministic
(keyed blake2b over the item's repr), so repeating a repartition with the
same inputs lands every item on the same shard.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax


def reshard(tree, shardings):
    """Move/repartition every leaf of ``tree`` onto ``shardings``.

    ``shardings`` is a matching pytree of ``jax.sharding.Sharding`` (or a
    single sharding applied to all leaves).  jax.device_put handles
    resharding committed arrays across meshes, including host transfers.
    """
    if isinstance(shardings, jax.sharding.Sharding):
        return jax.tree.map(lambda l: jax.device_put(l, shardings), tree)
    return jax.tree.map(lambda l, s: jax.device_put(l, s), tree, shardings)


def shrink_mesh(sizes: Dict[str, int], lost_devices: int,
                preserve: str = "model") -> Dict[str, int]:
    """New mesh axis sizes after losing ``lost_devices`` devices.

    Policy: tensor-parallel width (``preserve``) is never touched — param
    layouts and compiled kernels assume it.  The largest remaining axis is
    halved (keeping power-of-two shapes restartable from FSDP checkpoints)
    until the mesh fits in the surviving device count.
    """
    new = dict(sizes)
    total = 1
    for v in new.values():
        total *= v
    budget = total - lost_devices
    if budget < 1:
        raise ValueError(f"lost {lost_devices} of {total} devices")

    def prod():
        p = 1
        for v in new.values():
            p *= v
        return p

    while prod() > budget:
        candidates = [a for a, v in new.items() if a != preserve and v > 1]
        if not candidates:
            raise ValueError(
                f"cannot shrink {sizes} into {budget} devices while "
                f"preserving axis {preserve!r}")
        axis = max(candidates, key=lambda a: new[a])
        new[axis] //= 2
    return new


def repartition_shards(shard_docs: List[List], k_new: int,
                       route=None) -> List[List]:
    """Redistribute per-shard item lists onto exactly ``k_new`` shards.

    ``route(item, k) -> shard`` defaults to stable hashing of the item's
    repr; items already on the right shard stay put (minimal movement when
    k_new == k_old).  Shards the hash leaves unpopulated (common when
    ``k_new > k_old`` with few items) come back as empty lists — they stay
    addressable, because shard identity is positional.  A route landing
    outside [0, k_new) is an error, not a silent reshuffle.
    """
    if k_new < 1:
        raise ValueError(f"k_new must be >= 1, got {k_new}")
    if route is None:
        def route(item, k):
            import hashlib
            h = hashlib.blake2b(repr(item).encode(), digest_size=8)
            return int.from_bytes(h.digest(), "big") % k
    out: List[List] = [[] for _ in range(k_new)]
    for items in shard_docs:
        for item in items:
            shard = route(item, k_new)
            if not 0 <= shard < k_new:
                raise ValueError(
                    f"route({item!r}, {k_new}) returned {shard}")
            out[shard].append(item)
    return out


def repartition_replica_groups(group_docs: List[List], k_new: int,
                               replicas: int = 1,
                               route=None) -> List[List[List]]:
    """Repartition *whole replica groups* onto ``k_new`` logical shards.

    ``group_docs`` holds one item list per current shard group (replicas of
    a group are lockstep-identical, so one list describes the whole group).
    Items are re-routed with the same stable hash as ``repartition_shards``,
    then every new group's list is fanned out to ``replicas`` copies —
    replicas always move together, a group is never split across shards.

    Returns exactly ``k_new`` groups, each a list of ``replicas`` identical
    item lists (independent list objects, matching the independent
    per-replica indexes they describe).  A group the hash leaves empty is
    still returned with its ``replicas`` empty lists — dropping it would
    renumber every later group and corrupt positional routing.
    """
    if replicas < 1:
        raise ValueError("replicas must be >= 1")
    flat = repartition_shards(group_docs, k_new, route)
    assert len(flat) == k_new       # empty groups stay addressable
    return [[list(items) for _ in range(replicas)] for items in flat]


# ------------------------------------------------------------------ #
# live rebalancing (streaming, no writer pause) — see repro.dist.rebalance
# ------------------------------------------------------------------ #
def split_shard_group(warren, source: int, pivot: Optional[int] = None,
                      pool=None) -> int:
    """Split a live ShardedWarren replica group in two without pausing
    writers; returns the new group id.  Thin wrapper over
    :class:`repro.dist.rebalance.Rebalancer` for symmetry with the offline
    repartition helpers above — use the Rebalancer directly to batch
    several operations or to read the measured stall stats."""
    from repro.dist.rebalance import Rebalancer

    return Rebalancer(warren, pool=pool).split_group(source, pivot=pivot)


def merge_shard_groups(warren, dest: int, source: int, pool=None) -> None:
    """Fold one live replica group into another without pausing writers
    (demoted groups merge by shipping run manifests); the absorbed group
    is retired in place.  See :class:`repro.dist.rebalance.Rebalancer`."""
    from repro.dist.rebalance import Rebalancer

    Rebalancer(warren, pool=pool).merge_groups(dest, source)


def autopilot(warren, config=None, interval_s: float = 5.0,
              decision_log: Optional[str] = None):
    """Close the loop: start an autopilot controller over a live
    ShardedWarren and return ``(controller, stop_event)``.

    The controller ticks every ``interval_s`` seconds on a daemon thread,
    splitting hot groups, demoting and merging cold ones, and re-syncing
    diverged replicas — the manual `split_shard_group`/`merge_shard_groups`
    calls above, driven by policy instead of by an operator.  Set the
    stop event (or drop the warren) to halt it.  See
    :mod:`repro.dist.autopilot` for the policy knobs."""
    from repro.dist.autopilot import Controller

    ctl = Controller.for_warren(warren, config=config,
                                decision_log=decision_log)
    stop = ctl.spawn(interval_s)
    return ctl, stop

"""Fault-tolerant training loop: checkpoint/restart, straggler mitigation.

The loop is deliberately framework-shaped:

  * jit'd full step (donated params/opt) over an explicit mesh,
  * async checkpoints every `ckpt_every` steps (data-iterator state rides
    along, so restart resumes the exact batch stream),
  * `run_with_restarts` re-enters the loop after a failure, restoring the
    latest checkpoint — the single-process analogue of a scheduler retry,
  * straggler mitigation at the input edge: a prefetch thread with a
    bounded wait; a late batch is *skipped* (logged) and backfilled by the
    next ready one, bounding step-time tail latency at the cost of sample
    order (the standard data-path trick when an input shard straggles).
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.dist.checkpoint import CheckpointManager
from repro.train.optimizer import (AdamWConfig, init_opt_state,
                                   make_train_step)


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    opt: AdamWConfig = field(default_factory=AdamWConfig)
    straggler_timeout_s: float = 5.0
    prefetch: int = 2
    compress_grads: bool = False   # int8 error-feedback gradient compression


class _Prefetcher:
    """Bounded-queue prefetch thread with skip-and-backfill on timeout."""

    def __init__(self, it: Iterator, depth: int):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._it = it
        self._done = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._done = True
            self._q.put(None)

    def get(self, timeout: Optional[float]):
        try:
            item = self._q.get(timeout=timeout)
        except queue.Empty:
            return "TIMEOUT"
        return item


class Trainer:
    def __init__(self, loss_fn: Callable, params, cfg: TrainerConfig,
                 data_iter: Iterator, data_state_fn: Callable = None,
                 data_restore_fn: Callable = None, step_fn=None):
        self.cfg = cfg
        self.params = params
        self.opt_state = init_opt_state(params,
                                        compress_grads=cfg.compress_grads)
        self.step_fn = step_fn or jax.jit(
            make_train_step(loss_fn, cfg.opt,
                            compress_grads=cfg.compress_grads),
            donate_argnums=(0, 1))
        self.data_iter = data_iter
        self.data_state_fn = data_state_fn or (lambda: {})
        self.data_restore_fn = data_restore_fn or (lambda s: None)
        self.step = 0
        self.metrics_log: list = []
        self.skipped_batches = 0
        self.ckpt = (CheckpointManager(cfg.ckpt_dir)
                     if cfg.ckpt_dir else None)

    # ------------------------------------------------------------------ #
    def maybe_restore(self) -> bool:
        if self.ckpt is None:
            return False
        like = {"params": self.params, "opt": self.opt_state,
                "step": 0, "data": self.data_state_fn()}
        # corruption-tolerant: walk back past torn checkpoints to the
        # newest intact one (dist.checkpoint verifies the manifest crc)
        _, state = self.ckpt.restore_latest_good(like)
        if state is None:
            return False
        self.params = state["params"]
        self.opt_state = state["opt"]
        self.step = int(state["step"])
        self.data_restore_fn(state["data"])
        return True

    def _save(self, block=False):
        if self.ckpt is None:
            return
        if getattr(self, "_last_saved", -1) == self.step:
            if block:
                self.ckpt.wait()   # already queued async: make it durable
            return
        self._last_saved = self.step
        # data state must reflect batches *consumed*, not prefetched: prefer
        # the per-batch state stamped by the loader over the live iterator.
        data_state = getattr(self, "_consumed_data_state", None)
        if data_state is None:
            data_state = self.data_state_fn()
        self.ckpt.save(self.step, {
            "params": self.params, "opt": self.opt_state,
            "step": self.step, "data": data_state}, block=block)

    # ------------------------------------------------------------------ #
    def train(self, fail_at: Optional[int] = None) -> Dict[str, Any]:
        """Run to total_steps; `fail_at` injects a crash (tests)."""
        pf = _Prefetcher(self.data_iter, self.cfg.prefetch)
        while self.step < self.cfg.total_steps:
            batch = pf.get(timeout=self.cfg.straggler_timeout_s)
            if batch == "TIMEOUT":
                self.skipped_batches += 1   # skip-and-backfill
                continue
            if batch is None:
                break
            if fail_at is not None and self.step == fail_at:
                raise RuntimeError(f"injected failure at step {self.step}")
            if "_state" in batch:
                self._consumed_data_state = batch["_state"]
            batch = {k: v for k, v in batch.items()
                     if k not in ("step", "_state")}
            self.params, self.opt_state, m = self.step_fn(
                self.params, self.opt_state, batch)
            self.step += 1
            if self.step % self.cfg.log_every == 0 or \
                    self.step == self.cfg.total_steps:
                self.metrics_log.append(
                    {"step": self.step, "loss": float(m["loss"]),
                     "grad_norm": float(m["grad_norm"])})
            if self.step % self.cfg.ckpt_every == 0:
                self._save()
        self._save(block=True)
        return {"step": self.step, "metrics": self.metrics_log,
                "skipped": self.skipped_batches}


def run_with_restarts(make_trainer: Callable[[], Trainer],
                      max_failures: int = 3,
                      fail_at: Optional[int] = None) -> Trainer:
    """Scheduler-retry analogue: rebuild the trainer, restore, continue."""
    failures = 0
    inject = fail_at
    while True:
        trainer = make_trainer()
        trainer.maybe_restore()
        try:
            trainer.train(fail_at=inject)
            return trainer
        except RuntimeError:
            failures += 1
            inject = None       # the injected failure happens once
            if failures > max_failures:
                raise

"""Serving loops: dynamic request batching + the two first-stage retrievers.

RetrievalServer serves ranked retrieval straight from an annotative index
(the paper's workload): queries are micro-batched, impacts are laid out in
the block-impact format, and scoring runs through either the exhaustive
device path or the Block-Max Pallas kernel.

LMServer wraps the transformer decode path with a KV cache and a simple
continuous-batching slot scheduler.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import collection_stats, ranking
from repro.core.vectorized import bm25_topk


@dataclasses.dataclass
class BatcherConfig:
    max_batch: int = 16
    max_wait_ms: float = 2.0


class MicroBatcher:
    """Dynamic batching: collect up to max_batch requests or max_wait_ms."""

    def __init__(self, handler: Callable[[List[Any]], List[Any]],
                 cfg: BatcherConfig):
        self.handler = handler
        self.cfg = cfg
        self._q: "queue.Queue" = queue.Queue()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def submit(self, request) -> "queue.Queue":
        done: "queue.Queue" = queue.Queue(maxsize=1)
        self._q.put((request, done))
        return done

    def _loop(self):
        while not self._stop.is_set():
            try:
                first = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            batch = [first]
            deadline = time.time() + self.cfg.max_wait_ms / 1e3
            while len(batch) < self.cfg.max_batch:
                remaining = deadline - time.time()
                if remaining <= 0:
                    break
                try:
                    batch.append(self._q.get(timeout=remaining))
                except queue.Empty:
                    break
            results = self.handler([r for r, _ in batch])
            for (_, done), res in zip(batch, results):
                done.put(res)

    def close(self):
        self._stop.set()


class RetrievalServer:
    """BM25 top-k over an annotative index with batched device scoring.

    Works over any object with the Warren read surface — a single
    ``Warren``, a ``ShardedWarren`` (with demoted cold groups), or a
    ``TieredWarren``, whose ``annotations`` already k-way merge the hot
    memtable with every on-disk static run, so scoring sees one logical
    hot+cold list per term.  After commits, tier freezes, or shard
    demotions change the collection, call :meth:`refresh_stats`.
    """

    def __init__(self, warren, k: int = 10, batcher: BatcherConfig = None,
                 max_terms: int = 8, max_postings: int = 4096):
        self.warren = warren
        self.k = k
        self.max_terms = max_terms
        self.max_postings = max_postings
        with warren:
            self.stats = collection_stats(warren)
        self.batcher = MicroBatcher(self._handle, batcher or BatcherConfig())

    def refresh_stats(self) -> None:
        """Re-derive collection statistics from a fresh snapshot; queries
        already in flight finish against the stats they started with.
        Reads through a clone so it never collides with the batcher
        thread's start()/end() bracket on the serving warren."""
        w = self.warren.clone()
        with w:
            self.stats = collection_stats(w)

    def query(self, text: str, timeout: float = 10.0):
        return self.batcher.submit(text).get(timeout=timeout)

    def _handle(self, queries: List[str]) -> List[List[Tuple[int, float]]]:
        stats = self.stats      # one coherent stats version per batch
        qn, t, l = len(queries), self.max_terms, self.max_postings
        doc_idx = np.full((qn, t, l), stats.n_docs, np.int32)
        impacts = np.zeros((qn, t, l), np.float32)
        qmask = np.zeros((qn, t), np.float32)
        with self.warren:
            for qi, text in enumerate(queries):
                terms = list(dict.fromkeys(ranking.ranking_tokens(text)))[:t]
                for ti, term in enumerate(terms):
                    lst = self.warren.annotations(
                        ranking.TF_PREFIX + ranking.porter_stem(term))
                    if not len(lst):
                        continue
                    idf = np.log(1 + (stats.n_docs - len(lst) + 0.5)
                                 / (len(lst) + 0.5))
                    di = np.searchsorted(stats.doc_starts, lst.starts)
                    di = np.clip(di, 0, stats.n_docs - 1)
                    ok = stats.doc_starts[di] == lst.starts
                    di, tf = di[ok][:l], lst.values[ok][:l]
                    dl = stats.doc_lens[di]
                    imp = idf * tf * 1.9 / (tf + 0.9 * (0.6 + 0.4 * dl
                                                        / stats.avgdl))
                    doc_idx[qi, ti, :len(di)] = di
                    impacts[qi, ti, :len(di)] = imp
                    qmask[qi, ti] = 1.0
        scores, ids = bm25_topk(jnp.asarray(doc_idx), jnp.asarray(impacts),
                                jnp.asarray(qmask),
                                n_docs=stats.n_docs, k=self.k)
        scores, ids = np.asarray(scores), np.asarray(ids)
        out = []
        for qi in range(qn):
            res = [(int(stats.doc_starts[d]), float(s))
                   for d, s in zip(ids[qi], scores[qi]) if s > 0]
            out.append(res)
        return out

    def close(self):
        self.batcher.close()


class LMServer:
    """Continuous-batching decode server over the transformer decode path."""

    def __init__(self, params, cfg, max_slots: int = 8, max_len: int = 128):
        from repro.models import transformer as T
        self.T = T
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.cache = T.init_cache(cfg, max_slots, max_len)
        self.step_fn = jax.jit(lambda p, c, t: T.decode_step(p, c, t, cfg))
        self.slot_free = [True] * max_slots
        self.slot_out: List[List[int]] = [[] for _ in range(max_slots)]

    def generate(self, prompts: List[List[int]], max_new: int = 16
                 ) -> List[List[int]]:
        """Greedy-decode a batch of prompts (token-id lists)."""
        assert len(prompts) <= self.max_slots
        outs = [[] for _ in prompts]
        # prefill by stepping prompts token by token (cache fills)
        tokens = np.zeros((self.max_slots,), np.int32)
        max_prompt = max(len(p) for p in prompts)
        for i in range(max_prompt + max_new):
            for s, p in enumerate(prompts):
                if i < len(p):
                    tokens[s] = p[i]
            logits, self.cache = self.step_fn(self.params, self.cache,
                                              jnp.asarray(tokens))
            nxt = np.asarray(jnp.argmax(logits, -1))
            for s, p in enumerate(prompts):
                if i >= len(p) - 1:
                    outs[s].append(int(nxt[s]))
                    if i + 1 >= len(p):
                        tokens[s] = int(nxt[s])
        return [o[:max_new] for o in outs]

"""Serving loops: dynamic request batching + the two first-stage retrievers.

RetrievalServer serves ranked retrieval straight from an annotative index
(the paper's workload): queries are micro-batched, impacts are laid out in
the block-impact format, and scoring runs through either the exhaustive
device path or the Block-Max Pallas kernel.  Over a ``ShardedWarren`` it
serves *natively*: each micro-batch fans out once per shard group (on the
warren's scatter pool when async scatter is enabled), every group packs its
own ``(doc_idx, impacts, qmask)`` block with GLOBAL collection statistics,
per-group device ``bm25_topk`` dispatches overlap the next group's packing,
and a global k-way merge yields exactly the single-index results.

LMServer wraps the transformer decode path with a KV cache and a simple
continuous-batching slot scheduler.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import collection_stats, ranking
from repro.core.vectorized import bm25_topk
from repro.dist.parallel import ScatterTimings


@dataclasses.dataclass
class BatcherConfig:
    max_batch: int = 16
    max_wait_ms: float = 2.0


class _BatchFailure:
    """A handler exception, boxed so waiters can tell it from a result."""

    def __init__(self, exc: BaseException):
        self.exc = exc


class _Handle:
    """One request's completion slot; ``get`` re-raises handler failures."""

    def __init__(self):
        self._q: "queue.Queue" = queue.Queue(maxsize=1)

    def _put(self, item) -> None:
        self._q.put(item)

    def get(self, block: bool = True, timeout: Optional[float] = None):
        res = self._q.get(block, timeout)
        if isinstance(res, _BatchFailure):
            raise res.exc
        return res


class MicroBatcher:
    """Dynamic batching: collect up to max_batch requests or max_wait_ms.

    A handler exception fails only the requests of that batch — it is
    boxed, delivered to each waiter's handle (re-raised from ``get``), and
    the batching loop keeps serving later requests.
    """

    def __init__(self, handler: Callable[[List[Any]], List[Any]],
                 cfg: BatcherConfig):
        self.handler = handler
        self.cfg = cfg
        self._q: "queue.Queue" = queue.Queue()
        self._stop = threading.Event()
        # orders submit vs close-drain; contention-profiled
        # (lock_wait_ms{lock="microbatcher"})
        self._close_lock = obs.ProfiledLock("microbatcher")
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def submit(self, request) -> _Handle:
        done = _Handle()
        with self._close_lock:
            if self._stop.is_set():
                done._put(_BatchFailure(RuntimeError("MicroBatcher closed")))
                return done
            self._q.put((request, done))
        return done

    def _loop(self):
        while not self._stop.is_set():
            try:
                first = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            batch = [first]
            deadline = time.monotonic() + self.cfg.max_wait_ms / 1e3
            while len(batch) < self.cfg.max_batch:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                try:
                    batch.append(self._q.get(timeout=remaining))
                except queue.Empty:
                    break
            reg = obs.registry()
            if reg.enabled:
                reg.gauge("serve_queue_depth",
                          "requests still queued when a batch launches"
                          ).set(self._q.qsize())
                reg.histogram("serve_batch_size",
                              "requests coalesced per micro-batch",
                              lo=0.5, hi=1e4, per_decade=40
                              ).observe(len(batch))
            try:
                with obs.span("serve.batch", size=len(batch)):
                    results = self.handler([r for r, _ in batch])
                if len(results) != len(batch):
                    raise RuntimeError(
                        f"handler returned {len(results)} results for a "
                        f"batch of {len(batch)}")
            except Exception as e:
                failure = _BatchFailure(e)
                for _, done in batch:
                    done._put(failure)
                continue
            for (_, done), res in zip(batch, results):
                done._put(res)

    def close(self):
        """Stop the loop and promptly fail queued waiters — nobody blocks
        out their full timeout on a closed batcher."""
        with self._close_lock:    # no submit can slip in after the drain
            self._stop.set()
        self._thread.join(timeout=1.0)
        failure = _BatchFailure(RuntimeError("MicroBatcher closed"))
        while True:
            try:
                _, done = self._q.get_nowait()
            except queue.Empty:
                break
            done._put(failure)


class RetrievalServer:
    """BM25 top-k over an annotative index with batched device scoring.

    Works over any object with the Warren read surface — a single
    ``Warren``, a ``ShardedWarren`` (with demoted cold groups), or a
    ``TieredWarren``, whose ``annotations`` already k-way merge the hot
    memtable with every on-disk static run, so scoring sees one logical
    hot+cold list per term.  After commits, tier freezes, or shard
    demotions change the collection, call :meth:`refresh_stats`.

    A ``ShardedWarren`` is served natively (scatter once per group, score
    per group, merge globally); ``timings`` holds the per-batch
    scatter/score/merge breakdown.
    """

    def __init__(self, warren, k: int = 10, batcher: BatcherConfig = None,
                 max_terms: int = 8, max_postings: int = 4096,
                 sharded_native: bool = True):
        self.warren = warren
        self.k = k
        self.max_terms = max_terms
        self.max_postings = max_postings
        self._sharded = sharded_native and hasattr(warren, "map_groups")
        self.timings = ScatterTimings(site="server")
        # device shapes already scored: a new (qp, tp, l, nb) tuple means
        # the jitted scorer compiles again — the counter Autopilot watches
        # to tell shape-bucket churn from steady-state serving
        self._seen_shapes: set = set()
        if self._sharded:
            self.stats = None    # the native path re-scatters per batch
        else:
            with warren:
                self.stats = collection_stats(warren)
        self.batcher = MicroBatcher(self._handle, batcher or BatcherConfig())

    def refresh_stats(self) -> None:
        """Re-derive collection statistics from a fresh snapshot; queries
        already in flight finish against the stats they started with.
        Reads through a clone so it never collides with the batcher
        thread's start()/end() bracket on the serving warren.  The native
        sharded path scatters fresh stats every batch, so there is
        nothing to refresh."""
        if self._sharded:
            return
        w = self.warren.clone()
        with w:
            self.stats = collection_stats(w)

    def timing_summary(self) -> str:
        return self.timings.summary()

    def query(self, text: str, timeout: float = 10.0):
        return self.batcher.submit(text).get(timeout=timeout)

    def _handle(self, queries: List[str]) -> List[List[Tuple[int, float]]]:
        # coalesce duplicate requests: a batch scores each distinct query
        # once, every waiter gets (a copy of) the shared result row
        uniq = list(dict.fromkeys(queries))
        rows = (self._handle_sharded(uniq) if self._sharded
                else self._handle_single(uniq))
        if len(uniq) == len(queries):
            return rows
        # timings count served requests, so per-query figures stay
        # comparable with wall-clock ms/query over the same stream
        self.timings.add(queries=len(queries) - len(uniq))
        by_query = dict(zip(uniq, rows))
        return [list(by_query[q]) for q in queries]

    def _query_terms(self, queries: List[str]) -> List[List[str]]:
        return [list(dict.fromkeys(ranking.ranking_tokens(q)))[:self.max_terms]
                for q in queries]

    @staticmethod
    def _cap_by_impact(di: np.ndarray, imp: np.ndarray,
                       limit: int) -> Tuple[np.ndarray, np.ndarray]:
        """Keep the top-``limit`` postings by impact (stable, so equal
        impacts keep address order) — truncating by document order would
        silently drop high-impact documents past the cap."""
        if len(di) <= limit:
            return di, imp
        keep = np.argsort(-imp, kind="stable")[:limit]
        return di[keep], imp[keep]

    def _pad_sizes(self, qn: int, nterms: int,
                   longest: int) -> Tuple[int, int, int]:
        """Stable-ish device shapes: the batch and term dims bucket to
        powers of two and the postings dim to a multiple of 256, so the
        jitted ``bm25_topk`` compiles a bounded set of shapes instead of
        one per (batch size, term count, longest list) — and short queries
        don't pay for ``max_terms`` worth of padded scatter work."""
        qp = max(1 << max(qn - 1, 0).bit_length(), 1)
        tp = min(self.max_terms, max(1 << max(nterms - 1, 0).bit_length(), 1))
        l = max(256, -(-longest // 256) * 256)
        return qp, tp, min(self.max_postings, l)

    def _acc_pad(self, n_docs: int) -> int:
        """Accumulator-size bucket: a power of two ≥ max(n_docs, k), so a
        commit changing the live document count doesn't recompile the
        jitted scorer.  Padded slots never receive impacts, score 0, and
        are filtered by the ``s > 0`` result guard."""
        return 1 << max(max(n_docs, self.k) - 1, 0).bit_length()

    def _note_shapes(self, qp: int, tp: int, l: int, nb: int) -> None:
        """Count first sightings of a device shape bucket — each one is a
        fresh XLA compile of the jitted scorer."""
        key = (qp, tp, l, nb, self.k)
        if key not in self._seen_shapes:
            self._seen_shapes.add(key)
            reg = obs.registry()
            if reg.enabled:
                reg.counter(
                    "serve_jit_recompile_total",
                    "distinct (batch, terms, postings, accumulator) device "
                    "shape buckets scored — each costs one XLA compile"
                ).inc()

    # -- single-index path ------------------------------------------------- #
    def _handle_single(self, queries: List[str]
                       ) -> List[List[Tuple[int, float]]]:
        stats = self.stats      # one coherent stats version per batch
        qn, l_cap = len(queries), self.max_postings
        if stats.n_docs == 0:
            return [[] for _ in queries]
        t0 = time.perf_counter()
        entries: List[Tuple[int, int, np.ndarray, np.ndarray]] = []
        with self.warren:
            for qi, terms in enumerate(self._query_terms(queries)):
                for ti, term in enumerate(terms):
                    lst = self.warren.annotations(
                        ranking.TF_PREFIX + ranking.porter_stem(term))
                    if not len(lst):
                        continue
                    idf = ranking._bm25_idf(stats.n_docs, len(lst))
                    di, imp = ranking._impacts(lst, stats, idf,
                                               k1=0.9, b=0.4)
                    di, imp = self._cap_by_impact(di, imp, l_cap)
                    entries.append((qi, ti, di, imp))
        t_scatter = time.perf_counter() - t0
        t0 = time.perf_counter()
        qp, tp, l = self._pad_sizes(
            qn, max((e[1] + 1 for e in entries), default=1),
            max((len(e[2]) for e in entries), default=1))
        nb = self._acc_pad(stats.n_docs)
        self._note_shapes(qp, tp, l, nb)
        with obs.span("device_score"):
            with obs.phase_timer("bm25_topk", "gather"):
                doc_idx = np.full((qp, tp, l), nb, np.int32)
                impacts = np.zeros((qp, tp, l), np.float32)
                qmask = np.zeros((qp, tp), np.float32)
                for qi, ti, di, imp in entries:
                    doc_idx[qi, ti, :len(di)] = di
                    impacts[qi, ti, :len(di)] = imp
                    qmask[qi, ti] = 1.0
            with obs.phase_timer("bm25_topk", "compute"):
                scores, ids = bm25_topk(jnp.asarray(doc_idx),
                                        jnp.asarray(impacts),
                                        jnp.asarray(qmask),
                                        n_docs=nb, k=self.k)
                scores, ids = np.asarray(scores), np.asarray(ids)
        t_score = time.perf_counter() - t0
        t0 = time.perf_counter()
        with obs.span("merge"):
            out = []
            for qi in range(qn):
                res = [(int(stats.doc_starts[d]), float(s))
                       for d, s in zip(ids[qi], scores[qi]) if s > 0]
                out.append(res)
        t_merge = time.perf_counter() - t0
        self.timings.add(scatter=t_scatter, score=t_score, merge=t_merge,
                         queries=qn)
        return out

    # -- native ShardedWarren path ----------------------------------------- #
    def _handle_sharded(self, queries: List[str]
                        ) -> List[List[Tuple[int, float]]]:
        qn, l, k = len(queries), self.max_postings, self.k
        qterms = self._query_terms(queries)
        # stem every query term once; pack_group indexes these features
        qfeatures = [[ranking.TF_PREFIX + ranking.porter_stem(term)
                      for term in terms] for terms in qterms]
        stems = list(dict.fromkeys(f for row in qfeatures for f in row))
        # scatter: ONE fan-out per group for the whole micro-batch — every
        # group returns its stats and its slice of every term list (the
        # fan-out follows the session's pinned routing table, so the group
        # count comes from the gather, not from the live warren)
        t0 = time.perf_counter()
        with self.warren:
            gathered = self.warren.map_groups(
                lambda w: (ranking.collection_stats(w),
                           [w.annotations(f) for f in stems]))
        t_scatter = time.perf_counter() - t0
        t0 = time.perf_counter()
        n_groups = len(gathered)
        per = [s for s, _ in gathered]
        lists = [lst for _, lst in gathered]
        n_docs = sum(s.n_docs for s in per)
        if n_docs == 0:
            self.timings.add(scatter=t_scatter, queries=qn)
            return [[] for _ in queries]
        # global stats, computed exactly as collection_stats would over the
        # merged surface (avgdl is order-free; ties merge by address below)
        avgdl = float(np.concatenate([s.doc_lens for s in per]).mean())
        # per stem: per-group (doc_idx, impact) with GLOBAL df/avgdl, then
        # the posting cap applied to the *global* list so the kept postings
        # are exactly the single-index path's
        term_group: Dict[str, Optional[List[Tuple[np.ndarray, np.ndarray]]]] \
            = {}
        empty = (np.zeros(0, np.int64), np.zeros(0))
        for si, f in enumerate(stems):
            df = sum(len(lists[g][si]) for g in range(n_groups))
            if df == 0:
                term_group[f] = None
                continue
            idf = ranking._bm25_idf(n_docs, df)
            per_g = []
            for g in range(n_groups):
                lst, stats = lists[g][si], per[g]
                if len(lst) == 0 or stats.n_docs == 0:
                    per_g.append(empty)
                    continue
                per_g.append(ranking._impacts_with_avgdl(lst, stats, idf,
                                                         avgdl))
            total = sum(len(di) for di, _ in per_g)
            if total > l:
                cat = np.concatenate([imp for _, imp in per_g])
                keep = np.zeros(total, bool)
                keep[np.argsort(-cat, kind="stable")[:l]] = True
                capped, off = [], 0
                for di, imp in per_g:
                    m = keep[off:off + len(di)]
                    off += len(di)
                    capped.append((di[m], imp[m]))
                per_g = capped
            term_group[f] = per_g
        def pack_group(g: int):
            """This group's (doc_idx, impacts, qmask) block, or None when
            the group has no documents or no postings for the batch."""
            ng = per[g].n_docs
            if ng == 0:
                return None
            longest = max((len(per_g[g][0]) for per_g in term_group.values()
                           if per_g is not None), default=0)
            if longest == 0:    # nothing scored here: all-zero rows anyway
                return None
            qp, tp, lg = self._pad_sizes(
                qn, max((len(row) for row in qfeatures), default=1), longest)
            nb = self._acc_pad(ng)
            self._note_shapes(qp, tp, lg, nb)
            doc_idx = np.full((qp, tp, lg), nb, np.int32)
            impacts = np.zeros((qp, tp, lg), np.float32)
            qmask = np.zeros((qp, tp), np.float32)
            for qi, row in enumerate(qfeatures):
                for ti, f in enumerate(row):
                    per_g = term_group[f]
                    if per_g is None:
                        continue
                    qmask[qi, ti] = 1.0
                    di, imp = per_g[g]
                    if len(di):
                        doc_idx[qi, ti, :len(di)] = di
                        impacts[qi, ti, :len(di)] = imp
            return doc_idx, impacts, qmask, nb

        # pipelined scoring: jax dispatch is asynchronous, so group g's
        # device top-k computes while group g+1's block is being packed;
        # the np.asarray collection below blocks on all of them at once
        with obs.span("device_score"):
            pending = []
            for g in range(n_groups):
                with obs.phase_timer("bm25_topk", "gather"):
                    blk = pack_group(g)
                if blk is None:
                    pending.append(None)
                    continue
                doc_idx, impacts, qmask, nb = blk
                pending.append(bm25_topk(
                    jnp.asarray(doc_idx), jnp.asarray(impacts),
                    jnp.asarray(qmask), n_docs=nb, k=k))
            with obs.phase_timer("bm25_topk", "compute"):
                group_res = [None if p is None
                             else (np.asarray(p[0]), np.asarray(p[1]))
                             for p in pending]
        t_score = time.perf_counter() - t0
        # gather: global k-way merge; per-group lists come out of top_k
        # sorted by (-score, doc index) = (-score, address) within a group,
        # and the composite key merges on the document's ADDRESS, which is
        # the single-index tie order no matter how rebalancing has
        # interleaved group address ranges
        t0 = time.perf_counter()
        with obs.span("merge"):
            out = []
            for qi in range(qn):
                runs = []
                for g, res in enumerate(group_res):
                    if res is None:
                        continue
                    sc, ids = res
                    runs.append([(-float(s), int(per[g].doc_starts[int(d)]))
                                 for s, d in zip(sc[qi], ids[qi]) if s > 0])
                merged = heapq.merge(*runs)   # key: (-score, address)
                row = [(addr, -neg_s)
                       for neg_s, addr in itertools.islice(merged, k)]
                out.append(row)
        t_merge = time.perf_counter() - t0
        self.timings.add(scatter=t_scatter, score=t_score, merge=t_merge,
                         queries=qn)
        return out

    def close(self):
        self.batcher.close()


class LMServer:
    """Continuous-batching decode server over the transformer decode path."""

    def __init__(self, params, cfg, max_slots: int = 8, max_len: int = 128):
        from repro.models import transformer as T
        self.T = T
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.cache = T.init_cache(cfg, max_slots, max_len)
        self.step_fn = jax.jit(lambda p, c, t: T.decode_step(p, c, t, cfg))
        self.slot_free = [True] * max_slots
        self.slot_out: List[List[int]] = [[] for _ in range(max_slots)]

    def generate(self, prompts: List[List[int]], max_new: int = 16
                 ) -> List[List[int]]:
        """Greedy-decode a batch of prompts (token-id lists)."""
        assert len(prompts) <= self.max_slots
        # a fresh KV cache per call: decoding against a previous call's
        # cache would attend to its keys/values and resume at its length
        self.cache = self.T.init_cache(self.cfg, self.max_slots, self.max_len)
        outs = [[] for _ in prompts]
        # prefill by stepping prompts token by token (cache fills)
        tokens = np.zeros((self.max_slots,), np.int32)
        max_prompt = max(len(p) for p in prompts)
        for i in range(max_prompt + max_new):
            for s, p in enumerate(prompts):
                if i < len(p):
                    tokens[s] = p[i]
            logits, self.cache = self.step_fn(self.params, self.cache,
                                              jnp.asarray(tokens))
            nxt = np.asarray(jnp.argmax(logits, -1))
            for s, p in enumerate(prompts):
                if i >= len(p) - 1:       # past the prompt: greedy decode
                    outs[s].append(int(nxt[s]))
                    tokens[s] = int(nxt[s])
        return [o[:max_new] for o in outs]

"""Raw-JAX optimizers: AdamW with clipping + schedules (no optax offline)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, decay)


def init_opt_state(params, compress_grads: bool = False):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    state = {"mu": zeros,
             "nu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                params),
             "step": jnp.zeros((), jnp.int32)}
    if compress_grads:
        from repro.dist import compression
        state["ef"] = compression.init_residual(params)
    return state


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
    lr = lr_schedule(cfg, step)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mu_hat = mu / (1 - cfg.b1 ** step.astype(jnp.float32))
        nu_hat = nu / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {
        "grad_norm": gn, "lr": lr}


def make_train_step(loss_fn: Callable, opt_cfg: Optional[AdamWConfig] = None,
                    compress_grads: bool = False,
                    reduce_axis: Optional[str] = None):
    """loss_fn(params, batch) -> scalar; returns jit-able full train step.

    ``compress_grads`` passes gradients through int8 error-feedback
    quantization (the cross-pod wire format) before the AdamW update; the
    residual rides in ``opt_state["ef"]`` (see ``init_opt_state``).  Inside
    shard_map, ``reduce_axis`` additionally mean-reduces the compressed
    gradients over that mesh axis.
    """
    opt_cfg = opt_cfg or AdamWConfig()
    if compress_grads:
        from repro.dist import compression

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if compress_grads:
            ef = opt_state["ef"]
            if reduce_axis is not None:
                grads, new_ef = compression.cross_pod_reduce_compressed(
                    grads, ef, axis_name=reduce_axis)
            else:
                q, s, new_ef = compression.compress_with_feedback(grads, ef)
                grads = compression.decompress(q, s)
            opt_state = {k: v for k, v in opt_state.items() if k != "ef"}
        params, opt_state, metrics = adamw_update(opt_cfg, params, grads,
                                                  opt_state)
        if compress_grads:
            opt_state["ef"] = new_ef
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step

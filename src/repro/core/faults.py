"""Crash fault-point seam for the durable storage paths.

Every durability-critical boundary (block-run write, manifest publish,
sliced-run shipping) announces itself through :func:`fault_point` before
and/or after its fsync/rename.  In production the hook is ``None`` and the
call is one attribute load; the crash-injection test matrix
(``tests/test_tiered_crash.py``) installs a hook that raises at the k-th
announcement, simulating a process kill at exactly that boundary, then
reopens the store from disk and checks latest-good recovery.

The seam is deliberately tiny and process-global: fault names are plain
strings (``"run.synced"``, ``"manifest.published"``, ...) so the matrix can
enumerate every boundary a scenario crosses by counting one clean pass.
"""

from __future__ import annotations

from typing import Callable, Optional

_hook: Optional[Callable[[str], None]] = None


class InjectedCrash(Exception):
    """Raised by a test hook to simulate a process kill at a fault point."""

    def __init__(self, name: str, ordinal: int):
        super().__init__(f"injected crash at fault point {ordinal}: {name}")
        self.name = name
        self.ordinal = ordinal


def set_hook(hook: Optional[Callable[[str], None]]) -> None:
    """Install (or clear, with None) the process-global fault hook."""
    global _hook
    _hook = hook


def fault_point(name: str) -> None:
    """Announce a durability boundary; a no-op unless a hook is installed."""
    if _hook is not None:
        _hook(name)

"""Annotations and annotation lists under minimal-interval semantics.

An annotation is ``⟨f, (p, q), v⟩``.  The set of annotations for a feature
must form a *generalized concordance list* (GC-list): no interval nests in
another, so the list is strictly increasing in both start and end address.

``reduce_minimal`` implements the paper's ``G(S)`` reduction.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Tuple

import numpy as np

# Sentinels used by access methods: τ/ρ return (INF, INF, 0) past the end,
# τ'/ρ' return (NINF, NINF, 0) before the beginning.
INF = np.int64(2**62)
NINF = np.int64(-(2**62))


@dataclass(frozen=True)
class Annotation:
    feature: int
    p: int
    q: int
    v: float = 0.0

    def interval(self) -> Tuple[int, int]:
        return (self.p, self.q)


class AnnotationList:
    """Struct-of-arrays GC-list: sorted, non-nesting intervals with values."""

    __slots__ = ("starts", "ends", "values")

    def __init__(self, starts: np.ndarray, ends: np.ndarray, values: np.ndarray,
                 _checked: bool = False):
        starts = np.asarray(starts, dtype=np.int64)
        ends = np.asarray(ends, dtype=np.int64)
        values = np.asarray(values, dtype=np.float64)
        if not (starts.shape == ends.shape == values.shape):
            raise ValueError("mismatched SoA shapes")
        if not _checked and starts.size:
            if np.any(ends < starts):
                raise ValueError("interval with end < start")
            if np.any(np.diff(starts) <= 0) or np.any(np.diff(ends) <= 0):
                raise ValueError("minimal-interval semantics violated")
        self.starts = starts
        self.ends = ends
        self.values = values

    # ------------------------------------------------------------------ #
    @staticmethod
    def empty() -> "AnnotationList":
        z = np.zeros(0, dtype=np.int64)
        return AnnotationList(z, z, np.zeros(0), _checked=True)

    @staticmethod
    def from_intervals(intervals: Iterable[Tuple[int, int]],
                       values: Iterable[float] = None) -> "AnnotationList":
        ivs = list(intervals)
        vals = list(values) if values is not None else [0.0] * len(ivs)
        if not ivs:
            return AnnotationList.empty()
        s = np.array([i[0] for i in ivs], dtype=np.int64)
        e = np.array([i[1] for i in ivs], dtype=np.int64)
        v = np.array(vals, dtype=np.float64)
        return reduce_minimal(s, e, v)

    def __len__(self) -> int:
        return int(self.starts.size)

    def __iter__(self):
        for i in range(len(self)):
            yield (int(self.starts[i]), int(self.ends[i]), float(self.values[i]))

    def __eq__(self, other) -> bool:
        return (isinstance(other, AnnotationList)
                and np.array_equal(self.starts, other.starts)
                and np.array_equal(self.ends, other.ends)
                and np.array_equal(self.values, other.values))

    def __repr__(self) -> str:
        items = ", ".join(f"({p},{q};{v:g})" for p, q, v in list(self)[:8])
        more = "..." if len(self) > 8 else ""
        return f"AnnotationList[{len(self)}]({items}{more})"

    # --- access methods (paper Eq. 4/5 + backwards variants) ----------- #
    def tau(self, k: int) -> Tuple[int, int, float]:
        """First annotation with start >= k."""
        i = int(np.searchsorted(self.starts, k, side="left"))
        if i >= len(self):
            return (int(INF), int(INF), 0.0)
        return (int(self.starts[i]), int(self.ends[i]), float(self.values[i]))

    def rho(self, k: int) -> Tuple[int, int, float]:
        """First annotation with end >= k."""
        i = int(np.searchsorted(self.ends, k, side="left"))
        if i >= len(self):
            return (int(INF), int(INF), 0.0)
        return (int(self.starts[i]), int(self.ends[i]), float(self.values[i]))

    def tau_b(self, k: int) -> Tuple[int, int, float]:
        """Last annotation with start <= k (backwards τ)."""
        i = int(np.searchsorted(self.starts, k, side="right")) - 1
        if i < 0:
            return (int(NINF), int(NINF), 0.0)
        return (int(self.starts[i]), int(self.ends[i]), float(self.values[i]))

    def rho_b(self, k: int) -> Tuple[int, int, float]:
        """Last annotation with end <= k (backwards ρ)."""
        i = int(np.searchsorted(self.ends, k, side="right")) - 1
        if i < 0:
            return (int(NINF), int(NINF), 0.0)
        return (int(self.starts[i]), int(self.ends[i]), float(self.values[i]))


def reduce_minimal(starts: np.ndarray, ends: np.ndarray,
                   values: np.ndarray = None) -> AnnotationList:
    """G(S): drop intervals that (strictly) contain another interval.

    For duplicate (p, q) pairs the *last* value wins (paper's isolation rule:
    the annotation with the largest sequence number is retained).
    """
    starts = np.asarray(starts, dtype=np.int64)
    ends = np.asarray(ends, dtype=np.int64)
    if values is None:
        values = np.zeros(starts.shape, dtype=np.float64)
    values = np.asarray(values, dtype=np.float64)
    if starts.size == 0:
        return AnnotationList.empty()
    if np.any(ends < starts):
        raise ValueError("interval with end < start")
    # stable sort by (start asc, end asc); stability keeps insertion order of
    # duplicates so "last wins" is well defined.
    order = np.lexsort((ends, starts))
    s, e, v = starts[order], ends[order], values[order]
    # dedupe exact (p,q): keep the last occurrence in insertion order.  After
    # the stable lexsort, equal (p,q) runs preserve insertion order.
    same = np.concatenate(([False], (s[1:] == s[:-1]) & (e[1:] == e[:-1])))
    keep_last = np.ones(s.size, dtype=bool)
    keep_last[:-1] &= ~same[1:]
    s, e, v = s[keep_last], e[keep_last], v[keep_last]
    # Sorted by (start asc, end asc) with unique (p,q) pairs:
    #  - within an equal-start run, every later interval contains the first
    #    -> keep only the first of each run;
    #  - interval i strictly contains a later-starting interval j>i iff
    #    e[j] <= e[i]  -> keep i only if e[i] < min(e[i+1:]).
    # (Containment witnesses come from the full S, so both tests use the
    # unreduced arrays.)
    suffix_min = np.minimum.accumulate(e[::-1])[::-1]
    keep = np.ones(s.size, dtype=bool)
    keep[1:] &= s[1:] != s[:-1]
    keep[:-1] &= e[:-1] < suffix_min[1:]
    return AnnotationList(s[keep], e[keep], v[keep], _checked=True)


def union_intervals(lists: Iterable[AnnotationList]) -> AnnotationList:
    """Coalescing union of interval lists (for *erased* sets, not GC-lists).

    Erasure is permanent over a point-set of addresses, so erased intervals
    must accumulate as a union: overlapping, nested, and adjacent intervals
    coalesce instead of competing under minimal-interval reduction (where a
    nested erase would *drop* its enclosing interval and un-hide content).
    The result is a sorted, disjoint interval list — a valid GC-list — with
    all values zero.
    """
    ls = [l for l in lists if len(l)]
    if not ls:
        return AnnotationList.empty()
    s = np.concatenate([l.starts for l in ls])
    e = np.concatenate([l.ends for l in ls])
    order = np.argsort(s, kind="stable")
    s, e = s[order], e[order]
    # sweep: start a new interval only where the gap to the running
    # coalesced end is >= 2 (adjacent intervals merge: erased is a point-set)
    run_end = np.maximum.accumulate(e)
    new_run = np.ones(s.size, dtype=bool)
    new_run[1:] = s[1:] > run_end[:-1] + 1
    starts = s[new_run]
    idx = np.flatnonzero(new_run)
    bounds = np.append(idx[1:], s.size)
    ends = run_end[bounds - 1]
    return AnnotationList(starts, ends, np.zeros(starts.size), _checked=True)


def merge_lists(lists: Iterable[AnnotationList]) -> AnnotationList:
    """Merge GC-lists from multiple index segments into one GC-list.

    Nesting conflicts keep the innermost annotation (paper §5); exact
    duplicates keep the one from the latest segment (largest seqnum), so pass
    segments in sequence order.
    """
    ls = [l for l in lists if len(l)]
    if not ls:
        return AnnotationList.empty()
    if len(ls) == 1:
        return ls[0]
    s = np.concatenate([l.starts for l in ls])
    e = np.concatenate([l.ends for l in ls])
    v = np.concatenate([l.values for l in ls])
    return reduce_minimal(s, e, v)

"""Durable transaction log for the dynamic index (paper §5).

Append-only file of compressed msgpack frames (zstd when available, zlib
otherwise — see core/codec.py; the codec byte lives in the blob header):

  {"t": "ready",  "seq": n, "base": p, "length": L, ...payload}
  {"t": "commit", "seq": n}
  {"t": "abort",  "seq": n}

``ready`` records are written (and fsynced) during the first phase of the
two-phase commit; the transaction is durable once its ``commit`` frame is on
disk.  Recovery replays the log: ready-without-commit ⇒ aborted, its address
interval becomes a gap.  ``compact`` rewrites the log as a single merged
snapshot frame plus the tail of still-live transactions.
"""

from __future__ import annotations

import os
import struct
import threading
from typing import Any, Dict, Iterator, List, Optional

import msgpack

from . import codec

_MAGIC = b"ANOTLOG1"


class TransactionLog:
    def __init__(self, path: Optional[str]):
        """path=None gives an in-memory (non-durable) log, useful for tests."""
        self.path = path
        self._lock = threading.Lock()
        self._fh = None
        self._mem: List[bytes] = []
        if path is not None:
            exists = os.path.exists(path)
            self._fh = open(path, "ab")
            if not exists or os.path.getsize(path) == 0:
                self._fh.write(_MAGIC)
                self._fh.flush()
                os.fsync(self._fh.fileno())

    # ------------------------------------------------------------------ #
    def _write_frame(self, record: Dict[str, Any], sync: bool = True) -> None:
        payload = codec.compress(msgpack.packb(record, use_bin_type=True))
        frame = struct.pack("<I", len(payload)) + payload
        with self._lock:
            if self._fh is not None:
                self._fh.write(frame)
                self._fh.flush()
                if sync:
                    os.fsync(self._fh.fileno())
            else:
                self._mem.append(frame)

    def append(self, record: Dict[str, Any], sync: bool = True) -> None:
        self._write_frame(record, sync=sync)

    def replay(self) -> Iterator[Dict[str, Any]]:
        if self.path is not None:
            with self._lock:
                if self._fh is not None:
                    self._fh.flush()
            with open(self.path, "rb") as fh:
                magic = fh.read(len(_MAGIC))
                if magic != _MAGIC:
                    return
                while True:
                    hdr = fh.read(4)
                    if len(hdr) < 4:
                        return
                    (n,) = struct.unpack("<I", hdr)
                    payload = fh.read(n)
                    if len(payload) < n:
                        return  # torn tail frame: treat as not written
                    yield msgpack.unpackb(codec.decompress(payload),
                                          raw=False, strict_map_key=False)
        else:
            with self._lock:
                frames = list(self._mem)
            for frame in frames:
                (n,) = struct.unpack("<I", frame[:4])
                yield msgpack.unpackb(codec.decompress(frame[4:4 + n]),
                                      raw=False, strict_map_key=False)

    def compact(self, snapshot_records: List[Dict[str, Any]]) -> None:
        """Atomically replace the log with the given records."""
        if self.path is None:
            with self._lock:
                self._mem = []
            for r in snapshot_records:
                self._write_frame(r, sync=False)
            return
        tmp = self.path + ".compact"
        with open(tmp, "wb") as fh:
            fh.write(_MAGIC)
            for r in snapshot_records:
                payload = codec.compress(msgpack.packb(r, use_bin_type=True))
                fh.write(struct.pack("<I", len(payload)) + payload)
            fh.flush()
            os.fsync(fh.fileno())
        with self._lock:
            if self._fh is not None:
                self._fh.close()
            os.replace(tmp, self.path)
            self._fh = open(self.path, "ab")

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

"""Ranked retrieval over an annotative index (paper §2.2, Fig. 7 workload).

Annotation conventions (exactly the paper's):

  ⟨:, (d_lo, d_hi)⟩                  document extent (feature ":")
  ⟨tf:porter:<stem>, d_lo, tf⟩       per-document term frequency
  ⟨dl:, d_lo, len⟩                   document length in ranking tokens
  ⟨<word>, a⟩                        word occurrence (added by append)

The *index* only stores annotations; this module interprets them as BM25
(Robertson et al. 1994).  Query evaluation offers three strategies:

  score_bm25        exhaustive merge-join over tf lists (numpy)
  score_wand        document-at-a-time WAND with per-term upper bounds
  score_blockmax    Block-Max WAND: per-block maxima annotations prune
                    whole blocks (also the layout the Pallas kernel uses)

plus RM3-style pseudo-relevance feedback built on T(p, q).
"""

from __future__ import annotations

import heapq
import re
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .annotation import AnnotationList
from .stemmer import porter_stem

_WORD_RE = re.compile(r"\w+", re.UNICODE)

TF_PREFIX = "tf:porter:"
DOC_FEATURE = ":"
DL_FEATURE = "dl:"

# Stopwords for PRF expansion only (ranking uses raw idf).
_STOP = frozenset("""a an and are as at be by for from has have in is it its
of on or that the to was were will with this which not no but they he she we
you i his her their our your""".split())


def ranking_tokens(text: str) -> List[str]:
    return [w.lower() for w in _WORD_RE.findall(text)]


def index_document(txn_or_warren, text: str, docid: str = None,
                   extra_annotations: Sequence[Tuple[str, float]] = ()) -> Tuple[int, int]:
    """Append a document and add the ranking annotations above."""
    w = txn_or_warren
    lo, hi = w.append(text)
    w.annotate(DOC_FEATURE, lo, hi)
    words = ranking_tokens(text)
    stems: Dict[str, int] = {}
    for word in words:
        s = porter_stem(word)
        stems[s] = stems.get(s, 0) + 1
    for stem, tf in stems.items():
        w.annotate(TF_PREFIX + stem, lo, lo, float(tf))
    w.annotate(DL_FEATURE, lo, lo, float(len(words)))
    if docid is not None:
        w.annotate("docid:" + docid, lo, hi)
    for feature, value in extra_annotations:
        w.annotate(feature, lo, lo, value)
    return lo, hi


def ingest_documents(warren, docs, batch: int = 64) -> int:
    """Index ``(docid, text)`` pairs in chunked transactions.

    One transaction per chunk matters for a ShardedWarren: all appends of
    a transaction land on one shard group (routed by the first document),
    so chunking is what spreads a corpus across groups.  Returns the
    number of documents ingested."""
    n = 0
    it = iter(docs)
    while True:
        chunk = [d for _, d in zip(range(batch), it)]
        if not chunk:
            return n
        with warren:
            warren.transaction()
            for docid, text in chunk:
                index_document(warren, text, docid=docid)
            warren.commit()
        n += len(chunk)


@dataclass
class CollectionStats:
    n_docs: int
    avgdl: float
    doc_starts: np.ndarray   # sorted starts of ':' extents
    doc_ends: np.ndarray
    doc_lens: np.ndarray     # aligned with doc_starts


def collection_stats(snapshot_or_warren) -> CollectionStats:
    docs = snapshot_or_warren.annotations(DOC_FEATURE)
    dls = snapshot_or_warren.annotations(DL_FEATURE)
    lens = np.ones(len(docs))
    if len(dls):
        idx = np.searchsorted(dls.starts, docs.starts)
        idx = np.clip(idx, 0, len(dls) - 1)
        hit = dls.starts[idx] == docs.starts
        lens = np.where(hit, dls.values[idx], 1.0)
    avgdl = float(lens.mean()) if len(docs) else 1.0
    return CollectionStats(len(docs), avgdl, docs.starts.copy(),
                           docs.ends.copy(), lens)


def _term_lists(snapshot_or_warren, terms: Sequence[str]):
    return {t: snapshot_or_warren.annotations(TF_PREFIX + porter_stem(t))
            for t in terms}


def _bm25_idf(n_docs: int, df: int) -> float:
    return float(np.log(1.0 + (n_docs - df + 0.5) / (df + 0.5)))


def _impacts(lst: AnnotationList, stats: CollectionStats,
             idf: float, k1: float, b: float) -> Tuple[np.ndarray, np.ndarray]:
    """(doc_index, impact) pairs for one term's tf list."""
    di = np.searchsorted(stats.doc_starts, lst.starts)
    di = np.clip(di, 0, max(len(stats.doc_starts) - 1, 0))
    ok = (len(stats.doc_starts) > 0) & (stats.doc_starts[di] == lst.starts)
    di, tf = di[ok], lst.values[ok]
    dl = stats.doc_lens[di]
    denom = tf + k1 * (1.0 - b + b * dl / stats.avgdl)
    return di, idf * tf * (k1 + 1.0) / denom


def _impacts_with_avgdl(lst: AnnotationList, stats: CollectionStats,
                        idf: float, avgdl: float, k1: float = 0.9,
                        b: float = 0.4) -> Tuple[np.ndarray, np.ndarray]:
    """``_impacts`` with the collection's avgdl overridden — scatter-gather
    serving scores each shard's documents against the GLOBAL average, and
    every path sharing this helper is what keeps sharded results
    bit-identical to the single index."""
    local = CollectionStats(stats.n_docs, avgdl, stats.doc_starts,
                            stats.doc_ends, stats.doc_lens)
    return _impacts(lst, local, idf, k1, b)


def score_bm25(snapshot_or_warren, query: str, k: int = 10,
               k1: float = 0.9, b: float = 0.4,
               weights: Optional[Dict[str, float]] = None,
               stats: Optional[CollectionStats] = None) -> List[Tuple[int, float]]:
    """Exhaustive BM25; returns [(doc_start_address, score)] best-first."""
    stats = stats or collection_stats(snapshot_or_warren)
    if stats.n_docs == 0:
        return []
    terms = ranking_tokens(query) if weights is None else list(weights)
    lists = _term_lists(snapshot_or_warren, terms)
    acc = np.zeros(stats.n_docs)
    for t in set(terms):
        lst = lists[t]
        if len(lst) == 0:
            continue
        idf = _bm25_idf(stats.n_docs, len(lst))
        wq = 1.0 if weights is None else float(weights[t])
        di, imp = _impacts(lst, stats, idf, k1, b)
        np.add.at(acc, di, wq * imp)
    k = min(k, stats.n_docs)
    top = np.argpartition(-acc, k - 1)[:k]
    top = top[np.argsort(-acc[top], kind="stable")]
    return [(int(stats.doc_starts[i]), float(acc[i])) for i in top if acc[i] > 0]


# --------------------------------------------------------------------- #
# WAND (Broder et al. 2003) over hoppers: document-at-a-time with term
# upper bounds; generalizes directly because τ/ρ generalize seek().
# --------------------------------------------------------------------- #
def score_wand(snapshot_or_warren, query: str, k: int = 10,
               k1: float = 0.9, b: float = 0.4,
               stats: Optional[CollectionStats] = None) -> List[Tuple[int, float]]:
    stats = stats or collection_stats(snapshot_or_warren)
    if stats.n_docs == 0:
        return []
    terms = list(dict.fromkeys(ranking_tokens(query)))
    lists = _term_lists(snapshot_or_warren, terms)
    cursors = []
    for t in terms:
        lst = lists[t]
        if len(lst) == 0:
            continue
        idf = _bm25_idf(stats.n_docs, len(lst))
        # max impact: tf -> saturating, bound with dl -> 0 side
        ub = idf * (k1 + 1.0) * lst.values.max() / (lst.values.max() + k1 * (1.0 - b))
        di, imp = _impacts(lst, stats, idf, k1, b)
        cursors.append({"pos": 0, "di": di, "imp": imp, "ub": float(ub)})
    cursors = [c for c in cursors if len(c["di"])]
    if not cursors:
        return []
    heap: List[Tuple[float, int]] = []   # (score, doc_index) min-heap
    theta = 0.0
    evals = 0
    while True:
        live = [c for c in cursors if c["pos"] < len(c["di"])]
        if not live:
            break
        live.sort(key=lambda c: c["di"][c["pos"]])
        # pivot: first term where cumulative UB exceeds theta
        acc_ub, pivot = 0.0, None
        for i, c in enumerate(live):
            acc_ub += c["ub"]
            if acc_ub > theta or len(heap) < k:
                pivot = i
                break
        if pivot is None:
            break
        pivot_doc = int(live[pivot]["di"][live[pivot]["pos"]])
        if int(live[0]["di"][live[0]["pos"]]) == pivot_doc:
            score = 0.0
            for c in live:
                p = c["pos"]
                if p < len(c["di"]) and c["di"][p] == pivot_doc:
                    score += float(c["imp"][p])
                    c["pos"] = p + 1
            evals += 1
            if len(heap) < k:
                heapq.heappush(heap, (score, pivot_doc))
            elif score > heap[0][0]:
                heapq.heapreplace(heap, (score, pivot_doc))
            if len(heap) == k:
                theta = heap[0][0]
        else:
            for c in live[:pivot]:
                c["pos"] = int(np.searchsorted(c["di"], pivot_doc))
    out = sorted(heap, key=lambda x: -x[0])
    return [(int(stats.doc_starts[d]), s) for s, d in out if s > 0]


# --------------------------------------------------------------------- #
# Block-Max layout: doc space cut into fixed blocks; per-(term, block)
# maxima enable block skipping (Ding & Suel 2011).  This same layout feeds
# the Pallas TPU kernel (kernels/bm25_blockmax).
# --------------------------------------------------------------------- #
@dataclass
class BlockImpactIndex:
    block_size: int
    n_docs: int
    n_blocks: int
    terms: List[str]
    # per term: (block_ids, block_offsets_into doc/imp arrays, doc_idx, impacts, block_max)
    term_blocks: List[dict]
    doc_starts: np.ndarray


def build_block_impacts(snapshot_or_warren, terms: Sequence[str],
                        block_size: int = 128, k1: float = 0.9, b: float = 0.4,
                        stats: Optional[CollectionStats] = None) -> BlockImpactIndex:
    stats = stats or collection_stats(snapshot_or_warren)
    n_blocks = max(1, -(-stats.n_docs // block_size))
    lists = _term_lists(snapshot_or_warren, terms)
    tb = []
    kept_terms = []
    for t in terms:
        lst = lists[t]
        if len(lst) == 0:
            continue
        idf = _bm25_idf(stats.n_docs, len(lst))
        di, imp = _impacts(lst, stats, idf, k1, b)
        blk = di // block_size
        uniq, starts_in = np.unique(blk, return_index=True)
        bmax = np.maximum.reduceat(imp, starts_in) if len(imp) else np.zeros(0)
        tb.append({"blocks": uniq.astype(np.int64),
                   "offsets": np.append(starts_in, len(di)).astype(np.int64),
                   "di": di.astype(np.int64), "imp": imp,
                   "bmax": bmax})
        kept_terms.append(t)
    return BlockImpactIndex(block_size, stats.n_docs, n_blocks, kept_terms,
                            tb, stats.doc_starts.copy())


def score_blockmax(bidx: BlockImpactIndex, k: int = 10) -> List[Tuple[int, float]]:
    """Block-Max scoring over the block-impact layout (host reference)."""
    if not bidx.term_blocks:
        return []
    # per-block upper bound = sum over terms of that block's max impact
    ub = np.zeros(bidx.n_blocks)
    for t in bidx.term_blocks:
        ub[t["blocks"]] += t["bmax"]
    order = np.argsort(-ub, kind="stable")     # best blocks first
    heap: List[Tuple[float, int]] = []
    theta = 0.0
    bs = bidx.block_size
    scores = np.zeros(bs)
    for blk in order:
        if len(heap) >= k and ub[blk] <= theta:
            break                              # all remaining blocks pruned
        scores[:] = 0.0
        for t in bidx.term_blocks:
            j = int(np.searchsorted(t["blocks"], blk))
            if j < len(t["blocks"]) and t["blocks"][j] == blk:
                lo, hi = t["offsets"][j], t["offsets"][j + 1]
                np.add.at(scores, t["di"][lo:hi] - blk * bs, t["imp"][lo:hi])
        base = blk * bs
        for i in np.flatnonzero(scores):
            s = float(scores[i])
            d = int(base + i)
            if len(heap) < k:
                heapq.heappush(heap, (s, d))
            elif s > heap[0][0]:
                heapq.heapreplace(heap, (s, d))
        if len(heap) >= k:
            theta = heap[0][0]
    out = sorted(heap, key=lambda x: -x[0])
    return [(int(bidx.doc_starts[d]), s) for s, d in out if s > 0]


# --------------------------------------------------------------------- #
# RM3-flavoured pseudo-relevance feedback (paper Fig. 7 workload)
# --------------------------------------------------------------------- #
def expand_query(snapshot_or_warren, query: str, fb_docs: int = 20,
                 fb_terms: int = 20, orig_weight: float = 0.6,
                 stats: Optional[CollectionStats] = None) -> Dict[str, float]:
    stats = stats or collection_stats(snapshot_or_warren)
    top = score_bm25(snapshot_or_warren, query, k=fb_docs, stats=stats)
    counts: Dict[str, float] = {}
    doc_map = {int(s): i for i, s in enumerate(stats.doc_starts)}
    for d_lo, _ in top:
        i = doc_map.get(d_lo)
        hi = int(stats.doc_ends[i]) if i is not None else d_lo
        text = snapshot_or_warren.translate(d_lo, hi)
        if text is None:
            continue
        for wrd in ranking_tokens(text):
            if wrd in _STOP or len(wrd) <= 2 or wrd.isdigit():
                continue
            counts[wrd] = counts.get(wrd, 0.0) + 1.0
    scored = sorted(counts.items(), key=lambda kv: -kv[1])[:fb_terms]
    total = sum(v for _, v in scored) or 1.0
    weights: Dict[str, float] = {}
    for t in ranking_tokens(query):
        weights[t] = weights.get(t, 0.0) + orig_weight / max(len(ranking_tokens(query)), 1)
    for t, v in scored:
        weights[t] = weights.get(t, 0.0) + (1 - orig_weight) * v / total
    return weights


def average_precision(ranked_docs: Sequence[int], relevant: set) -> float:
    if not relevant:
        return 0.0
    hits, s = 0, 0.0
    for i, d in enumerate(ranked_docs, 1):
        if d in relevant:
            hits += 1
            s += hits / i
    return s / len(relevant)

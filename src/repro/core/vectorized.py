"""Vectorized (TPU-native) GCL algebra: batched array programs in JAX.

The lazy engine (gcl.py) chases one cursor at a time — ideal on a CPU,
hostile to a TPU.  Here the same operators are re-derived as dense array
programs over struct-of-arrays GC-lists:

  * τ/ρ become `searchsorted` over the starts/ends arrays (vmap-able),
  * containment operators become masks computed with one searchsorted probe
    per element (O(n log m), fully parallel),
  * combination operators materialize a *candidate* solution per input
    element (each candidate provably a solution; every minimal solution is a
    candidate) followed by a parallel G-reduction,
  * G-reduction = sort + suffix-min masking (no data-dependent shapes:
    everything returns fixed-size arrays + validity masks).

Padding convention: entries with start == PAD (= int32 max) are invalid.
Lists are int32 on device; segment-local coordinates (< 2^31) by
construction — the host index rebases segments before overflow (DESIGN §2).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

PAD = np.int32(np.iinfo(np.int32).max)


def pack(starts, ends, values=None, size: int = None):
    """Host → device: pad a GC-list to `size` entries."""
    n = len(starts)
    size = size or max(n, 1)
    s = np.full(size, PAD, dtype=np.int32)
    e = np.full(size, PAD, dtype=np.int32)
    v = np.zeros(size, dtype=np.float32)
    s[:n] = starts
    e[:n] = ends
    if values is not None:
        v[:n] = values
    return jnp.asarray(s), jnp.asarray(e), jnp.asarray(v)


def unpack(s, e, v=None) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    s, e = np.asarray(s), np.asarray(e)
    keep = s != PAD
    vv = np.asarray(v)[keep] if v is not None else np.zeros(keep.sum())
    return s[keep], e[keep], vv


# --------------------------------------------------------------------- #
# access methods: batched τ/ρ
# --------------------------------------------------------------------- #
def tau(starts, ends, k):
    """Batched τ: first annotation with start >= k (k may be an array)."""
    i = jnp.searchsorted(starts, k, side="left")
    i = jnp.minimum(i, starts.shape[0] - 1)
    s, e = starts[i], ends[i]
    ok = s >= k
    return jnp.where(ok, s, PAD), jnp.where(ok, e, PAD)


def rho(starts, ends, k):
    i = jnp.searchsorted(ends, k, side="left")
    i = jnp.minimum(i, ends.shape[0] - 1)
    s, e = starts[i], ends[i]
    ok = e >= k
    return jnp.where(ok, s, PAD), jnp.where(ok, e, PAD)


# --------------------------------------------------------------------- #
# G-reduction: parallel minimality mask over candidate intervals
# --------------------------------------------------------------------- #
def g_reduce_mask(s, e):
    """Given candidate intervals (PAD-padded), return (s, e, keep_mask) with
    the surviving minimal intervals, sorted by start.

    Sorting key pushes PAD entries to the tail.  Equal (p,q) duplicates keep
    one representative (the first after a stable sort)."""
    order = jnp.lexsort((e, s))
    s, e = s[order], e[order]
    n = s.shape[0]
    valid = s != PAD
    # drop exact duplicates
    dup = jnp.concatenate([jnp.zeros(1, bool),
                           (s[1:] == s[:-1]) & (e[1:] == e[:-1])])
    # equal-start run: keep first (others contain it)
    eq_start = jnp.concatenate([jnp.zeros(1, bool), s[1:] == s[:-1]])
    # contains a later-starting interval iff e >= suffix-min of later ends
    e_for_min = jnp.where(valid & ~dup, e, PAD)
    suffix_min = jax.lax.cummin(e_for_min[::-1])[::-1]
    nxt = jnp.concatenate([suffix_min[1:], jnp.full(1, PAD, suffix_min.dtype)])
    keep = valid & ~dup & ~eq_start & (e < nxt)
    return s, e, keep, order


# --------------------------------------------------------------------- #
# containment operators: masks over A
# --------------------------------------------------------------------- #
def contained_in_mask(a_s, a_e, b_s, b_e):
    """mask[i]: A[i] ⊑ some B[j].  First B ending >= A.end must start <= A.start."""
    j = jnp.searchsorted(b_e, a_e, side="left")
    j = jnp.minimum(j, b_e.shape[0] - 1)
    ok = (b_e[j] >= a_e) & (b_s[j] <= a_s) & (b_s[j] != PAD)
    return ok & (a_s != PAD)


def containing_mask(a_s, a_e, b_s, b_e):
    """mask[i]: A[i] ⊒ some B[j].  First B starting >= A.start must end <= A.end."""
    j = jnp.searchsorted(b_s, a_s, side="left")
    j = jnp.minimum(j, b_s.shape[0] - 1)
    ok = (b_s[j] >= a_s) & (b_e[j] <= a_e) & (b_s[j] != PAD)
    return ok & (a_s != PAD)


def _apply_mask(a_s, a_e, a_v, mask):
    s = jnp.where(mask, a_s, PAD)
    e = jnp.where(mask, a_e, PAD)
    v = jnp.where(mask, a_v, 0.0)
    order = jnp.argsort(s)
    return s[order], e[order], v[order]


def contained_in(a_s, a_e, a_v, b_s, b_e):
    return _apply_mask(a_s, a_e, a_v, contained_in_mask(a_s, a_e, b_s, b_e))


def containing(a_s, a_e, a_v, b_s, b_e):
    return _apply_mask(a_s, a_e, a_v, containing_mask(a_s, a_e, b_s, b_e))


def not_contained_in(a_s, a_e, a_v, b_s, b_e):
    m = (~contained_in_mask(a_s, a_e, b_s, b_e)) & (a_s != PAD)
    return _apply_mask(a_s, a_e, a_v, m)


def not_containing(a_s, a_e, a_v, b_s, b_e):
    m = (~containing_mask(a_s, a_e, b_s, b_e)) & (a_s != PAD)
    return _apply_mask(a_s, a_e, a_v, m)


# --------------------------------------------------------------------- #
# combination operators: candidates + parallel G-reduce
# --------------------------------------------------------------------- #
def _rho_b(b_s, b_e, k):
    """Backward ρ: last B with end <= k; PAD-aware (PAD entries sort high)."""
    j = jnp.searchsorted(b_e, k, side="right") - 1
    ok = j >= 0
    j = jnp.maximum(j, 0)
    s = jnp.where(ok, b_s[j], PAD)
    e = jnp.where(ok, b_e[j], PAD)
    return s, e


def both_of(a_s, a_e, b_s, b_e):
    """A △ B.  Candidates: for each a: (min(a.p, ρ'_B(a.q).p), a.q), plus the
    symmetric set anchored at B (DESIGN §2 / gcl.BothOf derivation)."""
    def anchored(x_s, x_e, y_s, y_e):
        ys, ye = _rho_b(y_s, y_e, x_e)
        ok = (x_s != PAD) & (ys != PAD)
        cs = jnp.minimum(x_s, ys)
        return jnp.where(ok, cs, PAD), jnp.where(ok, x_e, PAD)

    ca_s, ca_e = anchored(a_s, a_e, b_s, b_e)
    cb_s, cb_e = anchored(b_s, b_e, a_s, a_e)
    s = jnp.concatenate([ca_s, cb_s])
    e = jnp.concatenate([ca_e, cb_e])
    s, e, keep, _ = g_reduce_mask(s, e)
    return jnp.where(keep, s, PAD), jnp.where(keep, e, PAD)


def one_of(a_s, a_e, b_s, b_e):
    s = jnp.concatenate([a_s, b_s])
    e = jnp.concatenate([a_e, b_e])
    s, e, keep, _ = g_reduce_mask(s, e)
    return jnp.where(keep, s, PAD), jnp.where(keep, e, PAD)


def followed_by(a_s, a_e, b_s, b_e):
    """A ◇ B: for each b, pair with the last A ending < b.p."""
    as_, ae_ = _rho_b(a_s, a_e, b_s - 1)
    ok = (b_s != PAD) & (as_ != PAD)
    cs = jnp.where(ok, as_, PAD)
    ce = jnp.where(ok, b_e, PAD)
    s, e, keep, _ = g_reduce_mask(cs, ce)
    return jnp.where(keep, s, PAD), jnp.where(keep, e, PAD)


# --------------------------------------------------------------------- #
# batched BM25 scoring (dense scatter-add path; the Pallas kernel offers
# the block-max pruned variant)
# --------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("n_docs", "k"))
def bm25_topk(doc_idx, impacts, qmask, n_docs: int, k: int):
    """Batched exhaustive BM25.

    doc_idx  [Q, T, L] int32 padded with n_docs (scatter drop)
    impacts  [Q, T, L] f32, zero where padded
    qmask    [Q, T]    f32 per-query term weights (0 = absent term)
    returns  (scores [Q, k], ids [Q, k])
    """
    def per_query(di, im, qm):
        acc = jnp.zeros((n_docs,), jnp.float32)
        contrib = (im * qm[:, None]).reshape(-1)
        acc = acc.at[di.reshape(-1)].add(contrib, mode="drop")
        return jax.lax.top_k(acc, k)

    return jax.vmap(per_query)(doc_idx, impacts, qmask)

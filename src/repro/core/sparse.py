"""Learned sparse retrieval over annotations (paper §2.2).

"Annotative indexing trivially supports learned sparse retrieval by
creating an annotation for each element of a sparse vector" — here:

  ⟨w:<method>:<token>, (p, p), weight⟩       at the scored extent's start

Multiple methods coexist in one index (e.g. BM25 tf: at the document level
and SPLADE-style w:splade: at the passage level), and hybrid scoring is a
weighted sum over the same τ/ρ machinery.  Since learned weights lack the
distributional properties WAND exploits (paper's own caveat), scoring here
is score-at-a-time over the impact layout — which is exactly the
bm25_blockmax kernel's input format, so the device path is shared.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .annotation import AnnotationList

W_PREFIX = "w:"


def index_sparse_vector(w, extent: Tuple[int, int], weights: Dict[str, float],
                        method: str = "splade") -> int:
    """Annotate ⟨w:method:token, extent.start, weight⟩ per nonzero."""
    lo = extent[0]
    n = 0
    for token, weight in weights.items():
        if weight != 0.0:
            w.annotate(f"{W_PREFIX}{method}:{token}", lo, lo, float(weight))
            n += 1
    return n


def score_sparse(reader, query_weights: Dict[str, float], k: int = 10,
                 method: str = "splade",
                 extents: Optional[AnnotationList] = None
                 ) -> List[Tuple[int, float]]:
    """Dot product between the query vector and indexed sparse vectors.

    `extents` (default: ':' extents) defines the scored units; impact lists
    are keyed at extent starts, so scoring is a merge over starts — the same
    access pattern as BM25 and the same device layout."""
    extents = extents if extents is not None else reader.annotations(":")
    if len(extents) == 0:
        return []
    starts = extents.starts
    acc = np.zeros(len(starts))
    for token, qw in query_weights.items():
        lst = reader.annotations(f"{W_PREFIX}{method}:{token}")
        if len(lst) == 0:
            continue
        idx = np.searchsorted(starts, lst.starts)
        idx = np.clip(idx, 0, len(starts) - 1)
        ok = starts[idx] == lst.starts
        np.add.at(acc, idx[ok], qw * lst.values[ok])
    kk = min(k, len(starts))
    top = np.argpartition(-acc, kk - 1)[:kk]
    top = top[np.argsort(-acc[top], kind="stable")]
    return [(int(starts[i]), float(acc[i])) for i in top if acc[i] > 0]


def score_hybrid(reader, query: str, query_weights: Dict[str, float],
                 k: int = 10, alpha: float = 0.5,
                 method: str = "splade") -> List[Tuple[int, float]]:
    """alpha·BM25 + (1-alpha)·sparse, both from the same index."""
    from .ranking import collection_stats, score_bm25
    stats = collection_stats(reader)
    bm = dict(score_bm25(reader, query, k=max(k * 4, 50), stats=stats))
    sp = dict(score_sparse(reader, query_weights, k=max(k * 4, 50),
                           method=method))
    def norm(d):
        if not d:
            return {}
        m = max(d.values()) or 1.0
        return {doc: v / m for doc, v in d.items()}
    bm, sp = norm(bm), norm(sp)
    docs = set(bm) | set(sp)
    fused = {d: alpha * bm.get(d, 0.0) + (1 - alpha) * sp.get(d, 0.0)
             for d in docs}
    out = sorted(fused.items(), key=lambda kv: -kv[1])[:k]
    return [(d, s) for d, s in out]

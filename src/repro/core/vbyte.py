"""vByte (variable-byte) compression for gap-encoded posting lists.

Williams & Zobel (1999): each integer is emitted as 7-bit groups, low to
high, continuation bit set on all but the final byte.  Annotation lists
strictly increase in both start and end address (minimal-interval
semantics), so starts and ends are delta-encoded before compression; values
are zig-zag encoded (they are arbitrary 64-bit payloads).

Everything is vectorized with numpy; these codecs sit on the durable/on-disk
path (dynamic-index log records and static-index segments).
"""

from __future__ import annotations

import numpy as np


def encode(values: np.ndarray) -> bytes:
    """vByte-encode a 1-D array of non-negative int64 values."""
    v = np.asarray(values, dtype=np.uint64)
    if v.size == 0:
        return b""
    if values.min() < 0:
        raise ValueError("vByte encodes non-negative integers; zig-zag first")
    # byte length per value: ceil(bitlen/7), min 1
    nbits = np.zeros(v.shape, dtype=np.int64)
    tmp = v.copy()
    while True:
        nz = tmp != 0
        if not nz.any():
            break
        nbits[nz] += 7
        tmp >>= np.uint64(7)
    nbytes = np.maximum(nbits // 7, 1)
    total = int(nbytes.sum())
    out = np.empty(total, dtype=np.uint8)
    # positions of each value's first byte
    starts = np.concatenate(([0], np.cumsum(nbytes)[:-1]))
    # emit up to 10 byte-planes
    remaining = v.copy()
    idx = starts.copy()
    alive = np.ones(v.shape, dtype=bool)
    for _ in range(10):
        if not alive.any():
            break
        byte = (remaining[alive] & np.uint64(0x7F)).astype(np.uint8)
        remaining[alive] >>= np.uint64(7)
        last = remaining[alive] == 0
        # continuation bit on all but the last byte of each value
        byte = byte | np.where(last, 0, 0x80).astype(np.uint8)
        out[idx[alive]] = byte
        idx[alive] += 1
        alive_idx = np.flatnonzero(alive)
        alive[alive_idx[last]] = False
    return out.tobytes()


def decode(data: bytes, count: int) -> np.ndarray:
    """Decode ``count`` vByte values from ``data`` (vectorized)."""
    if count == 0:
        return np.zeros(0, dtype=np.int64)
    raw = np.frombuffer(data, dtype=np.uint8)
    is_last = (raw & 0x80) == 0
    ends = np.flatnonzero(is_last)[:count]
    starts = np.concatenate(([0], ends[:-1] + 1))
    out = np.zeros(count, dtype=np.uint64)
    maxlen = int((ends - starts).max()) + 1
    for plane in range(maxlen):
        pos = starts + plane
        valid = pos <= ends
        out[valid] |= (raw[pos[valid]].astype(np.uint64) & np.uint64(0x7F)) << np.uint64(7 * plane)
    return out.astype(np.int64)


def zigzag(values: np.ndarray) -> np.ndarray:
    v = np.asarray(values, dtype=np.int64)
    return ((v << 1) ^ (v >> 63)).astype(np.uint64).astype(np.int64)


def unzigzag(values: np.ndarray) -> np.ndarray:
    v = np.asarray(values, dtype=np.uint64)
    return ((v >> np.uint64(1)) ^ (np.uint64(0) - (v & np.uint64(1)))).astype(np.int64)


def encode_gaps(sorted_values: np.ndarray) -> bytes:
    """Gap-encode a strictly increasing array, then vByte."""
    v = np.asarray(sorted_values, dtype=np.int64)
    if v.size == 0:
        return b""
    gaps = np.concatenate(([v[0]], np.diff(v)))
    return encode(gaps)


def decode_gaps(data: bytes, count: int) -> np.ndarray:
    gaps = decode(data, count)
    return np.cumsum(gaps)

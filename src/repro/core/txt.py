"""Content storage and the translation function T(p, q).

Content is a sequence of tokens situated in a global address space (paper
Fig. 1).  Each ``append`` contributes one record: a contiguous run of token
addresses plus the original text and per-token character offsets, so
``translate`` reproduces the *original* text span (including separators)
between the first and last token of the interval.
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class AppendRecord:
    lo: int                 # first token address
    hi: int                 # last token address (inclusive)
    text: str               # original appended text
    offsets: np.ndarray     # [n_tokens, 2] char (offset, length)
    tokens: Tuple[str, ...] # token strings (content addressing)


class ContentStore:
    """Ordered, non-overlapping append records (one per ``append`` call)."""

    def __init__(self):
        self._records: List[AppendRecord] = []
        self._los: List[int] = []

    def add(self, record: AppendRecord) -> None:
        if self._los and record.lo <= self._records[-1].hi:
            raise ValueError("append records must be address-ordered")
        self._records.append(record)
        self._los.append(record.lo)

    def records(self) -> Sequence[AppendRecord]:
        return self._records

    def _covering(self, p: int, q: int) -> Optional[List[AppendRecord]]:
        """Records covering [p, q] with no address gap, else None."""
        if not self._records or q < p:
            return None
        i = bisect.bisect_right(self._los, p) - 1
        if i < 0:
            return None
        out: List[AppendRecord] = []
        expect = p
        while expect <= q:
            if i >= len(self._records):
                return None
            r = self._records[i]
            if not (r.lo <= expect <= r.hi):
                return None
            out.append(r)
            expect = r.hi + 1
            i += 1
        return out

    def translate(self, p: int, q: int) -> Optional[str]:
        """T(p, q): original text spanning token addresses [p, q]."""
        recs = self._covering(p, q)
        if recs is None:
            return None
        parts = []
        for r in recs:
            first = max(p, r.lo) - r.lo
            last = min(q, r.hi) - r.lo
            c0 = int(r.offsets[first, 0])
            c1 = int(r.offsets[last, 0] + r.offsets[last, 1])
            parts.append(r.text[c0:c1])
        return " ".join(parts)

    def tokens(self, p: int, q: int) -> Optional[List[str]]:
        recs = self._covering(p, q)
        if recs is None:
            return None
        out: List[str] = []
        for r in recs:
            first = max(p, r.lo) - r.lo
            last = min(q, r.hi) - r.lo
            out.extend(r.tokens[first:last + 1])
        return out

    def span(self) -> Tuple[int, int]:
        if not self._records:
            return (0, -1)
        return (self._records[0].lo, self._records[-1].hi)

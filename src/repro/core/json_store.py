"""A JSON store over the annotative index (paper Fig. 4-6).

JSON structure is kept *in the content* via Unicode noncharacter structural
tokens, and *in the features* via path annotations:

  ⟨:, (lo, hi)⟩                        object root (value 0)
  ⟨:name:, (p, q)⟩                     value interval of key "name"
  ⟨:batters:batter:, (p, q), len⟩      array extent, value = length
  ⟨:batters:batter:[1]:, (p, q)⟩       array element extent
  ⟨:ppu:, (p, q), 0.55⟩                numeric value as annotation value

Nothing is flattened: T(lo, hi) reproduces the full object.  A date
annotator shows post-hoc annotation (paper Examples 8/9): it unifies
heterogeneous date formats into year=/month=/day= features.
"""

from __future__ import annotations

import datetime as _dt
import re
from typing import Any, Dict, Iterable, List, Optional, Tuple

from .featurizer import (STRUCT_COLON, STRUCT_COMMA, STRUCT_LBRACE,
                         STRUCT_LBRACKET, STRUCT_QUOTE, STRUCT_RBRACE,
                         STRUCT_RBRACKET)
from .gcl import GCLNode, Phrase, Term
from .tokenizer import Utf8Tokenizer

ROOT_FEATURE = ":"

_DISPLAY = {STRUCT_LBRACE: "{", STRUCT_RBRACE: "}", STRUCT_LBRACKET: "[",
            STRUCT_RBRACKET: "]", STRUCT_COLON: ":", STRUCT_COMMA: ",",
            STRUCT_QUOTE: '"'}


class _Emitter:
    def __init__(self, tokenizer: Utf8Tokenizer):
        self.tokenizer = tokenizer
        self.parts: List[str] = []
        self.pos = 0  # token count so far

    def emit(self, text: str) -> Tuple[int, int]:
        n = len(self.tokenizer.tokenize(text))
        lo = self.pos
        self.pos += n
        self.parts.append(text)
        return lo, self.pos - 1

    def text(self) -> str:
        return "".join(self.parts)


def _scalar_repr(v: Any) -> Tuple[str, Optional[float]]:
    if v is None:
        return "null", 0.0
    if isinstance(v, bool):
        return ("true", 1.0) if v else ("false", 0.0)
    if isinstance(v, (int, float)):
        return repr(v), float(v)
    return str(v), None


def add_json(w, obj: Any, collection: Optional[str] = None) -> Tuple[int, int]:
    """Append a JSON object inside an open transaction on warren ``w``.

    Returns the object's global or staging address extent.  ``collection``
    adds a collection-membership feature over the object (the paper's
    ``Files/books.json`` convention).
    """
    em = _Emitter(w.index.tokenizer)
    annotations: List[Tuple[str, int, int, float]] = []

    def _annotation_value(node: Any) -> float:
        """Path-annotation value: array length, numeric value, else 0."""
        if isinstance(node, list):
            return float(len(node))
        if isinstance(node, dict) or isinstance(node, str):
            return 0.0
        _, num = _scalar_repr(node)
        return num if num is not None else 0.0

    def walk(node: Any, path: str) -> Tuple[int, int]:
        if isinstance(node, dict):
            lo, _ = em.emit(STRUCT_LBRACE)
            for i, (key, val) in enumerate(node.items()):
                if i:
                    em.emit(STRUCT_COMMA)
                em.emit(f"{STRUCT_QUOTE}{key}{STRUCT_QUOTE}{STRUCT_COLON}")
                cpath = f"{path}{key}:"
                vlo, vhi = walk(val, cpath)
                annotations.append((cpath, vlo, vhi, _annotation_value(val)))
            _, hi = em.emit(STRUCT_RBRACE)
            return lo, hi
        if isinstance(node, list):
            lo, _ = em.emit(STRUCT_LBRACKET)
            for i, val in enumerate(node):
                if i:
                    em.emit(STRUCT_COMMA)
                epath = f"{path}[{i}]:"
                vlo, vhi = walk(val, epath)
                annotations.append((epath, vlo, vhi, _annotation_value(val)))
            _, hi = em.emit(STRUCT_RBRACKET)
            return lo, hi
        text, num = _scalar_repr(node)
        if num is None:  # string value: quoted
            lo, hi = em.emit(f"{STRUCT_QUOTE}{text}{STRUCT_QUOTE}")
        else:
            lo, hi = em.emit(text)
        return lo, hi

    rlo, rhi = walk(obj, ":")
    glo, ghi = w.append(em.text())
    assert ghi - glo == em.pos - 1, "token accounting mismatch"

    def g(a: int) -> int:
        return glo + a

    for path, lo, hi, v in annotations:
        w.annotate(path, g(lo), g(hi), v)
    w.annotate(ROOT_FEATURE, g(rlo), g(rhi))
    if collection:
        w.annotate(collection, g(rlo), g(rhi))
    return g(rlo), g(rhi)


def render_tokens(tokens: List[str]) -> str:
    """Human-readable rendering of content tokens (noncharacters mapped back)."""
    out: List[str] = []
    for t in tokens:
        if t in _DISPLAY:
            out.append(_DISPLAY[t])
        else:
            if out and out[-1] not in '{[:"' and not out[-1].endswith(('"', "{", "[", ":", ",")):
                out.append(" ")
            out.append(t)
    return "".join(out)


def value_of(warren, p: int, q: int) -> Optional[str]:
    """String value of a path annotation interval (quotes stripped)."""
    toks = warren.tokens(p, q)
    if toks is None:
        return None
    words = [t for t in toks if t not in _DISPLAY]
    return " ".join(words)


def raw_value_of(warren, p: int, q: int) -> Optional[str]:
    """Original text of a value interval (exact, via T(p,q))."""
    text = warren.translate(p, q)
    if text is None:
        return None
    for ch in _DISPLAY:
        text = text.replace(ch, "")
    return text.strip()


def string_match(warren, text: str) -> GCLNode:
    """GCL node matching a literal string value (phrase over word tokens)."""
    return warren.phrase(text)


# --------------------------------------------------------------------- #
# Post-hoc date annotation (paper Examples 8/9): heterogeneous date fields
# are unified by *annotating*, never rewriting, the stored objects.
# --------------------------------------------------------------------- #
_MONTHS = {m: i + 1 for i, m in enumerate(
    ["jan", "feb", "mar", "apr", "may", "jun",
     "jul", "aug", "sep", "oct", "nov", "dec"])}
_HUMAN_DATE = re.compile(r"^([a-z]{3})[a-z]*\s+(\d{1,2})\s+(\d{4})$")
_ISO_DATE = re.compile(r"^(\d{4})-(\d{2})-(\d{2})")


def parse_date(value: str) -> Optional[Tuple[int, int, int]]:
    v = value.strip().lower()
    m = _HUMAN_DATE.match(v)
    if m and m.group(1) in _MONTHS:
        return int(m.group(3)), _MONTHS[m.group(1)], int(m.group(2))
    m = _ISO_DATE.match(v)
    if m:
        return int(m.group(1)), int(m.group(2)), int(m.group(3))
    if v.isdigit() and len(v) >= 12:  # unix millis
        d = _dt.datetime.fromtimestamp(int(v) / 1000.0, _dt.timezone.utc)
        return d.year, d.month, d.day
    return None


def annotate_dates(w, date_paths: Iterable[str]) -> int:
    """Read date-bearing fields via the index, write year=/month=/day=
    annotations in the same transaction.  Returns #annotated fields."""
    count = 0
    for path in date_paths:
        lst = w.annotations(path)
        for p, q, v in lst:
            if v and v > 1e11:  # numeric unix millis stored as value
                d = _dt.datetime.fromtimestamp(v / 1000.0, _dt.timezone.utc)
                ymd = (d.year, d.month, d.day)
            else:
                raw = raw_value_of(w, int(p), int(q))
                ymd = parse_date(raw) if raw else None
            if ymd is None:
                continue
            y, mo, dy = ymd
            w.annotate(f"year={y}", int(p), int(q))
            w.annotate(f"month={mo:02d}", int(p), int(q))
            w.annotate(f"day={dy:02d}", int(p), int(q))
            count += 1
    return count

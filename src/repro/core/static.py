"""Static index: larger-than-memory collections, batch update model (paper §3).

Built once (one batch transaction), written to a directory:

  meta.msgpack           address span, counts
  features.msgpack       fval -> (offset, nbytes, count) into postings.bin
  postings.bin           per-feature vByte-gap starts/ends + raw values
  content.bin            zstd msgpack append records

Reads decode one feature at a time (LRU cached) — annotation lists are
"compressed until active".  Batch update = build a merged directory from the
current one plus new documents, then atomic rename; a lock file enforces the
single-transaction rule.
"""

from __future__ import annotations

import os
import struct
import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import msgpack
import numpy as np

from . import codec, vbyte
from .annotation import AnnotationList
from .featurizer import Featurizer, JsonFeaturizer
from .gcl import Term
from .index import DynamicIndex, Snapshot
from .tokenizer import Tokenizer, Utf8Tokenizer
from .txt import AppendRecord, ContentStore


class StaticIndex:
    """Read-optimized on-disk annotative index."""

    def __init__(self, directory: str, tokenizer: Optional[Tokenizer] = None,
                 featurizer: Optional[Featurizer] = None, cache_size: int = 256):
        self.directory = directory
        self.tokenizer = tokenizer or Utf8Tokenizer()
        self.featurizer = featurizer or JsonFeaturizer()
        with open(os.path.join(directory, "meta.msgpack"), "rb") as fh:
            self.meta = msgpack.unpackb(fh.read(), raw=False)
        with open(os.path.join(directory, "features.msgpack"), "rb") as fh:
            self._features: Dict[int, Tuple[int, int, int]] = {
                int(k): tuple(v)
                for k, v in msgpack.unpackb(fh.read(), raw=False,
                                            strict_map_key=False).items()}
        self._postings_path = os.path.join(directory, "postings.bin")
        # erased intervals (absent in legacy directories: nothing erased)
        n_er = self.meta.get("er_n", 0)
        self._erased = AnnotationList(
            vbyte.decode_gaps(self.meta.get("er_s", b""), n_er),
            vbyte.decode_gaps(self.meta.get("er_e", b""), n_er),
            np.zeros(n_er), _checked=True)
        with open(os.path.join(directory, "content.bin"), "rb") as fh:
            recs = msgpack.unpackb(codec.decompress(fh.read()), raw=False)
        self._content = ContentStore()
        for a in recs:
            off = np.frombuffer(a["off"], dtype=np.int64).reshape(-1, 2)
            self._content.add(AppendRecord(a["lo"], a["hi"], a["text"], off,
                                           tuple(a["tok"])))
        self._cache: "OrderedDict[int, AnnotationList]" = OrderedDict()
        self._cache_size = cache_size
        self._lock = threading.Lock()
        self._fh = open(self._postings_path, "rb")

    # -- reads (same surface as Snapshot) ------------------------------- #
    def annotations(self, feature) -> AnnotationList:
        fval = (feature if isinstance(feature, int)
                else self.featurizer.featurize(feature))
        with self._lock:
            if fval in self._cache:
                self._cache.move_to_end(fval)
                return self._cache[fval]
        loc = self._features.get(fval)
        if loc is None:
            return AnnotationList.empty()
        offset, nbytes, count = loc
        with self._lock:
            self._fh.seek(offset)
            blob = self._fh.read(nbytes)
        ns, ne = struct.unpack("<II", blob[:8])
        s = vbyte.decode_gaps(blob[8:8 + ns], count)
        e = vbyte.decode_gaps(blob[8 + ns:8 + ns + ne], count)
        v = np.frombuffer(blob[8 + ns + ne:], dtype=np.float64)
        lst = AnnotationList(s, e, v, _checked=True)
        with self._lock:
            self._cache[fval] = lst
            while len(self._cache) > self._cache_size:
                self._cache.popitem(last=False)
        return lst

    def hopper(self, feature) -> Term:
        return Term(self.annotations(feature))

    def _erased_overlaps(self, p: int, q: int) -> bool:
        er = self._erased
        if len(er) == 0:
            return False
        i = int(np.searchsorted(er.ends, p, side="left"))
        return i < len(er) and int(er.starts[i]) <= q

    def translate(self, p: int, q: int) -> Optional[str]:
        if self._erased_overlaps(p, q):
            return None
        return self._content.translate(p, q)

    def tokens(self, p: int, q: int) -> Optional[List[str]]:
        if self._erased_overlaps(p, q):
            return None
        return self._content.tokens(p, q)

    # warren-compat helpers
    def featurize(self, feature: str) -> int:
        return self.featurizer.featurize(feature)

    @property
    def index(self):  # parity with Warren.phrase
        return self

    def phrase(self, text: str):
        from .gcl import Phrase
        from .annotation import AnnotationList as _AL
        words = self.tokenizer.split(text)
        terms = [self.hopper(w) for w in words]
        if not terms:
            return Term(_AL.empty())
        return terms[0] if len(terms) == 1 else Phrase(terms)

    def close(self) -> None:
        self._fh.close()


def write_static(snapshot_like, directory: str) -> None:
    """Freeze a DynamicIndex snapshot (or anything exposing segments) into
    the on-disk static layout."""
    os.makedirs(directory + ".build", exist_ok=True)
    build = directory + ".build"
    # gather merged features
    if isinstance(snapshot_like, Snapshot):
        snap = snapshot_like
    else:
        snap = snapshot_like.snapshot()
    feats: Dict[int, AnnotationList] = {}
    fvals = set()
    for seg in snap.segments:
        fvals.update(seg.postings.keys())
    for fval in fvals:
        lst = snap.annotations(fval)
        if len(lst):
            feats[fval] = lst
    offsets: Dict[int, Tuple[int, int, int]] = {}
    with open(os.path.join(build, "postings.bin"), "wb") as fh:
        pos = 0
        for fval, lst in feats.items():
            s = vbyte.encode_gaps(lst.starts)
            e = vbyte.encode_gaps(lst.ends)
            blob = struct.pack("<II", len(s), len(e)) + s + e + lst.values.tobytes()
            fh.write(blob)
            offsets[fval] = (pos, len(blob), len(lst))
            pos += len(blob)
    with open(os.path.join(build, "features.msgpack"), "wb") as fh:
        fh.write(msgpack.packb({str(k): list(v) for k, v in offsets.items()}))
    erased = snap.erased
    recs = []
    for seg in snap.segments:
        for r in seg.content.records():
            # GC content of fully-erased records; partially-erased spans are
            # hidden at read time by the persisted erased list below
            if len(erased):
                i = int(np.searchsorted(erased.starts, r.lo,
                                        side="right")) - 1
                if i >= 0 and int(erased.ends[i]) >= r.hi:
                    continue
            recs.append({"lo": r.lo, "hi": r.hi, "text": r.text,
                         "off": np.asarray(r.offsets, dtype=np.int64).tobytes(),
                         "tok": list(r.tokens)})
    recs.sort(key=lambda r: r["lo"])
    with open(os.path.join(build, "content.bin"), "wb") as fh:
        fh.write(codec.compress(msgpack.packb(recs), level=6))
    with open(os.path.join(build, "meta.msgpack"), "wb") as fh:
        fh.write(msgpack.packb({"n_features": len(feats),
                                "n_records": len(recs),
                                "er_n": len(erased),
                                "er_s": vbyte.encode_gaps(erased.starts),
                                "er_e": vbyte.encode_gaps(erased.ends)}))
    if os.path.exists(directory):
        import shutil
        shutil.rmtree(directory + ".old", ignore_errors=True)
        os.rename(directory, directory + ".old")
        os.rename(build, directory)
        shutil.rmtree(directory + ".old", ignore_errors=True)
    else:
        os.rename(build, directory)

"""Static index: larger-than-memory collections, batch update model (paper §3).

Two on-disk layouts share one reader class:

**v2 (current, block-oriented)** — one ``run.aix2`` file per directory
(:mod:`repro.core.runfile`): fixed-size crc'd blocks holding per-feature
posting blobs and per-record compressed content payloads, indexed by a
msgpack footer of extents, closed by a fixed trailer.  The reader ``mmap``'s
the file, parses only footer + trailer eagerly, and decodes *lazily per
block* through a pluggable block cache — content is **not** materialized
into a resident ContentStore, so corpus size is bounded by disk, not RAM.

**v1 (legacy, read-only)** — four files (``meta.msgpack`` /
``features.msgpack`` / ``postings.bin`` / ``content.bin``) with the content
store decoded resident at open.  v1 directories keep opening forever
(back-compat fixture under ``tests/fixtures/``); all new writes are v2.

Reads decode one feature at a time (LRU cached) — annotation lists are
"compressed until active".  Batch update = build a merged directory from the
current one plus new documents, then atomic rename; a lock file enforces the
single-transaction rule.

The same layout doubles as the immutable *run* format of the tiered storage
engine (``repro.tiered``): :func:`write_run` freezes a slice of committed
dynamic segments into one directory (meta gains seq/addr bounds),
:func:`merge_runs` folds several runs into one (optionally GC'ing erased
records — the tiered engine does that only at the bottom level),
:func:`slice_run` cuts a run to an address subrange by footer-index extents
(raw content payloads are copied without decompression — the sliced-run
shipping path of cold rebalancing), and :meth:`StaticIndex.to_segment`
streams a run back into the dynamic ``Segment`` form for resurrection.
"""

from __future__ import annotations

import bisect
import os
import struct
import threading
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import msgpack
import numpy as np

from . import codec, vbyte
from .annotation import AnnotationList, merge_lists, union_intervals
from .faults import fault_point
from .featurizer import Featurizer, JsonFeaturizer
from .gcl import Term
from .index import (DynamicIndex, Segment, Snapshot, _filter_erased,
                    erased_overlaps, tokens_sources, translate_sources)
from .runfile import (DEFAULT_BLOCK_SIZE, RUN_FILE, BlockRunReader,
                      BlockRunWriter, RunCorruption, is_v2_dir)
from .tokenizer import Tokenizer, Utf8Tokenizer
from .txt import AppendRecord, ContentStore

__all__ = [
    "StaticIndex", "RunCorruption", "write_static", "write_run",
    "merge_runs", "slice_run", "write_carrier_run", "run_bytes",
]


def _pack_record_payload(rec: dict) -> bytes:
    """Durable-form content record dict -> compressed v2 payload."""
    return codec.compress(msgpack.packb(
        {"text": rec["text"], "off": rec["off"], "tok": rec["tok"]}),
        level=6)


def _unpack_record_payload(lo: int, hi: int, payload: bytes) -> AppendRecord:
    try:
        obj = msgpack.unpackb(codec.decompress(payload), raw=False)
        off = np.frombuffer(obj["off"], dtype=np.int64).reshape(-1, 2)
        return AppendRecord(lo, hi, obj["text"], off, tuple(obj["tok"]))
    except RunCorruption:
        raise
    except Exception as e:
        raise RunCorruption(
            f"content record [{lo}, {hi}] undecodable: {e}") from e


class LazyContentStore:
    """ContentStore surface over v2 footer extents — nothing resident.

    Record address bounds come from the footer; payloads are fetched
    through the block cache and decoded on demand, with a small LRU of
    decoded records so a ``translate`` burst over one document does not
    re-inflate it per call.  Iterating ``records()`` streams decodes (the
    resurrection / merge paths) without retaining more than the LRU.
    """

    def __init__(self, reader: BlockRunReader, decoded_lru: int = 64):
        self._reader = reader
        self._extents = reader.records     # [(lo, hi, off, nbytes), ...]
        self._los = [r[0] for r in self._extents]
        self._lru: "OrderedDict[int, AppendRecord]" = OrderedDict()
        self._lru_size = decoded_lru
        self._lock = threading.Lock()

    # -- lazy record access --------------------------------------------- #
    def __len__(self) -> int:
        return len(self._extents)

    def record_bounds(self) -> List[Tuple[int, int]]:
        return [(r[0], r[1]) for r in self._extents]

    def decode(self, i: int) -> AppendRecord:
        with self._lock:
            got = self._lru.get(i)
            if got is not None:
                self._lru.move_to_end(i)
                return got
        lo, hi, off, nbytes = self._extents[i]
        rec = _unpack_record_payload(lo, hi, self._reader.read(off, nbytes))
        with self._lock:
            self._lru[i] = rec
            while len(self._lru) > self._lru_size:
                self._lru.popitem(last=False)
        return rec

    def raw_payload(self, i: int) -> bytes:
        """The stored (compressed) payload, streamed cache-neutrally —
        the no-decode copy path of merges and slicing."""
        lo, hi, off, nbytes = self._extents[i]
        return b"".join(self._reader.stream(off, nbytes, admit=False))

    def records(self) -> "_LazyRecords":
        return _LazyRecords(self)

    def add(self, record) -> None:
        raise TypeError("LazyContentStore is immutable (on-disk run)")

    # -- Txt surface ---------------------------------------------------- #
    def span(self) -> Tuple[int, int]:
        if not self._extents:
            return (0, -1)
        return (self._extents[0][0], self._extents[-1][1])

    def _covering(self, p: int, q: int) -> Optional[List[AppendRecord]]:
        if not self._extents or q < p:
            return None
        i = bisect.bisect_right(self._los, p) - 1
        if i < 0:
            return None
        out: List[AppendRecord] = []
        expect = p
        while expect <= q:
            if i >= len(self._extents):
                return None
            lo, hi = self._extents[i][0], self._extents[i][1]
            if not (lo <= expect <= hi):
                return None
            out.append(self.decode(i))
            expect = hi + 1
            i += 1
        return out

    def translate(self, p: int, q: int) -> Optional[str]:
        recs = self._covering(p, q)
        if recs is None:
            return None
        parts = []
        for r in recs:
            first = max(p, r.lo) - r.lo
            last = min(q, r.hi) - r.lo
            c0 = int(r.offsets[first, 0])
            c1 = int(r.offsets[last, 0] + r.offsets[last, 1])
            parts.append(r.text[c0:c1])
        return " ".join(parts)

    def tokens(self, p: int, q: int) -> Optional[List[str]]:
        recs = self._covering(p, q)
        if recs is None:
            return None
        out: List[str] = []
        for r in recs:
            first = max(p, r.lo) - r.lo
            last = min(q, r.hi) - r.lo
            out.extend(r.tokens[first:last + 1])
        return out


class _LazyRecords(Sequence):
    """Sequence view over a LazyContentStore: truthiness and ``len`` come
    from the footer (no decode); indexing/iteration decode on demand."""

    def __init__(self, store: LazyContentStore):
        self._store = store

    def __len__(self) -> int:
        return len(self._store)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self._store.decode(j)
                    for j in range(*i.indices(len(self._store)))]
        return self._store.decode(i)

    def __iter__(self):
        for i in range(len(self._store)):
            yield self._store.decode(i)


class StaticIndex:
    """Read-optimized on-disk annotative index (v2 mmap'd, v1 resident)."""

    def __init__(self, directory: str, tokenizer: Optional[Tokenizer] = None,
                 featurizer: Optional[Featurizer] = None,
                 cache_size: int = 256, block_cache=None):
        self.directory = directory
        self.tokenizer = tokenizer or Utf8Tokenizer()
        self.featurizer = featurizer or JsonFeaturizer()
        self._cache: "OrderedDict[int, AnnotationList]" = OrderedDict()
        self._cache_size = cache_size
        self._lock = threading.Lock()
        self._reader: Optional[BlockRunReader] = None
        self._fh = None
        if is_v2_dir(directory):
            self.layout = 2
            self._open_v2(directory, block_cache)
        elif os.path.exists(os.path.join(directory, "meta.msgpack")):
            self.layout = 1
            self._open_v1(directory)
        else:
            raise RunCorruption(
                f"{directory}: neither a v2 run ({RUN_FILE}) nor a v1 "
                "static directory (meta.msgpack)")
        n_er = self.meta.get("er_n", 0)
        self._erased = AnnotationList(
            vbyte.decode_gaps(self.meta.get("er_s", b""), n_er),
            vbyte.decode_gaps(self.meta.get("er_e", b""), n_er),
            np.zeros(n_er), _checked=True)

    # -- open ------------------------------------------------------------ #
    def _open_v2(self, directory: str, block_cache) -> None:
        self._reader = BlockRunReader(os.path.join(directory, RUN_FILE),
                                      cache=block_cache)
        self.meta = dict(self._reader.meta)
        self._features: Dict[int, Tuple[int, int, int]] = \
            dict(self._reader.features)
        self._content = LazyContentStore(self._reader)

    def _open_v1(self, directory: str) -> None:
        with open(os.path.join(directory, "meta.msgpack"), "rb") as fh:
            self.meta = msgpack.unpackb(fh.read(), raw=False)
        with open(os.path.join(directory, "features.msgpack"), "rb") as fh:
            self._features = {
                int(k): tuple(v)
                for k, v in msgpack.unpackb(fh.read(), raw=False,
                                            strict_map_key=False).items()}
        self._postings_path = os.path.join(directory, "postings.bin")
        with open(os.path.join(directory, "content.bin"), "rb") as fh:
            recs = msgpack.unpackb(codec.decompress(fh.read()), raw=False)
        self._content = ContentStore()
        for a in recs:
            off = np.frombuffer(a["off"], dtype=np.int64).reshape(-1, 2)
            self._content.add(AppendRecord(a["lo"], a["hi"], a["text"], off,
                                           tuple(a["tok"])))
        self._fh = open(self._postings_path, "rb")

    # -- reads (same surface as Snapshot) ------------------------------- #
    def _postings_blob(self, offset: int, nbytes: int) -> bytes:
        if self._reader is not None:
            return self._reader.read(offset, nbytes)
        with self._lock:
            self._fh.seek(offset)
            return self._fh.read(nbytes)

    def annotations(self, feature) -> AnnotationList:
        fval = (feature if isinstance(feature, int)
                else self.featurizer.featurize(feature))
        with self._lock:
            if fval in self._cache:
                self._cache.move_to_end(fval)
                return self._cache[fval]
        loc = self._features.get(fval)
        if loc is None:
            return AnnotationList.empty()
        offset, nbytes, count = loc
        blob = self._postings_blob(offset, nbytes)
        try:
            ns, ne = struct.unpack("<II", blob[:8])
            s = vbyte.decode_gaps(blob[8:8 + ns], count)
            e = vbyte.decode_gaps(blob[8 + ns:8 + ns + ne], count)
            v = np.frombuffer(blob[8 + ns + ne:], dtype=np.float64)
            if len(s) != count or len(e) != count or len(v) != count:
                raise ValueError(f"expected {count} postings")
        except RunCorruption:
            raise
        except Exception as exc:
            raise RunCorruption(
                f"{self.directory}: posting list for feature {fval} "
                f"undecodable: {exc}") from exc
        lst = AnnotationList(s, e, v, _checked=True)
        with self._lock:
            self._cache[fval] = lst
            while len(self._cache) > self._cache_size:
                self._cache.popitem(last=False)
        return lst

    def hopper(self, feature) -> Term:
        return Term(self.annotations(feature))

    def translate(self, p: int, q: int) -> Optional[str]:
        if erased_overlaps(self._erased, p, q):
            return None
        return translate_sources([self._content], p, q)

    def tokens(self, p: int, q: int) -> Optional[List[str]]:
        if erased_overlaps(self._erased, p, q):
            return None
        return tokens_sources([self._content], p, q)

    # -- run accessors (tiered storage) --------------------------------- #
    @property
    def erased(self) -> AnnotationList:
        """Persisted erased intervals (tombstones of this run)."""
        return self._erased

    @property
    def content(self) -> Union[ContentStore, LazyContentStore]:
        return self._content

    def features(self) -> List[int]:
        """All feature values with a stored annotation list, sorted."""
        return sorted(self._features)

    def record_bounds(self) -> List[Tuple[int, int]]:
        """``(lo, hi)`` address bounds per content record, footer-only for
        v2 (no decode) — pivot selection for sliced-run rebalancing."""
        if isinstance(self._content, LazyContentStore):
            return self._content.record_bounds()
        return [(r.lo, r.hi) for r in self._content.records()]

    def to_segment(self, seqnum: Optional[int] = None) -> Segment:
        """Materialize the whole run as a dynamic :class:`Segment` (loads
        every annotation list and — for v2 — decodes every content record
        into a resident store) — the resurrection path back to the hot
        tier; fan out to replicas via ``Segment.to_record``.  This is the
        one deliberately non-lazy read: promotion means going hot."""
        postings = {f: self.annotations(f) for f in self.features()}
        content = self._content
        if isinstance(content, LazyContentStore):
            resident = ContentStore()
            for rec in content.records():
                resident.add(rec)
            content = resident
        seq = seqnum if seqnum is not None else int(self.meta.get("seq_hi", 0))
        lo = int(self.meta.get("addr_lo", 0))
        hi = int(self.meta.get("addr_hi", -1))
        return Segment(seq, lo, max(0, hi - lo + 1), content, postings,
                       self._erased)

    # warren-compat helpers
    def featurize(self, feature: str) -> int:
        return self.featurizer.featurize(feature)

    @property
    def index(self):  # parity with Warren.phrase
        return self

    def phrase(self, text: str):
        from .gcl import Phrase
        from .annotation import AnnotationList as _AL
        words = self.tokenizer.split(text)
        terms = [self.hopper(w) for w in words]
        if not terms:
            return Term(_AL.empty())
        return terms[0] if len(terms) == 1 else Phrase(terms)

    def file_bytes(self) -> int:
        """On-disk size of this run (level-target accounting)."""
        return run_bytes(self.directory)

    def close(self) -> None:
        if self._reader is not None:
            self._reader.close()
        if self._fh is not None:
            self._fh.close()

    def __del__(self):
        # last-resort fd cleanup: runs retired by a tiered compaction are
        # dropped without close() once no pinned snapshot references them
        try:
            self.close()
        except Exception:
            pass


def run_bytes(directory: str) -> int:
    """Total on-disk bytes of a run directory (v1 or v2)."""
    total = 0
    try:
        for fn in os.listdir(directory):
            try:
                total += os.path.getsize(os.path.join(directory, fn))
            except OSError:
                pass
    except OSError:
        pass
    return total


def _gc_records(records, erased: AnnotationList) -> List[dict]:
    """Durable-form content records minus those fully covered by an erased
    interval; partially-erased spans stay and are hidden at read time."""
    recs = []
    for r in records:
        if _record_fully_erased(r.lo, r.hi, erased):
            continue
        recs.append({"lo": r.lo, "hi": r.hi, "text": r.text,
                     "off": np.asarray(r.offsets, dtype=np.int64).tobytes(),
                     "tok": list(r.tokens)})
    recs.sort(key=lambda r: r["lo"])
    return recs


def _record_fully_erased(lo: int, hi: int, erased: AnnotationList) -> bool:
    if not len(erased):
        return False
    i = int(np.searchsorted(erased.starts, lo, side="right")) - 1
    return i >= 0 and int(erased.ends[i]) >= hi


class _RawRecord:
    """A content record travelling as its stored compressed payload —
    footer bounds + bytes, never decoded (merge/slice copy path)."""

    __slots__ = ("lo", "hi", "payload")

    def __init__(self, lo: int, hi: int, payload: bytes):
        self.lo = lo
        self.hi = hi
        self.payload = payload


def _write_layout(directory: str,
                  feats_items: Iterable[Tuple[int, AnnotationList]],
                  erased: AnnotationList,
                  recs: Iterable,
                  extra_meta: Optional[dict] = None,
                  block_size: int = DEFAULT_BLOCK_SIZE) -> dict:
    """Write the v2 block layout into a build directory, then publish it
    with an atomic rename.  ``feats_items`` streams ``(fval, list)`` pairs
    and ``recs`` streams either durable-form dicts or :class:`_RawRecord`
    payloads (sorted by ``lo``) — nothing is required to be materialized.
    Returns the meta record (with address bounds and ``nbytes``)."""
    build = directory + ".build"
    os.makedirs(build, exist_ok=True)
    path = os.path.join(build, RUN_FILE)
    writer = BlockRunWriter(path, block_size=block_size)
    addr_lo, addr_hi = None, None

    def _bound(lo: int, hi: int) -> None:
        nonlocal addr_lo, addr_hi
        addr_lo = lo if addr_lo is None else min(addr_lo, lo)
        addr_hi = hi if addr_hi is None else max(addr_hi, hi)

    try:
        offsets: Dict[int, Tuple[int, int, int]] = {}
        for fval, lst in feats_items:
            s = vbyte.encode_gaps(lst.starts)
            e = vbyte.encode_gaps(lst.ends)
            blob = (struct.pack("<II", len(s), len(e)) + s + e
                    + lst.values.tobytes())
            pos, nbytes = writer.append(blob)
            offsets[fval] = (pos, nbytes, len(lst))
            if len(lst):
                _bound(int(lst.starts[0]), int(lst.ends[-1]))
        record_index: List[Tuple[int, int, int, int]] = []
        for rec in recs:
            if isinstance(rec, _RawRecord):
                lo, hi, payload = rec.lo, rec.hi, rec.payload
            else:
                lo, hi, payload = rec["lo"], rec["hi"], \
                    _pack_record_payload(rec)
            pos, nbytes = writer.append(payload)
            record_index.append((lo, hi, pos, nbytes))
            _bound(lo, hi)
        if len(erased):
            _bound(int(erased.starts[0]), int(erased.ends[-1]))
        meta = {"n_features": len(offsets), "n_records": len(record_index),
                "er_n": len(erased),
                "er_s": vbyte.encode_gaps(erased.starts),
                "er_e": vbyte.encode_gaps(erased.ends),
                "layout": 2,
                "addr_lo": int(addr_lo if addr_lo is not None else 0),
                "addr_hi": int(addr_hi if addr_hi is not None else -1)}
        meta.update(extra_meta or {})
        writer.finish(offsets, record_index, meta)
    except BaseException:
        writer.abort()
        raise
    meta["nbytes"] = os.path.getsize(path)
    fault_point("static.pre_publish")
    if os.path.exists(directory):
        import shutil
        shutil.rmtree(directory + ".old", ignore_errors=True)
        os.rename(directory, directory + ".old")
        os.rename(build, directory)
        shutil.rmtree(directory + ".old", ignore_errors=True)
    else:
        os.rename(build, directory)
    fault_point("static.published")
    return meta


def _write_layout_v1(directory: str, feats: Dict[int, AnnotationList],
                     erased: AnnotationList, recs: List[dict],
                     extra_meta: Optional[dict] = None) -> dict:
    """The legacy four-file layout — retained ONLY to regenerate the
    back-compat fixture (``tests/fixtures/v1_run``); every production
    write path emits v2."""
    build = directory + ".build"
    os.makedirs(build, exist_ok=True)
    offsets: Dict[int, Tuple[int, int, int]] = {}
    with open(os.path.join(build, "postings.bin"), "wb") as fh:
        pos = 0
        for fval, lst in feats.items():
            s = vbyte.encode_gaps(lst.starts)
            e = vbyte.encode_gaps(lst.ends)
            blob = (struct.pack("<II", len(s), len(e)) + s + e
                    + lst.values.tobytes())
            fh.write(blob)
            offsets[fval] = (pos, len(blob), len(lst))
            pos += len(blob)
        fh.flush()
        os.fsync(fh.fileno())
    with open(os.path.join(build, "features.msgpack"), "wb") as fh:
        fh.write(msgpack.packb({str(k): list(v) for k, v in offsets.items()}))
        fh.flush()
        os.fsync(fh.fileno())
    with open(os.path.join(build, "content.bin"), "wb") as fh:
        fh.write(codec.compress(msgpack.packb(recs), level=6))
        fh.flush()
        os.fsync(fh.fileno())
    meta = {"n_features": len(feats), "n_records": len(recs),
            "er_n": len(erased),
            "er_s": vbyte.encode_gaps(erased.starts),
            "er_e": vbyte.encode_gaps(erased.ends)}
    meta.update(extra_meta or {})
    with open(os.path.join(build, "meta.msgpack"), "wb") as fh:
        fh.write(msgpack.packb(meta))
        fh.flush()
        os.fsync(fh.fileno())
    if os.path.exists(directory):
        import shutil
        shutil.rmtree(directory + ".old", ignore_errors=True)
        os.rename(directory, directory + ".old")
        os.rename(build, directory)
        shutil.rmtree(directory + ".old", ignore_errors=True)
    else:
        os.rename(build, directory)
    return meta


def write_static(snapshot_like, directory: str) -> None:
    """Freeze a DynamicIndex snapshot (or anything exposing segments) into
    the on-disk static layout."""
    if isinstance(snapshot_like, Snapshot):
        snap = snapshot_like
    else:
        snap = snapshot_like.snapshot()
    feats: Dict[int, AnnotationList] = {}
    fvals = set()
    for seg in snap.segments:
        fvals.update(seg.postings.keys())
    for fval in fvals:
        lst = snap.annotations(fval)
        if len(lst):
            feats[fval] = lst
    erased = snap.erased
    recs = _gc_records([r for seg in snap.segments
                        for r in seg.content.records()], erased)
    _write_layout(directory, feats.items(), erased, recs)


def _write_static_v1(snapshot_like, directory: str) -> None:
    """``write_static`` but emitting the legacy v1 four-file layout — only
    for the back-compat fixture and the v1-reader regression tests."""
    if isinstance(snapshot_like, Snapshot):
        snap = snapshot_like
    else:
        snap = snapshot_like.snapshot()
    feats: Dict[int, AnnotationList] = {}
    fvals = set()
    for seg in snap.segments:
        fvals.update(seg.postings.keys())
    for fval in fvals:
        lst = snap.annotations(fval)
        if len(lst):
            feats[fval] = lst
    erased = snap.erased
    recs = _gc_records([r for seg in snap.segments
                        for r in seg.content.records()], erased)
    _write_layout_v1(directory, feats, erased, recs)


def write_run(segments: Sequence[Segment], directory: str,
              block_size: int = DEFAULT_BLOCK_SIZE) -> dict:
    """Freeze committed dynamic segments into one immutable *run* directory
    (the tiered storage engine's on-disk tier).

    Postings are k-way merged in sequence order and filtered by the
    segments' own erased set; fully-erased content records are GC'd;
    partially-erased spans and erases targeting *older* runs survive as
    tombstones in the persisted erased list, so a reader merging runs in
    sequence order reconstructs exactly the dynamic semantics.  Returns the
    meta record (with ``seq_lo/seq_hi/addr_lo/addr_hi`` bounds).
    """
    segments = sorted(segments, key=lambda s: s.seqnum)
    if not segments:
        raise ValueError("write_run of an empty segment set")
    erased = union_intervals([s.erased for s in segments])
    by_feature: Dict[int, List[AnnotationList]] = {}
    for seg in segments:                       # sequence order: last wins
        for fval, lst in seg.postings.items():
            by_feature.setdefault(fval, []).append(lst)
    feats = {f: _filter_erased(merge_lists(ls), erased)
             for f, ls in by_feature.items()}
    feats = {f: l for f, l in feats.items() if len(l)}
    recs = _gc_records([r for seg in segments
                        for r in seg.content.records()], erased)
    return _write_layout(directory, feats.items(), erased, recs, {
        "seq_lo": int(segments[0].seqnum),
        "seq_hi": int(segments[-1].seqnum)}, block_size=block_size)


def _merged_record_stream(runs: List[StaticIndex], erased: AnnotationList,
                          gc_records: bool):
    """Stream every surviving content record across ``runs`` in address
    order — raw compressed payloads for v2 sources (no decode), durable
    dicts for v1.  Lazily: only footer bounds are materialized up front."""
    entries = []                     # (lo, hi, run_idx, rec_idx)
    for ri, r in enumerate(runs):
        for i, (lo, hi) in enumerate(r.record_bounds()):
            entries.append((lo, hi, ri, i))
    entries.sort(key=lambda t: t[0])
    for lo, hi, ri, i in entries:
        if gc_records and _record_fully_erased(lo, hi, erased):
            continue
        content = runs[ri].content
        if isinstance(content, LazyContentStore):
            yield _RawRecord(lo, hi, content.raw_payload(i))
        else:
            rec = content.records()[i]
            yield {"lo": rec.lo, "hi": rec.hi, "text": rec.text,
                   "off": np.asarray(rec.offsets,
                                     dtype=np.int64).tobytes(),
                   "tok": list(rec.tokens)}


def merge_runs(run_dirs: List[str], directory: str,
               gc_records: bool = True,
               block_size: int = DEFAULT_BLOCK_SIZE) -> dict:
    """Fold several runs (oldest first — recency order of the caller's
    read path) into one.

    With ``gc_records`` (bottom-level compaction), records fully covered
    by the union of the runs' tombstones are dropped; upper-level merges
    pass False and defer the GC, matching classic leveled doctrine.  The
    tombstones themselves are *always* retained — annotative indexing lets
    later transactions annotate erased address ranges, so a tombstone
    keeps filtering reads forever (unlike classic LSM deletes, it can
    never be dropped once no older run exists).  v2 sources stream their
    content payloads without decompression.  Returns the merged meta
    record.
    """
    if not run_dirs:
        raise ValueError("merge_runs of an empty run set")
    runs = [StaticIndex(d) for d in run_dirs]
    try:
        erased = union_intervals([r.erased for r in runs])
        fvals = sorted({f for r in runs for f in r.features()})

        def feats_stream():
            for fval in fvals:
                lst = _filter_erased(
                    merge_lists([r.annotations(fval) for r in runs]),
                    erased)
                if len(lst):
                    yield fval, lst

        recs = _merged_record_stream(runs, erased, gc_records)
        return _write_layout(directory, feats_stream(), erased, recs, {
            "seq_lo": min(int(r.meta.get("seq_lo", 0)) for r in runs),
            "seq_hi": max(int(r.meta.get("seq_hi", 0)) for r in runs)},
            block_size=block_size)
    finally:
        for r in runs:
            r.close()


def write_carrier_run(directory: str, erased: AnnotationList,
                      seq_lo: int = 0, seq_hi: int = 0,
                      block_size: int = DEFAULT_BLOCK_SIZE) -> dict:
    """Write a run holding *only* tombstones (no postings, no content) —
    the erased-carrier of sliced-run shipping: when whole runs are copied
    to one side of a split, the other side still needs the full tombstone
    union so cross-run erases keep filtering its reads."""
    return _write_layout(directory, [], erased, [], {
        "seq_lo": int(seq_lo), "seq_hi": int(seq_hi)},
        block_size=block_size)


def slice_run(run_dir: str, directory: str, lo: int, hi: int,
              erased_override: Optional[AnnotationList] = None,
              invert: bool = False,
              block_size: int = DEFAULT_BLOCK_SIZE) -> Optional[dict]:
    """Cut one run to the address window ``[lo, hi)`` — or, with
    ``invert``, to the window's complement — by footer-index extents: the
    sliced-run shipping path of cold-group rebalancing.

    Postings are sliced per feature (an annotation belongs to the side
    owning its *start* address — the cross-shard routing rule); content
    records travel with their first address, copied as **raw compressed
    payloads** for v2 sources (no decode, no decompress).  The output
    carries ``erased_override`` (callers pass the source group's full
    tombstone union — a tombstone recorded anywhere may cover either
    side), or the source run's own tombstones.  Returns the sliced meta
    record, or None when nothing (no postings, records, or tombstones)
    lands on the selected side.
    """
    src = StaticIndex(run_dir)
    try:
        erased = (erased_override if erased_override is not None
                  else src.erased)

        def feats_stream():
            for fval in src.features():
                lst = src.annotations(fval)
                mask = (lst.starts >= lo) & (lst.starts < hi)
                if invert:
                    mask = ~mask
                if not mask.any():
                    continue
                if mask.all():
                    yield fval, lst
                else:
                    yield fval, AnnotationList(
                        lst.starts[mask], lst.ends[mask], lst.values[mask],
                        _checked=True)

        def recs_stream():
            content = src.content
            for i, (rlo, rhi) in enumerate(src.record_bounds()):
                if (lo <= rlo < hi) == invert:
                    continue
                if isinstance(content, LazyContentStore):
                    yield _RawRecord(rlo, rhi, content.raw_payload(i))
                else:
                    rec = content.records()[i]
                    yield {"lo": rec.lo, "hi": rec.hi, "text": rec.text,
                           "off": np.asarray(rec.offsets,
                                             dtype=np.int64).tobytes(),
                           "tok": list(rec.tokens)}

        meta = _write_layout(directory, feats_stream(), erased,
                             recs_stream(), {
                                 "seq_lo": int(src.meta.get("seq_lo", 0)),
                                 "seq_hi": int(src.meta.get("seq_hi", 0))},
                             block_size=block_size)
        if (meta["n_features"] == 0 and meta["n_records"] == 0
                and meta["er_n"] == 0):
            import shutil
            shutil.rmtree(directory, ignore_errors=True)
            return None
        return meta
    finally:
        src.close()

"""Static index: larger-than-memory collections, batch update model (paper §3).

Built once (one batch transaction), written to a directory:

  meta.msgpack           address span, counts
  features.msgpack       fval -> (offset, nbytes, count) into postings.bin
  postings.bin           per-feature vByte-gap starts/ends + raw values
  content.bin            zstd msgpack append records

Reads decode one feature at a time (LRU cached) — annotation lists are
"compressed until active".  Batch update = build a merged directory from the
current one plus new documents, then atomic rename; a lock file enforces the
single-transaction rule.

The same layout doubles as the immutable *run* format of the tiered storage
engine (``repro.tiered``): :func:`write_run` freezes a slice of committed
dynamic segments into one directory (meta gains seq/addr bounds),
:func:`merge_runs` folds several runs into one (GC'ing erased records), and
:meth:`StaticIndex.to_segment` streams a run back into the dynamic
``Segment`` form for resurrection.
"""

from __future__ import annotations

import os
import struct
import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import msgpack
import numpy as np

from . import codec, vbyte
from .annotation import AnnotationList, merge_lists, union_intervals
from .featurizer import Featurizer, JsonFeaturizer
from .gcl import Term
from .index import (DynamicIndex, Segment, Snapshot, _filter_erased,
                    erased_overlaps, tokens_sources, translate_sources)
from .tokenizer import Tokenizer, Utf8Tokenizer
from .txt import AppendRecord, ContentStore


class StaticIndex:
    """Read-optimized on-disk annotative index."""

    def __init__(self, directory: str, tokenizer: Optional[Tokenizer] = None,
                 featurizer: Optional[Featurizer] = None, cache_size: int = 256):
        self.directory = directory
        self.tokenizer = tokenizer or Utf8Tokenizer()
        self.featurizer = featurizer or JsonFeaturizer()
        with open(os.path.join(directory, "meta.msgpack"), "rb") as fh:
            self.meta = msgpack.unpackb(fh.read(), raw=False)
        with open(os.path.join(directory, "features.msgpack"), "rb") as fh:
            self._features: Dict[int, Tuple[int, int, int]] = {
                int(k): tuple(v)
                for k, v in msgpack.unpackb(fh.read(), raw=False,
                                            strict_map_key=False).items()}
        self._postings_path = os.path.join(directory, "postings.bin")
        # erased intervals (absent in legacy directories: nothing erased)
        n_er = self.meta.get("er_n", 0)
        self._erased = AnnotationList(
            vbyte.decode_gaps(self.meta.get("er_s", b""), n_er),
            vbyte.decode_gaps(self.meta.get("er_e", b""), n_er),
            np.zeros(n_er), _checked=True)
        with open(os.path.join(directory, "content.bin"), "rb") as fh:
            recs = msgpack.unpackb(codec.decompress(fh.read()), raw=False)
        self._content = ContentStore()
        for a in recs:
            off = np.frombuffer(a["off"], dtype=np.int64).reshape(-1, 2)
            self._content.add(AppendRecord(a["lo"], a["hi"], a["text"], off,
                                           tuple(a["tok"])))
        self._cache: "OrderedDict[int, AnnotationList]" = OrderedDict()
        self._cache_size = cache_size
        self._lock = threading.Lock()
        self._fh = open(self._postings_path, "rb")

    # -- reads (same surface as Snapshot) ------------------------------- #
    def annotations(self, feature) -> AnnotationList:
        fval = (feature if isinstance(feature, int)
                else self.featurizer.featurize(feature))
        with self._lock:
            if fval in self._cache:
                self._cache.move_to_end(fval)
                return self._cache[fval]
        loc = self._features.get(fval)
        if loc is None:
            return AnnotationList.empty()
        offset, nbytes, count = loc
        with self._lock:
            self._fh.seek(offset)
            blob = self._fh.read(nbytes)
        ns, ne = struct.unpack("<II", blob[:8])
        s = vbyte.decode_gaps(blob[8:8 + ns], count)
        e = vbyte.decode_gaps(blob[8 + ns:8 + ns + ne], count)
        v = np.frombuffer(blob[8 + ns + ne:], dtype=np.float64)
        lst = AnnotationList(s, e, v, _checked=True)
        with self._lock:
            self._cache[fval] = lst
            while len(self._cache) > self._cache_size:
                self._cache.popitem(last=False)
        return lst

    def hopper(self, feature) -> Term:
        return Term(self.annotations(feature))

    def translate(self, p: int, q: int) -> Optional[str]:
        if erased_overlaps(self._erased, p, q):
            return None
        return translate_sources([self._content], p, q)

    def tokens(self, p: int, q: int) -> Optional[List[str]]:
        if erased_overlaps(self._erased, p, q):
            return None
        return tokens_sources([self._content], p, q)

    # -- run accessors (tiered storage) --------------------------------- #
    @property
    def erased(self) -> AnnotationList:
        """Persisted erased intervals (tombstones of this run)."""
        return self._erased

    @property
    def content(self) -> ContentStore:
        return self._content

    def features(self) -> List[int]:
        """All feature values with a stored annotation list, sorted."""
        return sorted(self._features)

    def to_segment(self, seqnum: Optional[int] = None) -> Segment:
        """Materialize the whole run as a dynamic :class:`Segment` (loads
        every annotation list) — the resurrection path back to the hot tier;
        fan out to replicas via ``Segment.to_record``."""
        postings = {f: self.annotations(f) for f in self.features()}
        seq = seqnum if seqnum is not None else int(self.meta.get("seq_hi", 0))
        lo = int(self.meta.get("addr_lo", 0))
        hi = int(self.meta.get("addr_hi", -1))
        return Segment(seq, lo, max(0, hi - lo + 1), self._content, postings,
                       self._erased)

    # warren-compat helpers
    def featurize(self, feature: str) -> int:
        return self.featurizer.featurize(feature)

    @property
    def index(self):  # parity with Warren.phrase
        return self

    def phrase(self, text: str):
        from .gcl import Phrase
        from .annotation import AnnotationList as _AL
        words = self.tokenizer.split(text)
        terms = [self.hopper(w) for w in words]
        if not terms:
            return Term(_AL.empty())
        return terms[0] if len(terms) == 1 else Phrase(terms)

    def close(self) -> None:
        self._fh.close()

    def __del__(self):
        # last-resort fd cleanup: runs retired by a tiered compaction are
        # dropped without close() once no pinned snapshot references them
        try:
            self._fh.close()
        except Exception:
            pass


def _gc_records(records, erased: AnnotationList) -> List[dict]:
    """Durable-form content records minus those fully covered by an erased
    interval; partially-erased spans stay and are hidden at read time."""
    recs = []
    for r in records:
        if len(erased):
            i = int(np.searchsorted(erased.starts, r.lo, side="right")) - 1
            if i >= 0 and int(erased.ends[i]) >= r.hi:
                continue
        recs.append({"lo": r.lo, "hi": r.hi, "text": r.text,
                     "off": np.asarray(r.offsets, dtype=np.int64).tobytes(),
                     "tok": list(r.tokens)})
    recs.sort(key=lambda r: r["lo"])
    return recs


def _write_layout(directory: str, feats: Dict[int, AnnotationList],
                  erased: AnnotationList, recs: List[dict],
                  extra_meta: Optional[dict] = None) -> dict:
    """Write the static layout into a build directory, then publish it with
    an atomic rename.  Returns the meta record."""
    build = directory + ".build"
    os.makedirs(build, exist_ok=True)
    offsets: Dict[int, Tuple[int, int, int]] = {}
    with open(os.path.join(build, "postings.bin"), "wb") as fh:
        pos = 0
        for fval, lst in feats.items():
            s = vbyte.encode_gaps(lst.starts)
            e = vbyte.encode_gaps(lst.ends)
            blob = (struct.pack("<II", len(s), len(e)) + s + e
                    + lst.values.tobytes())
            fh.write(blob)
            offsets[fval] = (pos, len(blob), len(lst))
            pos += len(blob)
        fh.flush()
        os.fsync(fh.fileno())
    with open(os.path.join(build, "features.msgpack"), "wb") as fh:
        fh.write(msgpack.packb({str(k): list(v) for k, v in offsets.items()}))
        fh.flush()
        os.fsync(fh.fileno())
    with open(os.path.join(build, "content.bin"), "wb") as fh:
        fh.write(codec.compress(msgpack.packb(recs), level=6))
        fh.flush()
        os.fsync(fh.fileno())
    meta = {"n_features": len(feats), "n_records": len(recs),
            "er_n": len(erased),
            "er_s": vbyte.encode_gaps(erased.starts),
            "er_e": vbyte.encode_gaps(erased.ends)}
    meta.update(extra_meta or {})
    with open(os.path.join(build, "meta.msgpack"), "wb") as fh:
        fh.write(msgpack.packb(meta))
        fh.flush()
        os.fsync(fh.fileno())
    if os.path.exists(directory):
        import shutil
        shutil.rmtree(directory + ".old", ignore_errors=True)
        os.rename(directory, directory + ".old")
        os.rename(build, directory)
        shutil.rmtree(directory + ".old", ignore_errors=True)
    else:
        os.rename(build, directory)
    return meta


def write_static(snapshot_like, directory: str) -> None:
    """Freeze a DynamicIndex snapshot (or anything exposing segments) into
    the on-disk static layout."""
    if isinstance(snapshot_like, Snapshot):
        snap = snapshot_like
    else:
        snap = snapshot_like.snapshot()
    feats: Dict[int, AnnotationList] = {}
    fvals = set()
    for seg in snap.segments:
        fvals.update(seg.postings.keys())
    for fval in fvals:
        lst = snap.annotations(fval)
        if len(lst):
            feats[fval] = lst
    erased = snap.erased
    recs = _gc_records([r for seg in snap.segments
                        for r in seg.content.records()], erased)
    _write_layout(directory, feats, erased, recs)


def _addr_bounds(feats: Dict[int, AnnotationList], erased: AnnotationList,
                 recs: List[dict]) -> Tuple[int, int]:
    lows = [r["lo"] for r in recs]
    highs = [r["hi"] for r in recs]
    for lst in list(feats.values()) + [erased]:
        if len(lst):
            lows.append(int(lst.starts[0]))
            highs.append(int(lst.ends[-1]))
    return (min(lows), max(highs)) if lows else (0, -1)


def write_run(segments: Sequence[Segment], directory: str) -> dict:
    """Freeze committed dynamic segments into one immutable *run* directory
    (the tiered storage engine's on-disk tier).

    Postings are k-way merged in sequence order and filtered by the
    segments' own erased set; fully-erased content records are GC'd;
    partially-erased spans and erases targeting *older* runs survive as
    tombstones in the persisted erased list, so a reader merging runs in
    sequence order reconstructs exactly the dynamic semantics.  Returns the
    meta record (with ``seq_lo/seq_hi/addr_lo/addr_hi`` bounds).
    """
    segments = sorted(segments, key=lambda s: s.seqnum)
    if not segments:
        raise ValueError("write_run of an empty segment set")
    erased = union_intervals([s.erased for s in segments])
    by_feature: Dict[int, List[AnnotationList]] = {}
    for seg in segments:                       # sequence order: last wins
        for fval, lst in seg.postings.items():
            by_feature.setdefault(fval, []).append(lst)
    feats = {f: _filter_erased(merge_lists(ls), erased)
             for f, ls in by_feature.items()}
    feats = {f: l for f, l in feats.items() if len(l)}
    recs = _gc_records([r for seg in segments
                        for r in seg.content.records()], erased)
    addr_lo, addr_hi = _addr_bounds(feats, erased, recs)
    return _write_layout(directory, feats, erased, recs, {
        "seq_lo": int(segments[0].seqnum),
        "seq_hi": int(segments[-1].seqnum),
        "addr_lo": int(addr_lo), "addr_hi": int(addr_hi)})


def merge_runs(run_dirs: List[str], directory: str) -> dict:
    """Fold several runs (ascending sequence order) into one.

    Erased records are GC'd against the union of the runs' tombstones; the
    tombstones themselves are retained — annotative indexing lets *later*
    transactions annotate erased address ranges, so a tombstone keeps
    filtering reads forever (unlike classic LSM deletes, it can never be
    dropped once no older run exists).  Returns the merged meta record.
    """
    if not run_dirs:
        raise ValueError("merge_runs of an empty run set")
    runs = [StaticIndex(d) for d in run_dirs]
    try:
        erased = union_intervals([r.erased for r in runs])
        fvals = sorted({f for r in runs for f in r.features()})
        feats: Dict[int, AnnotationList] = {}
        for fval in fvals:
            lst = _filter_erased(
                merge_lists([r.annotations(fval) for r in runs]), erased)
            if len(lst):
                feats[fval] = lst
        recs = _gc_records([rec for r in runs
                            for rec in r.content.records()], erased)
        addr_lo, addr_hi = _addr_bounds(feats, erased, recs)
        return _write_layout(directory, feats, erased, recs, {
            "seq_lo": min(int(r.meta.get("seq_lo", 0)) for r in runs),
            "seq_hi": max(int(r.meta.get("seq_hi", 0)) for r in runs),
            "addr_lo": int(addr_lo), "addr_hi": int(addr_hi)})
    finally:
        for r in runs:
            r.close()

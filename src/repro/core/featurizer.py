"""Featurizers map feature strings to 64-bit values (paper Fig. 3).

By convention a feature mapped to 0 is not indexed.  ``HashingFeaturizer``
implements MurmurHash64A; wrappers record vocabulary or suppress structural
tokens (``JsonFeaturizer``).
"""

from __future__ import annotations

import threading
from typing import Dict, Iterable, Optional

_MASK64 = (1 << 64) - 1


def murmur64a(data: bytes, seed: int = 0x8445D61A4E774912) -> int:
    """MurmurHash64A (Austin Appleby), pure-python, matches the reference C."""
    m = 0xC6A4A7935BD1E995
    r = 47
    h = (seed ^ ((len(data) * m) & _MASK64)) & _MASK64
    n = len(data) // 8
    for i in range(n):
        k = int.from_bytes(data[i * 8:(i + 1) * 8], "little")
        k = (k * m) & _MASK64
        k ^= k >> r
        k = (k * m) & _MASK64
        h ^= k
        h = (h * m) & _MASK64
    tail = data[n * 8:]
    if tail:
        h ^= int.from_bytes(tail, "little")
        h = (h * m) & _MASK64
    h ^= h >> r
    h = (h * m) & _MASK64
    h ^= h >> r
    return h


class Featurizer:
    """Base featurizer interface: ``featurize(feature: str) -> int``."""

    def featurize(self, feature: str) -> int:
        raise NotImplementedError

    def translate(self, fval: int) -> Optional[str]:
        """Reverse lookup when the featurizer records vocabulary, else None."""
        return None


class HashingFeaturizer(Featurizer):
    def __init__(self, seed: int = 0x8445D61A4E774912):
        self.seed = seed

    def featurize(self, feature: str) -> int:
        h = murmur64a(feature.encode("utf-8"), self.seed)
        return h if h != 0 else 1  # 0 is reserved (= not indexed / erased)


class VocabFeaturizer(Featurizer):
    """Wraps another featurizer and records the vocabulary for reverse lookup."""

    def __init__(self, inner: Optional[Featurizer] = None):
        self.inner = inner or HashingFeaturizer()
        self._vocab: Dict[int, str] = {}
        self._lock = threading.Lock()

    def featurize(self, feature: str) -> int:
        fval = self.inner.featurize(feature)
        if fval != 0:
            with self._lock:
                self._vocab.setdefault(fval, feature)
        return fval

    def translate(self, fval: int) -> Optional[str]:
        return self._vocab.get(fval)

    def vocabulary(self) -> Iterable[str]:
        return list(self._vocab.values())


# Unicode noncharacters are permanently reserved for internal use; the paper
# uses them to encode JSON structural elements inside the content stream.
STRUCT_LBRACE = "﷐"
STRUCT_RBRACE = "﷑"
STRUCT_LBRACKET = "﷒"
STRUCT_RBRACKET = "﷓"
STRUCT_COLON = "﷔"
STRUCT_COMMA = "﷕"
STRUCT_QUOTE = "﷖"
STRUCT_TOKENS = frozenset(
    {
        STRUCT_LBRACE,
        STRUCT_RBRACE,
        STRUCT_LBRACKET,
        STRUCT_RBRACKET,
        STRUCT_COLON,
        STRUCT_COMMA,
        STRUCT_QUOTE,
    }
)


class JsonFeaturizer(Featurizer):
    """Maps JSON structural tokens to 0 (not indexed); delegates otherwise."""

    def __init__(self, inner: Optional[Featurizer] = None):
        self.inner = inner or VocabFeaturizer()

    def featurize(self, feature: str) -> int:
        if feature in STRUCT_TOKENS:
            return 0
        return self.inner.featurize(feature)

    def translate(self, fval: int) -> Optional[str]:
        return self.inner.translate(fval)

"""Block-oriented immutable run file (static layout v2).

One file (``run.aix2``) per run directory::

    [block 0][block 1]...[block N-1][footer][trailer]

Each block is exactly ``block_size`` bytes: an 8-byte header (crc32 of the
used payload bytes + used length) followed by payload, zero-padded.  All
blocks carry ``block_size - 8`` payload bytes except possibly the last, so
a logical *payload-stream* offset maps to its block by integer division —
no per-block index needed.  Extents (a feature's posting blob, one content
record's compressed payload) are ``(offset, nbytes)`` pairs into the
payload stream and may span blocks.

The footer is a msgpack document recording the extent index — per-feature
posting extents, per-record content extents with their address bounds, and
the run meta (erased intervals, seq/addr bounds).  The trailer is a
fixed-size struct at EOF: footer offset/length, footer crc32, magic.

Readers ``mmap`` the file, parse only footer + trailer eagerly, and fetch
blocks lazily through a pluggable block cache — the larger-than-memory
serving path.  Every block is crc-checked on (cache-miss) load; any
truncation, bit flip, bad magic, or impossible extent raises the typed
:class:`RunCorruption`, never a garbage decode.

Crash safety: the writer fsyncs the finished file and announces
``run.blocks_written`` / ``run.synced`` fault points
(:mod:`repro.core.faults`); publication is the caller's atomic directory
rename.
"""

from __future__ import annotations

import mmap
import os
import struct
import threading
import zlib
from typing import Dict, Iterator, List, Optional, Tuple

import msgpack

from .faults import fault_point

MAGIC = b"AIX2"
FORMAT_VERSION = 2
RUN_FILE = "run.aix2"
DEFAULT_BLOCK_SIZE = 4096

_TRAILER = struct.Struct("<QQI4s")      # footer_off, footer_len, crc, magic
_BLOCK_HEADER = struct.Struct("<II")    # crc32(payload[:used]), used


class RunCorruption(RuntimeError):
    """A v2 run file failed a structural or crc check (truncation, bit
    flip, bad magic, extent out of bounds).  Reads never return garbage:
    every decode path raises this instead."""


class _NoCache:
    """Pass-through block 'cache' for standalone readers (plain
    :class:`~repro.core.static.StaticIndex` outside a tiered store): every
    access loads from the mmap — the OS page cache is the only caching."""

    def get_or_load(self, key, loader, admit: bool = True) -> bytes:
        return loader()

    def pin(self, key) -> None:
        pass

    def unpin(self, key) -> None:
        pass


NO_CACHE = _NoCache()


# --------------------------------------------------------------------- #
class BlockRunWriter:
    """Streams payload extents into fixed-size crc'd blocks.

    ``append`` returns the extent of the bytes just written; ``finish``
    flushes the final partial block, writes footer + trailer, and fsyncs.
    Nothing is visible to readers until the caller publishes the directory
    (atomic rename) — a torn file is unreachable by construction.
    """

    def __init__(self, path: str, block_size: int = DEFAULT_BLOCK_SIZE):
        if block_size <= _BLOCK_HEADER.size:
            raise ValueError(f"block_size {block_size} too small")
        self.path = path
        self.block_size = block_size
        self.payload_cap = block_size - _BLOCK_HEADER.size
        self._fh = open(path, "wb")
        self._buf = bytearray()          # current (unflushed) block payload
        self._pos = 0                    # payload-stream length so far
        self._n_blocks = 0
        self._finished = False

    @property
    def tell(self) -> int:
        """Current payload-stream position (the next extent's offset)."""
        return self._pos

    def append(self, data: bytes) -> Tuple[int, int]:
        """Write one extent; returns ``(offset, nbytes)``."""
        off = self._pos
        view = memoryview(data)
        while len(view):
            room = self.payload_cap - len(self._buf)
            take = min(room, len(view))
            self._buf += view[:take]
            view = view[take:]
            if len(self._buf) == self.payload_cap:
                self._flush_block()
        self._pos = off + len(data)
        return off, len(data)

    def _flush_block(self) -> None:
        payload = bytes(self._buf)
        header = _BLOCK_HEADER.pack(zlib.crc32(payload), len(payload))
        block = header + payload
        if len(block) < self.block_size:
            block += b"\x00" * (self.block_size - len(block))
        self._fh.write(block)
        self._buf.clear()
        self._n_blocks += 1

    def finish(self, features: Dict[int, Tuple[int, int, int]],
               records: List[Tuple[int, int, int, int]],
               meta: dict) -> None:
        """Flush the tail block, then footer + trailer, then fsync."""
        if self._finished:
            raise RuntimeError("writer already finished")
        if self._buf:
            self._flush_block()
        fault_point("run.blocks_written")
        footer = msgpack.packb({
            "version": FORMAT_VERSION,
            "block_size": self.block_size,
            "n_blocks": self._n_blocks,
            "payload_len": self._pos,
            "features": {int(k): list(v) for k, v in features.items()},
            "records": [list(r) for r in records],
            "meta": meta,
        })
        footer_off = self._n_blocks * self.block_size
        self._fh.write(footer)
        self._fh.write(_TRAILER.pack(footer_off, len(footer),
                                     zlib.crc32(footer), MAGIC))
        self._fh.flush()
        os.fsync(self._fh.fileno())
        self._fh.close()
        self._finished = True
        fault_point("run.synced")

    def abort(self) -> None:
        if not self._finished:
            self._fh.close()
            try:
                os.unlink(self.path)
            except OSError:
                pass


# --------------------------------------------------------------------- #
class BlockRunReader:
    """mmap-backed lazy reader over a v2 run file.

    Footer and trailer are parsed (and crc-checked) eagerly — they are
    small.  Block payloads are fetched on demand through the block cache;
    a cache miss loads the block from the mapping and verifies its crc, so
    a flipped bit anywhere in the block region surfaces as
    :class:`RunCorruption` on first touch, never as a garbage decode.
    Cache keys include the file's identity (device, inode) and footer crc,
    so two readers of the same file share cached blocks while a recycled
    inode cannot alias a stale entry.
    """

    def __init__(self, path: str, cache=None):
        self.path = path
        self._cache = cache if cache is not None else NO_CACHE
        self._fh = open(path, "rb")
        try:
            st = os.fstat(self._fh.fileno())
            if st.st_size < _TRAILER.size:
                raise RunCorruption(f"{path}: truncated (no trailer)")
            self._mm = mmap.mmap(self._fh.fileno(), 0,
                                 access=mmap.ACCESS_READ)
            size = st.st_size
            (footer_off, footer_len, footer_crc,
             magic) = _TRAILER.unpack(self._mm[size - _TRAILER.size:size])
            if magic != MAGIC:
                raise RunCorruption(f"{path}: bad magic {magic!r}")
            if footer_off + footer_len > size - _TRAILER.size:
                raise RunCorruption(f"{path}: footer extent out of bounds")
            footer_bytes = self._mm[footer_off:footer_off + footer_len]
            if zlib.crc32(footer_bytes) != footer_crc:
                raise RunCorruption(f"{path}: footer crc mismatch")
            try:
                footer = msgpack.unpackb(footer_bytes, raw=False,
                                         strict_map_key=False)
            except Exception as e:
                raise RunCorruption(f"{path}: footer undecodable: {e}") from e
            if footer.get("version") != FORMAT_VERSION:
                raise RunCorruption(
                    f"{path}: unsupported layout version "
                    f"{footer.get('version')!r}")
            self.block_size = int(footer["block_size"])
            self.payload_cap = self.block_size - _BLOCK_HEADER.size
            self.n_blocks = int(footer["n_blocks"])
            self.payload_len = int(footer["payload_len"])
            if self.n_blocks * self.block_size != footer_off:
                raise RunCorruption(
                    f"{path}: block region/footer offset mismatch")
            if not (self.payload_cap * (self.n_blocks - 1)
                    < self.payload_len <= self.payload_cap * self.n_blocks
                    or (self.payload_len == 0 and self.n_blocks == 0)):
                raise RunCorruption(f"{path}: payload length inconsistent")
            self.features: Dict[int, Tuple[int, int, int]] = {
                int(k): tuple(v) for k, v in footer["features"].items()}
            self.records: List[Tuple[int, int, int, int]] = [
                tuple(r) for r in footer["records"]]
            self.meta: dict = footer["meta"]
            self._key_base = (st.st_dev, st.st_ino, footer_crc)
            self._lock = threading.Lock()
        except Exception:
            self._fh.close()
            raise

    # -- block access --------------------------------------------------- #
    def _block_key(self, i: int):
        return (*self._key_base, i)

    def _load_block(self, i: int) -> bytes:
        lo = i * self.block_size
        raw = self._mm[lo:lo + self.block_size]
        if len(raw) < _BLOCK_HEADER.size:
            raise RunCorruption(f"{self.path}: block {i} truncated")
        crc, used = _BLOCK_HEADER.unpack(raw[:_BLOCK_HEADER.size])
        if used > self.payload_cap or _BLOCK_HEADER.size + used > len(raw):
            raise RunCorruption(
                f"{self.path}: block {i} used-length {used} impossible")
        payload = raw[_BLOCK_HEADER.size:_BLOCK_HEADER.size + used]
        if zlib.crc32(payload) != crc:
            raise RunCorruption(f"{self.path}: block {i} crc mismatch")
        return payload

    def read(self, offset: int, nbytes: int) -> bytes:
        """Assemble one payload-stream extent from its blocks (cached).

        Blocks are pinned in the cache for the duration of the assembly so
        a concurrent eviction sweep cannot drop a block another reader is
        mid-way through re-fetching (the cache-invariant tests exercise
        exactly this)."""
        if nbytes == 0:
            return b""
        if offset < 0 or nbytes < 0 or offset + nbytes > self.payload_len:
            raise RunCorruption(
                f"{self.path}: extent ({offset}, {nbytes}) beyond payload "
                f"length {self.payload_len}")
        cap = self.payload_cap
        first, last = offset // cap, (offset + nbytes - 1) // cap
        if last >= self.n_blocks:
            raise RunCorruption(
                f"{self.path}: extent ({offset}, {nbytes}) names block "
                f"{last} of {self.n_blocks}")
        parts = []
        cache = self._cache
        pinned = []
        try:
            for i in range(first, last + 1):
                key = self._block_key(i)
                payload = cache.get_or_load(key, lambda i=i:
                                            self._load_block(i))
                cache.pin(key)
                pinned.append(key)
                lo = max(0, offset - i * cap)
                hi = min(len(payload), offset + nbytes - i * cap)
                if hi > len(payload):
                    raise RunCorruption(
                        f"{self.path}: block {i} shorter than extent")
                parts.append(payload[lo:hi])
        finally:
            for key in pinned:
                cache.unpin(key)
        out = parts[0] if len(parts) == 1 else b"".join(parts)
        if len(out) != nbytes:
            raise RunCorruption(
                f"{self.path}: extent ({offset}, {nbytes}) assembled "
                f"{len(out)} bytes")
        return out

    def stream(self, offset: int, nbytes: int,
               admit: bool = False) -> Iterator[bytes]:
        """Yield an extent block-by-block WITHOUT admitting to the cache by
        default — the compaction/slice streaming path, so bulk scans never
        thrash resident reader blocks."""
        if nbytes == 0:
            return
        cap = self.payload_cap
        first, last = offset // cap, (offset + nbytes - 1) // cap
        for i in range(first, last + 1):
            payload = self._cache.get_or_load(
                self._block_key(i), lambda i=i: self._load_block(i),
                admit=admit)
            lo = max(0, offset - i * cap)
            hi = min(len(payload), offset + nbytes - i * cap)
            yield payload[lo:hi]

    def file_size(self) -> int:
        return os.fstat(self._fh.fileno()).st_size

    def close(self) -> None:
        try:
            self._mm.close()
        except Exception:
            pass
        try:
            self._fh.close()
        except Exception:
            pass


def is_v2_dir(directory: str) -> bool:
    return os.path.exists(os.path.join(directory, RUN_FILE))

"""Structural query language: text → GCL operator tree (paper Fig. 2).

The paper's Conclusion envisions LLMs emitting structural queries; this is
the textual syntax they would emit.  Grammar (precedence low → high):

  expr    := or
  or      := and ( "|" and )*                       A ▽ B   one of
  and     := seq ( "&" seq )*                       A △ B   both of
  seq     := cont ( "..." cont )*                   A ◇ B   followed by
  cont    := atom ( ("<<" | ">>" | "!<<" | "!>>") atom )*
             A << B  contained in      A >> B  containing
             !<<     not contained in  !>>     not containing
  atom    := "(" expr ")" | '"phrase words"' | "[feature]" | word

  word          a single term (tokenized, stemless content word)
  "…"           phrase (adjacent tokens)
  [feature]     a raw feature name, e.g. [:city:], [Files/zips.json],
                [year=2008]

Examples (paper Fig. 6):
  [:city:] >> "new york" << [Files/zips.json]
  [:] >> ([year=2008] & [month=12] & [day=01])
  [:title:] | [:authors:] << [Files/books.json]
"""

from __future__ import annotations

import re
from typing import List, Optional

from .annotation import AnnotationList
from .gcl import (BothOf, ContainedIn, Containing, FollowedBy, GCLNode,
                  NotContainedIn, NotContaining, OneOf, Phrase, Term)

_TOKEN_RE = re.compile(r"""
    (?P<phrase>"[^"]*")
  | (?P<feature>\[[^\]]+\])
  | (?P<op><<|>>|!<<|!>>|\||&|\.\.\.|\(|\))
  | (?P<word>[^\s()"\[\]|&<>!]+)
""", re.VERBOSE)


class QueryError(ValueError):
    pass


def _lex(text: str) -> List[tuple]:
    out = []
    pos = 0
    for m in _TOKEN_RE.finditer(text):
        if text[pos:m.start()].strip():
            raise QueryError(f"bad syntax near {text[pos:m.start()]!r}")
        pos = m.end()
        if m.lastgroup == "op":
            out.append(("op", m.group()))
        elif m.lastgroup == "phrase":
            out.append(("phrase", m.group()[1:-1]))
        elif m.lastgroup == "feature":
            out.append(("feature", m.group()[1:-1]))
        else:
            out.append(("word", m.group()))
    if text[pos:].strip():
        raise QueryError(f"bad syntax near {text[pos:]!r}")
    return out


class _Parser:
    def __init__(self, tokens: List[tuple], warren):
        self.toks = tokens
        self.i = 0
        self.w = warren

    def _peek(self) -> Optional[tuple]:
        return self.toks[self.i] if self.i < len(self.toks) else None

    def _eat(self, kind=None, value=None):
        t = self._peek()
        if t is None or (kind and t[0] != kind) or (value and t[1] != value):
            raise QueryError(f"expected {value or kind}, got {t}")
        self.i += 1
        return t

    def parse(self) -> GCLNode:
        node = self.expr()
        if self._peek() is not None:
            raise QueryError(f"trailing input: {self._peek()}")
        return node

    def expr(self) -> GCLNode:
        node = self.and_()
        while self._peek() == ("op", "|"):
            self._eat()
            node = OneOf(node, self.and_())
        return node

    def and_(self) -> GCLNode:
        node = self.seq()
        while self._peek() == ("op", "&"):
            self._eat()
            node = BothOf(node, self.seq())
        return node

    def seq(self) -> GCLNode:
        node = self.cont()
        while self._peek() == ("op", "..."):
            self._eat()
            node = FollowedBy(node, self.cont())
        return node

    def cont(self) -> GCLNode:
        node = self.atom()
        ops = {"<<": ContainedIn, ">>": Containing,
               "!<<": NotContainedIn, "!>>": NotContaining}
        while self._peek() is not None and self._peek()[0] == "op" \
                and self._peek()[1] in ops:
            op = self._eat()[1]
            node = ops[op](node, self.atom())
        return node

    def atom(self) -> GCLNode:
        t = self._peek()
        if t is None:
            raise QueryError("unexpected end of query")
        if t == ("op", "("):
            self._eat()
            node = self.expr()
            self._eat("op", ")")
            return node
        if t[0] == "phrase":
            self._eat()
            return self.w.phrase(t[1])
        if t[0] == "feature":
            self._eat()
            return self.w.hopper(t[1])
        if t[0] == "word":
            self._eat()
            return self.w.hopper(t[1].lower())
        raise QueryError(f"unexpected {t}")


def parse_query(text: str, warren) -> GCLNode:
    """Compile query text to a lazy GCL node over an open warren/reader."""
    return _Parser(_lex(text), warren).parse()


def solve(text: str, warren, limit: int = 1000):
    """Parse + enumerate solutions (paper's Solve loop)."""
    node = parse_query(text, warren)
    out = node.solutions()
    return out[:limit]

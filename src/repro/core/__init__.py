"""Annotative indexing core (Clarke 2024), paper-faithful reference layer."""

from .annotation import (INF, NINF, Annotation, AnnotationList, merge_lists,
                         reduce_minimal, union_intervals)
from .featurizer import (HashingFeaturizer, JsonFeaturizer, VocabFeaturizer,
                         murmur64a)
from .gcl import (BothOf, ContainedIn, Containing, FollowedBy, GCLNode,
                  NotContainedIn, NotContaining, OneOf, Phrase, Term,
                  both_of_all, one_of_all)
from .graph_store import GraphStore
from .index import DynamicIndex, Segment, Snapshot, Transaction
from .json_store import add_json, annotate_dates, render_tokens, value_of
from .ranking import (average_precision, build_block_impacts, collection_stats,
                      expand_query, index_document, ingest_documents,
                      score_blockmax, score_bm25, score_wand)
from .query import parse_query, solve
from .sparse import index_sparse_vector, score_hybrid, score_sparse
from .static import StaticIndex, merge_runs, write_run, write_static
from .stemmer import porter_stem
from .tokenizer import AsciiTokenizer, Utf8Tokenizer
from .warren import Warren

__all__ = [
    "INF", "NINF", "Annotation", "AnnotationList", "merge_lists",
    "reduce_minimal", "HashingFeaturizer", "JsonFeaturizer", "VocabFeaturizer",
    "murmur64a", "BothOf", "ContainedIn", "Containing", "FollowedBy",
    "GCLNode", "NotContainedIn", "NotContaining", "OneOf", "Phrase", "Term",
    "both_of_all", "one_of_all", "GraphStore", "DynamicIndex", "Segment",
    "Snapshot", "Transaction", "add_json", "annotate_dates", "render_tokens",
    "value_of", "average_precision", "build_block_impacts", "collection_stats",
    "expand_query", "index_document", "ingest_documents", "score_blockmax",
    "score_bm25",
    "score_wand", "StaticIndex", "write_static", "write_run", "merge_runs",
    "union_intervals", "porter_stem",
    "parse_query", "solve", "index_sparse_vector", "score_hybrid",
    "score_sparse",
    "AsciiTokenizer", "Utf8Tokenizer", "Warren",
]

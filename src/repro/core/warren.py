"""Warren: groups components and manages transactions (paper Fig. 3).

A Warren exposes exactly the paper's operations:

  clone, start, end, transaction, ready, commit, abort      (lifecycle)
  hopper(f)      — Idx: cursor over a feature's annotation list
  translate(p,q) — Txt: T(p, q)
  append / annotate / erase — Appender/Annotator (inside a transaction)

Each clone manages at most one transaction at a time; any access, even
read-only, must be bracketed by start/end.  Updates become visible only
after end() followed by another start().
"""

from __future__ import annotations

from typing import List, Optional, Tuple

from .annotation import AnnotationList
from .gcl import GCLNode, Phrase, Term
from .index import DynamicIndex, Snapshot, Transaction


class Warren:
    def __init__(self, index: DynamicIndex):
        self.index = index
        self._snapshot: Optional[Snapshot] = None
        self._txn: Optional[Transaction] = None

    # -- lifecycle ------------------------------------------------------ #
    def clone(self) -> "Warren":
        return Warren(self.index)

    def start(self) -> None:
        if self._snapshot is not None:
            raise RuntimeError("already started")
        self._snapshot = self.index.snapshot()

    def end(self) -> None:
        self._snapshot = None

    def __enter__(self) -> "Warren":
        self.start()
        return self

    def __exit__(self, *exc) -> bool:
        if self._txn is not None and self._txn._state in ("open", "ready"):
            self._txn.abort()
            self._txn = None
        self.end()
        return False

    # -- transactions ---------------------------------------------------- #
    def transaction(self) -> None:
        self._require_started()
        if self._txn is not None:
            raise RuntimeError("transaction already active on this warren")
        self._txn = self.index.transaction()

    def append(self, text: str) -> Tuple[int, int]:
        return self._require_txn().append(text)

    def annotate(self, feature, p: int, q: int, v: float = 0.0,
                 v_is_address: bool = False) -> None:
        self._require_txn().annotate(feature, p, q, v, v_is_address=v_is_address)

    def erase(self, p: int, q: int) -> None:
        self._require_txn().erase(p, q)

    def ready(self) -> None:
        self._require_txn().ready()

    def commit(self):
        """Commit; returns the staging→permanent address remap function."""
        txn = self._require_txn()
        txn.commit()
        self._txn = None
        return txn.remap

    def abort(self) -> None:
        self._require_txn().abort()
        self._txn = None

    # -- reads ------------------------------------------------------------ #
    def featurize(self, feature: str) -> int:
        return self.index.featurizer.featurize(feature)

    def annotations(self, feature) -> AnnotationList:
        self._require_started()
        fval = feature if isinstance(feature, int) else self.featurize(feature)
        return self._snapshot.annotations(fval)

    def hopper(self, feature) -> Term:
        self._require_started()
        fval = feature if isinstance(feature, int) else self.featurize(feature)
        return self._snapshot.hopper(fval)

    def translate(self, p: int, q: int) -> Optional[str]:
        self._require_started()
        return self._snapshot.translate(p, q)

    def tokens(self, p: int, q: int) -> Optional[List[str]]:
        self._require_started()
        return self._snapshot.tokens(p, q)

    def phrase(self, text: str) -> GCLNode:
        """Query helper: tokenize text, AND-adjacent tokens into a Phrase."""
        self._require_started()
        words = self.index.tokenizer.split(text)
        terms = [self.hopper(w) for w in words]
        if not terms:
            return Term(AnnotationList.empty())
        if len(terms) == 1:
            return terms[0]
        return Phrase(terms)

    # -- internals ---------------------------------------------------------- #
    def _require_started(self) -> None:
        if self._snapshot is None:
            raise RuntimeError("warren access outside start()/end()")

    def _require_txn(self) -> Transaction:
        if self._txn is None:
            raise RuntimeError("no active transaction")
        return self._txn

"""Graphs as annotations (paper §2.5 and Conclusion).

Two encodings, both from the paper:

  direct:    ⟨G, p, v⟩            directed edge from content at address p to
                                   content at address v (value = address)
  edge-list: ⟨G, p, E⟩ + ⟨E, p'⟩   value = feature holding the out-edges
                                   (avoids dangling references on delete)

Subject-predicate-object triples: ⟨predicate, subject_addr, object_addr⟩.
"""

from __future__ import annotations

from typing import Any, Dict, Iterator, List, Optional, Tuple

from .json_store import ROOT_FEATURE, add_json, value_of
from .warren import Warren


class GraphStore:
    def __init__(self, warren: Warren):
        self.w = warren
        self._anchors: Dict[Tuple[str, int], int] = {}

    # -- nodes ----------------------------------------------------------- #
    def add_node(self, obj: Any, graph: str = "@node") -> Tuple[int, int]:
        lo, hi = add_json(self.w, obj)
        self.w.annotate(graph, lo, hi)
        return lo, hi

    # -- direct encoding --------------------------------------------------- #
    def add_edge(self, graph: str, src: int, dst: int,
                 anchor: Optional[int] = None) -> None:
        """⟨G, anchor, dst⟩ (paper §2.5): edge from the object containing
        ``src`` to the content at ``dst``.  Minimal-interval semantics allow
        one annotation per (feature, interval), so successive edges from the
        same source anchor at successive addresses inside the source object
        (the paper anchors each friend-edge at that friend's array slot)."""
        if anchor is None:
            key = (graph, src)
            anchor = src + self._anchors.get(key, 0)
            self._anchors[key] = self._anchors.get(key, 0) + 1
        self.w.annotate(graph, anchor, anchor, float(dst), v_is_address=True)

    def neighbors(self, graph: str, lo: int, hi: int) -> List[int]:
        """Target addresses of edges whose source lies inside [lo, hi]."""
        hop = self.w.hopper(graph)
        out = []
        t = hop.tau(lo)
        while t[1] <= hi:
            out.append(int(t[2]))
            t = hop.tau(t[0] + 1)
        return out

    # -- edge-list encoding (paper Conclusion) --------------------------------- #
    # ⟨G, p, E⟩ where the value E is a *feature* holding the out-edges as
    # ⟨E, p'⟩ annotations: no dangling references on delete — erased targets
    # simply vanish from E's annotation list.
    def add_out_edges(self, graph: str, src_extent: Tuple[int, int],
                      dst_addrs: List[int]) -> None:
        """Per-source edge-list feature E = "@edges:<graph>:<src_lo>"; the
        ⟨G:out, src, E⟩ annotation stores src_lo (< 2^53, exact in the value
        channel) and the out-edges are ⟨E, dst⟩ singletons, so deleting a
        target erases its edge entries with it — no dangling references.
        Use on *committed* extents (the annotate-later model): the feature
        name bakes in the permanent source address."""
        lo = src_extent[0]
        if lo < 0:
            raise ValueError("edge-list encoding requires committed extents")
        self.w.annotate(graph + ":out", lo, lo, float(lo))
        edge_feature = f"@edges:{graph}:{lo}"
        for dst in sorted(set(dst_addrs)):
            self.w.annotate(edge_feature, dst, dst)

    def out_edges(self, graph: str, src_extent: Tuple[int, int]) -> List[int]:
        lo = src_extent[0]
        hop = self.w.hopper(graph + ":out")
        t = hop.tau(lo)
        if t[0] != lo:
            return []
        edge_list = self.w.annotations(f"@edges:{graph}:{int(t[2])}")
        return [int(p) for p, _, _ in edge_list]

    # -- triples -------------------------------------------------------------- #
    def add_triple(self, subject_addr: int, predicate: str, object_addr: int) -> None:
        self.add_edge(f"@rel:{predicate}", subject_addr, object_addr)

    def objects_of(self, subject_extent: Tuple[int, int], predicate: str) -> List[int]:
        return self.neighbors(f"@rel:{predicate}", *subject_extent)

    # -- resolution -------------------------------------------------------------- #
    def containing_object(self, addr: int) -> Optional[Tuple[int, int]]:
        """The ':' extent containing an address (object identity)."""
        root = self.w.hopper(ROOT_FEATURE)
        t = root.rho(addr)          # first object ending >= addr
        if t[0] <= addr <= t[1]:
            return (t[0], t[1])
        return None

    def bfs(self, graph: str, start: Tuple[int, int], max_nodes: int = 1000
            ) -> Iterator[Tuple[int, int]]:
        seen = {start}
        frontier = [start]
        while frontier and len(seen) <= max_nodes:
            nxt: List[Tuple[int, int]] = []
            for node in frontier:
                yield node
                for addr in self.neighbors(graph, *node):
                    obj = self.containing_object(addr)
                    if obj is not None and obj not in seen:
                        seen.add(obj)
                        nxt.append(obj)
            frontier = nxt

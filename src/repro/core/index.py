"""The dynamic annotative index: MVCC segments, ACID transactions (paper §5).

Each committed transaction becomes an immutable :class:`Segment` (the paper's
"update Warren") holding the content it appended plus *all* annotations it
added — which may reference addresses appended by earlier transactions (the
defining flexibility of annotative indexing).  A read :class:`Snapshot` is a
sequence-ordered tuple of segments; per-feature views are K-way merges with
the paper's conflict rules (innermost annotation wins; on exact interval
ties, the largest sequence number wins) and erased intervals filtered out.

Transactions follow two-phase commit:

  transaction() → append()/annotate()/erase() in a *local* (negative)
  address space → ready() assigns the permanent base address + seqnum under
  a brief global lock and durably logs the update → commit() logs the commit
  marker and atomically publishes the segment → (abort() leaves a gap).
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from .annotation import (AnnotationList, merge_lists, reduce_minimal,
                         union_intervals)
from .featurizer import Featurizer, JsonFeaturizer
from .gcl import GCLNode, Term
from .log import TransactionLog
from .tokenizer import Tokenizer, Utf8Tokenizer
from .txt import AppendRecord, ContentStore

ERASE_FEATURE = 0                 # reserved: erased intervals
_LOCAL_BASE = -(1 << 40)          # staging addresses are negative (paper §1)


# --------------------------------------------------------------------- #
class Segment:
    """Immutable committed update."""

    __slots__ = ("seqnum", "base", "length", "content", "postings", "erased")

    def __init__(self, seqnum: int, base: int, length: int,
                 content: ContentStore,
                 postings: Dict[int, AnnotationList],
                 erased: AnnotationList):
        self.seqnum = seqnum
        self.base = base
        self.length = length
        self.content = content
        self.postings = postings
        self.erased = erased

    # -- durable form -------------------------------------------------- #
    def to_record(self) -> dict:
        from . import vbyte
        feats = []
        for fval, lst in self.postings.items():
            feats.append({
                "f": fval,
                "n": len(lst),
                "s": vbyte.encode_gaps(lst.starts),
                "e": vbyte.encode_gaps(lst.ends),
                "v": lst.values.tobytes(),
            })
        appends = [{
            "lo": r.lo, "hi": r.hi, "text": r.text,
            "off": np.asarray(r.offsets, dtype=np.int64).tobytes(),
            "tok": list(r.tokens),
        } for r in self.content.records()]
        return {
            "t": "ready", "seq": self.seqnum, "base": self.base,
            "length": self.length, "appends": appends, "features": feats,
            "er_s": vbyte.encode_gaps(self.erased.starts),
            "er_e": vbyte.encode_gaps(self.erased.ends),
            "er_n": len(self.erased),
        }

    @staticmethod
    def from_record(rec: dict) -> "Segment":
        from . import vbyte
        content = ContentStore()
        for a in rec["appends"]:
            off = np.frombuffer(a["off"], dtype=np.int64).reshape(-1, 2)
            content.add(AppendRecord(a["lo"], a["hi"], a["text"], off,
                                     tuple(a["tok"])))
        postings: Dict[int, AnnotationList] = {}
        for f in rec["features"]:
            n = f["n"]
            postings[f["f"]] = AnnotationList(
                vbyte.decode_gaps(f["s"], n), vbyte.decode_gaps(f["e"], n),
                np.frombuffer(f["v"], dtype=np.float64), _checked=True)
        erased = AnnotationList(
            vbyte.decode_gaps(rec["er_s"], rec["er_n"]),
            vbyte.decode_gaps(rec["er_e"], rec["er_n"]),
            np.zeros(rec["er_n"]), _checked=True)
        return Segment(rec["seq"], rec["base"], rec["length"], content,
                       postings, erased)


def partition_segment(seg: Segment, lo: int, hi: int
                      ) -> Tuple[Optional[Segment], Optional[Segment]]:
    """Split one committed segment at the address window [lo, hi) for shard
    rebalancing: returns ``(inside, outside)``.

    A content record belongs to the side owning its first address (records
    never straddle a rebalance pivot — pivots are document boundaries), and
    an annotation belongs to the side owning its *start* address — the same
    rule cross-shard routing uses, so after a split every annotation still
    lives in exactly one replica group.  Neither side carries erased
    intervals: erasure is a point-set over addresses, and a tombstone may be
    recorded in a segment that lands wholly on the other side, so the caller
    installs the group's full tombstone union separately (an erased-carrier
    segment) on *both* sides.  A side with no content and no postings is
    returned as None.
    """
    in_content, out_content = ContentStore(), ContentStore()
    for r in seg.content.records():
        (in_content if lo <= r.lo < hi else out_content).add(r)
    in_postings: Dict[int, AnnotationList] = {}
    out_postings: Dict[int, AnnotationList] = {}
    for fval, lst in seg.postings.items():
        mask = (lst.starts >= lo) & (lst.starts < hi)
        if mask.all():
            in_postings[fval] = lst
        elif not mask.any():
            out_postings[fval] = lst
        else:
            in_postings[fval] = AnnotationList(
                lst.starts[mask], lst.ends[mask], lst.values[mask],
                _checked=True)
            keep = ~mask
            out_postings[fval] = AnnotationList(
                lst.starts[keep], lst.ends[keep], lst.values[keep],
                _checked=True)

    def _side(content: ContentStore, postings: Dict[int, AnnotationList]
              ) -> Optional[Segment]:
        postings = {f: l for f, l in postings.items() if len(l)}
        recs = content.records()
        if not recs and not postings:
            return None
        if recs:
            base = min(r.lo for r in recs)
            length = max(r.hi for r in recs) - base + 1
        else:
            base, length = seg.base, 0
        return Segment(seg.seqnum, base, length, content, postings,
                       AnnotationList.empty())

    return (_side(in_content, in_postings), _side(out_content, out_postings))


def erased_carrier(seqnum: int, base: int,
                   erased: AnnotationList) -> Segment:
    """A zero-length segment holding only erased intervals — the durable
    form of a replica group's full tombstone union after a rebalance
    partition (see :func:`partition_segment`)."""
    return Segment(seqnum, base, 0, ContentStore(), {}, erased)


def erased_overlaps(erased: AnnotationList, p: int, q: int) -> bool:
    """Does [p, q] intersect any erased interval?"""
    if len(erased) == 0:
        return False
    i = int(np.searchsorted(erased.ends, p, side="left"))
    return i < len(erased) and int(erased.starts[i]) <= q


def translate_sources(sources, p: int, q: int) -> Optional[str]:
    """T(p, q) stitched across address-ordered content stores; None on any
    gap (the shared Txt walk of Snapshot, StaticIndex, and TieredSnapshot —
    erased filtering is the caller's job)."""
    parts = []
    expect = p
    for content in sources:
        lo, hi = content.span()
        if hi < expect or lo > q:
            continue
        if lo > expect:
            return None  # gap
        t = content.translate(expect, min(q, hi))
        if t is None:
            return None
        parts.append(t)
        expect = hi + 1
        if expect > q:
            break
    if expect <= q:
        return None
    return " ".join(parts)


def tokens_sources(sources, p: int, q: int) -> Optional[List[str]]:
    """Token strings over [p, q] across address-ordered content stores."""
    out: List[str] = []
    expect = p
    for content in sources:
        lo, hi = content.span()
        if hi < expect or lo > q:
            continue
        if lo > expect:
            return None
        t = content.tokens(expect, min(q, hi))
        if t is None:
            return None
        out.extend(t)
        expect = hi + 1
        if expect > q:
            break
    return out if expect > q else None


def _filter_erased(lst: AnnotationList, erased: AnnotationList) -> AnnotationList:
    """Drop annotations whose interval intersects any erased interval."""
    if len(lst) == 0 or len(erased) == 0:
        return lst
    # first erased interval with end >= annotation start; intersects if its
    # start <= annotation end.
    idx = np.searchsorted(erased.ends, lst.starts, side="left")
    valid = idx < len(erased)
    hit = np.zeros(len(lst), dtype=bool)
    hit[valid] = erased.starts[idx[valid]] <= lst.ends[valid]
    if not hit.any():
        return lst
    keep = ~hit
    return AnnotationList(lst.starts[keep], lst.ends[keep], lst.values[keep],
                          _checked=True)


class Snapshot:
    """A consistent read view: immutable segment tuple + merged-view caches.

    The cache dict is shared via the owning index and keyed by
    (version, feature), so concurrent snapshots of the same version reuse
    merged lists.
    """

    def __init__(self, version: int, segments: Tuple[Segment, ...],
                 cache: dict, cache_lock: threading.Lock):
        self.version = version
        self.segments = segments
        self._cache = cache
        self._cache_lock = cache_lock
        # erasure is permanent over a point-set of addresses: coalescing
        # union, NOT minimal-interval reduction (a nested erase must never
        # un-hide the rest of its enclosing erased range)
        self.erased = union_intervals([s.erased for s in segments])

    # -- Idx ------------------------------------------------------------ #
    def annotations(self, fval: int) -> AnnotationList:
        key = (self.version, fval)
        with self._cache_lock:
            got = self._cache.get(key)
        if got is not None:
            return got
        pieces = [s.postings[fval] for s in self.segments if fval in s.postings]
        merged = _filter_erased(merge_lists(pieces), self.erased)
        with self._cache_lock:
            self._cache[key] = merged
        return merged

    def hopper(self, fval: int) -> Term:
        """Create a cursor (the paper's Hopper) for a feature value."""
        return Term(self.annotations(fval))

    # -- Txt ------------------------------------------------------------ #
    def _sources(self):
        return [s.content for s in self.segments if s.length]

    def translate(self, p: int, q: int) -> Optional[str]:
        if erased_overlaps(self.erased, p, q):
            return None
        return translate_sources(self._sources(), p, q)

    def tokens(self, p: int, q: int) -> Optional[List[str]]:
        if erased_overlaps(self.erased, p, q):
            return None
        return tokens_sources(self._sources(), p, q)


# --------------------------------------------------------------------- #
class Transaction:
    """Two-phase-commit update; see module docstring."""

    def __init__(self, index: "DynamicIndex"):
        self._index = index
        self._tokenizer = index.tokenizer
        self._featurizer = index.featurizer
        self._local_next = 0
        self._appends: List[Tuple[int, str, np.ndarray, Tuple[str, ...]]] = []
        self._ann: List[Tuple[int, int, int, float]] = []  # (fval, p, q, v)
        self._addr_valued: List[int] = []  # indices of address-valued annotations
        self._erase: List[Tuple[int, int]] = []
        self._state = "open"
        self._segment: Optional[Segment] = None
        self._base: Optional[int] = None

    def remap(self, addr: int) -> int:
        """Map a staging (negative) address to its permanent address.

        Valid once ready() has assigned the base address (paper §5).
        """
        if self._base is None:
            raise RuntimeError("remap before ready()")
        return self._base + (addr - _LOCAL_BASE) if addr < 0 else addr

    # -- update operations ---------------------------------------------- #
    def append(self, text: str) -> Tuple[int, int]:
        """Append content; returns its (local) address interval.

        Single-token annotations are added automatically (paper §3) unless
        the featurizer maps the token to 0.
        """
        self._check_open()
        toks = self._tokenizer.tokenize(text)
        if not toks:
            raise ValueError("append of content with no tokens")
        lo = _LOCAL_BASE + self._local_next
        self._local_next += len(toks)
        offsets = np.array([[t.offset, t.length] for t in toks], dtype=np.int64)
        token_strs = tuple(t.text for t in toks)
        self._appends.append((lo, text, offsets, token_strs))
        for i, t in enumerate(token_strs):
            fval = self._featurizer.featurize(t)
            if fval != 0:
                self._ann.append((fval, lo + i, lo + i, 0.0))
        return (lo, lo + len(toks) - 1)

    def annotate(self, feature, p: int, q: int, v: float = 0.0,
                 v_is_address: bool = False) -> None:
        """Add ⟨f, (p,q), v⟩.  ``v_is_address`` marks the value as an address
        (graph edges, §2.5) so staging addresses get remapped at ready()."""
        self._check_open()
        fval = feature if isinstance(feature, int) else self._featurizer.featurize(feature)
        if fval == 0:
            return
        if q < p:
            raise ValueError("annotation with end < start")
        if v_is_address:
            self._addr_valued.append(len(self._ann))
        self._ann.append((fval, p, q, float(v)))

    def erase(self, p: int, q: int) -> None:
        """Remove content + annotations over [p, q] (reserved feature 0)."""
        self._check_open()
        if q < p:
            raise ValueError("erase with end < start")
        self._erase.append((p, q))

    # -- two-phase commit ------------------------------------------------ #
    def ready(self) -> None:
        self._check_open()
        index = self._index
        with index._addr_lock:       # brief global lock (paper §5)
            base = index._next_addr
            seq = index._next_seq
            index._next_addr += self._local_next
            index._next_seq += 1
        self._base = base
        remap = self.remap

        content = ContentStore()
        for lo, text, offsets, toks in self._appends:
            glo = remap(lo)
            content.add(AppendRecord(glo, glo + len(toks) - 1, text, offsets, toks))

        addr_valued = set(self._addr_valued)
        by_feature: Dict[int, List[Tuple[int, int, float]]] = {}
        for i, (fval, p, q, v) in enumerate(self._ann):
            if i in addr_valued:
                v = float(remap(int(v)))
            by_feature.setdefault(fval, []).append((remap(p), remap(q), v))
        postings: Dict[int, AnnotationList] = {}
        for fval, items in by_feature.items():
            s = np.array([i[0] for i in items], dtype=np.int64)
            e = np.array([i[1] for i in items], dtype=np.int64)
            v = np.array([i[2] for i in items], dtype=np.float64)
            postings[fval] = reduce_minimal(s, e, v)
        if self._erase:
            er_s = np.array([remap(p) for p, _ in self._erase], dtype=np.int64)
            er_e = np.array([remap(q) for _, q in self._erase], dtype=np.int64)
            erased = union_intervals([AnnotationList(
                er_s, er_e, np.zeros(er_s.size), _checked=True)])
        else:
            erased = AnnotationList.empty()

        self._segment = Segment(seq, base, self._local_next, content,
                                postings, erased)
        rec = self._segment.to_record()
        with index._durable_lock:       # vs. concurrent log compaction
            index._log.append(rec)
            index._pending[seq] = rec
        self._state = "ready"

    def commit(self) -> None:
        t0 = time.perf_counter()
        if self._state == "open":
            self.ready()
        if self._state != "ready":
            raise RuntimeError(f"commit in state {self._state}")
        index = self._index
        seq = self._segment.seqnum
        with index._durable_lock:
            index._log.append({"t": "commit", "seq": seq})
            index._pending.pop(seq, None)
            index._publish(self._segment)
        self._state = "committed"
        reg = obs.registry()
        if reg.enabled:
            reg.histogram(
                "txn_commit_latency_ms",
                "ready (if pending) + durable commit marker + publish"
            ).observe(1e3 * (time.perf_counter() - t0))
        index._maybe_auto_merge()

    def abort(self) -> None:
        if self._state == "ready":
            seq = self._segment.seqnum
            with self._index._durable_lock:
                self._index._log.append({"t": "abort", "seq": seq})
                self._index._pending.pop(seq, None)
        self._state = "aborted"  # address interval (if assigned) becomes a gap

    def _check_open(self) -> None:
        if self._state != "open":
            raise RuntimeError(f"transaction is {self._state}")


# --------------------------------------------------------------------- #
class DynamicIndex:
    """Fully dynamic annotative index with concurrent readers and writers."""

    def __init__(self, tokenizer: Optional[Tokenizer] = None,
                 featurizer: Optional[Featurizer] = None,
                 log_path: Optional[str] = None,
                 auto_merge_threshold: Optional[int] = None):
        self.tokenizer = tokenizer or Utf8Tokenizer()
        self.featurizer = featurizer or JsonFeaturizer()
        self._log = TransactionLog(log_path)
        self._segments: Tuple[Segment, ...] = ()
        self._version = 0
        self._next_addr = 0
        self._next_seq = 0
        self._addr_lock = threading.Lock()
        self._publish_lock = threading.Lock()
        self._cache: dict = {}
        self._cache_lock = threading.Lock()
        # size-tiered auto-merge: compact when the committed segment count
        # exceeds this (None = never, the historical behavior)
        self.auto_merge_threshold = auto_merge_threshold
        # serializes log compaction against ready/commit/abort log appends;
        # _pending holds readied-but-uncommitted records so a compaction
        # never drops the durable phase-1 frame of an in-flight transaction
        # contention-profiled as "wal" (lock_wait_ms{lock="wal"}) and
        # witness-tracked: group-commit stalls surface here first
        self._durable_lock = obs.ProfiledLock("wal", threading.RLock())
        self._pending: Dict[int, dict] = {}
        # merges are serialized; segments with seqnum <= _merge_fence are
        # off-limits to merge_segments (a tiered freeze is copying them out)
        self._merge_lock = threading.Lock()
        self._merge_fence = -1

    # -- reads ----------------------------------------------------------- #
    def snapshot(self) -> Snapshot:
        with self._publish_lock:
            return Snapshot(self._version, self._segments,
                            self._cache, self._cache_lock)

    # -- writes ---------------------------------------------------------- #
    def transaction(self) -> Transaction:
        return Transaction(self)

    def _publish(self, segment: Segment) -> None:
        with self._publish_lock:
            segs = list(self._segments)
            segs.append(segment)
            segs.sort(key=lambda s: s.seqnum)
            self._segments = tuple(segs)
            self._version += 1
            self._trim_cache()

    def _trim_cache(self) -> None:
        with self._cache_lock:
            stale = [k for k in self._cache if k[0] != self._version]
            # keep the latest version's entries plus nothing else; snapshots
            # pinned to older versions simply re-merge on demand.
            for k in stale:
                del self._cache[k]

    def _maybe_auto_merge(self) -> None:
        t = self.auto_merge_threshold
        if t is not None and len(self._segments) > t:
            self.merge_segments()

    # -- maintenance ------------------------------------------------------ #
    def merge_segments(self, upto: Optional[int] = None) -> None:
        """Background merge: compact committed segments into one subindex
        (paper: "warrens multiply like rabbits"), applying erases and
        logging the compacted state.  Segments at or below the merge fence
        (a tiered freeze in flight) are left untouched."""
        with self._merge_lock:
            fence = self._merge_fence
            with self._publish_lock:
                segs = self._segments
            victims = [s for s in segs
                       if (upto is None or s.seqnum <= upto)
                       and s.seqnum > fence]
            if len(victims) <= 1:
                return
            erased = union_intervals([s.erased for s in victims])
            feats: Dict[int, List[AnnotationList]] = {}
            for s in victims:
                for fval, lst in s.postings.items():
                    feats.setdefault(fval, []).append(lst)
            postings = {f: _filter_erased(merge_lists(ls), erased)
                        for f, ls in feats.items()}
            postings = {f: l for f, l in postings.items() if len(l)}
            content = ContentStore()
            for s in sorted(victims, key=lambda s: s.base):
                for r in s.content.records():
                    # drop fully erased records (GC of content)
                    if len(erased):
                        i = int(np.searchsorted(erased.starts, r.lo,
                                                side="right")) - 1
                        if i >= 0 and int(erased.ends[i]) >= r.hi:
                            continue
                    content.add(r)
            merged = Segment(max(s.seqnum for s in victims), 0, 0, content,
                             postings, erased)
            merged.length = sum(s.length for s in victims)
            merged.base = min(s.base for s in victims)
            with self._publish_lock:
                keep = [s for s in self._segments if s not in victims]
                self._segments = tuple(sorted([merged] + keep,
                                              key=lambda s: s.seqnum))
                self._version += 1
                self._trim_cache()
            self.compact_log()

    def compact_log(self) -> None:
        """Durably rewrite the log as the current committed segments plus
        the phase-1 frames of still-in-flight (readied) transactions."""
        with self._durable_lock:
            with self._publish_lock:
                segs = self._segments
            records = []
            for s in segs:
                records.append(s.to_record())
                records.append({"t": "commit", "seq": s.seqnum})
            records.extend(self._pending.values())
            self._log.compact(records)

    # -- tiered-storage entry points -------------------------------------- #
    def max_committed_seq(self) -> int:
        """Largest committed seqnum (-1 when empty)."""
        with self._publish_lock:
            return max((s.seqnum for s in self._segments), default=-1)

    def set_merge_fence(self, seqnum: int) -> None:
        """Exclude segments with seqnum <= ``seqnum`` from merges (a freeze
        is copying them into a static run); -1 lifts the fence.  Waits out
        any in-flight merge so the fenced set is stable on return."""
        with self._merge_lock:
            self._merge_fence = seqnum

    def detach_segments(self, upto: int) -> Tuple[Segment, ...]:
        """Freeze-at-seqnum: atomically remove committed segments with
        seqnum <= ``upto`` from this index and return them.

        Pinned snapshots keep serving their immutable segment tuples; the
        caller owns making the detached data readable elsewhere (a static
        run published to a manifest) *before* calling this.  The log is NOT
        compacted here — call :meth:`compact_log` once the new tier is
        durable, so a crash in between recovers everything from the log.
        """
        with self._publish_lock:
            frozen = tuple(s for s in self._segments if s.seqnum <= upto)
            if frozen:
                self._segments = tuple(s for s in self._segments
                                       if s.seqnum > upto)
                self._version += 1
                self._trim_cache()
        return frozen

    # -- recovery ---------------------------------------------------------- #
    @staticmethod
    def recover(log_path: str, tokenizer: Optional[Tokenizer] = None,
                featurizer: Optional[Featurizer] = None) -> "DynamicIndex":
        index = DynamicIndex(tokenizer, featurizer, log_path=None)
        ready: Dict[int, dict] = {}
        committed: List[Segment] = []
        log = TransactionLog(log_path)
        for rec in log.replay():
            if rec["t"] == "ready":
                ready[rec["seq"]] = rec
            elif rec["t"] == "commit" and rec["seq"] in ready:
                committed.append(Segment.from_record(ready.pop(rec["seq"])))
            elif rec["t"] == "abort":
                ready.pop(rec["seq"], None)
        log.close()
        committed.sort(key=lambda s: s.seqnum)
        index._segments = tuple(committed)
        index._version = 1
        if committed:
            index._next_seq = max(s.seqnum for s in committed) + 1
            index._next_addr = max(s.base + s.length for s in committed)
        # ready-without-commit transactions are aborted; their intervals are
        # gaps, so the next address must clear them too.
        for rec in ready.values():
            index._next_addr = max(index._next_addr, rec["base"] + rec["length"])
            index._next_seq = max(index._next_seq, rec["seq"] + 1)
        index._log = TransactionLog(log_path)
        return index

"""Compression codec for durable storage (log frames, static content).

The core index has zero hard native deps: zstandard is used when present,
otherwise the stdlib zlib.  Every compressed blob is self-describing — its
first byte names the codec — so a log written with zstd reads back fine in a
zlib-only environment *if* zstandard is importable there, and vice versa
always (zlib is stdlib).  Frame format stays `<u32 len><blob>`; only the
blob header gained the codec byte.
"""

from __future__ import annotations

import zlib

try:
    import zstandard as _zstd
except ImportError:          # pure-stdlib fallback
    _zstd = None

ZSTD = 1
ZLIB = 2

_zstd_c = _zstd.ZstdCompressor(level=3) if _zstd is not None else None
_zstd_d = _zstd.ZstdDecompressor() if _zstd is not None else None


def have_zstd() -> bool:
    return _zstd is not None


def compress(data: bytes, level: int = 3) -> bytes:
    """Compress with the best available codec; blob[0] is the codec id."""
    if _zstd is not None:
        cctx = (_zstd_c if level == 3
                else _zstd.ZstdCompressor(level=level))
        return bytes([ZSTD]) + cctx.compress(data)
    return bytes([ZLIB]) + zlib.compress(data, min(level + 3, 9))


_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"   # raw zstd frame (pre-codec-byte files)


def decompress(blob: bytes) -> bytes:
    codec = blob[0]
    if blob[:4] == _ZSTD_MAGIC:      # legacy blob with no codec byte
        codec = ZSTD
        blob = b"\x00" + blob        # fall through with payload at blob[1:]
    if codec == ZSTD:
        if _zstd is None:
            raise RuntimeError(
                "blob was written with zstandard, which is not installed")
        return _zstd_d.decompress(blob[1:])
    if codec == ZLIB:
        return zlib.decompress(blob[1:])
    raise ValueError(f"unknown codec byte {codec}")

"""Tokenizers facilitate content addressability (paper Fig. 3).

A tokenizer's only role in a Warren is to split appended strings into the
tokens that occupy consecutive addresses.  Ranking-specific tokenization
(stemming, WordPiece, ...) is expressed through *features*, not here.

Operations: ``tokenize`` (tokens + character offsets), ``split`` (tokens
only), ``skip`` (count tokens without materializing them).
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import List

from .featurizer import STRUCT_TOKENS


@dataclass(frozen=True)
class Token:
    text: str
    offset: int  # character offset into the appended string
    length: int  # character length


class Tokenizer:
    def tokenize(self, text: str) -> List[Token]:
        raise NotImplementedError

    def split(self, text: str) -> List[str]:
        return [t.text for t in self.tokenize(text)]

    def skip(self, text: str) -> int:
        return len(self.tokenize(text))


_ASCII_RE = re.compile(r"<[^>]*>|[A-Za-z0-9]+")


class AsciiTokenizer(Tokenizer):
    """Alphanumeric words; HTML-style tags kept whole (older TREC content)."""

    def tokenize(self, text: str) -> List[Token]:
        return [
            Token(m.group(0).lower(), m.start(), m.end() - m.start())
            for m in _ASCII_RE.finditer(text)
        ]


# Word characters: unicode letters/digits/underscore, plus each structural
# noncharacter is its own single token, plus "." for decimals inside numbers.
_UTF8_RE = re.compile(
    r"[" + "".join(STRUCT_TOKENS) + r"]|\w+(?:\.\w+)*",
    re.UNICODE,
)


class Utf8Tokenizer(Tokenizer):
    """Generic unicode word tokenizer; structural noncharacters are single
    tokens so JSON structure survives round-trips through the address space."""

    def tokenize(self, text: str) -> List[Token]:
        return [
            Token(m.group(0) if m.group(0) in STRUCT_TOKENS else m.group(0).lower(),
                  m.start(), m.end() - m.start())
            for m in _UTF8_RE.finditer(text)
        ]

"""Lazy GCL operator algebra under minimal-interval semantics (paper Fig. 2).

Every node supports four access methods over its (conceptual) solution list:

  tau(k)    first solution with start >= k
  rho(k)    first solution with end   >= k
  tau_b(k)  last  solution with start <= k   ("backwards" τ, Clarke 1996)
  rho_b(k)  last  solution with end   <= k   ("backwards" ρ)

All return ``(p, q, v)`` with ``(INF, INF, 0)`` / ``(NINF, NINF, 0)``
sentinels.  Operator access methods are written in terms of their children's
access methods only, so evaluation is lazy and solutions to subqueries that
cannot contribute are skipped (the WAND-like behaviour the paper describes).
Each failed probe advances a child cursor by a *proved-safe* skip, giving the
O(n · A · log(L/A)) bound of Clarke & Cormack (2000) when the leaf access
methods use galloping search.

This module is the paper-faithful reference engine; ``core/vectorized.py``
re-derives the same algebra as batched array programs for TPU execution, and
tests/ verifies both against a brute-force oracle.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

from .annotation import INF, NINF, AnnotationList

Result = Tuple[int, int, float]
_INF_T: Result = (int(INF), int(INF), 0.0)
_NINF_T: Result = (int(NINF), int(NINF), 0.0)


def _is_inf(t: Result) -> bool:
    return t[1] >= INF


def _is_ninf(t: Result) -> bool:
    return t[0] <= NINF


class GCLNode:
    """Base class: a lazily evaluated GC-list."""

    def tau(self, k: int) -> Result:
        raise NotImplementedError

    def rho(self, k: int) -> Result:
        raise NotImplementedError

    def tau_b(self, k: int) -> Result:
        raise NotImplementedError

    def rho_b(self, k: int) -> Result:
        raise NotImplementedError

    # ------------------------------------------------------------------ #
    def solutions(self, lo: int = None, hi: int = None) -> List[Result]:
        """All minimal solutions, optionally restricted to [lo, hi]."""
        out: List[Result] = []
        k = int(NINF) + 1 if lo is None else lo
        t = self.tau(k)
        while not _is_inf(t) and (hi is None or t[1] <= hi):
            out.append(t)
            t = self.tau(t[0] + 1)
        return out

    def solutions_disjoint(self, lo: int = None, hi: int = None) -> List[Result]:
        """The paper's Solve(Q) loop: successive τ(q + 1), disjoint witnesses."""
        out: List[Result] = []
        k = int(NINF) + 1 if lo is None else lo
        t = self.tau(k)
        while not _is_inf(t) and (hi is None or t[1] <= hi):
            out.append(t)
            t = self.tau(t[1] + 1)
        return out

    def to_list(self) -> AnnotationList:
        sols = self.solutions()
        return AnnotationList.from_intervals([(p, q) for p, q, _ in sols],
                                             [v for _, _, v in sols])

    # Operator sugar mirroring Fig. 2 --------------------------------- #
    def contained_in(self, other: "GCLNode") -> "GCLNode":
        return ContainedIn(self, other)

    def containing(self, other: "GCLNode") -> "GCLNode":
        return Containing(self, other)

    def not_contained_in(self, other: "GCLNode") -> "GCLNode":
        return NotContainedIn(self, other)

    def not_containing(self, other: "GCLNode") -> "GCLNode":
        return NotContaining(self, other)

    def both_of(self, other: "GCLNode") -> "GCLNode":
        return BothOf(self, other)

    def one_of(self, other: "GCLNode") -> "GCLNode":
        return OneOf(self, other)

    def followed_by(self, other: "GCLNode") -> "GCLNode":
        return FollowedBy(self, other)

    __and__ = both_of
    __or__ = one_of
    __rshift__ = followed_by
    __lt__ = contained_in
    __gt__ = containing


class Term(GCLNode):
    """Leaf node over a materialized annotation list.

    Maintains a cached cursor per access method and *gallops* from the cached
    position (Büttcher et al. 2010, pp. 42-44) so a sequence of increasing
    probes costs O(log gap) each rather than O(log L).
    """

    def __init__(self, annotations: AnnotationList):
        self.list = annotations
        self._n = len(annotations)
        self._cache = {"tau": 0, "rho": 0, "tau_b": self._n - 1, "rho_b": self._n - 1}

    def _at(self, i: int) -> Result:
        l = self.list
        return (int(l.starts[i]), int(l.ends[i]), float(l.values[i]))

    def _gallop_ge(self, arr, k: int, hint: int) -> int:
        """Smallest i with arr[i] >= k, galloping from hint."""
        n = self._n
        if hint >= n:
            hint = n - 1
        if hint < 0:
            hint = 0
        if arr[hint] >= k:
            # gallop left
            step, hi = 1, hint
            lo = hint - 1
            while lo >= 0 and arr[lo] >= k:
                hi = lo
                lo -= step
                step <<= 1
            lo = max(lo, -1)
        else:
            # gallop right
            step, lo = 1, hint
            hi = hint + 1
            while hi < n and arr[hi] < k:
                lo = hi
                hi += step
                step <<= 1
            hi = min(hi, n)
            if hi == n:
                # arr[n-1] may still be < k
                if arr[n - 1] < k:
                    return n
        # binary search in (lo, hi]: arr[lo] < k <= arr[hi]
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if arr[mid] >= k:
                hi = mid
            else:
                lo = mid
        return hi

    def tau(self, k: int) -> Result:
        if self._n == 0:
            return _INF_T
        i = self._gallop_ge(self.list.starts, k, self._cache["tau"])
        self._cache["tau"] = i
        return _INF_T if i >= self._n else self._at(i)

    def rho(self, k: int) -> Result:
        if self._n == 0:
            return _INF_T
        i = self._gallop_ge(self.list.ends, k, self._cache["rho"])
        self._cache["rho"] = i
        return _INF_T if i >= self._n else self._at(i)

    def tau_b(self, k: int) -> Result:
        if self._n == 0:
            return _NINF_T
        i = self._gallop_ge(self.list.starts, k + 1, self._cache["tau_b"]) - 1
        self._cache["tau_b"] = max(i, 0)
        return _NINF_T if i < 0 else self._at(i)

    def rho_b(self, k: int) -> Result:
        if self._n == 0:
            return _NINF_T
        i = self._gallop_ge(self.list.ends, k + 1, self._cache["rho_b"]) - 1
        self._cache["rho_b"] = max(i, 0)
        return _NINF_T if i < 0 else self._at(i)


class _Binary(GCLNode):
    def __init__(self, a: GCLNode, b: GCLNode):
        self.a = a
        self.b = b


class _CombinationBase(_Binary):
    """Combination operators (△ ▽ ◇) synthesize intervals, so only τ and ρ'
    admit direct constructions: a candidate for ρ(k) could contain a minimal
    solution whose end lies *below* k (resp. τ' and starts above k), which no
    bounded probe of the children can rule out.  Because the solution list
    strictly increases in both start and end, the remaining two methods are
    exact successor/predecessor hops:

        ρ(k)  = successor(ρ'(k-1))  = τ(ρ'(k-1).start + 1)
        τ'(k) = predecessor(τ(k+1)) = ρ'(τ(k+1).end - 1)
    """

    def rho(self, k: int) -> Result:
        r = self.rho_b(k - 1)
        if _is_ninf(r):
            return self.tau(int(NINF) + 1)
        return self.tau(r[0] + 1)

    def tau_b(self, k: int) -> Result:
        t = self.tau(k + 1)
        if _is_inf(t):
            return self.rho_b(int(INF) - 1)
        return self.rho_b(t[1] - 1)


class ContainedIn(_Binary):
    """A ⊲ B: annotations of A contained in some annotation of B."""

    def _scan(self, a: Result) -> Result:
        A, B = self.a, self.b
        while not _is_inf(a):
            b = B.rho(a[1])           # first b ending >= a.q
            if _is_inf(b):
                return _INF_T
            if b[0] <= a[0]:          # b contains a
                return a
            a = A.tau(b[0])           # safe skip: a container must start <= a.p
        return _INF_T

    def tau(self, k: int) -> Result:
        return self._scan(self.a.tau(k))

    def rho(self, k: int) -> Result:
        return self._scan(self.a.rho(k))

    def _scan_b(self, a: Result) -> Result:
        A, B = self.a, self.b
        while not _is_ninf(a):
            b = B.tau_b(a[0])         # last b starting <= a.p
            if _is_ninf(b):
                return _NINF_T
            if b[1] >= a[1]:          # b contains a
                return a
            a = A.rho_b(b[1])         # safe skip backwards
        return _NINF_T

    def tau_b(self, k: int) -> Result:
        return self._scan_b(self.a.tau_b(k))

    def rho_b(self, k: int) -> Result:
        return self._scan_b(self.a.rho_b(k))


class Containing(_Binary):
    """A ⊳ B: annotations of A containing some annotation of B."""

    def _scan(self, a: Result) -> Result:
        A, B = self.a, self.b
        while not _is_inf(a):
            b = B.tau(a[0])           # first b starting >= a.p
            if _is_inf(b):
                return _INF_T
            if b[1] <= a[1]:          # a contains b
                return a
            a = A.rho(b[1])           # safe skip: a must end >= b.q
        return _INF_T

    def tau(self, k: int) -> Result:
        return self._scan(self.a.tau(k))

    def rho(self, k: int) -> Result:
        return self._scan(self.a.rho(k))

    def _scan_b(self, a: Result) -> Result:
        A, B = self.a, self.b
        while not _is_ninf(a):
            b = B.rho_b(a[1])         # last b ending <= a.q
            if _is_ninf(b):
                return _NINF_T
            if b[0] >= a[0]:          # a contains b
                return a
            a = A.tau_b(b[0])
        return _NINF_T

    def tau_b(self, k: int) -> Result:
        return self._scan_b(self.a.tau_b(k))

    def rho_b(self, k: int) -> Result:
        return self._scan_b(self.a.rho_b(k))


class NotContainedIn(_Binary):
    """A ⋪ B: annotations of A not contained in any annotation of B."""

    def _ok(self, a: Result) -> bool:
        b = self.b.rho(a[1])
        return _is_inf(b) or b[0] > a[0]

    def tau(self, k: int) -> Result:
        a = self.a.tau(k)
        while not _is_inf(a) and not self._ok(a):
            a = self.a.tau(a[0] + 1)
        return a

    def rho(self, k: int) -> Result:
        a = self.a.rho(k)
        while not _is_inf(a) and not self._ok(a):
            a = self.a.tau(a[0] + 1)
        return a

    def tau_b(self, k: int) -> Result:
        a = self.a.tau_b(k)
        while not _is_ninf(a) and not self._ok(a):
            a = self.a.tau_b(a[0] - 1)
        return a

    def rho_b(self, k: int) -> Result:
        a = self.a.rho_b(k)
        while not _is_ninf(a) and not self._ok(a):
            a = self.a.tau_b(a[0] - 1)
        return a


class NotContaining(_Binary):
    """A ⋫ B: annotations of A not containing any annotation of B."""

    def _ok(self, a: Result) -> bool:
        b = self.b.tau(a[0])
        return _is_inf(b) or b[1] > a[1]

    def tau(self, k: int) -> Result:
        a = self.a.tau(k)
        while not _is_inf(a) and not self._ok(a):
            a = self.a.tau(a[0] + 1)
        return a

    def rho(self, k: int) -> Result:
        a = self.a.rho(k)
        while not _is_inf(a) and not self._ok(a):
            a = self.a.tau(a[0] + 1)
        return a

    def tau_b(self, k: int) -> Result:
        a = self.a.tau_b(k)
        while not _is_ninf(a) and not self._ok(a):
            a = self.a.tau_b(a[0] - 1)
        return a

    def rho_b(self, k: int) -> Result:
        a = self.a.rho_b(k)
        while not _is_ninf(a) and not self._ok(a):
            a = self.a.tau_b(a[0] - 1)
        return a


class BothOf(_CombinationBase):
    """A △ B: minimal intervals containing one annotation of each."""

    def tau(self, k: int) -> Result:
        a = self.a.tau(k)
        b = self.b.tau(k)
        if _is_inf(a) or _is_inf(b):
            return _INF_T
        v = max(a[1], b[1])                      # minimal end, both starts >= k
        ra = self.a.rho_b(v)                     # maximize start for this end
        rb = self.b.rho_b(v)
        return (min(ra[0], rb[0]), v, 0.0)

    def rho_b(self, k: int) -> Result:
        a = self.a.rho_b(k)
        b = self.b.rho_b(k)
        if _is_ninf(a) or _is_ninf(b):
            return _NINF_T
        u = min(a[0], b[0])                      # maximal start, both ends <= k
        ta = self.a.tau(u)                       # minimize end for this start
        tb = self.b.tau(u)
        return (u, max(ta[1], tb[1]), 0.0)


class OneOf(_CombinationBase):
    """A ▽ B: G(A ∪ B) — merge with nesting elimination."""

    def tau(self, k: int) -> Result:
        a = self.a.tau(k)
        b = self.b.tau(k)
        while True:
            if _is_inf(a):
                return b
            if _is_inf(b):
                return a
            if a[0] == b[0] and a[1] == b[1]:
                return a
            if a[0] <= b[0] and b[1] <= a[1]:    # b nests (strictly) in a
                a = self.a.tau(a[0] + 1)
            elif b[0] <= a[0] and a[1] <= b[1]:  # a nests in b
                b = self.b.tau(b[0] + 1)
            else:
                return a if a[0] < b[0] else b

    def rho_b(self, k: int) -> Result:
        a = self.a.rho_b(k)
        b = self.b.rho_b(k)
        while True:
            if _is_ninf(a):
                return b
            if _is_ninf(b):
                return a
            if a[0] == b[0] and a[1] == b[1]:
                return a
            if a[0] <= b[0] and b[1] <= a[1]:
                a = self.a.rho_b(a[1] - 1)
            elif b[0] <= a[0] and a[1] <= b[1]:
                b = self.b.rho_b(b[1] - 1)
            else:
                return a if a[1] > b[1] else b


class FollowedBy(_CombinationBase):
    """A ◇ B: minimal intervals covering an A-annotation strictly followed by
    a B-annotation."""

    def tau(self, k: int) -> Result:
        a = self.a.tau(k)
        if _is_inf(a):
            return _INF_T
        b = self.b.tau(a[1] + 1)
        if _is_inf(b):
            return _INF_T
        a2 = self.a.rho_b(b[0] - 1)              # maximize start (a exists)
        return (a2[0], b[1], 0.0)

    def rho_b(self, k: int) -> Result:
        b = self.b.rho_b(k)
        if _is_ninf(b):
            return _NINF_T
        a = self.a.rho_b(b[0] - 1)
        if _is_ninf(a):
            return _NINF_T
        b2 = self.b.tau(a[1] + 1)                # minimize end (b exists)
        return (a[0], b2[1], 0.0)


class Phrase(GCLNode):
    """Fixed adjacency over singleton token lists: t₀ t₁ … tₙ₋₁."""

    def __init__(self, terms: Sequence[GCLNode]):
        if not terms:
            raise ValueError("empty phrase")
        self.terms = list(terms)

    def _match_at(self, k: int) -> Result:
        """First phrase occurrence with start >= k."""
        n = len(self.terms)
        while True:
            t0 = self.terms[0].tau(k)
            if _is_inf(t0):
                return _INF_T
            p = t0[0]
            restart = None
            for i in range(1, n):
                ti = self.terms[i].tau(p + i)
                if _is_inf(ti):
                    return _INF_T
                if ti[0] != p + i:
                    restart = ti[0] - i  # earliest start that could align tᵢ
                    break
            if restart is None:
                return (p, p + n - 1, 0.0)
            k = max(restart, p + 1)

    def tau(self, k: int) -> Result:
        return self._match_at(k)

    def rho(self, k: int) -> Result:
        return self._match_at(k - len(self.terms) + 1)

    def _match_at_b(self, k: int) -> Result:
        """Last phrase occurrence with start <= k."""
        n = len(self.terms)
        while True:
            t0 = self.terms[0].tau_b(k)
            if _is_ninf(t0):
                return _NINF_T
            p = t0[0]
            restart = None
            for i in range(1, n):
                ti = self.terms[i].tau_b(p + i)
                if _is_ninf(ti):
                    return _NINF_T
                if ti[0] != p + i:
                    restart = ti[0] - i
                    break
            if restart is None:
                return (p, p + n - 1, 0.0)
            k = min(restart, p - 1)

    def tau_b(self, k: int) -> Result:
        return self._match_at_b(k)

    def rho_b(self, k: int) -> Result:
        return self._match_at_b(k - len(self.terms) + 1)


def one_of_all(nodes: Sequence[GCLNode]) -> GCLNode:
    """Balanced ▽-tree over many nodes (e.g. query-term merge)."""
    nodes = list(nodes)
    if not nodes:
        return Term(AnnotationList.empty())
    while len(nodes) > 1:
        nodes = [OneOf(nodes[i], nodes[i + 1]) if i + 1 < len(nodes) else nodes[i]
                 for i in range(0, len(nodes), 2)]
    return nodes[0]


def both_of_all(nodes: Sequence[GCLNode]) -> GCLNode:
    nodes = list(nodes)
    if not nodes:
        return Term(AnnotationList.empty())
    while len(nodes) > 1:
        nodes = [BothOf(nodes[i], nodes[i + 1]) if i + 1 < len(nodes) else nodes[i]
                 for i in range(0, len(nodes), 2)]
    return nodes[0]

"""The five assigned LM-family transformers, their shapes, and smoke configs.

Shapes (assigned):
  train_4k     seq 4,096  × global_batch 256   (train_step)
  prefill_32k  seq 32,768 × global_batch 32    (serve: prefill)
  decode_32k   one token, KV cache 32,768, batch 128   (serve: decode)
  long_500k    one token, KV cache 524,288, batch 1    (serve: decode)

All five archs are full-attention GQA, so 500k *prefill* is skipped
(quadratic — DESIGN §6); 500k *decode* runs via sequence-sharded KV.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.data import synth
from repro.models import transformer as T

from .base import ArchSpec, Cell, bf16, i32, sds

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32_768, batch=32, kind="serve_prefill"),
    "decode_32k": dict(seq=32_768, batch=128, kind="serve_decode"),
    "long_500k": dict(seq=524_288, batch=1, kind="serve_decode"),
}


def lm_cells(cfg: T.TransformerConfig) -> Dict[str, Cell]:
    cells = {}
    for name, sh in SHAPES.items():
        if sh["kind"] == "train":
            specs = {"tokens": sds((sh["batch"], sh["seq"]), i32),
                     "labels": sds((sh["batch"], sh["seq"]), i32)}
            cells[name] = Cell(name, "train", specs)
        elif sh["kind"] == "serve_prefill":
            specs = {"tokens": sds((sh["batch"], sh["seq"]), i32)}
            cells[name] = Cell(name, "serve", specs, note="prefill")
        else:
            specs = {"tokens": sds((sh["batch"],), i32)}
            cells[name] = Cell(name, "serve", specs,
                               note=f"decode kv={sh['seq']}")
    return cells


def lm_cache_spec(cfg: T.TransformerConfig, batch: int, seq: int):
    shape = (cfg.n_layers, batch, seq, cfg.n_kv_heads, cfg.head_dim)
    dt = cfg.jnp_dtype
    return {"k": sds(shape, dt), "v": sds(shape, dt),
            "length": sds((batch,), i32)}


def lm_smoke_batch(cfg: T.TransformerConfig, kind: str, seed: int = 0):
    if kind == "train":
        gen = synth.token_batches(seed, cfg.vocab, batch=2, seq_len=64)
        b = next(gen)
        return {"tokens": b["tokens"], "labels": b["labels"]}
    rng = np.random.default_rng(seed)
    return {"tokens": rng.integers(0, cfg.vocab, size=(2,), dtype=np.int32)}


def _smoke(cfg: T.TransformerConfig, **over) -> T.TransformerConfig:
    base = dict(
        name=cfg.name + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=max(1, 4 // cfg.group_size if cfg.group_size <= 4 else 1),
        head_dim=16, d_ff=128, vocab=512, qkv_bias=cfg.qkv_bias,
        qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta, max_seq_len=256,
        dtype="float32", remat=False,
    )
    if cfg.moe is not None:
        base["moe"] = T.MoEConfig(
            n_experts=8, top_k=min(cfg.moe.top_k, 4),
            d_expert_ff=32,
            n_shared=cfg.moe.n_shared, d_shared_ff=64 if cfg.moe.n_shared else 0)
    base.update(over)
    return T.TransformerConfig(**base)


def make_lm_spec(cfg: T.TransformerConfig) -> ArchSpec:
    return ArchSpec(
        name=cfg.name, family="lm", config=cfg, smoke_config=_smoke(cfg),
        init_fn=T.init_params,
        loss_fn=lambda p, c, b: T.loss_fn(p, b, c),
        serve_fn=None,  # family dispatch in launch/dryrun (prefill vs decode)
        cells=lm_cells, smoke_batch=lm_smoke_batch, cache_spec=lm_cache_spec,
    )


# -- the five assigned configs [source; verified-tier in assignment] -------- #
QWEN2_5_14B = T.TransformerConfig(
    name="qwen2.5-14b", n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=13824, vocab=152_064, head_dim=128, qkv_bias=True, rope_theta=1e6)

YI_9B = T.TransformerConfig(
    name="yi-9b", n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
    d_ff=11008, vocab=64_000, head_dim=128, rope_theta=1e4)

INTERNLM2_1_8B = T.TransformerConfig(
    name="internlm2-1.8b", n_layers=24, d_model=2048, n_heads=16,
    n_kv_heads=8, d_ff=8192, vocab=92_544, head_dim=128, rope_theta=1e6)

QWEN3_MOE_235B = T.TransformerConfig(
    name="qwen3-moe-235b-a22b", n_layers=94, d_model=4096, n_heads=64,
    n_kv_heads=4, d_ff=1536, vocab=151_936, head_dim=128, qk_norm=True,
    rope_theta=1e6,
    moe=T.MoEConfig(n_experts=128, top_k=8, d_expert_ff=1536))

QWEN2_MOE_A2_7B = T.TransformerConfig(
    name="qwen2-moe-a2.7b", n_layers=24, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=1408, vocab=151_936, head_dim=128, qkv_bias=True,
    rope_theta=1e6,
    moe=T.MoEConfig(n_experts=60, top_k=4, d_expert_ff=1408,
                    n_shared=4, d_shared_ff=5632))

LM_SPECS = {c.name: make_lm_spec(c) for c in
            [QWEN2_5_14B, YI_9B, INTERNLM2_1_8B, QWEN3_MOE_235B,
             QWEN2_MOE_A2_7B]}

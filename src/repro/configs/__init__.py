from .base import ArchSpec, Cell
from .registry import ARCHS, all_cells, get_arch

__all__ = ["ArchSpec", "Cell", "ARCHS", "all_cells", "get_arch"]

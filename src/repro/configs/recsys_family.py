"""The four assigned recsys architectures × their shape cells.

  train_batch     batch 65,536      (training)
  serve_p99       batch 512         (online inference)
  serve_bulk      batch 262,144     (offline scoring)
  retrieval_cand  batch 1 × 1,000,000 candidates (retrieval scoring)

retrieval_cand semantics per arch: two-tower and SASRec score one query
against 1M candidate item embeddings (batched dot, not a loop); DLRM and
xDeepFM score 1M candidate feature rows for one request (offline-scoring
formulation) — noted in DESIGN §6.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.data import synth
from repro.models import recsys as R

from .base import ArchSpec, Cell, f32, i32, sds

BATCHES = {"train_batch": 65_536, "serve_p99": 512, "serve_bulk": 262_144}
N_CAND = 1_000_000
HIST_LEN = 8


# --------------------------------------------------------------------- #
def dlrm_cells(cfg: R.DLRMConfig) -> Dict[str, Cell]:
    def specs(b):
        return {"dense": sds((b, cfg.n_dense), f32),
                "sparse": sds((b, cfg.n_sparse), i32),
                "labels": sds((b,), f32)}
    cells = {n: Cell(n, "train" if n == "train_batch" else "serve", specs(b))
             for n, b in BATCHES.items()}
    cells["retrieval_cand"] = Cell("retrieval_cand", "serve", specs(N_CAND),
                                   note="1M candidate rows, one request")
    return cells


def xdeepfm_cells(cfg: R.XDeepFMConfig) -> Dict[str, Cell]:
    def specs(b):
        return {"sparse": sds((b, cfg.n_sparse), i32), "labels": sds((b,), f32)}
    cells = {n: Cell(n, "train" if n == "train_batch" else "serve", specs(b))
             for n, b in BATCHES.items()}
    cells["retrieval_cand"] = Cell("retrieval_cand", "serve", specs(N_CAND),
                                   note="1M candidate rows, one request")
    return cells


def twotower_cells(cfg: R.TwoTowerConfig) -> Dict[str, Cell]:
    def specs(b):
        return {"user_ids": sds((b,), i32),
                "hist_ids": sds((b, HIST_LEN), i32),
                "hist_w": sds((b, HIST_LEN), f32),
                "item_ids": sds((b,), i32),
                "logq": sds((b,), f32)}
    cells = {n: Cell(n, "train" if n == "train_batch" else "serve", specs(b))
             for n, b in BATCHES.items()}
    cells["retrieval_cand"] = Cell(
        "retrieval_cand", "serve",
        {"user_ids": sds((1,), i32), "hist_ids": sds((1, HIST_LEN), i32),
         "hist_w": sds((1, HIST_LEN), f32), "cand_ids": sds((N_CAND,), i32)},
        note="1 query × 1M candidates, sharded matmul")
    return cells


def sasrec_cells(cfg: R.SASRecConfig) -> Dict[str, Cell]:
    def specs(b):
        return {"item_seq": sds((b, cfg.seq_len), i32),
                "pos_items": sds((b, cfg.seq_len), i32),
                "neg_items": sds((b, cfg.seq_len), i32)}
    cells = {n: Cell(n, "train" if n == "train_batch" else "serve", specs(b))
             for n, b in BATCHES.items()}
    cells["retrieval_cand"] = Cell(
        "retrieval_cand", "serve",
        {"item_seq": sds((1, cfg.seq_len), i32), "cand_ids": sds((N_CAND,), i32)},
        note="1 user history × 1M candidate items")
    return cells


# --------------------------------------------------------------------- #
def dlrm_smoke_batch(cfg, kind, seed=0):
    return synth.dlrm_batch(seed, 8, cfg.n_dense, cfg.n_sparse,
                            cfg.vocab_per_table)


def xdeepfm_smoke_batch(cfg, kind, seed=0):
    return synth.xdeepfm_batch(seed, 8, cfg.n_sparse, cfg.vocab_per_table)


def twotower_smoke_batch(cfg, kind, seed=0):
    b = synth.twotower_batch(seed, 8, cfg.n_users, cfg.n_items, HIST_LEN)
    if kind == "serve":
        b["cand_ids"] = np.arange(64, dtype=np.int32) % cfg.n_items
    return b


def sasrec_smoke_batch(cfg, kind, seed=0):
    b = synth.sasrec_batch(seed, 8, cfg.seq_len, cfg.n_items)
    if kind == "serve":
        b["cand_ids"] = (np.arange(64, dtype=np.int32) % cfg.n_items)
    return b


# --------------------------------------------------------------------- #
DLRM_RM2 = R.DLRMConfig()
DLRM_SMOKE = dataclasses.replace(DLRM_RM2, name="dlrm-smoke",
                                 vocab_per_table=1000, n_sparse=6,
                                 bot_mlp=(13, 32, 16), top_mlp=(32, 16, 1),
                                 embed_dim=16)
XDEEPFM = R.XDeepFMConfig()
XDEEPFM_SMOKE = dataclasses.replace(XDEEPFM, name="xdeepfm-smoke",
                                    vocab_per_table=500, n_sparse=6,
                                    cin_layers=(8, 8), mlp=(16,), embed_dim=4)
TWOTOWER = R.TwoTowerConfig()
TWOTOWER_SMOKE = dataclasses.replace(TWOTOWER, name="two-tower-smoke",
                                     n_users=1000, n_items=500,
                                     tower_mlp=(32, 16), embed_dim=16)
SASREC = R.SASRecConfig()
SASREC_SMOKE = dataclasses.replace(SASREC, name="sasrec-smoke", n_items=200,
                                   embed_dim=16, seq_len=20)

RECSYS_SPECS = {
    "dlrm-rm2": ArchSpec(
        name="dlrm-rm2", family="recsys", config=DLRM_RM2,
        smoke_config=DLRM_SMOKE, init_fn=R.dlrm_init,
        loss_fn=lambda p, c, b: R.dlrm_loss(p, c, b),
        serve_fn=lambda p, c, b: R.dlrm_forward(p, c, b["dense"], b["sparse"]),
        cells=dlrm_cells, smoke_batch=dlrm_smoke_batch),
    "xdeepfm": ArchSpec(
        name="xdeepfm", family="recsys", config=XDEEPFM,
        smoke_config=XDEEPFM_SMOKE, init_fn=R.xdeepfm_init,
        loss_fn=lambda p, c, b: R.xdeepfm_loss(p, c, b),
        serve_fn=lambda p, c, b: R.xdeepfm_forward(p, c, b["sparse"]),
        cells=xdeepfm_cells, smoke_batch=xdeepfm_smoke_batch),
    "two-tower-retrieval": ArchSpec(
        name="two-tower-retrieval", family="recsys", config=TWOTOWER,
        smoke_config=TWOTOWER_SMOKE, init_fn=R.twotower_init,
        loss_fn=lambda p, c, b: R.twotower_loss(p, c, b),
        serve_fn=lambda p, c, b: (
            R.twotower_score_candidates(p, c, b) if "cand_ids" in b
            else R.twotower_user_embed(p, c, b["user_ids"], b["hist_ids"],
                                       b["hist_w"])),
        cells=twotower_cells, smoke_batch=twotower_smoke_batch),
    "sasrec": ArchSpec(
        name="sasrec", family="recsys", config=SASREC,
        smoke_config=SASREC_SMOKE, init_fn=R.sasrec_init,
        loss_fn=lambda p, c, b: R.sasrec_loss(p, c, b),
        serve_fn=lambda p, c, b: (
            R.sasrec_score_candidates(p, c, b) if "cand_ids" in b
            else R.sasrec_encode(p, c, b["item_seq"])),
        cells=sasrec_cells, smoke_batch=sasrec_smoke_batch),
}

"""NequIP arch × the four assigned GNN shape cells.

  full_graph_sm  2,708 nodes / 10,556 edges / d_feat 1,433  (full-batch)
  minibatch_lg   232,965-node graph, sampled: 1,024 seeds, fanout 15-10
  ogb_products   2,449,029 nodes / 61,859,140 edges / d_feat 100
  molecule       128 graphs × 30 nodes / 64 edges (energy + forces)

NequIP is an interatomic potential; the generic graph cells are mapped onto
it as *spatial graphs*: every node carries a position (the geometry the
equivariant tensor products consume) plus optional high-dim features; the
classification shapes use a node-classification head (DESIGN §6).
"""

from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.data import synth
from repro.models import nequip as NQ

from .base import ArchSpec, Cell, f32, i32, sds

def _pad512(n: int) -> int:
    """Graph arrays are padded to a 512-multiple so they shard on any mesh
    axis combination (padding = masked nodes/edges, standard practice)."""
    return -(-n // 512) * 512


# sampled-subgraph padded sizes for minibatch_lg (1024 seeds, fanout 15-10)
_MB_NODES = 1024 + 1024 * 15 + 1024 * 150          # padded upper bound
_MB_EDGES = 1024 * 15 + 1024 * 15 * 10

SHAPES = {
    "full_graph_sm": dict(n=_pad512(2708), e=_pad512(10_556), d_feat=1433,
                          n_classes=7, kind="train"),
    "minibatch_lg": dict(n=_pad512(_MB_NODES), e=_pad512(_MB_EDGES),
                         d_feat=602, n_classes=41, kind="train"),
    "ogb_products": dict(n=_pad512(2_449_029), e=_pad512(61_859_140),
                         d_feat=100, n_classes=47, kind="train"),
    "molecule": dict(n=_pad512(128 * 30), e=_pad512(128 * 64), d_feat=0,
                     n_classes=0, kind="train", n_graphs=128),
}


def gnn_cells(cfg: NQ.NequipConfig) -> Dict[str, Cell]:
    cells = {}
    for name, sh in SHAPES.items():
        specs = {
            "positions": sds((sh["n"], 3), f32),
            "species": sds((sh["n"],), i32),
            "senders": sds((sh["e"],), i32),
            "receivers": sds((sh["e"],), i32),
        }
        if sh["n_classes"]:
            specs["node_feats"] = sds((sh["n"], sh["d_feat"]), f32)
            specs["labels"] = sds((sh["n"],), i32)
            specs["label_mask"] = sds((sh["n"],), f32)
        else:
            specs["graph_ids"] = sds((sh["n"],), i32)
            specs["energies"] = sds((sh["n_graphs"],), f32)
            specs["forces"] = sds((sh["n"], 3), f32)
        cells[name] = Cell(name, "train", specs,
                           note=f"{sh['n']} nodes / {sh['e']} edges")
    return cells


def gnn_smoke_batch(cfg: NQ.NequipConfig, kind: str, seed: int = 0):
    if cfg.n_classes:
        g = synth.random_graph(seed, 64, 256, d_feat=cfg.d_feat,
                               n_classes=cfg.n_classes)
        return g
    b = synth.molecule_batch(seed, batch=4, n_nodes=8, n_edges=16)
    return b


def cfg_for_cell(cfg: NQ.NequipConfig, shape_name: str) -> NQ.NequipConfig:
    """Shape cells differ in head (classes) and input feature width."""
    sh = SHAPES[shape_name]
    import dataclasses
    return dataclasses.replace(cfg, d_feat=sh["d_feat"],
                               n_classes=sh["n_classes"])


NEQUIP = NQ.NequipConfig(name="nequip", n_layers=5, d_hidden=32, l_max=2,
                         n_rbf=8, cutoff=5.0)

NEQUIP_SMOKE = NQ.NequipConfig(name="nequip-smoke", n_layers=2, d_hidden=8,
                               n_rbf=4, cutoff=5.0, d_feat=16, n_classes=5)


def make_gnn_spec() -> ArchSpec:
    return ArchSpec(
        name="nequip", family="gnn", config=NEQUIP, smoke_config=NEQUIP_SMOKE,
        init_fn=NQ.init_params,
        loss_fn=lambda p, c, b: NQ.loss_fn(p, c, b),
        serve_fn=lambda p, c, b: NQ.classify(p, c, b["positions"],
                                             b["species"], b["senders"],
                                             b["receivers"],
                                             b.get("node_feats")),
        cells=gnn_cells, smoke_batch=gnn_smoke_batch,
    )


GNN_SPECS = {"nequip": make_gnn_spec()}

"""--arch registry: the 10 assigned architectures (+ the paper's own config)."""

from __future__ import annotations

from typing import Dict

from .base import ArchSpec
from .gnn_family import GNN_SPECS
from .lm_family import LM_SPECS
from .recsys_family import RECSYS_SPECS

ARCHS: Dict[str, ArchSpec] = {}
ARCHS.update(LM_SPECS)
ARCHS.update(GNN_SPECS)
ARCHS.update(RECSYS_SPECS)


def get_arch(name: str) -> ArchSpec:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def all_cells():
    """Every (arch, shape) dry-run cell — the 40-cell matrix."""
    out = []
    for name, spec in ARCHS.items():
        for shape_name, cell in spec.cells(spec.config).items():
            out.append((name, shape_name, cell))
    return out

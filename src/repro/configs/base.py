"""ArchSpec: uniform interface every assigned architecture implements.

An ArchSpec knows how to
  * build its full config (the assigned public-literature scale) and a
    reduced smoke config,
  * init params (concretely, or abstractly via jax.eval_shape),
  * produce loss/serve functions,
  * describe ShapeDtypeStruct inputs for each of its shape cells
    (`input_specs`), including whether the cell lowers train_step or
    serve_step,
  * generate small concrete batches for smoke tests (`smoke_batch`).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

f32 = jnp.float32
bf16 = jnp.bfloat16
i32 = jnp.int32


def sds(shape, dtype=f32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


@dataclasses.dataclass
class Cell:
    """One (arch × input-shape) dry-run cell."""
    shape_name: str
    kind: str                     # "train" | "serve"
    batch_specs: Dict[str, Any]   # name -> ShapeDtypeStruct (model inputs)
    note: str = ""


@dataclasses.dataclass
class ArchSpec:
    name: str
    family: str                   # "lm" | "gnn" | "recsys"
    config: Any                   # full assigned config
    smoke_config: Any             # reduced config
    init_fn: Callable             # (cfg, key) -> params
    loss_fn: Callable             # (params, cfg, batch) -> scalar
    serve_fn: Optional[Callable]  # (params, cfg, batch) -> outputs
    cells: Callable               # (cfg) -> Dict[shape_name, Cell]
    smoke_batch: Callable         # (cfg, kind, seed) -> concrete batch dict
    # decode-style serving needs a cache spec builder
    cache_spec: Optional[Callable] = None   # (cfg, batch, seq) -> pytree of SDS

    def abstract_params(self, cfg=None):
        cfg = cfg or self.config
        return jax.eval_shape(lambda k: self.init_fn(cfg, k),
                              jax.random.PRNGKey(0))

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this lowers the *real* step function (train_step including the
AdamW update, or the serving step) against ShapeDtypeStruct inputs on the
production mesh, compiles it, and records:

  * memory_analysis()  — per-device bytes (proves the cell fits),
  * cost_analysis()    — per-device HLO FLOPs / bytes for §Roofline,
  * collective bytes   — parsed from the post-SPMD HLO text, summed per
    collective kind (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute),

and appends the record to experiments/dryrun_<mesh>.jsonl.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--multi-pod] [--arch A]
      [--shape S] [--out FILE] [--fsdp {auto,on,off}]
"""

import argparse
import dataclasses
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_arch
from repro.configs.gnn_family import cfg_for_cell
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.train.optimizer import AdamWConfig, init_opt_state, make_train_step

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s64": 8,
                "u64": 8, "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s16": 2,
                "u16": 2, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f32|bf16|f16|f64|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum result-shape bytes of collective ops in post-SPMD HLO."""
    out = {k: {"count": 0, "bytes": 0.0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        # result side only: "%name = <shape(s)> <op>(" — find which op
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*?)\s+(all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)", line)
        if not m:
            continue
        shapes_str, op = m.group(1), m.group(2)
        nbytes = 0.0
        for dt, dims in _SHAPE_RE.findall(shapes_str):
            n = 1
            if dims:
                for d in dims.split(","):
                    if d:
                        n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[op]["count"] += 1
        out[op]["bytes"] += nbytes
    return {k: v for k, v in out.items() if v["count"]}


def _first(d):
    return d[0] if isinstance(d, (list, tuple)) else d


def memory_record(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
        ma = _first(ma)
        return {
            "argument_bytes": float(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": float(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": float(getattr(ma, "temp_size_in_bytes", 0)),
            "alias_bytes": float(getattr(ma, "alias_size_in_bytes", 0)),
            "peak_bytes": float(
                getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                + getattr(ma, "temp_size_in_bytes", 0)
                - getattr(ma, "alias_size_in_bytes", 0)),
        }
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def cost_record(compiled) -> Dict[str, float]:
    try:
        ca = _first(compiled.cost_analysis())
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and np.isfinite(v)}
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


# --------------------------------------------------------------------- #
def _first_dim_sharding(mesh: Mesh, leaf, preferred) -> NamedSharding:
    """Shard dim0 over the longest prefix of `preferred` it divides by."""
    dim0 = leaf.shape[0] if leaf.ndim else 1
    axes = tuple(preferred)
    while axes and dim0 % int(np.prod([mesh.shape[a] for a in axes])) != 0:
        axes = axes[:-1]
    spec = [axes if axes else None] + [None] * (leaf.ndim - 1)
    return NamedSharding(mesh, P(*spec))


def build_cell(arch_name: str, shape_name: str, mesh: Mesh,
               fsdp_mode: str = "auto", unroll: int = 1):
    """Returns (fn, arg_specs, in_shardings, out_shardings, meta)."""
    spec = get_arch(arch_name)
    cfg = spec.config
    if unroll != 1 and hasattr(cfg, "scan_unroll"):
        cfg = dataclasses.replace(cfg, scan_unroll=unroll)
    cell = spec.cells(cfg)[shape_name]
    dp = shd.data_axes(mesh)

    if spec.family == "lm":
        fsdp = (cfg.moe is not None) if fsdp_mode == "auto" else (fsdp_mode == "on")
        aparams = spec.abstract_params()
        p_sh = shd.lm_param_sharding(mesh, aparams, fsdp=fsdp)
        if cell.kind == "train":
            aopt = jax.eval_shape(init_opt_state, aparams)
            o_sh = shd.opt_state_sharding(p_sh)
            b_sh = {k: _first_dim_sharding(mesh, v, dp)
                    for k, v in cell.batch_specs.items()}
            step = make_train_step(lambda p, b: T.loss_fn(p, b, cfg))
            return (step, (aparams, aopt, cell.batch_specs),
                    (p_sh, o_sh, b_sh), (p_sh, o_sh, None),
                    {"fsdp": fsdp})
        if cell.note == "prefill":
            b = cell.batch_specs["tokens"]
            tok_sh = _first_dim_sharding(mesh, b, dp)
            fn = lambda p, t: T.prefill(p, t, cfg)
            return fn, (aparams, b), (p_sh, tok_sh), None, {"fsdp": fsdp}
        # decode
        batch = cell.batch_specs["tokens"].shape[0]
        seq = int(cell.note.split("=")[1])
        cache_spec = spec.cache_spec(cfg, batch, seq)
        long_ctx = batch == 1
        c_sh = shd.lm_cache_sharding(mesh, batch, long_context=long_ctx)
        tok_sh = (NamedSharding(mesh, P()) if long_ctx
                  else _first_dim_sharding(mesh, cell.batch_specs["tokens"], dp))
        fn = lambda p, c, t: T.decode_step(p, c, t, cfg)
        return (fn, (aparams, cache_spec, cell.batch_specs["tokens"]),
                (p_sh, c_sh, tok_sh), (None, c_sh), {"fsdp": fsdp})

    if spec.family == "gnn":
        ccfg = cfg_for_cell(cfg, shape_name)
        aparams = jax.eval_shape(lambda k: spec.init_fn(ccfg, k),
                                 jax.random.PRNGKey(0))
        p_sh = shd.gnn_param_sharding(mesh, aparams)
        all_axes = tuple(mesh.axis_names)
        b_sh = {k: _first_dim_sharding(mesh, v, all_axes)
                for k, v in cell.batch_specs.items()}
        aopt = jax.eval_shape(init_opt_state, aparams)
        o_sh = shd.opt_state_sharding(p_sh)
        step = make_train_step(lambda p, b: spec.loss_fn(p, ccfg, b))
        return (step, (aparams, aopt, cell.batch_specs),
                (p_sh, o_sh, b_sh), (p_sh, o_sh, None), {"cfg": ccfg.name})

    # recsys
    aparams = spec.abstract_params()
    p_sh = shd.recsys_param_sharding(mesh, aparams)
    rs = shd.recsys_batch_sharding(mesh)
    b_sh = {}
    for k, v in cell.batch_specs.items():
        if k == "cand_ids":
            b_sh[k] = NamedSharding(mesh, P("model"))
        else:
            b_sh[k] = _first_dim_sharding(mesh, v, dp)
    if cell.kind == "train":
        aopt = jax.eval_shape(init_opt_state, aparams)
        o_sh = shd.opt_state_sharding(p_sh)
        step = make_train_step(lambda p, b: spec.loss_fn(p, cfg, b))
        return (step, (aparams, aopt, cell.batch_specs),
                (p_sh, o_sh, b_sh), (p_sh, o_sh, None), {})
    fn = lambda p, b: spec.serve_fn(p, cfg, b)
    return fn, (aparams, cell.batch_specs), (p_sh, b_sh), None, {}


def run_cell(arch_name: str, shape_name: str, mesh: Mesh, mesh_name: str,
             fsdp_mode: str = "auto", unroll: int = 1) -> Dict[str, Any]:
    t0 = time.time()
    rec: Dict[str, Any] = {"arch": arch_name, "shape": shape_name,
                           "mesh": mesh_name, "unroll": unroll,
                           "n_devices": int(np.prod(list(mesh.shape.values())))}
    try:
        fn, args, in_sh, out_sh, meta = build_cell(arch_name, shape_name,
                                                   mesh, fsdp_mode, unroll)
        rec.update(meta if isinstance(meta, dict) else {})
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jitted.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        rec["memory"] = memory_record(compiled)
        rec["cost"] = cost_record(compiled)
        hlo = compiled.as_text()
        rec["collectives"] = collective_stats(hlo)
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)
        rec["ok"] = True
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default=None)
    ap.add_argument("--fsdp", default="auto", choices=["auto", "on", "off"])
    ap.add_argument("--unroll", type=int, default=1,
                    help="scan unroll for the two-point cost probe")
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    out_path = args.out or f"experiments/dryrun_{mesh_name}.jsonl"
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)

    cells = []
    for name, spec in ARCHS.items():
        if args.arch and name != args.arch:
            continue
        for shape_name in spec.cells(spec.config):
            if args.shape and shape_name != args.shape:
                continue
            cells.append((name, shape_name))

    n_ok = 0
    with open(out_path, "a") as fh:
        for arch_name, shape_name in cells:
            rec = run_cell(arch_name, shape_name, mesh, mesh_name, args.fsdp,
                           args.unroll)
            line = {k: v for k, v in rec.items() if k != "traceback"}
            fh.write(json.dumps(line) + "\n")
            fh.flush()
            status = "OK " if rec["ok"] else "FAIL"
            mem = rec.get("memory", {}).get("peak_bytes", 0) / 2**30
            fl = rec.get("cost", {}).get("flops", 0)
            print(f"[{status}] {arch_name:24s} {shape_name:16s} "
                  f"mem/dev={mem:7.2f}GiB flops/dev={fl:.3e} "
                  f"({rec['total_s']}s)", flush=True)
            if not rec["ok"]:
                print(rec["error"], flush=True)
            else:
                n_ok += 1
    print(f"\n{n_ok}/{len(cells)} cells compiled on {mesh_name}", flush=True)
    return 0 if n_ok == len(cells) else 1


if __name__ == "__main__":
    sys.exit(main())

"""Serving launcher: LM decode smoke or index-backed retrieval.

  PYTHONPATH=src python -m repro.launch.serve --mode lm --arch yi-9b
  PYTHONPATH=src python -m repro.launch.serve --mode retrieval --docs 1000
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch


def serve_lm(args):
    from repro.train.serve import LMServer
    spec = get_arch(args.arch)
    cfg = spec.smoke_config
    params = spec.init_fn(cfg, jax.random.PRNGKey(0))
    server = LMServer(params, cfg, max_slots=4, max_len=64)
    prompts = [[1, 5, 9], [2, 7], [3, 3, 3, 3], [4]]
    t0 = time.time()
    outs = server.generate(prompts, max_new=args.tokens)
    dt = time.time() - t0
    total = sum(len(o) for o in outs)
    print(f"decoded {total} tokens for {len(prompts)} sequences in {dt:.2f}s "
          f"({total / dt:.1f} tok/s, continuous batching)")
    for p, o in zip(prompts, outs):
        print(f"  prompt {p} -> {o[:8]}")


def serve_retrieval(args):
    from repro.core import DynamicIndex, Warren, ingest_documents
    from repro.data.synth import doc_generator
    from repro.train.serve import RetrievalServer
    if args.shards > 1:
        from repro.dist.shard_router import ShardedWarren
        warren = ShardedWarren(n_shards=args.shards,
                               async_scatter=args.async_scatter)
    else:
        warren = Warren(DynamicIndex())
    ingest_documents(warren, doc_generator(0, args.docs))
    server = RetrievalServer(warren, k=10)
    queries = ["vibration conductor", "school student", "stock money"] * 8
    t0 = time.time()
    handles = [server.batcher.submit(q) for q in queries]
    results = [h.get(timeout=60) for h in handles]
    dt = time.time() - t0
    print(f"served {len(queries)} queries in {dt:.2f}s "
          f"({1e3 * dt / len(queries):.2f} ms/query, micro-batched)")
    if args.shards > 1:
        print(f"sharded serving breakdown: {server.timing_summary()}")
    print(f"top-3 for {queries[0]!r}: {results[0][:3]}")
    server.close()
    if args.shards > 1:
        warren.close()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["lm", "retrieval"], default="lm")
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--docs", type=int, default=1000)
    ap.add_argument("--shards", type=int, default=1,
                    help="retrieval mode: serve a ShardedWarren natively")
    ap.add_argument("--async-scatter", action="store_true",
                    help="with --shards: pool-based per-group fan-out")
    args = ap.parse_args(argv)
    if args.mode == "lm":
        serve_lm(args)
    else:
        serve_retrieval(args)


if __name__ == "__main__":
    main()

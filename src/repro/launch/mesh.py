"""Production meshes.

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / CPU smoke runs)."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def make_mesh_from_sizes(sizes):
    """Mesh from an {axis: size} dict (the elastic-restart path: feed it
    the output of ``repro.dist.elastic.shrink_mesh`` after device loss)."""
    axes = tuple(sizes)
    return jax.make_mesh(tuple(sizes[a] for a in axes), axes)

"""Training launcher: --arch <id> on the local mesh (smoke scale on CPU;
the full configs are exercised through launch/dryrun.py).

  PYTHONPATH=src python -m repro.launch.train --arch sasrec --steps 20
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def batch_stream(spec, cfg):
    seed = 0
    while True:
        b = spec.smoke_batch(cfg, "train", seed=seed)
        yield {k: jnp.asarray(v) if not np.isscalar(v) else v
               for k, v in b.items()}
        seed += 1


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    cfg = spec.smoke_config
    params = spec.init_fn(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{args.arch} (smoke config): {n_params / 1e6:.2f}M params")

    tc = TrainerConfig(total_steps=args.steps,
                       ckpt_every=max(args.steps // 2, 1),
                       ckpt_dir=args.ckpt_dir,
                       log_every=max(args.steps // 5, 1),
                       opt=AdamWConfig(lr=1e-3, warmup_steps=5,
                                       total_steps=args.steps))
    trainer = Trainer(lambda p, b: spec.loss_fn(p, cfg, b), params, tc,
                      batch_stream(spec, cfg))
    t0 = time.time()
    out = trainer.train()
    dt = time.time() - t0
    for m in out["metrics"]:
        print(f"  step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"|g| {m['grad_norm']:.3f}")
    print(f"{out['step']} steps in {dt:.1f}s ({out['step'] / dt:.2f} steps/s)")


if __name__ == "__main__":
    main()

"""Lock-order analysis: the interprocedural acquisition graph.

Per function, a linear abstract scan tracks which lock classes are held
(``with``-statement nesting plus explicit ``.acquire()``/``.release()``
bookkeeping, including locks a helper *leaves held on return* — the
``_acquire_locks``/``_release_locks`` pattern).  Every acquisition event
and every call into another analyzed function is recorded with the
held-set at that point; a fixpoint over the call graph then expands
calls into edges ``held → may-acquire(callee)``.

On the resulting digraph of lock classes the checker reports:

* **cycles** — a potential deadlock, regardless of any declared order;
* **hierarchy violations** — an edge from a lower-ranked (inner) lock to
  a higher-ranked (outer) one per ``analysis/lock_hierarchy.toml``;
* **self-deadlocks** — re-acquiring a held non-reentrant single-instance
  lock;
* **unordered multi-acquires** — a loop acquiring an ``ascending``-class
  lock (many instances, group-write rule) without iterating a
  ``sorted(...)``/``range(...)`` sequence.

The static graph deliberately over-approximates reachability and
under-approximates aliasing; the runtime :class:`repro.obs.LockWitness`
covers the remainder from observed executions.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .callgraph import CallGraph, FuncInfo, infer_local_types
from .config import Hierarchy
from .findings import Finding
from .lockmap import LockMap, _dotted


# --------------------------------------------------------------------- #
# events
# --------------------------------------------------------------------- #
@dataclass
class AcqEvent:
    lock: str
    held: Tuple[str, ...]
    line: int
    loop: Optional[str] = None      # None | "sorted" | "unsorted"
    floating: bool = False          # bare .acquire(), not a with-block


@dataclass
class CallEvent:
    target: str                     # qualname
    held: Tuple[str, ...]
    line: int


@dataclass
class BlockEvent:
    call: str                       # dotted name, e.g. "os.fsync"
    held: Tuple[str, ...]
    line: int


# --------------------------------------------------------------------- #
# lock-expression resolution
# --------------------------------------------------------------------- #
def resolve_lock_expr(expr: ast.AST, cls: str, module: str,
                      lockmap: LockMap) -> Optional[str]:
    if isinstance(expr, ast.Attribute):
        if isinstance(expr.value, ast.Name) and expr.value.id == "self":
            got = lockmap.resolve_self_attr(cls, expr.attr)
            if got is not None:
                return got
        return lockmap.resolve_attr(expr.attr, module)
    if isinstance(expr, ast.Subscript):
        sl = expr.slice
        if isinstance(sl, ast.Constant) and isinstance(sl.value, str):
            return lockmap.resolve_key(sl.value)
    return None


def _iter_is_ordered(it: ast.AST) -> bool:
    """True when a loop iterates an inherently ordered sequence."""
    if isinstance(it, ast.Call) and isinstance(it.func, ast.Name):
        return it.func.id in ("sorted", "range", "enumerate", "reversed")
    return False


# --------------------------------------------------------------------- #
# the per-function scanner
# --------------------------------------------------------------------- #
class _FnScanner:
    def __init__(self, fi: FuncInfo, graph: CallGraph, lockmap: LockMap,
                 blocking: Set[str],
                 held_on_return: Dict[str, Tuple[str, ...]],
                 releases: Dict[str, Tuple[str, ...]]):
        self.fi = fi
        self.graph = graph
        self.lockmap = lockmap
        self.blocking = blocking
        self.H = held_on_return
        self.R = releases
        self.local_types = infer_local_types(fi.node, graph,
                                             fi.module, fi.cls)
        self.with_stack: List[str] = []
        self.floating: Dict[str, int] = {}
        self.foreign_releases: List[str] = []
        self.events: List[object] = []
        self.loop_ctx: List[str] = []       # "sorted"/"unsorted" markers

    # -- held-set ---------------------------------------------------------- #
    def _held(self) -> Tuple[str, ...]:
        seen, out = set(), []
        for name in self.with_stack + list(self.floating):
            if name not in seen:
                seen.add(name)
                out.append(name)
        return tuple(out)

    # -- entry ------------------------------------------------------------- #
    def scan(self) -> None:
        self._stmts(self.fi.node.body)

    def _stmts(self, body: Sequence[ast.stmt]) -> None:
        for stmt in body:
            self._stmt(stmt)

    def _stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.With):
            self._with(stmt)
        elif isinstance(stmt, ast.For):
            self._exprs(stmt.iter)
            marker = "sorted" if _iter_is_ordered(stmt.iter) else "unsorted"
            self.loop_ctx.append(marker)
            try:
                self._stmts(stmt.body)
                self._stmts(stmt.orelse)
            finally:
                self.loop_ctx.pop()
        elif isinstance(stmt, ast.While):
            self._exprs(stmt.test)
            self.loop_ctx.append("unsorted")
            try:
                self._stmts(stmt.body)
                self._stmts(stmt.orelse)
            finally:
                self.loop_ctx.pop()
        elif isinstance(stmt, ast.If):
            self._exprs(stmt.test)
            self._stmts(stmt.body)
            self._stmts(stmt.orelse)
        elif isinstance(stmt, ast.Try):
            self._stmts(stmt.body)
            for h in stmt.handlers:
                self._stmts(h.body)
            self._stmts(stmt.orelse)
            self._stmts(stmt.finalbody)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            pass        # nested defs are separate execution contexts
        else:
            for child in ast.iter_child_nodes(stmt):
                self._exprs(child)

    def _with(self, stmt: ast.With) -> None:
        pushed = 0
        try:
            for item in stmt.items:
                lock = resolve_lock_expr(item.context_expr, self.fi.cls,
                                         self.fi.module, self.lockmap)
                if lock is None:
                    self._exprs(item.context_expr)
                else:
                    self._acquire(lock, item.context_expr.lineno)
                    self.with_stack.append(lock)
                    pushed += 1
            self._stmts(stmt.body)
        finally:
            for _ in range(pushed):
                self.with_stack.pop()

    # -- expression walking ------------------------------------------------ #
    def _exprs(self, node: Optional[ast.AST]) -> None:
        if node is None:
            return
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                self._call(sub)

    def _call(self, call: ast.Call) -> None:
        fn = call.func
        # explicit lock protocol: <lockexpr>.acquire() / .release()
        if isinstance(fn, ast.Attribute) and fn.attr in ("acquire",
                                                         "release"):
            lock = resolve_lock_expr(fn.value, self.fi.cls,
                                     self.fi.module, self.lockmap)
            if lock is not None:
                if fn.attr == "acquire":
                    self._acquire(lock, call.lineno, floating=True)
                    self.floating[lock] = self.floating.get(lock, 0) + 1
                else:
                    if self.floating.get(lock, 0) > 0:
                        self.floating[lock] -= 1
                        if not self.floating[lock]:
                            del self.floating[lock]
                    else:
                        self.foreign_releases.append(lock)
                return
        # blocking call?
        path = _dotted(fn)
        if path is not None and (path in self.blocking
                                 or path.rsplit(".", 1)[-1] in self.blocking):
            self.events.append(BlockEvent(call=path, held=self._held(),
                                          line=call.lineno))
        # pool fan-out heuristic: .map/.submit on something pool-like
        if (isinstance(fn, ast.Attribute) and fn.attr in ("map", "submit")
                and "pool" in ast.dump(fn.value).lower()):
            self.events.append(BlockEvent(call=f"<pool>.{fn.attr}",
                                          held=self._held(),
                                          line=call.lineno))
        # call into an analyzed function
        target = self.graph.resolve_call(call, self.fi.module, self.fi.cls,
                                         self.local_types)
        if target is not None and target != self.fi.qualname:
            self.events.append(CallEvent(target=target, held=self._held(),
                                         line=call.lineno))
            for a in self.H.get(target, ()):
                self.floating[a] = self.floating.get(a, 0) + 1
            for a in self.R.get(target, ()):
                if self.floating.get(a, 0) > 0:
                    self.floating[a] -= 1
                    if not self.floating[a]:
                        del self.floating[a]

    def _acquire(self, lock: str, line: int, floating: bool = False) -> None:
        loop = self.loop_ctx[-1] if self.loop_ctx else None
        self.events.append(AcqEvent(lock=lock, held=self._held(),
                                    line=line, loop=loop,
                                    floating=floating))


# --------------------------------------------------------------------- #
# the interprocedural pass
# --------------------------------------------------------------------- #
@dataclass
class Edge:
    src: str
    dst: str
    provenance: List[str] = field(default_factory=list)


@dataclass
class LockOrderResult:
    edges: Dict[Tuple[str, str], Edge] = field(default_factory=dict)
    acquires: Dict[str, Set[str]] = field(default_factory=dict)   # A(f)
    events: Dict[str, List[object]] = field(default_factory=dict)
    findings: List[Finding] = field(default_factory=list)


def _scan_all(graph: CallGraph, lockmap: LockMap, blocking: Set[str],
              rounds: int = 4) -> Tuple[Dict[str, List[object]],
                                        Dict[str, Tuple[str, ...]]]:
    """Fixpoint the held-on-return / releases maps, then return events."""
    H: Dict[str, Tuple[str, ...]] = {}
    R: Dict[str, Tuple[str, ...]] = {}
    events: Dict[str, List[object]] = {}
    opaque = graph.lock_like_classes()
    for _ in range(rounds):
        new_H: Dict[str, Tuple[str, ...]] = {}
        new_R: Dict[str, Tuple[str, ...]] = {}
        for qual, fi in graph.functions.items():
            if fi.cls in opaque:
                events[qual] = []
                continue
            sc = _FnScanner(fi, graph, lockmap, blocking, H, R)
            sc.scan()
            events[qual] = sc.events
            if sc.floating:
                new_H[qual] = tuple(sc.floating)
            if sc.foreign_releases:
                new_R[qual] = tuple(dict.fromkeys(sc.foreign_releases))
        if new_H == H and new_R == R:
            break
        H, R = new_H, new_R
    return events, H


def _fixpoint_acquires(graph: CallGraph,
                       events: Dict[str, List[object]]
                       ) -> Dict[str, Set[str]]:
    A: Dict[str, Set[str]] = {q: set() for q in graph.functions}
    for qual, evs in events.items():
        for ev in evs:
            if isinstance(ev, AcqEvent):
                A[qual].add(ev.lock)
    changed = True
    while changed:
        changed = False
        for qual, evs in events.items():
            for ev in evs:
                if isinstance(ev, CallEvent):
                    extra = A.get(ev.target, set()) - A[qual]
                    if extra:
                        A[qual] |= extra
                        changed = True
    return A


def _shortest_cycle(edges: Dict[Tuple[str, str], Edge],
                    start: str) -> Optional[List[str]]:
    """BFS for the shortest cycle through ``start``."""
    adj: Dict[str, List[str]] = {}
    for (a, b) in edges:
        adj.setdefault(a, []).append(b)
    frontier = [[start]]
    seen = set()
    while frontier:
        nxt = []
        for path in frontier:
            for b in adj.get(path[-1], []):
                if b == start:
                    return path + [b]
                if b not in seen:
                    seen.add(b)
                    nxt.append(path + [b])
        frontier = nxt
    return None


def analyze_lock_order(graph: CallGraph, lockmap: LockMap,
                       hierarchy: Hierarchy,
                       blocking: Set[str]) -> LockOrderResult:
    res = LockOrderResult()
    events, _ = _scan_all(graph, lockmap, blocking)
    res.events = events
    A = _fixpoint_acquires(graph, events)
    res.acquires = A

    def is_reentrant(name: str) -> bool:
        d = lockmap.locks.get(name)
        return (d is not None and d.reentrant) \
            or hierarchy.multi(name) == "reentrant"

    def add_edge(a: str, b: str, prov: str) -> None:
        e = res.edges.get((a, b))
        if e is None:
            e = res.edges[(a, b)] = Edge(src=a, dst=b)
        if len(e.provenance) < 3 and prov not in e.provenance:
            e.provenance.append(prov)

    seen_self: Set[Tuple[str, str]] = set()
    seen_loop: Set[Tuple[str, str]] = set()
    for qual, evs in events.items():
        fi = graph.functions[qual]
        for ev in evs:
            if isinstance(ev, AcqEvent):
                prov = f"{fi.module}:{ev.line} ({qual.split('::')[-1]})"
                for h in ev.held:
                    if h == ev.lock:
                        if (hierarchy.multi(h) == "ascending"
                                or is_reentrant(h)):
                            continue
                        key = (qual, h)
                        if key not in seen_self:
                            seen_self.add(key)
                            res.findings.append(Finding(
                                kind="self-deadlock",
                                id=f"self-deadlock:{h}:{qual.split('::')[-1]}",
                                message=(f"non-reentrant lock {h!r} "
                                         f"re-acquired while already held "
                                         f"at {prov}"),
                                module=fi.module, line=ev.line))
                    else:
                        add_edge(h, ev.lock, prov)
                # only *accumulating* loop acquires can violate the
                # ascending rule — a per-iteration `with` releases before
                # the next instance is taken
                if (ev.loop == "unsorted" and ev.floating
                        and hierarchy.multi(ev.lock) == "ascending"):
                    key = (qual, ev.lock)
                    if key not in seen_loop:
                        seen_loop.add(key)
                        res.findings.append(Finding(
                            kind="unordered-multi-acquire",
                            id=(f"unordered-multi-acquire:{ev.lock}:"
                                f"{qual.split('::')[-1]}"),
                            message=(f"{ev.lock!r} instances acquired in a "
                                     f"loop whose iteration order is not "
                                     f"sorted at {prov} — the "
                                     f"ascending-order rule cannot hold"),
                            module=fi.module, line=ev.line))
            elif isinstance(ev, CallEvent):
                if not ev.held:
                    continue
                prov = (f"{fi.module}:{ev.line} "
                        f"({qual.split('::')[-1]} → "
                        f"{ev.target.split('::')[-1]})")
                for a in A.get(ev.target, ()):
                    for h in ev.held:
                        if h == a:
                            continue
                        add_edge(h, a, prov)

    # hierarchy violations
    for (a, b), edge in sorted(res.edges.items()):
        ra, rb = hierarchy.rank(a), hierarchy.rank(b)
        if ra is None or rb is None or ra <= rb:
            continue
        module, line = "", 0
        if edge.provenance:
            mod_line = edge.provenance[0].split(" ")[0]
            module, _, lineno = mod_line.rpartition(":")
            if lineno.isdigit():
                line = int(lineno)
        res.findings.append(Finding(
            kind="lock-hierarchy",
            id=f"lock-hierarchy:{a}->{b}",
            message=(f"declared order puts {b!r} (rank {rb}) above "
                     f"{a!r} (rank {ra}), but {b!r} is acquired while "
                     f"{a!r} is held: " + "; ".join(edge.provenance)),
            module=module, line=line))

    # cycles (excluding self-loops, reported above)
    in_cycle_reported: Set[str] = set()
    for node in sorted({a for a, _ in res.edges}):
        if node in in_cycle_reported:
            continue
        cyc = _shortest_cycle(res.edges, node)
        if cyc is None:
            continue
        # normalize: rotate so the lexicographically smallest lock leads
        body = cyc[:-1]
        k = body.index(min(body))
        norm = body[k:] + body[:k] + [body[k]]
        in_cycle_reported.update(body)
        cyc_id = "->".join(norm)
        provs = []
        for a, b in zip(norm, norm[1:]):
            e = res.edges.get((a, b))
            if e is not None and e.provenance:
                provs.append(f"{a}→{b} at {e.provenance[0]}")
        res.findings.append(Finding(
            kind="lock-cycle",
            id=f"lock-cycle:{cyc_id}",
            message=("potential deadlock: lock classes form a cycle "
                     + " ; ".join(provs)),
            module="", line=0))
    return res

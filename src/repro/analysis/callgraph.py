"""Function index + call resolution for the interprocedural passes.

Resolution is deliberately cheap and honest: a call edge is added only
when the target is *unambiguous* —

1. ``self.m(...)``        → method ``m`` of the enclosing class
2. ``f(...)``             → function ``f`` of the same module, else the
                            unique function of that name anywhere
3. ``self.attr.m(...)``   → method ``m`` of the type constructed into
                            ``self.attr`` (constructor-assignment type
                            inference from :mod:`lockmap`)
4. ``<var>.m(...)``       → method ``m`` of the type a local
                            ``var = SomeClass(...)`` assignment gives
5. ``<anything>.m(...)``  → the unique method named ``m`` in the whole
                            analyzed tree, unless ``m`` collides with a
                            common builtin-container method name

Anything still ambiguous resolves to nothing: the analyzer would rather
miss an edge than invent one (missed edges are the runtime witness's
job to catch; invented edges would drown the report in false cycles).
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .lockmap import LockMap, _dotted

# method names too generic to resolve by uniqueness — they collide with
# list/dict/set/str/queue/file methods and would wire the graph to noise
_GENERIC_METHODS = frozenset({
    "append", "add", "get", "put", "pop", "items", "keys", "values",
    "sort", "join", "split", "update", "extend", "remove", "clear",
    "copy", "index", "count", "insert", "read", "write", "close",
    "open", "flush", "seek", "send", "recv", "start", "stop", "run",
    "result", "set", "wait", "map", "submit", "acquire", "release",
    "setdefault", "format", "strip", "encode", "decode", "search",
    "match", "group", "commit", "abort", "snapshot", "reset",
})


@dataclass
class FuncInfo:
    qualname: str                      # "path.py::Class.meth" or "path.py::fn"
    module: str
    cls: str                           # "" for module-level functions
    name: str
    node: ast.AST
    line: int = 0
    # filled by the scanning passes (lockorder/blocking)
    events: list = field(default_factory=list)


class CallGraph:
    def __init__(self, modules: Dict[str, ast.Module], lockmap: LockMap):
        self.modules = modules
        self.lockmap = lockmap
        self.functions: Dict[str, FuncInfo] = {}
        # name -> [qualname]  (module-level functions)
        self._globals_by_module: Dict[Tuple[str, str], str] = {}
        self._globals_by_name: Dict[str, List[str]] = {}
        # (cls, meth) -> qualname ; meth -> [qualname]
        self._methods: Dict[Tuple[str, str], str] = {}
        self._methods_by_name: Dict[str, List[str]] = {}
        self._index()

    # -- indexing ---------------------------------------------------------- #
    def _index(self) -> None:
        for module, tree in self.modules.items():
            for node in tree.body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    self._add_func(module, "", node)
                elif isinstance(node, ast.ClassDef):
                    for sub in node.body:
                        if isinstance(sub, (ast.FunctionDef,
                                            ast.AsyncFunctionDef)):
                            self._add_func(module, node.name, sub)

    def _add_func(self, module: str, cls: str,
                  node: ast.FunctionDef) -> None:
        qual = (f"{module}::{cls}.{node.name}" if cls
                else f"{module}::{node.name}")
        fi = FuncInfo(qualname=qual, module=module, cls=cls,
                      name=node.name, node=node, line=node.lineno)
        self.functions[qual] = fi
        if cls:
            self._methods.setdefault((cls, node.name), qual)
            self._methods_by_name.setdefault(node.name, []).append(qual)
        else:
            self._globals_by_module.setdefault((module, node.name), qual)
            self._globals_by_name.setdefault(node.name, []).append(qual)

    # -- receiver typing --------------------------------------------------- #
    def _attr_type(self, cls: str, attr: str, module: str) -> Optional[str]:
        t = self.lockmap.attr_types.get((cls, attr))
        if t is not None:
            return t
        pairs = self.lockmap.attr_types_by_attr.get(attr, [])
        types = {t for _, t in pairs}
        if len(types) == 1:
            return next(iter(types))
        return None

    def resolve_call(self, call: ast.Call, module: str, cls: str,
                     local_types: Dict[str, str]) -> Optional[str]:
        fn = call.func
        # f(...) — plain name
        if isinstance(fn, ast.Name):
            got = self._globals_by_module.get((module, fn.id))
            if got is not None:
                return got
            cands = self._globals_by_name.get(fn.id, [])
            if len(cands) == 1:
                return cands[0]
            return None
        if not isinstance(fn, ast.Attribute):
            return None
        meth = fn.attr
        recv = fn.value
        # self.m(...)
        if isinstance(recv, ast.Name) and recv.id == "self" and cls:
            got = self._methods.get((cls, meth))
            if got is not None:
                return got
        # typed receivers
        recv_type: Optional[str] = None
        if isinstance(recv, ast.Name):
            recv_type = local_types.get(recv.id)
        elif isinstance(recv, ast.Attribute):
            base = recv.value
            if isinstance(base, ast.Name) and base.id == "self" and cls:
                recv_type = self._attr_type(cls, recv.attr, module)
            else:
                recv_type = self._attr_type("", recv.attr, module)
        elif isinstance(recv, ast.Call):
            # registry().counter(...) style: type = callee's return class
            path = _dotted(recv.func)
            if path is not None:
                tail = path.rsplit(".", 1)[-1]
                recv_type = self._return_type(tail)
        if recv_type is not None:
            got = self._methods.get((recv_type, meth))
            if got is not None:
                return got
        # unique-method fallback
        if meth not in _GENERIC_METHODS:
            cands = self._methods_by_name.get(meth, [])
            if len(cands) == 1:
                return cands[0]
        return None

    def lock_like_classes(self) -> set:
        """Classes that implement the lock protocol themselves
        (``acquire`` + ``release`` + ``__enter__``).  Their *internals*
        are the lock implementation, not client acquisition order, and
        are skipped by the lock-order scanner — a ProfiledLock timing a
        contended acquire is not a client re-acquiring a held lock."""
        out = set()
        for (cls, meth) in self._methods:
            if meth == "acquire" and (cls, "release") in self._methods \
                    and (cls, "__enter__") in self._methods:
                out.add(cls)
        return out

    def _return_type(self, func_name: str) -> Optional[str]:
        """Return-annotation type of the unique global ``func_name``."""
        cands = self._globals_by_name.get(func_name, [])
        if len(cands) != 1:
            return None
        node = self.functions[cands[0]].node
        ret = getattr(node, "returns", None)
        if isinstance(ret, ast.Name):
            return ret.id
        if isinstance(ret, ast.Constant) and isinstance(ret.value, str):
            return ret.value.strip('"\'')
        if isinstance(ret, ast.Attribute):
            return ret.attr
        return None


def infer_local_types(fn_node: ast.AST, graph: "CallGraph",
                      module: str, cls: str) -> Dict[str, str]:
    """``var = SomeClass(...)`` / ``var = registry()`` → {var: TypeName}.

    One linear pass; last assignment wins.  Also follows
    ``var = self.attr`` through the constructor-assignment type map.
    """
    out: Dict[str, str] = {}
    for stmt in ast.walk(fn_node):
        if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
            continue
        tgt = stmt.targets[0]
        if not isinstance(tgt, ast.Name):
            continue
        val = stmt.value
        if isinstance(val, ast.Call):
            path = _dotted(val.func)
            if path is None:
                continue
            tail = path.rsplit(".", 1)[-1]
            if tail and tail[0].isupper():
                out[tgt.id] = tail
            else:
                ret = graph._return_type(tail)
                if ret is not None:
                    out[tgt.id] = ret
        elif isinstance(val, ast.Attribute):
            base = val.value
            if isinstance(base, ast.Name) and base.id == "self" and cls:
                t = graph._attr_type(cls, val.attr, module)
                if t is not None:
                    out[tgt.id] = t
    return out

"""Lock discovery: find every lock object the tree constructs.

Scans class bodies (``__init__`` and every other method) for

* ``self.x = threading.Lock() / RLock() / Condition()``
* ``self.x = obs.ProfiledLock("name", ...)`` — the profiled name becomes
  the lock's canonical identity, shared across instances (every
  ``ReplicaGroup.write_lock`` is one ``group_write`` lock class)
* dict-literal values holding locks with constant string keys
  (``self._ctx = {"rebalance_lock": obs.ProfiledLock("rebalance")}``),
  so subscript acquisitions (``with w._ctx["rebalance_lock"]``) resolve

and records, as a side product, attribute *types* from
``self.x = SomeClass(...)`` constructor assignments — the cheap type
inference the call-graph resolver runs on.

Identity model: one :class:`LockDef` per *lock class*, not per instance.
A plain lock is named ``Class.attr``; a ProfiledLock is named by its
profile string.  Acquisition sites resolve ``recv.attr`` by (class,
attr) when the receiver is ``self``, else by attribute-name uniqueness
with a same-module preference (see :meth:`LockMap.resolve_attr`).
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_LOCK_CTORS = {"Lock": "lock", "RLock": "rlock", "Condition": "condition"}


@dataclass
class LockDef:
    name: str           # canonical lock-class name
    kind: str           # "lock" | "rlock" | "condition" | "profiled"
    module: str         # repo-relative path
    cls: str            # owning class ("" for module-level)
    attr: str           # attribute or dict key it is stored under
    line: int = 0
    reentrant: bool = False

    def __repr__(self) -> str:            # pragma: no cover
        return f"LockDef({self.name!r} {self.kind} @ {self.module}:{self.line})"


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def classify_lock_ctor(call: ast.Call) -> Optional[Tuple[str, Optional[str]]]:
    """(kind, profiled_name) when ``call`` constructs a lock, else None."""
    path = _dotted(call.func)
    if path is None:
        return None
    tail = path.rsplit(".", 1)[-1]
    if tail in _LOCK_CTORS and path in (tail, f"threading.{tail}"):
        return _LOCK_CTORS[tail], None
    if tail == "ProfiledLock":
        pname: Optional[str] = None
        if call.args and isinstance(call.args[0], ast.Constant) \
                and isinstance(call.args[0].value, str):
            pname = call.args[0].value
        return "profiled", pname
    return None


def profiled_wraps_rlock(call: ast.Call) -> bool:
    """True when a ProfiledLock ctor call wraps an RLock."""
    for arg in list(call.args[1:]) + [kw.value for kw in call.keywords]:
        if isinstance(arg, ast.Call):
            got = classify_lock_ctor(arg)
            if got is not None and got[0] == "rlock":
                return True
    return False


@dataclass
class LockMap:
    # canonical name -> LockDef
    locks: Dict[str, LockDef] = field(default_factory=dict)
    # (cls, attr) -> canonical name
    by_class_attr: Dict[Tuple[str, str], str] = field(default_factory=dict)
    # attr -> [(module, canonical name)]
    by_attr: Dict[str, List[Tuple[str, str]]] = field(default_factory=dict)
    # dict-literal key -> canonical name
    by_key: Dict[str, str] = field(default_factory=dict)
    # (cls, attr) -> constructed type name   (cheap type inference)
    attr_types: Dict[Tuple[str, str], str] = field(default_factory=dict)
    # attr -> [(cls, type)] across all classes
    attr_types_by_attr: Dict[str, List[Tuple[str, str]]] = \
        field(default_factory=dict)

    # -- registration ------------------------------------------------------ #
    def _add(self, d: LockDef) -> None:
        prior = self.locks.get(d.name)
        if prior is None:
            self.locks[d.name] = d
        elif prior.kind != d.kind and d.kind == "rlock":
            prior.reentrant = True
        if d.attr:
            self.by_class_attr.setdefault((d.cls, d.attr), d.name)
            pairs = self.by_attr.setdefault(d.attr, [])
            if (d.module, d.name) not in pairs:
                pairs.append((d.module, d.name))

    def _add_type(self, cls: str, attr: str, type_name: str) -> None:
        self.attr_types.setdefault((cls, attr), type_name)
        pairs = self.attr_types_by_attr.setdefault(attr, [])
        if (cls, type_name) not in pairs:
            pairs.append((cls, type_name))

    # -- resolution -------------------------------------------------------- #
    def resolve_self_attr(self, cls: str, attr: str) -> Optional[str]:
        return self.by_class_attr.get((cls, attr))

    def resolve_attr(self, attr: str, module: str = "") -> Optional[str]:
        """Resolve ``<expr>.attr`` by attribute-name uniqueness; when the
        attr is defined in several classes, prefer the current module's
        definition; still-ambiguous resolutions return None (the scanner
        skips rather than invents edges)."""
        pairs = self.by_attr.get(attr)
        if not pairs:
            return None
        names = {n for _, n in pairs}
        if len(names) == 1:
            return next(iter(names))
        local = {n for m, n in pairs if m == module}
        if len(local) == 1:
            return next(iter(local))
        return None

    def resolve_key(self, key: str) -> Optional[str]:
        return self.by_key.get(key)


def _scan_assign_value(lm: LockMap, module: str, cls: str, attr: str,
                       value: ast.AST, line: int) -> None:
    if isinstance(value, ast.Call):
        got = classify_lock_ctor(value)
        if got is not None:
            kind, pname = got
            if kind == "profiled":
                name = pname or f"{cls}.{attr}" or attr
                lm._add(LockDef(name=name, kind="profiled", module=module,
                                cls=cls, attr=attr, line=line,
                                reentrant=profiled_wraps_rlock(value)))
            else:
                name = f"{cls}.{attr}" if cls else attr
                lm._add(LockDef(name=name, kind=kind, module=module,
                                cls=cls, attr=attr, line=line,
                                reentrant=(kind == "rlock")))
            return
        # plain constructor → attribute type
        path = _dotted(value.func)
        if path is not None:
            type_name = path.rsplit(".", 1)[-1]
            if type_name and type_name[0].isupper():
                lm._add_type(cls, attr, type_name)
    elif isinstance(value, ast.Dict):
        for k, v in zip(value.keys, value.values):
            if (isinstance(k, ast.Constant) and isinstance(k.value, str)
                    and isinstance(v, ast.Call)):
                got = classify_lock_ctor(v)
                if got is None:
                    continue
                kind, pname = got
                name = pname or f"{cls}.{k.value}"
                lm._add(LockDef(name=name, kind="profiled"
                                if kind == "profiled" else kind,
                                module=module, cls=cls, attr="",
                                line=v.lineno,
                                reentrant=(kind == "rlock"
                                           or (kind == "profiled"
                                               and profiled_wraps_rlock(v)))))
                lm.by_key.setdefault(k.value, name)


def build_lockmap(modules: Dict[str, ast.Module]) -> LockMap:
    """Scan every parsed module (repo-relative path → AST)."""
    lm = LockMap()
    for module, tree in modules.items():
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            cls = node.name
            for fn in node.body:
                if not isinstance(fn, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    continue
                for stmt in ast.walk(fn):
                    if not isinstance(stmt, ast.Assign):
                        continue
                    for tgt in stmt.targets:
                        if (isinstance(tgt, ast.Attribute)
                                and isinstance(tgt.value, ast.Name)
                                and tgt.value.id == "self"):
                            _scan_assign_value(lm, module, cls, tgt.attr,
                                               stmt.value, stmt.lineno)
    return lm

"""Findings and the justification-required suppression file.

Every check emits :class:`Finding` records with a *stable id* — the
suppression key.  ``analysis/suppressions.toml`` maps exact ids to
one-line justifications; there are deliberately no wildcard or
per-file blanket ignores, so every intentional violation in the tree is
individually visible and carries its reason next to it.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from . import toml_lite


@dataclass
class Finding:
    kind: str           # e.g. "lock-cycle", "blocking-under-lock"
    id: str             # stable suppression key
    message: str        # human explanation with provenance
    module: str = ""    # repo-relative path of the principal site
    line: int = 0
    severity: str = "error"     # "error" | "warning"

    def format(self) -> str:
        loc = f"{self.module}:{self.line}" if self.module else "<global>"
        return f"[{self.kind}] {loc}\n  id: {self.id}\n  {self.message}"


class SuppressionError(ValueError):
    pass


@dataclass
class Suppressions:
    """Exact-id suppression set, each entry with a required reason."""

    entries: Dict[str, str] = field(default_factory=dict)

    @classmethod
    def load(cls, path: Optional[str]) -> "Suppressions":
        if path is None:
            return cls()
        doc = toml_lite.load(path)
        entries: Dict[str, str] = {}
        for item in doc.get("suppress", []):
            sid = item.get("id", "")
            reason = str(item.get("reason", "")).strip()
            if not sid:
                raise SuppressionError("suppression entry without an id")
            if not reason:
                raise SuppressionError(
                    f"suppression {sid!r} has no justification — every "
                    "suppressed finding must say why it is intentional")
            if "*" in sid or sid.endswith(":"):
                raise SuppressionError(
                    f"suppression {sid!r} looks like a blanket ignore; "
                    "only exact finding ids are accepted")
            if sid in entries:
                raise SuppressionError(f"duplicate suppression {sid!r}")
            entries[sid] = reason
        return cls(entries)

    def split(self, findings: List[Finding]
              ) -> Tuple[List[Finding], List[Tuple[Finding, str]], List[str]]:
        """Partition into (active, suppressed-with-reason, unused-ids)."""
        active: List[Finding] = []
        suppressed: List[Tuple[Finding, str]] = []
        used = set()
        for f in findings:
            reason = self.entries.get(f.id)
            if reason is not None:
                suppressed.append((f, reason))
                used.add(f.id)
            else:
                active.append(f)
        unused = sorted(set(self.entries) - used)
        return active, suppressed, unused

"""repro.analysis — concurrency contract checker for the warren.

Static companion to the runtime :class:`repro.obs.LockWitness`:

* lockdep-style lock-order analysis over the interprocedural
  acquisition graph (cycles, declared-hierarchy violations,
  self-deadlocks, unordered ascending multi-acquires)
* blocking-call-under-hot-lock detection (fsync/file I/O/pool fan-out
  while a request-path lock is held)
* contract lints tying code to ``docs/architecture.md`` (metric names
  and label sets, hot-path ``registry().enabled`` guards, span names)

Run as ``python -m repro.analysis src/``.  Exit is nonzero iff any
finding is not suppressed (with justification) in
``analysis/suppressions.toml``.
"""

from .blocking import DEFAULT_BLOCKING, analyze_blocking, blocking_set
from .callgraph import CallGraph
from .config import Catalog, Hierarchy, LockLevel
from .contracts import analyze_contracts
from .driver import AnalysisReport, main, run_analysis
from .findings import Finding, Suppressions, SuppressionError
from .lockmap import LockDef, LockMap, build_lockmap
from .lockorder import LockOrderResult, analyze_lock_order

__all__ = [
    "AnalysisReport", "CallGraph", "Catalog", "DEFAULT_BLOCKING",
    "Finding", "Hierarchy", "LockDef", "LockLevel", "LockMap",
    "LockOrderResult", "Suppressions", "SuppressionError",
    "analyze_blocking", "analyze_contracts", "analyze_lock_order",
    "blocking_set", "build_lockmap", "main", "run_analysis",
]

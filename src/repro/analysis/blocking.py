"""Blocking-call-under-hot-lock detection.

A *blocking call* is anything that can stall a thread for an unbounded
or I/O-bound time: ``os.fsync``, file opens/renames, subprocess spawns,
``time.sleep``, socket connects, pool fan-outs.  Holding a **hot** lock
(per ``analysis/lock_hierarchy.toml``) across one serializes the warren
write path behind disk or network latency.

The detector combines the per-function event streams from
:mod:`lockorder` (which already tag blocking sites with the held-set at
that point) with a transitive may-block summary ``B(f)``: a call into
``commit`` while ``group_write`` is held inherits commit's WAL fsync.

Findings dedup to one per ``(hot lock, blocking call, function holding
the lock)`` so the suppression file stays reviewable; each carries the
call chain as provenance.
"""

from __future__ import annotations

from typing import Dict, List, Set, Tuple

from .callgraph import CallGraph
from .config import Hierarchy
from .findings import Finding
from .lockorder import BlockEvent, CallEvent

# Default dotted names treated as blocking.  Matched on the full dotted
# path *or* its final component, so both ``os.fsync`` and a bare
# ``fsync`` import hit.  Extended by ``[blocking].calls`` in the
# hierarchy file.
DEFAULT_BLOCKING: Set[str] = {
    "os.fsync", "fsync", "os.fdatasync",
    "time.sleep", "sleep",
    "open", "os.open",
    "os.replace", "os.rename", "os.remove", "os.unlink",
    "shutil.copytree", "shutil.rmtree", "shutil.move", "shutil.copyfile",
    "subprocess.run", "subprocess.Popen", "subprocess.check_output",
    "socket.create_connection",
    "urlopen", "requests.get", "requests.post",
}


def blocking_set(hierarchy: Hierarchy) -> Set[str]:
    return DEFAULT_BLOCKING | set(hierarchy.blocking_calls)


# B(f): blocking call name -> (line of first local site/call, chain)
_Summary = Dict[str, Tuple[int, Tuple[str, ...]]]


def _summaries(graph: CallGraph,
               events: Dict[str, List[object]]) -> Dict[str, _Summary]:
    B: Dict[str, _Summary] = {q: {} for q in graph.functions}
    for qual, evs in events.items():
        for ev in evs:
            if isinstance(ev, BlockEvent):
                B[qual].setdefault(ev.call, (ev.line, ()))
    changed = True
    while changed:
        changed = False
        for qual, evs in events.items():
            for ev in evs:
                if not isinstance(ev, CallEvent):
                    continue
                for call, (_, chain) in B.get(ev.target, {}).items():
                    if call not in B[qual] and len(chain) < 6:
                        tgt = ev.target.split("::")[-1]
                        B[qual][call] = (ev.line, (tgt,) + chain)
                        changed = True
    return B


def analyze_blocking(graph: CallGraph, events: Dict[str, List[object]],
                     hierarchy: Hierarchy) -> List[Finding]:
    B = _summaries(graph, events)
    findings: List[Finding] = []
    seen: Set[Tuple[str, str, str]] = set()

    def emit(lock: str, call: str, qual: str, line: int,
             chain: Tuple[str, ...]) -> None:
        fi = graph.functions[qual]
        fn = qual.split("::")[-1]
        key = (lock, call, fn)
        if key in seen:
            return
        seen.add(key)
        via = " via " + " → ".join(chain) if chain else ""
        findings.append(Finding(
            kind="blocking-under-lock",
            id=f"blocking-under-lock:{lock}:{fn}:{call}",
            message=(f"blocking call {call!r} reachable while hot lock "
                     f"{lock!r} is held in {fn} "
                     f"({fi.module}:{line}){via}"),
            module=fi.module, line=line))

    for qual, evs in events.items():
        for ev in evs:
            if isinstance(ev, BlockEvent):
                for lock in ev.held:
                    if hierarchy.is_hot(lock):
                        emit(lock, ev.call, qual, ev.line, ())
            elif isinstance(ev, CallEvent) and ev.held:
                hot = [h for h in ev.held if hierarchy.is_hot(h)]
                if not hot:
                    continue
                for call, (_, chain) in B.get(ev.target, {}).items():
                    tgt = ev.target.split("::")[-1]
                    for lock in hot:
                        emit(lock, call, qual, ev.line, (tgt,) + chain)
    return findings

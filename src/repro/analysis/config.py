"""Analysis configuration: the declared lock hierarchy + the doc catalog.

Two sources of truth feed the checker:

* ``analysis/lock_hierarchy.toml`` — the canonical lock hierarchy
  (rank-ordered lock levels, which locks are hot, which lock classes
  have many instances and a legal same-class acquisition order), plus
  the blocking-call list for the blocking-under-lock detector.
* ``docs/architecture.md`` — the metric catalog and span catalog tables
  (§6 Observability).  The contract lints parse the *documentation*, so
  an undocumented metric or span is a finding: the docs stay complete
  by construction.
"""

from __future__ import annotations

import os
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from . import toml_lite


# --------------------------------------------------------------------- #
# lock hierarchy
# --------------------------------------------------------------------- #
@dataclass
class LockLevel:
    name: str
    rank: int
    hot: bool = False
    # "none"      — single instance, nested same-class acquire is a bug
    # "reentrant" — RLock semantics: same-instance re-acquire is legal
    # "ascending" — many instances, must be acquired in ascending
    #               order-key order (the group-write rule)
    multi: str = "none"


@dataclass
class Hierarchy:
    levels: Dict[str, LockLevel] = field(default_factory=dict)
    blocking_calls: List[str] = field(default_factory=list)

    def rank(self, name: str) -> Optional[int]:
        lvl = self.levels.get(name)
        return None if lvl is None else lvl.rank

    def is_hot(self, name: str) -> bool:
        lvl = self.levels.get(name)
        return lvl is not None and lvl.hot

    def multi(self, name: str) -> str:
        lvl = self.levels.get(name)
        return "none" if lvl is None else lvl.multi

    def ordered(self) -> List[LockLevel]:
        return sorted(self.levels.values(), key=lambda l: l.rank)

    @classmethod
    def load(cls, path: Optional[str]) -> "Hierarchy":
        if path is None:
            return cls()
        doc = toml_lite.load(path)
        levels: Dict[str, LockLevel] = {}
        for name, spec in doc.get("locks", {}).items():
            if not isinstance(spec, dict) or "rank" not in spec:
                raise ValueError(f"lock level {name!r} needs a rank")
            multi = str(spec.get("multi", "none"))
            if multi not in ("none", "reentrant", "ascending"):
                raise ValueError(f"lock level {name!r}: bad multi={multi!r}")
            levels[name] = LockLevel(
                name=name, rank=int(spec["rank"]),
                hot=bool(spec.get("hot", False)), multi=multi)
        ranks: Dict[int, str] = {}
        for lvl in levels.values():
            if lvl.rank in ranks:
                raise ValueError(
                    f"lock levels {ranks[lvl.rank]!r} and {lvl.name!r} "
                    f"share rank {lvl.rank} — the hierarchy must be a "
                    "total order over declared locks")
            ranks[lvl.rank] = lvl.name
        blocking = [str(c) for c in
                    doc.get("blocking", {}).get("calls", [])]
        return cls(levels=levels, blocking_calls=blocking)


# --------------------------------------------------------------------- #
# doc catalog (metrics + spans) parsed from architecture.md
# --------------------------------------------------------------------- #
_BACKTICK = re.compile(r"`([^`]+)`")
_PAREN = re.compile(r"\([^)]*\)")


@dataclass
class Catalog:
    metrics: Dict[str, Set[str]] = field(default_factory=dict)  # name→labels
    spans: Set[str] = field(default_factory=set)

    @classmethod
    def load(cls, path: Optional[str]) -> "Catalog":
        if path is None or not os.path.exists(path):
            return cls()
        with open(path, "r", encoding="utf-8") as fh:
            text = fh.read()
        return cls.parse(text)

    @classmethod
    def parse(cls, text: str) -> "Catalog":
        metrics: Dict[str, Set[str]] = {}
        spans: Set[str] = set()
        mode = None
        for line in text.splitlines():
            stripped = line.strip()
            if not stripped.startswith("|"):
                mode = None
                continue
            cells = [c.strip() for c in stripped.strip("|").split("|")]
            if not cells:
                continue
            head = cells[0].lower()
            if head == "metric":
                mode = "metrics"
                continue
            if head == "span":
                mode = "spans"
                continue
            if set(cells[0]) <= {"-", ":", " "}:    # separator row
                continue
            if mode == "metrics" and len(cells) >= 3:
                names = _BACKTICK.findall(cells[0])
                label_cell = _PAREN.sub("", cells[2])
                labels = set(_BACKTICK.findall(label_cell))
                for name in names:
                    metrics[name.strip()] = labels
            elif mode == "spans":
                for name in _BACKTICK.findall(cells[0]):
                    spans.add(name.strip())
        return cls(metrics=metrics, spans=spans)


# --------------------------------------------------------------------- #
# config discovery
# --------------------------------------------------------------------- #
def find_repo_root(start: str) -> Optional[str]:
    """Walk up from ``start`` to the directory holding ``analysis/`` (or
    ``pyproject.toml``) — where the default config files live."""
    cur = os.path.abspath(start)
    if os.path.isfile(cur):
        cur = os.path.dirname(cur)
    while True:
        if (os.path.isdir(os.path.join(cur, "analysis"))
                or os.path.isfile(os.path.join(cur, "pyproject.toml"))):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            return None
        cur = parent


def default_paths(root: Optional[str]) -> Tuple[Optional[str], Optional[str],
                                                Optional[str]]:
    """(hierarchy, suppressions, catalog) paths under ``root`` that exist."""
    if root is None:
        return None, None, None

    def opt(*parts: str) -> Optional[str]:
        p = os.path.join(root, *parts)
        return p if os.path.exists(p) else None

    return (opt("analysis", "lock_hierarchy.toml"),
            opt("analysis", "suppressions.toml"),
            opt("docs", "architecture.md"))

"""Pass orchestration + report for ``python -m repro.analysis``.

``run_analysis`` parses every ``.py`` under the given paths, builds the
lock map and call graph once, runs the three passes (lock order,
blocking-under-lock, contracts), then filters through the
justification-required suppression file.  Exit is nonzero iff any
*unsuppressed* finding remains — the CI gate.
"""

from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .blocking import analyze_blocking, blocking_set
from .callgraph import CallGraph
from .config import Catalog, Hierarchy, default_paths, find_repo_root
from .contracts import analyze_contracts
from .findings import Finding, Suppressions
from .lockmap import build_lockmap
from .lockorder import LockOrderResult, analyze_lock_order


@dataclass
class AnalysisReport:
    findings: List[Finding] = field(default_factory=list)       # all
    active: List[Finding] = field(default_factory=list)         # unsuppressed
    suppressed: List[Tuple[Finding, str]] = field(default_factory=list)
    unused_suppressions: List[str] = field(default_factory=list)
    lock_order: Optional[LockOrderResult] = None
    modules: List[str] = field(default_factory=list)

    @property
    def exit_code(self) -> int:
        return 1 if self.active else 0

    def render(self, verbose: bool = False) -> str:
        lines: List[str] = []
        for f in self.active:
            lines.append(f.format())
        if self.suppressed and verbose:
            lines.append(f"-- {len(self.suppressed)} suppressed:")
            for f, reason in self.suppressed:
                lines.append(f"   {f.id}  ({reason})")
        for sid in self.unused_suppressions:
            lines.append(f"warning: suppression {sid!r} matched nothing "
                         "(stale entry?)")
        n_edges = len(self.lock_order.edges) if self.lock_order else 0
        lines.append(
            f"repro.analysis: {len(self.modules)} modules, "
            f"{n_edges} lock-order edges, "
            f"{len(self.findings)} findings "
            f"({len(self.active)} active, {len(self.suppressed)} "
            f"suppressed)")
        return "\n".join(lines)

    def to_json(self) -> dict:
        def fd(f: Finding) -> dict:
            return {"kind": f.kind, "id": f.id, "message": f.message,
                    "module": f.module, "line": f.line}
        return {
            "modules": len(self.modules),
            "edges": sorted(f"{a}->{b}" for a, b in
                            (self.lock_order.edges if self.lock_order
                             else {})),
            "active": [fd(f) for f in self.active],
            "suppressed": [{**fd(f), "reason": r}
                           for f, r in self.suppressed],
            "unused_suppressions": self.unused_suppressions,
        }


def collect_sources(paths: List[str], root: str) -> Dict[str, str]:
    """{repo-relative module path: absolute file path} for every .py."""
    out: Dict[str, str] = {}
    for p in paths:
        p = os.path.abspath(p)
        if os.path.isfile(p):
            out[os.path.relpath(p, root)] = p
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = [d for d in dirnames
                           if d != "__pycache__" and not d.startswith(".")]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    full = os.path.join(dirpath, fn)
                    out[os.path.relpath(full, root)] = full
    return out


def parse_modules(sources: Dict[str, str]) -> Dict[str, ast.Module]:
    modules: Dict[str, ast.Module] = {}
    for rel, full in sorted(sources.items()):
        with open(full, "r", encoding="utf-8") as fh:
            text = fh.read()
        modules[rel.replace("\\", "/")] = ast.parse(text, filename=full)
    return modules


def run_analysis(paths: List[str],
                 hierarchy_path: Optional[str] = None,
                 suppressions_path: Optional[str] = None,
                 catalog_path: Optional[str] = None,
                 use_defaults: bool = True) -> AnalysisReport:
    root = find_repo_root(paths[0] if paths else os.getcwd()) or os.getcwd()
    if use_defaults:
        dh, ds, dc = default_paths(root)
        hierarchy_path = hierarchy_path or dh
        suppressions_path = suppressions_path or ds
        catalog_path = catalog_path or dc

    hierarchy = Hierarchy.load(hierarchy_path)
    suppressions = Suppressions.load(suppressions_path)
    catalog = Catalog.load(catalog_path)

    modules = parse_modules(collect_sources(paths, root))
    lockmap = build_lockmap(modules)
    graph = CallGraph(modules, lockmap)

    lo = analyze_lock_order(graph, lockmap, hierarchy,
                            blocking_set(hierarchy))
    findings = list(lo.findings)
    findings += analyze_blocking(graph, lo.events, hierarchy)
    findings += analyze_contracts(graph, catalog)
    findings.sort(key=lambda f: (f.kind, f.module, f.line, f.id))

    active, suppressed, unused = suppressions.split(findings)
    return AnalysisReport(findings=findings, active=active,
                          suppressed=suppressed,
                          unused_suppressions=unused,
                          lock_order=lo,
                          modules=sorted(modules))


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    import json

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="concurrency contract checker: lock-order analysis, "
                    "blocking-under-lock detection, metric/span lints")
    ap.add_argument("paths", nargs="+", help="files or directories to scan")
    ap.add_argument("--hierarchy", help="lock_hierarchy.toml "
                    "(default: <root>/analysis/lock_hierarchy.toml)")
    ap.add_argument("--suppressions", help="suppressions.toml "
                    "(default: <root>/analysis/suppressions.toml)")
    ap.add_argument("--catalog", help="architecture.md with metric/span "
                    "catalog tables (default: <root>/docs/architecture.md)")
    ap.add_argument("--no-defaults", action="store_true",
                    help="do not auto-discover config files")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit the report as JSON")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="also list suppressed findings")
    args = ap.parse_args(argv)

    report = run_analysis(args.paths,
                          hierarchy_path=args.hierarchy,
                          suppressions_path=args.suppressions,
                          catalog_path=args.catalog,
                          use_defaults=not args.no_defaults)
    if args.as_json:
        print(json.dumps(report.to_json(), indent=2, sort_keys=True))
    else:
        print(report.render(verbose=args.verbose))
    return report.exit_code

"""A small TOML-subset reader for the analysis config files.

The container pins Python 3.10 (no stdlib ``tomllib``) and the repo adds
no third-party deps, so the two analysis config files —
``analysis/lock_hierarchy.toml`` and ``analysis/suppressions.toml`` —
are parsed by this deliberately small reader.  Supported subset:

* ``[section]``, ``[a.b]``, ``[a."quoted name"]`` tables
* ``[[name]]`` arrays of tables
* ``key = value`` with string / int / float / bool / array-of-scalars
  values (arrays may span multiple lines)
* ``#`` comments and blank lines

That covers everything the checker needs while staying honest: a
construct outside the subset raises ``TomlError`` instead of silently
misparsing.
"""

from __future__ import annotations

import re
from typing import Any, Dict, List, Tuple


class TomlError(ValueError):
    pass


_KEY_RE = re.compile(r'^([A-Za-z0-9_\-]+|"[^"]*")\s*=\s*(.*)$')


def _parse_key(raw: str) -> str:
    raw = raw.strip()
    if raw.startswith('"') and raw.endswith('"'):
        return raw[1:-1]
    return raw


def _split_dotted(header: str) -> List[str]:
    """Split ``a.b."c.d"`` into ['a', 'b', 'c.d']."""
    parts, buf, i, n = [], "", 0, len(header)
    while i < n:
        c = header[i]
        if c == '"':
            j = header.index('"', i + 1)
            buf += header[i + 1:j]
            i = j + 1
        elif c == ".":
            parts.append(buf.strip())
            buf = ""
            i += 1
        else:
            buf += c
            i += 1
    parts.append(buf.strip())
    if any(not p for p in parts):
        raise TomlError(f"bad table header: {header!r}")
    return parts


def _strip_comment(line: str) -> str:
    out, in_str = [], False
    for c in line:
        if c == '"':
            in_str = not in_str
        if c == "#" and not in_str:
            break
        out.append(c)
    return "".join(out).rstrip()


def _parse_scalar(tok: str) -> Any:
    tok = tok.strip()
    if tok.startswith('"') and tok.endswith('"') and len(tok) >= 2:
        return tok[1:-1].replace('\\"', '"').replace("\\\\", "\\")
    if tok in ("true", "false"):
        return tok == "true"
    try:
        return int(tok)
    except ValueError:
        pass
    try:
        return float(tok)
    except ValueError:
        pass
    raise TomlError(f"unsupported value: {tok!r}")


def _split_array_items(body: str) -> List[str]:
    items, buf, in_str = [], "", False
    for c in body:
        if c == '"':
            in_str = not in_str
            buf += c
        elif c == "," and not in_str:
            if buf.strip():
                items.append(buf.strip())
            buf = ""
        else:
            buf += c
    if buf.strip():
        items.append(buf.strip())
    return items


def _parse_value(tok: str) -> Any:
    tok = tok.strip()
    if tok.startswith("["):
        if not tok.endswith("]"):
            raise TomlError(f"unterminated array: {tok!r}")
        return [_parse_scalar(t) for t in _split_array_items(tok[1:-1])]
    return _parse_scalar(tok)


def loads(text: str) -> Dict[str, Any]:
    root: Dict[str, Any] = {}
    current = root
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        line = _strip_comment(lines[i]).strip()
        i += 1
        if not line:
            continue
        if line.startswith("[["):
            if not line.endswith("]]"):
                raise TomlError(f"bad array-of-tables header: {line!r}")
            path = _split_dotted(line[2:-2])
            node = root
            for p in path[:-1]:
                node = node.setdefault(p, {})
            arr = node.setdefault(path[-1], [])
            if not isinstance(arr, list):
                raise TomlError(f"{'.'.join(path)} is not an array of tables")
            current = {}
            arr.append(current)
            continue
        if line.startswith("["):
            if not line.endswith("]"):
                raise TomlError(f"bad table header: {line!r}")
            path = _split_dotted(line[1:-1])
            node = root
            for p in path:
                nxt = node.setdefault(p, {})
                if not isinstance(nxt, dict):
                    raise TomlError(f"table {p!r} collides with a value")
                node = nxt
            current = node
            continue
        m = _KEY_RE.match(line)
        if m is None:
            raise TomlError(f"cannot parse line: {line!r}")
        key, val = _parse_key(m.group(1)), m.group(2).strip()
        # multi-line array: keep consuming until brackets balance
        while val.startswith("[") and not val.endswith("]"):
            if i >= len(lines):
                raise TomlError(f"unterminated array for key {key!r}")
            val += " " + _strip_comment(lines[i]).strip()
            i += 1
        current[key] = _parse_value(val)
    return root


def load(path: str) -> Dict[str, Any]:
    with open(path, "r", encoding="utf-8") as fh:
        return loads(fh.read())

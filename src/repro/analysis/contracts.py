"""Contract lints: code ↔ documentation ↔ hot-path discipline.

Three checks over every analyzed module:

* **undeclared-metric / metric-labels** — every
  ``registry().counter/gauge/histogram(name, ...)`` site with a constant
  name must use a metric name from the ``docs/architecture.md`` catalog,
  with exactly the documented label set.  The docs are the schema; an
  undocumented metric is a finding, so the catalog stays complete by
  construction.  Dynamic names are skipped (nothing to check
  statically).
* **unguarded-metric** — in hot-path modules, metric mutation sites must
  be guarded on ``registry().enabled`` (directly in an enclosing ``if``,
  via an early ``if not reg.enabled: return``, or through a local
  variable derived from ``.enabled``).  Constructors (``__init__``) are
  exempt: family pre-creation is one-time work.
* **undeclared-span** — every ``obs.span("name", ...)`` constant name
  must appear in the span catalog table.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from .callgraph import CallGraph, infer_local_types
from .config import Catalog
from .findings import Finding
from .lockmap import _dotted

_METRIC_METHODS = {"counter", "gauge", "histogram"}
_CONFIG_KWARGS = {
    "counter": {"help"},
    "gauge": {"help"},
    "histogram": {"help", "lo", "hi", "per_decade"},
}

# modules where metric mutation sits on the request path — guard required
HOT_MODULES = (
    "core/index.py",
    "dist/shard_router.py",
    "dist/parallel.py",
    "train/serve.py",
)


def _is_hot_module(module: str) -> bool:
    m = module.replace("\\", "/")
    return any(m.endswith(h) for h in HOT_MODULES)


def _is_metric_site(call: ast.Call, graph: CallGraph, module: str,
                    cls: str, local_types: Dict[str, str]) -> Optional[str]:
    """The accessor name ('counter'/...) when ``call`` hits the registry."""
    fn = call.func
    if not isinstance(fn, ast.Attribute) or fn.attr not in _METRIC_METHODS:
        return None
    target = graph.resolve_call(call, module, cls, local_types)
    if target is not None and target.endswith(f"::MetricsRegistry.{fn.attr}"):
        return fn.attr
    # textual fallback for trees analyzed without the obs package
    # (test fixtures): obs.registry().counter(...), reg.counter(...)
    recv = ast.unparse(fn.value).lower()
    if "registry" in recv or recv in ("reg", "self._reg", "self._registry"):
        return fn.attr
    return None


def _const_name(call: ast.Call) -> Optional[str]:
    if call.args and isinstance(call.args[0], ast.Constant) \
            and isinstance(call.args[0].value, str):
        return call.args[0].value
    return None


def _guard_vars(fn_node: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for stmt in ast.walk(fn_node):
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            if ".enabled" in ast.unparse(stmt.value):
                out.add(stmt.targets[0].id)
    return out


def _is_guard_test(test: ast.expr, guard_vars: Set[str]) -> bool:
    if ".enabled" in ast.unparse(test):
        return True
    return any(isinstance(n, ast.Name) and n.id in guard_vars
               for n in ast.walk(test))


def _terminates(body: List[ast.stmt]) -> bool:
    return bool(body) and isinstance(
        body[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))


class _Collector:
    """Walks one function, tagging metric/span call sites with whether a
    ``registry().enabled`` guard dominates them."""

    def __init__(self, guard_vars: Set[str]):
        self.guard_vars = guard_vars
        # (call node, guarded?)
        self.sites: List = []

    def walk(self, body: List[ast.stmt], guarded: bool) -> None:
        i = 0
        while i < len(body):
            stmt = body[i]
            if isinstance(stmt, ast.If):
                self._calls(stmt.test, guarded)
                if _is_guard_test(stmt.test, self.guard_vars):
                    if _terminates(stmt.body):
                        # `if not reg.enabled: return` — dominates the rest
                        self.walk(stmt.body, guarded)
                        self.walk(stmt.orelse, True)
                        self.walk(body[i + 1:], True)
                        return
                    self.walk(stmt.body, True)
                    self.walk(stmt.orelse, guarded)
                else:
                    self.walk(stmt.body, guarded)
                    self.walk(stmt.orelse, guarded)
            elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                self._calls(stmt.iter, guarded)
                self.walk(stmt.body, guarded)
                self.walk(stmt.orelse, guarded)
            elif isinstance(stmt, ast.While):
                self._calls(stmt.test, guarded)
                self.walk(stmt.body, guarded)
                self.walk(stmt.orelse, guarded)
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    self._calls(item.context_expr, guarded)
                self.walk(stmt.body, guarded)
            elif isinstance(stmt, ast.Try):
                self.walk(stmt.body, guarded)
                for h in stmt.handlers:
                    self.walk(h.body, guarded)
                self.walk(stmt.orelse, guarded)
                self.walk(stmt.finalbody, guarded)
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef)):
                pass
            else:
                self._calls(stmt, guarded)
            i += 1

    def _calls(self, node: ast.AST, guarded: bool) -> None:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                self.sites.append((sub, guarded))


def analyze_contracts(graph: CallGraph, catalog: Catalog) -> List[Finding]:
    findings: List[Finding] = []
    seen: Set[str] = set()

    def emit(kind: str, fid: str, message: str, module: str,
             line: int) -> None:
        if fid in seen:
            return
        seen.add(fid)
        findings.append(Finding(kind=kind, id=fid, message=message,
                                module=module, line=line))

    for qual, fi in graph.functions.items():
        in_obs = "/obs/" in fi.module.replace("\\", "/")
        local_types = infer_local_types(fi.node, graph, fi.module, fi.cls)
        coll = _Collector(_guard_vars(fi.node))
        coll.walk(fi.node.body, False)
        fn_name = qual.split("::")[-1]
        for call, guarded in coll.sites:
            path = _dotted(call.func)
            # spans -------------------------------------------------- #
            if (path is not None and path.rsplit(".", 1)[-1] == "span"
                    and not in_obs and catalog.spans):
                name = _const_name(call)
                if name is not None and name not in catalog.spans:
                    emit("undeclared-span", f"undeclared-span:{name}",
                         f"span {name!r} at {fi.module}:{call.lineno} is "
                         f"not in the span catalog "
                         f"(docs/architecture.md §6)",
                         fi.module, call.lineno)
                continue
            # metrics ------------------------------------------------ #
            accessor = _is_metric_site(call, graph, fi.module, fi.cls,
                                       local_types)
            if accessor is None:
                continue
            name = _const_name(call)
            if name is None:
                continue        # dynamic name — witness territory
            if catalog.metrics:
                if name not in catalog.metrics:
                    emit("undeclared-metric", f"undeclared-metric:{name}",
                         f"metric {name!r} at {fi.module}:{call.lineno} "
                         f"is not in the metric catalog "
                         f"(docs/architecture.md §6)",
                         fi.module, call.lineno)
                else:
                    kwargs = {kw.arg for kw in call.keywords
                              if kw.arg is not None}
                    dynamic = any(kw.arg is None for kw in call.keywords)
                    labels = kwargs - _CONFIG_KWARGS[accessor]
                    want = catalog.metrics[name]
                    if not dynamic and labels != want:
                        emit("metric-labels",
                             f"metric-labels:{name}:{fn_name}",
                             f"metric {name!r} at "
                             f"{fi.module}:{call.lineno} uses labels "
                             f"{sorted(labels)} but the catalog declares "
                             f"{sorted(want)}",
                             fi.module, call.lineno)
            # hot-path guard ----------------------------------------- #
            if (_is_hot_module(fi.module) and not in_obs
                    and not guarded and fi.name != "__init__"):
                emit("unguarded-metric",
                     f"unguarded-metric:{name}:{fn_name}",
                     f"hot-path metric site {name!r} at "
                     f"{fi.module}:{call.lineno} ({fn_name}) is not "
                     f"guarded on registry().enabled — disabled-telemetry "
                     f"runs still pay the family lookup",
                     fi.module, call.lineno)
    return findings

"""Index-backed training data pipeline (the paper's RAG-ingestion scenario
as the LM input path; DESIGN §4).

Stage 1 (ingest):  append documents, annotate ':' extents.
Stage 2 (dedup):   content-hash duplicates marked with 'dup:' annotations —
                   written *after* ingestion, in separate transactions, which
                   is precisely what annotative indexing enables.
Stage 3 (segment): fixed-window/stride segmentation recorded as 'seg:'
                   annotations over the content (window/stride in tokens,
                   like the MS MARCO segmentation in the paper's intro).

The loader walks 'seg:' extents via τ, hydrates token spans with
Snapshot.tokens, hashes words to ids, and emits deterministic, resumable
batches (iterator state = (segment cursor, epoch) — checkpointable).
"""

from __future__ import annotations

import hashlib
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core import Warren, index_document
from repro.core.featurizer import murmur64a

SEG_FEATURE = "seg:"
DUP_FEATURE = "dup:"


def ingest(warren: Warren, docs, batch_docs: int = 64) -> int:
    """Stage 1: one transaction per batch of documents."""
    n = 0
    it = iter(docs)
    done = False
    while not done:
        with warren:
            warren.transaction()
            wrote = 0
            for _ in range(batch_docs):
                try:
                    docid, text = next(it)
                except StopIteration:
                    done = True
                    break
                index_document(warren, text, docid=docid)
                wrote += 1
                n += 1
            if wrote:
                warren.commit()
            else:
                warren.abort()
    return n


def mark_duplicates(warren: Warren) -> int:
    """Stage 2: annotate exact-duplicate documents (keep first)."""
    seen: Dict[str, int] = {}
    dups: List[Tuple[int, int]] = []
    with warren:
        docs = warren.annotations(":")
        for p, q, _ in docs:
            text = warren.translate(int(p), int(q))
            h = hashlib.sha1(text.encode()).hexdigest()
            if h in seen:
                dups.append((int(p), int(q)))
            else:
                seen[h] = int(p)
    if dups:
        with warren:
            warren.transaction()
            for p, q in dups:
                warren.annotate(DUP_FEATURE, p, q)
            warren.commit()
    return len(dups)


def segment(warren: Warren, window: int = 128, stride: int = 64) -> int:
    """Stage 3: sliding-window segmentation as annotations (value=index)."""
    n = 0
    with warren:
        docs = warren.annotations(":")
        dups = warren.annotations(DUP_FEATURE)
        dup_starts = set(int(s) for s in dups.starts)
        warren.transaction()
        for p, q, _ in docs:
            p, q = int(p), int(q)
            if p in dup_starts:
                continue
            i = 0
            while True:
                lo = p + i * stride
                hi = min(lo + window - 1, q)
                if lo > q:
                    break
                warren.annotate(SEG_FEATURE, lo, hi, float(i))
                n += 1
                if hi == q:
                    break
                i += 1
        warren.commit()
    return n


def token_id(word: str, vocab: int) -> int:
    return int(murmur64a(word.encode()) % (vocab - 2)) + 2  # 0=pad, 1=bos


class IndexedCorpusLoader:
    """Deterministic, resumable batches from 'seg:' extents."""

    def __init__(self, warren: Warren, vocab: int, batch: int, seq_len: int,
                 seed: int = 0):
        self.warren = warren
        self.vocab = vocab
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        with warren:
            segs = warren.annotations(SEG_FEATURE)
            self.extents = [(int(p), int(q)) for p, q, _ in segs]
        if not self.extents:
            raise ValueError("no segments; run pipeline stages first")
        self.order = np.random.default_rng(seed).permutation(len(self.extents))
        self.cursor = 0
        self.epoch = 0

    def state(self) -> Dict[str, int]:
        return {"cursor": self.cursor, "epoch": self.epoch}

    def restore(self, state: Dict[str, int]) -> None:
        self.cursor = int(state["cursor"])
        self.epoch = int(state["epoch"])
        self.order = np.random.default_rng(self.seed + self.epoch
                                           ).permutation(len(self.extents))

    def _segment_tokens(self, p: int, q: int) -> List[int]:
        with self.warren:
            toks = self.warren.tokens(p, q)
        toks = toks or []
        return [token_id(t, self.vocab) for t in toks]

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        out = np.zeros((self.batch, self.seq_len + 1), np.int32)
        for b in range(self.batch):
            if self.cursor >= len(self.order):
                self.epoch += 1
                self.cursor = 0
                self.order = np.random.default_rng(self.seed + self.epoch
                                                   ).permutation(len(self.extents))
            p, q = self.extents[self.order[self.cursor]]
            self.cursor += 1
            ids = [1] + self._segment_tokens(p, q)[: self.seq_len]
            out[b, :len(ids)] = ids
        return {"tokens": out[:, :-1], "labels": out[:, 1:].astype(np.int32),
                "_state": self.state()}

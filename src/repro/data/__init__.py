from . import synth

__all__ = ["synth"]

"""Synthetic data generators: token batches, graphs, recsys logs, JSON corpora.

Offline container — no MS MARCO / TREC / Criteo; these generators produce
schema- and skew-matched stand-ins (DESIGN §9.3).  All are seeded and
deterministic (fault-tolerance tests rely on bitwise-reproducible batches).
"""

from __future__ import annotations

import hashlib
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

_WORDS = """time year people way day man thing woman life child world school
state family student group country problem hand part place case week company
system program question work government number night point home water room
mother area money story fact month lot right study book eye job word business
issue side kind head house service friend father power hour game line end
member law car city community name president team minute idea body
information back parent face others level office door health person art war
history party result change morning reason research girl guy moment air
teacher force education vibration transmission conductor aeolian wind
frequency damping resonance amplitude""".split()


def doc_generator(seed: int, n_docs: int, mean_len: int = 80) -> Iterator[Tuple[str, str]]:
    """Yields (docid, text) with Zipfian vocabulary (TREC-like)."""
    rng = np.random.default_rng(seed)
    probs = 1.0 / np.arange(1, len(_WORDS) + 1) ** 1.1
    probs /= probs.sum()
    for i in range(n_docs):
        n = max(8, int(rng.normal(mean_len, mean_len / 3)))
        words = rng.choice(_WORDS, size=n, p=probs)
        yield f"doc{seed}_{i}", " ".join(words)


def token_batches(seed: int, vocab: int, batch: int, seq_len: int,
                  start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Deterministic LM batches; resumable from any step (ckpt restart)."""
    step = start_step
    while True:
        rng = np.random.default_rng(hash((seed, step)) % 2**32)
        toks = rng.integers(0, vocab, size=(batch, seq_len + 1), dtype=np.int32)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:].astype(np.int32),
               "step": step}
        step += 1


# ------------------------------------------------------------------ #
# graphs
# ------------------------------------------------------------------ #
def random_graph(seed: int, n_nodes: int, n_edges: int, d_feat: int = 0,
                 n_classes: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    senders = rng.integers(0, n_nodes, size=n_edges, dtype=np.int32)
    receivers = rng.integers(0, n_nodes, size=n_edges, dtype=np.int32)
    out = {
        "positions": rng.standard_normal((n_nodes, 3)).astype(np.float32) * 3,
        "species": rng.integers(0, 16, size=n_nodes, dtype=np.int32),
        "senders": senders, "receivers": receivers,
    }
    if d_feat:
        out["node_feats"] = (rng.standard_normal((n_nodes, d_feat)) < -1
                             ).astype(np.float32)  # sparse binary features
    if n_classes:
        out["labels"] = rng.integers(0, n_classes, size=n_nodes, dtype=np.int32)
        out["label_mask"] = np.ones(n_nodes, np.float32)
    return out


def molecule_batch(seed: int, batch: int = 128, n_nodes: int = 30,
                   n_edges: int = 64) -> Dict[str, np.ndarray]:
    """Batched small molecules with energies/forces (padded batching)."""
    rng = np.random.default_rng(seed)
    N, E = batch * n_nodes, batch * n_edges
    pos = rng.standard_normal((N, 3)).astype(np.float32)
    senders = np.concatenate([
        rng.integers(0, n_nodes, n_edges) + g * n_nodes for g in range(batch)
    ]).astype(np.int32)
    receivers = np.concatenate([
        rng.integers(0, n_nodes, n_edges) + g * n_nodes for g in range(batch)
    ]).astype(np.int32)
    return {
        "positions": pos,
        "species": rng.integers(0, 16, size=N, dtype=np.int32),
        "senders": senders, "receivers": receivers,
        "graph_ids": np.repeat(np.arange(batch), n_nodes).astype(np.int32),
        "n_graphs": batch,
        "energies": rng.standard_normal(batch).astype(np.float32),
        "forces": rng.standard_normal((N, 3)).astype(np.float32) * 0.1,
    }


class NeighborSampler:
    """Real fanout sampler over a CSR adjacency (minibatch_lg shape).

    GraphSAGE-style layered sampling: seed nodes, then `fanout[i]` neighbors
    per node per hop, with padding by self-loops when degree is short."""

    def __init__(self, n_nodes: int, senders: np.ndarray, receivers: np.ndarray):
        order = np.argsort(receivers, kind="stable")
        self.dst_sorted = receivers[order]
        self.src_sorted = senders[order]
        self.indptr = np.searchsorted(self.dst_sorted, np.arange(n_nodes + 1))
        self.n_nodes = n_nodes

    def sample(self, seed_nodes: np.ndarray, fanouts: List[int],
               rng: np.random.Generator) -> Dict[str, np.ndarray]:
        layers = [seed_nodes.astype(np.int32)]
        all_src, all_dst = [], []
        frontier = seed_nodes
        for f in fanouts:
            lo = self.indptr[frontier]
            deg = self.indptr[frontier + 1] - lo
            # sample f neighbors per frontier node (with replacement; self-
            # loop when isolated)
            r = rng.integers(0, np.maximum(deg, 1)[:, None],
                             size=(len(frontier), f))
            src = np.where(deg[:, None] > 0,
                           self.src_sorted[np.minimum(lo[:, None] + r,
                                                      len(self.src_sorted) - 1)],
                           frontier[:, None])
            dst = np.broadcast_to(frontier[:, None], src.shape)
            all_src.append(src.reshape(-1))
            all_dst.append(dst.reshape(-1))
            frontier = np.unique(src)
            layers.append(frontier.astype(np.int32))
        nodes = np.unique(np.concatenate(layers))
        remap = {int(n): i for i, n in enumerate(nodes)}
        lut = np.zeros(self.n_nodes, np.int32)
        lut[nodes] = np.arange(len(nodes), dtype=np.int32)
        senders = lut[np.concatenate(all_src)]
        receivers = lut[np.concatenate(all_dst)]
        return {"nodes": nodes.astype(np.int32), "senders": senders,
                "receivers": receivers,
                "seed_local": lut[seed_nodes.astype(np.int64)]}


# ------------------------------------------------------------------ #
# recsys
# ------------------------------------------------------------------ #
def dlrm_batch(seed: int, batch: int, n_dense=13, n_sparse=26,
               vocab=1_000_000) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    return {
        "dense": rng.standard_normal((batch, n_dense)).astype(np.float32),
        "sparse": (rng.zipf(1.2, size=(batch, n_sparse)) % vocab).astype(np.int32),
        "labels": (rng.random(batch) < 0.25).astype(np.float32),
    }


def xdeepfm_batch(seed: int, batch: int, n_sparse=39,
                  vocab=100_000) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    return {
        "sparse": (rng.zipf(1.2, size=(batch, n_sparse)) % vocab).astype(np.int32),
        "labels": (rng.random(batch) < 0.2).astype(np.float32),
    }


def twotower_batch(seed: int, batch: int, n_users=2_000_000, n_items=1_000_000,
                   hist_len=8) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    item_ids = (rng.zipf(1.2, size=batch) % n_items).astype(np.int32)
    freq = np.maximum(1.0 / (1.0 + item_ids), 1e-9)
    return {
        "user_ids": rng.integers(0, n_users, batch).astype(np.int32),
        "hist_ids": (rng.zipf(1.3, size=(batch, hist_len)) % n_items).astype(np.int32),
        "hist_w": (rng.random((batch, hist_len)) < 0.9).astype(np.float32),
        "item_ids": item_ids,
        "logq": np.log(freq).astype(np.float32),
    }


def sasrec_batch(seed: int, batch: int, seq_len=50,
                 n_items=1_000_000) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    seq = (rng.zipf(1.3, size=(batch, seq_len)) % n_items).astype(np.int32)
    # zero-pad prefixes of random length
    lens = rng.integers(3, seq_len + 1, batch)
    mask = np.arange(seq_len)[None, :] >= (seq_len - lens[:, None])
    seq = np.where(mask, np.maximum(seq, 1), 0).astype(np.int32)
    pos = np.roll(seq, -1, axis=1)
    pos[:, -1] = np.maximum(rng.integers(1, n_items, batch), 1)
    pos = np.where(seq != 0, pos, 0).astype(np.int32)
    neg = np.where(seq != 0, (rng.zipf(1.3, size=(batch, seq_len)) % n_items)
                   .astype(np.int32), 0)
    return {"item_seq": seq, "pos_items": pos,
            "neg_items": np.maximum(neg, 1) * (seq != 0)}


# ------------------------------------------------------------------ #
# heterogeneous JSON collections (paper Fig. 5 analogue)
# ------------------------------------------------------------------ #
def json_collection(seed: int = 0, scale: float = 1.0) -> Dict[str, list]:
    """Schema-heterogeneous JSON subcollections matching Fig. 5's shapes."""
    rng = np.random.default_rng(seed)
    cities = ["new york", "brooklyn", "queens", "albany", "buffalo"]
    cuisines = ["pizza", "thai", "diner", "bakery", "sushi"]
    results = ["pass", "fail", "violation", "warning"]
    cats = ["software", "web", "nanotech", "biotech", "games"]
    n = lambda k: max(2, int(k * scale))

    def date_h(i):  # human-readable
        return f"{'Jan Feb Mar Apr May Jun Jul Aug Sep Oct Nov Dec'.split()[i % 12]} {i % 28 + 1} {2005 + i % 10}"

    books = [{"title": f"technical book {i} on {rng.choice(cats)}",
              "authors": [f"author {rng.integers(50)}" for _ in range(rng.integers(1, 4))],
              "pageCount": int(rng.integers(80, 900)),
              "created": f"{2005 + i % 10}-{i % 12 + 1:02d}-{i % 28 + 1:02d}",
              "status": "PUBLISH"} for i in range(n(40))]
    zips = [{"city": str(rng.choice(cities)), "zip": f"{10000 + i}",
             "pop": int(rng.integers(1000, 90000)), "state": "NY"}
            for i in range(n(120))]
    restaurants = [{"name": f"restaurant {i}", "cuisine": str(rng.choice(cuisines)),
                    "rating": float(np.round(rng.random() * 5, 1)),
                    "city": str(rng.choice(cities))} for i in range(n(80))]
    inspections = [{"id": f"insp-{i}", "result": str(rng.choice(results)),
                    "sector": str(rng.choice(cats)),
                    "date": date_h(i)} for i in range(n(300))]
    companies = [{"name": f"company {i}", "category_code": str(rng.choice(cats)),
                  "founded_year": int(2000 + i % 20),
                  "created_at": {"$date": int(1.1e12 + rng.integers(0, 3e11))},
                  "description": f"a {rng.choice(cats)} company doing {rng.choice(cats)}"}
                 for i in range(n(150))]
    trades = [{"ticker": str(rng.choice(["AAA", "BBB", "CCC"])),
               "price": float(np.round(10 + rng.random() * 90, 2)),
               "qty": int(rng.integers(1, 1000))} for i in range(n(500))]
    return {"books": books, "zips": zips, "restaurant": restaurants,
            "city_inspections": inspections, "companies": companies,
            "trades": trades}

"""NequIP-style E(3)-equivariant GNN (arXiv:2101.03164), Cartesian irreps.

TPU adaptation (DESIGN §6): e3nn's Clebsch–Gordan machinery over complex/real
spherical harmonics is gather-heavy; for l ≤ 2 the same equivariant algebra
has a closed Cartesian form —

  l=0 scalars        [N, C]
  l=1 vectors        [N, C, 3]
  l=2 sym-traceless  [N, C, 3, 3]

with tensor-product paths written as dot / cross / symmetric-traceless outer
products: dense einsums that map straight onto the MXU.  Message passing is
`jax.ops.segment_sum` over an edge index (JAX is BCOO-only — the scatter IS
part of the system, per the assignment).

Energy is a sum of per-node scalars; forces come from jax.grad wrt
positions, so equivariance is testable end to end (E invariant, F rotates).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class NequipConfig:
    name: str = "nequip"
    n_layers: int = 5
    d_hidden: int = 32          # channels per irrep order
    l_max: int = 2              # fixed Cartesian implementation for l <= 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 16
    d_feat: int = 0             # raw input node-feature dim (0 = species only)
    n_classes: int = 0          # >0 → node classification head (graph shapes)
    dtype: str = "float32"
    scan_unroll: int = 1

    @property
    def jnp_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def param_count(self) -> int:
        c = self.d_hidden
        per_layer = (self.n_rbf * 2 * c * 8          # radial MLP (8 paths)
                     + 3 * c * c                      # per-l channel mixers
                     + 2 * c * c)                     # gates
        head = c * c + c * max(self.n_classes, 1)
        return self.n_layers * per_layer + self.n_species * c + head


def bessel_rbf(r, n_rbf: int, cutoff: float):
    """Radial Bessel basis with smooth cutoff envelope (NequIP eq. 8)."""
    r = jnp.maximum(r, 1e-9)
    n = jnp.arange(1, n_rbf + 1, dtype=r.dtype)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * r[..., None] / cutoff) / r[..., None]
    x = r / cutoff
    env = jnp.where(x < 1.0, 1.0 - 10.0 * x**3 + 15.0 * x**4 - 6.0 * x**5, 0.0)
    return basis * env[..., None]


def _sym_traceless(m):
    """Project [..., 3, 3] onto symmetric-traceless (l=2) part."""
    sym = 0.5 * (m + jnp.swapaxes(m, -1, -2))
    tr = jnp.trace(sym, axis1=-2, axis2=-1)[..., None, None]
    eye = jnp.eye(3, dtype=m.dtype)
    return sym - tr * eye / 3.0


def init_params(cfg: NequipConfig, key):
    dt = cfg.jnp_dtype
    c = cfg.d_hidden
    ks = jax.random.split(key, 8 + cfg.n_layers)

    def dense(k, shape, scale=None):
        scale = scale or 1.0 / np.sqrt(max(shape[0], 1))
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dt)

    layers = []
    n_paths = 8
    for i in range(cfg.n_layers):
        lk = jax.random.split(ks[i], 8)
        layers.append({
            # radial MLP: rbf -> hidden -> per-(path, channel) weights
            "r_w1": dense(lk[0], (cfg.n_rbf, 2 * c)),
            "r_w2": dense(lk[1], (2 * c, n_paths * c)),
            "mix0": dense(lk[2], (c, c)),
            "mix1": dense(lk[3], (c, c)),
            "mix2": dense(lk[4], (c, c)),
            "gate1": dense(lk[5], (c, c)),
            "gate2": dense(lk[6], (c, c)),
            "self0": dense(lk[7], (c, c)),
        })
    layers = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    params = {
        "species_embed": dense(ks[-1], (cfg.n_species, c), scale=1.0),
        "layers": layers,
        "head_w1": dense(ks[-2], (c, c)),
        "head_w2": dense(ks[-3], (c, max(cfg.n_classes, 1))),
    }
    if cfg.d_feat:
        params["feat_embed"] = dense(ks[-4], (cfg.d_feat, c))
    return params


def _interact(cfg, lp, h0, h1, h2, senders, receivers, rbf, u, n_nodes):
    """One interaction block: TP messages over edges → segment-sum → update."""
    c = cfg.d_hidden
    w = jax.nn.silu(rbf @ lp["r_w1"]) @ lp["r_w2"]       # [E, 8c]
    w = w.reshape(-1, 8, c)                              # per-path radial wts

    s0, s1, s2 = h0[senders], h1[senders], h2[senders]   # [E, c(,3,(3))]
    y1 = u[:, None, :]                                   # [E, 1, 3]
    y2 = _sym_traceless(u[:, :, None] * u[:, None, :])[:, None]  # [E,1,3,3]

    # tensor-product paths (Cartesian CG for l ≤ 2)
    m0 = (w[:, 0] * s0                                   # (0,0)->0
          + w[:, 1] * jnp.einsum("eci,eci->ec", s1, jnp.broadcast_to(y1, s1.shape))  # (1,1)->0
          + w[:, 2] * jnp.einsum("ecij,ecij->ec", s2, jnp.broadcast_to(y2, s2.shape)))  # (2,2)->0
    m1 = (w[:, 3, :, None] * s0[:, :, None] * y1         # (0,1)->1
          + w[:, 4, :, None] * s1                        # (1,0)->1
          + w[:, 5, :, None] * jnp.cross(s1, jnp.broadcast_to(y1, s1.shape))  # (1,1)->1
          + w[:, 6, :, None] * jnp.einsum("ecij,ecj->eci", s2,
                                          jnp.broadcast_to(y1, s1.shape)))    # (2,1)->1
    m2 = (w[:, 7, :, None, None]
          * _sym_traceless(s1[..., :, None] * y1[..., None, :]))              # (1,1)->2

    a0 = jax.ops.segment_sum(m0, receivers, num_segments=n_nodes)
    a1 = jax.ops.segment_sum(m1, receivers, num_segments=n_nodes)
    a2 = jax.ops.segment_sum(m2, receivers, num_segments=n_nodes)

    # node update: channel mixing per l + gated nonlinearity
    g1 = jax.nn.sigmoid(a0 @ lp["gate1"])
    g2 = jax.nn.sigmoid(a0 @ lp["gate2"])
    h0 = jax.nn.silu(h0 @ lp["self0"] + a0 @ lp["mix0"])
    h1 = h1 + g1[:, :, None] * jnp.einsum("eci,cz->ezi", a1, lp["mix1"])
    h2 = h2 + g2[:, :, None, None] * jnp.einsum("ecij,cz->ezij", a2, lp["mix2"])
    return h0, h1, h2


def apply(params, cfg: NequipConfig, positions, species, senders, receivers,
          node_feats=None):
    """positions [N,3]; species [N] int; edges (senders→receivers) [E].

    Returns per-node scalars [N, C] after the interaction stack."""
    n = positions.shape[0]
    c = cfg.d_hidden
    dt = cfg.jnp_dtype
    h0 = params["species_embed"][species % cfg.n_species]
    if node_feats is not None and "feat_embed" in params:
        h0 = h0 + (node_feats.astype(dt) @ params["feat_embed"])
    h1 = jnp.zeros((n, c, 3), dt)
    h2 = jnp.zeros((n, c, 3, 3), dt)

    # safe norm: zero-length edges (self loops / padding) contribute nothing
    # and their gradient path is cleanly severed (jnp.where on both sides),
    # otherwise d(rel/ε)/d(pos) injects huge non-equivariant force noise.
    rel = positions[receivers] - positions[senders]
    r2 = jnp.sum(rel * rel, axis=-1)
    ok = r2 > 1e-10
    r = jnp.sqrt(jnp.where(ok, r2, 1.0))
    u = jnp.where(ok[:, None], rel / r[:, None], 0.0).astype(dt)
    r = jnp.where(ok, r, 2.0 * cfg.cutoff)   # outside cutoff → rbf = 0
    rbf = bessel_rbf(r, cfg.n_rbf, cfg.cutoff).astype(dt)

    def body(carry, lp):
        h0, h1, h2 = carry
        return _interact(cfg, lp, h0, h1, h2, senders, receivers, rbf, u, n), None

    (h0, h1, h2), _ = jax.lax.scan(body, (h0, h1, h2), params["layers"],
                                   unroll=min(cfg.scan_unroll, cfg.n_layers))
    return h0


def energy_fn(params, cfg: NequipConfig, positions, species, senders,
              receivers, graph_ids=None, n_graphs: int = 1):
    """Total energy per graph: sum of per-node scalar readouts."""
    h0 = apply(params, cfg, positions, species, senders, receivers)
    e_node = (jax.nn.silu(h0 @ params["head_w1"]) @ params["head_w2"])[:, 0]
    if graph_ids is None:
        return e_node.sum()[None]
    return jax.ops.segment_sum(e_node, graph_ids, num_segments=n_graphs)


def energy_and_forces(params, cfg: NequipConfig, positions, species, senders,
                      receivers, graph_ids=None, n_graphs: int = 1):
    def total(pos):
        return energy_fn(params, cfg, pos, species, senders, receivers,
                         graph_ids, n_graphs).sum()
    e, neg_f = jax.value_and_grad(total)(positions)
    energies = energy_fn(params, cfg, positions, species, senders, receivers,
                         graph_ids, n_graphs)
    return energies, -neg_f


def classify(params, cfg: NequipConfig, positions, species, senders,
             receivers, node_feats=None):
    """Node classification head (full_graph / minibatch shapes)."""
    h0 = apply(params, cfg, positions, species, senders, receivers, node_feats)
    return jax.nn.silu(h0 @ params["head_w1"]) @ params["head_w2"]


def loss_fn(params, cfg: NequipConfig, batch):
    """Dispatch on task: molecule (energy+forces MSE) vs node classification."""
    if "energies" in batch:
        n_graphs = batch["energies"].shape[0]   # static (from the input spec)
        e, f = energy_and_forces(params, cfg, batch["positions"],
                                 batch["species"], batch["senders"],
                                 batch["receivers"], batch.get("graph_ids"),
                                 n_graphs)
        le = jnp.mean((e - batch["energies"]) ** 2)
        lf = jnp.mean((f - batch["forces"]) ** 2)
        return le + lf
    logits = classify(params, cfg, batch["positions"], batch["species"],
                      batch["senders"], batch["receivers"],
                      batch.get("node_feats"))
    labels = batch["labels"]
    mask = batch.get("label_mask", jnp.ones_like(labels, jnp.float32))
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels[:, None], 1)[:, 0]
    return ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)

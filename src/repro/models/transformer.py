"""GQA transformer LM (dense + MoE) with scan-over-layers, raw JAX.

Covers qwen2.5 / yi / internlm2 (dense GQA, optional QKV bias) and
qwen3-moe / qwen2-moe (top-k routed experts, optional shared expert,
optional QK-norm) from a single config.

Layer parameters are stacked along a leading [L] axis and the decoder body
is a `jax.lax.scan`, keeping compile time flat in depth (94-layer MoE lowers
as one layer) — essential for the 80-compile dry-run matrix.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import (apply_rope, causal_gqa_attention,
                     chunked_causal_gqa_attention, cross_entropy_loss,
                     decode_gqa_attention, rms_norm, rope_frequencies, swiglu)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert_ff: int
    n_shared: int = 0           # shared experts (qwen2-moe style)
    d_shared_ff: int = 0
    capacity_factor: float = 1.25
    router_norm_topk: bool = True   # normalize top-k probabilities


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e6
    max_seq_len: int = 32_768
    moe: Optional[MoEConfig] = None
    dtype: str = "bfloat16"
    remat: bool = True
    scan_unroll: int = 1      # full unroll (=n_layers) for exact cost_analysis
    # beyond-paper perf knobs (EXPERIMENTS.md §Perf): 0 = off (baseline)
    attn_chunk_q: int = 0     # flash-style blocked attention chunk sizes
    attn_chunk_kv: int = 0
    moe_shard: str = ""       # "" | "all" | "combine": wsc inside moe_block

    @property
    def group_size(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def jnp_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def param_count(self) -> int:
        """Total (and active) parameter counts for roofline MODEL_FLOPS."""
        d, hd = self.d_model, self.head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.moe is None:
            mlp = 3 * d * self.d_ff
        else:
            mlp = (self.moe.n_experts * 3 * d * self.moe.d_expert_ff
                   + d * self.moe.n_experts
                   + (3 * d * self.moe.d_shared_ff if self.moe.n_shared else 0))
        emb = self.vocab * d * 2
        return self.n_layers * (attn + mlp + 2 * d) + emb + d

    def active_param_count(self) -> int:
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        hd = self.head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        mlp = (self.moe.top_k * 3 * d * self.moe.d_expert_ff
               + d * self.moe.n_experts
               + (3 * d * self.moe.d_shared_ff if self.moe.n_shared else 0))
        emb = self.vocab * d * 2
        return self.n_layers * (attn + mlp + 2 * d) + emb + d


# --------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------- #
def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) > 1 else 1
    scale = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_params(cfg: TransformerConfig, key):
    dt = cfg.jnp_dtype
    d, hd = cfg.d_model, cfg.head_dim
    h, hkv, L = cfg.n_heads, cfg.n_kv_heads, cfg.n_layers
    keys = jax.random.split(key, 16)

    def stacked(k, shape, scale=None):
        return _dense_init(k, (L,) + shape, dt, scale)

    layer = {
        "attn_norm": jnp.ones((L, d), dt),
        "mlp_norm": jnp.ones((L, d), dt),
        "wq": stacked(keys[0], (d, h * hd)),
        "wk": stacked(keys[1], (d, hkv * hd)),
        "wv": stacked(keys[2], (d, hkv * hd)),
        "wo": stacked(keys[3], (h * hd, d)),
    }
    if cfg.qkv_bias:
        layer["bq"] = jnp.zeros((L, h * hd), dt)
        layer["bk"] = jnp.zeros((L, hkv * hd), dt)
        layer["bv"] = jnp.zeros((L, hkv * hd), dt)
    if cfg.qk_norm:
        layer["q_norm"] = jnp.ones((L, hd), dt)
        layer["k_norm"] = jnp.ones((L, hd), dt)
    if cfg.moe is None:
        layer["w_gate"] = stacked(keys[4], (d, cfg.d_ff))
        layer["w_up"] = stacked(keys[5], (d, cfg.d_ff))
        layer["w_down"] = stacked(keys[6], (cfg.d_ff, d))
    else:
        m = cfg.moe
        layer["router"] = stacked(keys[7], (d, m.n_experts))
        layer["e_gate"] = stacked(keys[8], (m.n_experts, d, m.d_expert_ff))
        layer["e_up"] = stacked(keys[9], (m.n_experts, d, m.d_expert_ff))
        layer["e_down"] = stacked(keys[10], (m.n_experts, m.d_expert_ff, d))
        if m.n_shared:
            layer["s_gate"] = stacked(keys[11], (d, m.d_shared_ff))
            layer["s_up"] = stacked(keys[12], (d, m.d_shared_ff))
            layer["s_down"] = stacked(keys[13], (m.d_shared_ff, d))
            layer["s_gate_proj"] = stacked(keys[14], (d, 1))
    return {
        "embed": _dense_init(keys[15], (cfg.vocab, d), dt, scale=0.02),
        "layers": layer,
        "final_norm": jnp.ones((d,), dt),
        "lm_head": _dense_init(keys[15], (d, cfg.vocab), dt),
    }


# --------------------------------------------------------------------- #
# MoE dispatch (gather formulation; DESIGN §7)
# --------------------------------------------------------------------- #
def moe_block(x, lp, cfg: TransformerConfig):
    """x [T, D] (token-major) → [T, D]."""
    m = cfg.moe
    T, D = x.shape
    E, K = m.n_experts, m.top_k
    C = max(int(np.ceil(T * K / E * m.capacity_factor)), 1)

    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), lp["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)              # [T, K]
    if m.router_norm_topk:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # slot-major position assignment: scan over the K routing slots keeps the
    # intermediate one-hot at [T, E] instead of [T*K, E].
    def slot(counts, e_col):
        oh = jax.nn.one_hot(e_col, E, dtype=jnp.int32)         # [T, E]
        pos_in = jnp.cumsum(oh, axis=0) - 1                    # [T, E]
        pos = jnp.take_along_axis(pos_in, e_col[:, None], 1)[:, 0] + counts[e_col]
        return counts + oh.sum(0), pos

    counts0 = jnp.zeros((E,), jnp.int32)
    _, pos_k = jax.lax.scan(slot, counts0, top_e.T)            # [K, T]
    pos = pos_k.T                                              # [T, K]
    keep = pos < C
    pos_c = jnp.where(keep, pos, C - 1)

    # scatter token ids -> [E, C]; gather token activations
    tok_ids = jnp.broadcast_to(jnp.arange(T)[:, None], (T, K))
    idx_buf = jnp.full((E, C), T, jnp.int32)                  # T = OOB sentinel
    idx_buf = idx_buf.at[top_e, pos_c].set(jnp.where(keep, tok_ids, T),
                                           mode="drop")
    x_pad = jnp.concatenate([x, jnp.zeros((1, D), x.dtype)], 0)
    xe = x_pad[idx_buf]                                        # [E, C, D]

    if cfg.moe_shard == "all":
        # pin the dispatch layout: expert buffers expert-sharded ('model'),
        # capacity sharded over 'data' — the gather becomes one all-to-all
        # instead of GSPMD's default gather-to-replicated (§Perf iteration 2)
        from jax.sharding import PartitionSpec as _P
        from jax.lax import with_sharding_constraint as _wsc
        xe = _wsc(xe, _P("model", "data", None))

    h_g = jnp.einsum("ecd,edf->ecf", xe, lp["e_gate"])
    h_u = jnp.einsum("ecd,edf->ecf", xe, lp["e_up"])
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h_g) * h_u, lp["e_down"])
    if cfg.moe_shard == "all":
        ye = _wsc(ye, _P("model", "data", None))

    # combine: gather each (t, k) slot's output, weight, sum over K
    y_slots = ye[top_e, pos_c]                                 # [T, K, D]
    if cfg.moe_shard:
        from jax.sharding import PartitionSpec as _P2
        from jax.lax import with_sharding_constraint as _wsc2
        y_slots = _wsc2(y_slots, _P2("data", None, None))
    w = (top_p * keep).astype(ye.dtype)
    y = jnp.einsum("tkd,tk->td", y_slots, w)

    if m.n_shared:
        g = jax.nn.sigmoid(jnp.einsum("td,dz->tz", x.astype(jnp.float32),
                                      lp["s_gate_proj"].astype(jnp.float32)))
        y = y + (g.astype(x.dtype)
                 * swiglu(x, lp["s_gate"], lp["s_up"], lp["s_down"]))
    return y.astype(x.dtype)


# --------------------------------------------------------------------- #
# forward
# --------------------------------------------------------------------- #
def _layer_body(cfg: TransformerConfig, cos, sin, x, lp):
    b, s, d = x.shape
    h, hkv, hd, g = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.group_size

    xn = rms_norm(x, lp["attn_norm"])
    q = jnp.einsum("bsd,dh->bsh", xn, lp["wq"])
    k = jnp.einsum("bsd,dh->bsh", xn, lp["wk"])
    v = jnp.einsum("bsd,dh->bsh", xn, lp["wv"])
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(b, s, hkv, g, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"])
        k = rms_norm(k, lp["k_norm"])
    q = apply_rope(q.reshape(b, s, hkv * g, hd), cos, sin).reshape(b, s, hkv, g, hd)
    k = apply_rope(k, cos, sin)
    if cfg.attn_chunk_q and s > cfg.attn_chunk_q:
        attn = chunked_causal_gqa_attention(
            q, k, v, q_chunk=min(cfg.attn_chunk_q, s),
            kv_chunk=min(cfg.attn_chunk_kv or cfg.attn_chunk_q, s))
    else:
        attn = causal_gqa_attention(q, k, v)
    attn = attn.reshape(b, s, h * hd)
    x = x + jnp.einsum("bsh,hd->bsd", attn, lp["wo"])

    xn = rms_norm(x, lp["mlp_norm"])
    if cfg.moe is None:
        y = swiglu(xn, lp["w_gate"], lp["w_up"], lp["w_down"])
    else:
        y = moe_block(xn.reshape(b * s, d), lp, cfg).reshape(b, s, d)
    return x + y


def forward(params, tokens, cfg: TransformerConfig):
    """tokens [B, S] → logits [B, S, V]."""
    b, s = tokens.shape
    cos, sin = rope_frequencies(cfg.head_dim, s, cfg.rope_theta)
    x = params["embed"][tokens]

    body = functools.partial(_layer_body, cfg, cos, sin)
    if cfg.remat:
        body = jax.checkpoint(body)

    def scan_fn(x, lp):
        return body(x, lp), None

    x, _ = jax.lax.scan(scan_fn, x, params["layers"],
                        unroll=min(cfg.scan_unroll, cfg.n_layers))
    x = rms_norm(x, params["final_norm"])
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])


def loss_fn(params, batch, cfg: TransformerConfig):
    logits = forward(params, batch["tokens"], cfg)
    return cross_entropy_loss(logits, batch["labels"])


# --------------------------------------------------------------------- #
# decode path (serve_step): one token in, KV cache of seq_len
# --------------------------------------------------------------------- #
def init_cache(cfg: TransformerConfig, batch: int, seq_len: int):
    shape = (cfg.n_layers, batch, seq_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, cfg.jnp_dtype),
            "v": jnp.zeros(shape, cfg.jnp_dtype),
            "length": jnp.zeros((batch,), jnp.int32)}


def decode_step(params, cache, tokens, cfg: TransformerConfig):
    """tokens [B] (one new token per sequence) → (logits [B, V], new cache).

    The KV cache S axis may be sharded ('model' axis for long_500k): the
    attention below reduces over S with max/sum combines, which GSPMD turns
    into the flash-decoding partial-softmax all-reduce.
    """
    b = tokens.shape[0]
    s_cache = cache["k"].shape[2]
    hkv, g, hd = cfg.n_kv_heads, cfg.group_size, cfg.head_dim
    length = cache["length"]
    cos, sin = rope_frequencies(cfg.head_dim, cfg.max_seq_len, cfg.rope_theta)
    x = params["embed"][tokens][:, None, :]            # [B, 1, D]

    def layer(carry, inputs):
        x = carry
        lp, k_cache, v_cache = inputs
        b = x.shape[0]
        xn = rms_norm(x, lp["attn_norm"])
        q = jnp.einsum("bsd,dh->bsh", xn, lp["wq"])
        k = jnp.einsum("bsd,dh->bsh", xn, lp["wk"])
        v = jnp.einsum("bsd,dh->bsh", xn, lp["wv"])
        if cfg.qkv_bias:
            q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
        q = q.reshape(b, 1, hkv, g, hd)
        k = k.reshape(b, 1, hkv, hd)
        v = v.reshape(b, 1, hkv, hd)
        if cfg.qk_norm:
            q = rms_norm(q, lp["q_norm"])
            k = rms_norm(k, lp["k_norm"])
        pos = length[:, None]                          # [B, 1]
        q = apply_rope(q.reshape(b, 1, hkv * g, hd), cos, sin, pos).reshape(
            b, 1, hkv, g, hd)
        k = apply_rope(k, cos, sin, pos)
        # write new KV at position `length` (dynamic per-batch scatter)
        bidx = jnp.arange(b)
        k_cache = k_cache.at[bidx, length].set(k[:, 0], mode="drop")
        v_cache = v_cache.at[bidx, length].set(v[:, 0], mode="drop")
        attn = decode_gqa_attention(q[:, 0], k_cache, v_cache, length + 1)
        attn = attn.reshape(b, 1, cfg.n_heads * hd)
        x = x + jnp.einsum("bsh,hd->bsd", attn, lp["wo"])
        xn = rms_norm(x, lp["mlp_norm"])
        if cfg.moe is None:
            y = swiglu(xn, lp["w_gate"], lp["w_up"], lp["w_down"])
        else:
            y = moe_block(xn.reshape(b, -1), lp, cfg).reshape(b, 1, -1)
        return x + y, (k_cache, v_cache)

    x, (new_k, new_v) = jax.lax.scan(layer, x, (params["layers"],
                                                cache["k"], cache["v"]),
                                     unroll=min(cfg.scan_unroll, cfg.n_layers))
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
    new_cache = {"k": new_k, "v": new_v, "length": length + 1}
    return logits, new_cache


def prefill(params, tokens, cfg: TransformerConfig):
    """Prefill forward (logits only; used by the prefill_32k shape)."""
    return forward(params, tokens, cfg)

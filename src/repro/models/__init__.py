from . import layers, nequip, recsys, transformer

__all__ = ["layers", "nequip", "recsys", "transformer"]

"""Shared model layers: RMSNorm, RoPE, GQA attention, SwiGLU — raw JAX.

Parameters are plain pytrees (dicts of jnp arrays); init functions are pure
so `jax.eval_shape(init, ...)` gives allocation-free abstract params for the
multi-pod dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight).astype(dtype)


def rope_frequencies(head_dim: int, max_len: int, theta: float = 10_000.0):
    inv = 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))
    t = np.arange(max_len)
    freqs = np.outer(t, inv)                       # [S, D/2]
    return jnp.asarray(np.cos(freqs), jnp.float32), jnp.asarray(np.sin(freqs), jnp.float32)


def apply_rope(x, cos, sin, positions=None):
    """x [..., S, H, D]; cos/sin [max_len, D/2]; positions [..., S] optional."""
    if positions is None:
        s = x.shape[-3]
        c = cos[:s][:, None, :]
        sn = sin[:s][:, None, :]
    else:
        c = cos[positions][..., None, :]
        sn = sin[positions][..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * c - x2 * sn, x1 * sn + x2 * c], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def causal_gqa_attention(q, k, v, *, qk_norm_scale=None):
    """Training-shape attention.

    q [B, S, Hkv, G, Dh]; k/v [B, S, Hkv, Dh] → [B, S, Hkv, G, Dh].
    Grouped heads share KV without materializing repeats.
    """
    b, s, hkv, g, dh = q.shape
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def chunked_causal_gqa_attention(q, k, v, *, q_chunk: int, kv_chunk: int):
    """Flash-style blocked causal attention in pure jnp (beyond-paper perf
    path for long prefill): scores never materialize beyond
    [B, Hkv, G, q_chunk, kv_chunk]; online-softmax running (m, l, acc) over
    KV chunks, scanned over query chunks.  Cuts the memory roofline term of
    prefill_32k by ~S/q_chunk and removes the giant activation reshards.

    q [B, S, Hkv, G, Dh]; k/v [B, S, Hkv, Dh] → [B, S, Hkv, G, Dh].
    """
    b, s, hkv, g, dh = q.shape
    nq = s // q_chunk
    nk = s // kv_chunk
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    qc = q.reshape(b, nq, q_chunk, hkv, g, dh)
    kc = k.reshape(b, nk, kv_chunk, hkv, dh)
    vc = v.reshape(b, nk, kv_chunk, hkv, dh)

    def q_block(qi, q_tile):
        # q_tile [B, q_chunk, Hkv, G, Dh]
        m0 = jnp.full((b, hkv, g, q_chunk, 1), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk, 1), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, dh), jnp.float32)

        def kv_block(carry, kj):
            m, l, acc = carry
            k_tile = kc[:, kj]
            v_tile = vc[:, kj]
            sco = jnp.einsum("bqhgd,bkhd->bhgqk", q_tile.astype(jnp.float32),
                             k_tile.astype(jnp.float32)) * scale
            qpos = qi * q_chunk + jnp.arange(q_chunk)
            kpos = kj * kv_chunk + jnp.arange(kv_chunk)
            mask = qpos[:, None] >= kpos[None, :]
            sco = jnp.where(mask[None, None, None], sco, -1e30)
            m_new = jnp.maximum(m, sco.max(axis=-1, keepdims=True))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(sco - m_new)
            l = l * alpha + p.sum(axis=-1, keepdims=True)
            acc = acc * alpha + jnp.einsum("bhgqk,bkhd->bhgqd", p,
                                           v_tile.astype(jnp.float32))
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)
        return out.transpose(0, 3, 1, 2, 4)  # [B, q_chunk, Hkv, G, Dh]

    def scan_q(_, qi):
        return None, q_block(qi, qc[:, qi])

    _, blocks = jax.lax.scan(scan_q, None, jnp.arange(nq))
    # blocks [nq, B, q_chunk, Hkv, G, Dh] → [B, S, Hkv, G, Dh]
    return blocks.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, hkv, g, dh
                                                      ).astype(q.dtype)


def decode_gqa_attention(q, k_cache, v_cache, length):
    """Decode-shape attention (one new token vs cache), pure-jnp flash-
    decoding analogue: safe to shard the S axis (partial-softmax combine
    is an einsum + max/sum reductions that GSPMD reduces over shards).

    q [B, Hkv, G, Dh]; caches [B, S, Hkv, Dh]; length [B] → [B, Hkv, G, Dh].
    """
    b, hkv, g, dh = q.shape
    s = k_cache.shape[1]
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(s)[None, None, None, :]
    scores = jnp.where(pos < length[:, None, None, None], scores, -1e30)
    m = scores.max(axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    out = out / p.sum(axis=-1)[..., None]
    return out.astype(q.dtype)


def cross_entropy_loss(logits, labels, ignore_id: int = -1):
    """Mean token cross entropy in f32; labels == ignore_id are masked."""
    logits = logits.astype(jnp.float32)
    mask = labels != ignore_id
    labels_safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)

"""Recsys architectures: DLRM-RM2, xDeepFM (CIN), two-tower, SASRec.

The shared substrate is the embedding lookup (JAX has no EmbeddingBag —
built here from take + segment-sum / einsum, with the fused Pallas kernel as
the opt-in fast path).  Tables are row-sharded over the 'model' mesh axis;
interactions and MLPs are small and replicated (DESIGN §7).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _dense(key, shape, dtype, scale=None):
    scale = scale or 1.0 / np.sqrt(max(shape[0], 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _mlp_init(key, dims: Sequence[int], dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": _dense(ks[i], (dims[i], dims[i + 1]), dtype),
             "b": jnp.zeros((dims[i + 1],), dtype)} for i in range(len(dims) - 1)]


def _mlp_apply(layers, x, final_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def bce_with_logits(logits, labels):
    logits = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


# ===================================================================== #
# DLRM (arXiv:1906.00091), RM2 scale
# ===================================================================== #
@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-rm2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    vocab_per_table: int = 1_000_000
    bot_mlp: Tuple[int, ...] = (13, 512, 256, 64)
    top_mlp: Tuple[int, ...] = (512, 512, 256, 1)
    dtype: str = "float32"

    @property
    def jnp_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32


def dlrm_init(cfg: DLRMConfig, key):
    ks = jax.random.split(key, 4)
    dt = cfg.jnp_dtype
    n_feat = cfg.n_sparse + 1
    n_pairs = n_feat * (n_feat - 1) // 2
    top_in = cfg.embed_dim + n_pairs
    return {
        # one stacked table tensor → a single row-sharded array
        "tables": _dense(ks[0], (cfg.n_sparse, cfg.vocab_per_table,
                                 cfg.embed_dim), dt, scale=0.01),
        "bot": _mlp_init(ks[1], cfg.bot_mlp, dt),
        "top": _mlp_init(ks[2], (top_in,) + cfg.top_mlp[1:], dt),
    }


def _field_lookup(tables, sparse_ids):
    """tables [F, V, D]; ids [B, F] → [B, F, D] (vmap over fields)."""
    return jax.vmap(lambda t, i: jnp.take(t, i, axis=0),
                    in_axes=(0, 1), out_axes=1)(tables, sparse_ids)


def dlrm_forward(params, cfg: DLRMConfig, dense, sparse_ids):
    """dense [B, 13] f32; sparse_ids [B, 26] int32 → logits [B]."""
    b = dense.shape[0]
    d = _mlp_apply(params["bot"], dense.astype(cfg.jnp_dtype), final_act=True)
    emb = _field_lookup(params["tables"], sparse_ids)  # [B, F, D]
    feats = jnp.concatenate([d[:, None, :], emb], axis=1)   # [B, F+1, D]
    inter = jnp.einsum("bfd,bgd->bfg", feats, feats)
    iu, ju = jnp.triu_indices(feats.shape[1], k=1)
    pairs = inter[:, iu, ju]                          # [B, n_pairs]
    z = jnp.concatenate([d, pairs.astype(d.dtype)], axis=1)
    return _mlp_apply(params["top"], z)[:, 0]


def dlrm_loss(params, cfg: DLRMConfig, batch):
    logits = dlrm_forward(params, cfg, batch["dense"], batch["sparse"])
    return bce_with_logits(logits, batch["labels"])


# ===================================================================== #
# xDeepFM (arXiv:1803.05170)
# ===================================================================== #
@dataclasses.dataclass(frozen=True)
class XDeepFMConfig:
    name: str = "xdeepfm"
    n_sparse: int = 39
    embed_dim: int = 10
    vocab_per_table: int = 100_000
    cin_layers: Tuple[int, ...] = (200, 200, 200)
    mlp: Tuple[int, ...] = (400, 400)
    dtype: str = "float32"

    @property
    def jnp_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32


def xdeepfm_init(cfg: XDeepFMConfig, key):
    ks = jax.random.split(key, 6)
    dt = cfg.jnp_dtype
    m = cfg.n_sparse
    cin = []
    h_prev = m
    for i, h in enumerate(cfg.cin_layers):
        cin.append(_dense(jax.random.fold_in(ks[1], i), (h, h_prev * m), dt))
        h_prev = h
    mlp_dims = (m * cfg.embed_dim,) + cfg.mlp + (1,)
    return {
        "tables": _dense(ks[0], (m, cfg.vocab_per_table, cfg.embed_dim), dt,
                         scale=0.01),
        "cin": cin,
        "cin_out": _dense(ks[2], (sum(cfg.cin_layers), 1), dt),
        "mlp": _mlp_init(ks[3], mlp_dims, dt),
        "linear": _dense(ks[4], (m, cfg.vocab_per_table, 1), dt, scale=0.01),
    }


def xdeepfm_forward(params, cfg: XDeepFMConfig, sparse_ids):
    """sparse_ids [B, F] → logits [B]."""
    b, m = sparse_ids.shape
    emb = _field_lookup(params["tables"], sparse_ids)  # [B, F, D]
    x0 = emb
    xs: List[jnp.ndarray] = []
    xk = x0
    for w in params["cin"]:
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0)       # [B, Hk-1, F, D]
        z = z.reshape(b, -1, cfg.embed_dim)           # [B, Hk-1*F, D]
        xk = jnp.einsum("hz,bzd->bhd", w, z)          # [B, Hk, D]
        xs.append(xk.sum(axis=-1))                    # sum-pool over D
    cin_feat = jnp.concatenate(xs, axis=-1)           # [B, ΣH]
    y_cin = (cin_feat @ params["cin_out"])[:, 0]
    y_dnn = _mlp_apply(params["mlp"], emb.reshape(b, -1))[:, 0]
    lin = _field_lookup(params["linear"], sparse_ids)  # [B, F, 1]
    y_lin = lin.sum(axis=(1, 2))
    return y_cin + y_dnn + y_lin


def xdeepfm_loss(params, cfg: XDeepFMConfig, batch):
    logits = xdeepfm_forward(params, cfg, batch["sparse"])
    return bce_with_logits(logits, batch["labels"])


# ===================================================================== #
# Two-tower retrieval (Yi et al., RecSys'19)
# ===================================================================== #
@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    embed_dim: int = 256
    tower_mlp: Tuple[int, ...] = (1024, 512, 256)
    n_users: int = 2_000_000
    n_items: int = 1_000_000
    n_user_feats: int = 8        # multi-hot user history features per example
    loss_chunk: int = 0          # streamed in-batch softmax chunk (0 = off)
    dtype: str = "float32"

    @property
    def jnp_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32


def twotower_init(cfg: TwoTowerConfig, key):
    ks = jax.random.split(key, 4)
    dt = cfg.jnp_dtype
    d = cfg.embed_dim
    return {
        "user_table": _dense(ks[0], (cfg.n_users, d), dt, scale=0.01),
        "item_table": _dense(ks[1], (cfg.n_items, d), dt, scale=0.01),
        "user_tower": _mlp_init(ks[2], (d,) + cfg.tower_mlp, dt),
        "item_tower": _mlp_init(ks[3], (d,) + cfg.tower_mlp, dt),
    }


def _embed_bag(table, ids, weights):
    """EmbeddingBag built from take + einsum (no native op in JAX)."""
    rows = jnp.take(table, ids, axis=0)               # [B, L, D]
    return jnp.einsum("bld,bl->bd", rows, weights.astype(table.dtype))


def twotower_user_embed(params, cfg, user_ids, hist_ids, hist_w):
    u = jnp.take(params["user_table"], user_ids, axis=0)
    u = u + _embed_bag(params["item_table"], hist_ids, hist_w)
    u = _mlp_apply(params["user_tower"], u, final_act=False)
    return u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-6)


def twotower_item_embed(params, cfg, item_ids):
    i = jnp.take(params["item_table"], item_ids, axis=0)
    i = _mlp_apply(params["item_tower"], i, final_act=False)
    return i / jnp.maximum(jnp.linalg.norm(i, axis=-1, keepdims=True), 1e-6)


def twotower_loss(params, cfg: TwoTowerConfig, batch):
    """In-batch sampled softmax with logQ correction.

    With ``loss_chunk`` set, the [B, B] logits matrix is never materialized:
    the log-normalizer streams over item chunks with a running
    max/accumulator (§Perf iteration 3) — O(B · chunk) memory instead of
    O(B²), same result to fp rounding."""
    u = twotower_user_embed(params, cfg, batch["user_ids"],
                            batch["hist_ids"], batch["hist_w"])
    i = twotower_item_embed(params, cfg, batch["item_ids"])
    logq = batch.get("logq")
    b = u.shape[0]
    gold = (jnp.sum(u * i, axis=-1).astype(jnp.float32) * 20.0
            - (logq if logq is not None else 0.0))
    if not cfg.loss_chunk or b <= cfg.loss_chunk:
        logits = (u @ i.T).astype(jnp.float32) * 20.0       # temperature
        if logq is not None:
            logits = logits - logq[None, :]
        logz = jax.nn.logsumexp(logits, axis=-1)
        return jnp.mean(logz - gold)
    c = cfg.loss_chunk
    nc = b // c
    ic = i.reshape(nc, c, -1)
    lqc = (logq.reshape(nc, c) if logq is not None
           else jnp.zeros((nc, c), jnp.float32))

    def chunk(carry, xs):
        m, s = carry
        i_tile, lq_tile = xs
        lg = (u @ i_tile.T).astype(jnp.float32) * 20.0 - lq_tile[None, :]
        m_new = jnp.maximum(m, lg.max(axis=-1))
        s = s * jnp.exp(m - m_new) + jnp.exp(lg - m_new[:, None]).sum(-1)
        return (m_new, s), None

    (m, s), _ = jax.lax.scan(
        chunk, (jnp.full((b,), -1e30, jnp.float32), jnp.zeros((b,))),
        (ic, lqc))
    logz = m + jnp.log(jnp.maximum(s, 1e-30))
    return jnp.mean(logz - gold)


def twotower_score_candidates(params, cfg: TwoTowerConfig, batch):
    """retrieval_cand: one query vs n_candidates (sharded matmul)."""
    u = twotower_user_embed(params, cfg, batch["user_ids"],
                            batch["hist_ids"], batch["hist_w"])
    i = twotower_item_embed(params, cfg, batch["cand_ids"])
    return (u @ i.T).astype(jnp.float32)                # [B, n_cand]


# ===================================================================== #
# SASRec (arXiv:1808.09781)
# ===================================================================== #
@dataclasses.dataclass(frozen=True)
class SASRecConfig:
    name: str = "sasrec"
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    n_items: int = 1_000_000
    dropout: float = 0.0         # deterministic runs
    dtype: str = "float32"
    scan_unroll: int = 1

    @property
    def jnp_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32


def sasrec_init(cfg: SASRecConfig, key):
    dt = cfg.jnp_dtype
    d = cfg.embed_dim
    ks = jax.random.split(key, 2 + 6 * cfg.n_blocks)
    blocks = []
    for i in range(cfg.n_blocks):
        bk = ks[2 + i * 6: 8 + i * 6]
        blocks.append({
            "wq": _dense(bk[0], (d, d), dt), "wk": _dense(bk[1], (d, d), dt),
            "wv": _dense(bk[2], (d, d), dt), "wo": _dense(bk[3], (d, d), dt),
            "ln1": jnp.ones((d,), dt), "ln2": jnp.ones((d,), dt),
            "ff1": _dense(bk[4], (d, d), dt), "ff2": _dense(bk[5], (d, d), dt),
        })
    blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    return {
        "item_embed": _dense(ks[0], (cfg.n_items, d), dt, scale=0.01),
        "pos_embed": _dense(ks[1], (cfg.seq_len, d), dt, scale=0.01),
        "blocks": blocks,
    }


def _ln(x, g, eps=1e-6):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * g


def sasrec_encode(params, cfg: SASRecConfig, item_seq):
    """item_seq [B, S] (0 = padding) → hidden [B, S, D]."""
    b, s = item_seq.shape
    x = jnp.take(params["item_embed"], item_seq, axis=0)
    x = x + params["pos_embed"][None, :s]
    mask = (item_seq != 0)
    causal = jnp.tril(jnp.ones((s, s), bool))

    def block(x, bp):
        xn = _ln(x, bp["ln1"])
        q = xn @ bp["wq"]
        k = xn @ bp["wk"]
        v = xn @ bp["wv"]
        scores = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                            k.astype(jnp.float32)) / np.sqrt(cfg.embed_dim)
        scores = jnp.where(causal[None] & mask[:, None, :], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        x = x + (jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
                 .astype(x.dtype) @ bp["wo"])
        xn = _ln(x, bp["ln2"])
        x = x + jax.nn.relu(xn @ bp["ff1"]) @ bp["ff2"]
        return x, None

    x, _ = jax.lax.scan(block, x, params["blocks"],
                        unroll=min(cfg.scan_unroll, cfg.n_blocks))
    return x * mask[..., None]


def sasrec_loss(params, cfg: SASRecConfig, batch):
    """Next-item BCE with sampled negatives (paper's training objective)."""
    h = sasrec_encode(params, cfg, batch["item_seq"])        # [B, S, D]
    pos = jnp.take(params["item_embed"], batch["pos_items"], axis=0)
    neg = jnp.take(params["item_embed"], batch["neg_items"], axis=0)
    pos_logit = jnp.einsum("bsd,bsd->bs", h, pos).astype(jnp.float32)
    neg_logit = jnp.einsum("bsd,bsd->bs", h, neg).astype(jnp.float32)
    mask = (batch["pos_items"] != 0).astype(jnp.float32)
    loss = (jnp.log1p(jnp.exp(-pos_logit)) + jnp.log1p(jnp.exp(neg_logit)))
    return (loss * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def sasrec_score_candidates(params, cfg: SASRecConfig, batch):
    """Score candidate items against the last hidden state."""
    h = sasrec_encode(params, cfg, batch["item_seq"])        # [B, S, D]
    lengths = (batch["item_seq"] != 0).sum(-1)
    last = h[jnp.arange(h.shape[0]), jnp.maximum(lengths - 1, 0)]  # [B, D]
    cand = jnp.take(params["item_embed"], batch["cand_ids"], axis=0)
    if cand.ndim == 2:                                       # shared cands
        return (last @ cand.T).astype(jnp.float32)
    return jnp.einsum("bd,bcd->bc", last, cand).astype(jnp.float32)

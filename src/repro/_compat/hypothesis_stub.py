"""Minimal, dependency-free stand-in for the ``hypothesis`` API surface the
test-suite uses, so property tests still run (as seeded random sampling)
in environments where the real package cannot be installed.

Covers: ``@given`` (positional + keyword strategies), ``@settings``
(max_examples / deadline), and ``strategies.integers / floats / lists /
tuples / sampled_from`` with ``.map`` and ``.filter``.  No shrinking, no
database — when the real hypothesis is importable, ``install()`` is a
no-op and the genuine package wins.

The draw sequence is seeded from the test's qualified name (crc32, not the
salted builtin hash), so failures reproduce across runs.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

DEFAULT_MAX_EXAMPLES = 50


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rnd: random.Random):
        return self._draw(rnd)

    def map(self, f) -> "SearchStrategy":
        return SearchStrategy(lambda rnd: f(self._draw(rnd)))

    def filter(self, pred) -> "SearchStrategy":
        def draw(rnd):
            for _ in range(1000):
                v = self._draw(rnd)
                if pred(v):
                    return v
            raise ValueError("filter predicate too restrictive")
        return SearchStrategy(draw)


def integers(min_value=None, max_value=None) -> SearchStrategy:
    lo = -(2 ** 63) if min_value is None else int(min_value)
    hi = 2 ** 63 if max_value is None else int(max_value)

    def draw(rnd):
        # mix small boundary-ish values with the full range
        if rnd.random() < 0.25:
            return rnd.choice([lo, hi, min(lo + 1, hi), max(hi - 1, lo),
                               min(max(0, lo), hi)])
        return rnd.randint(lo, hi)
    return SearchStrategy(draw)


def floats(min_value=None, max_value=None, allow_nan=False,
           allow_infinity=False, width=64) -> SearchStrategy:
    lo = -1e9 if min_value is None else float(min_value)
    hi = 1e9 if max_value is None else float(max_value)
    return SearchStrategy(lambda rnd: rnd.uniform(lo, hi))


def lists(elements: SearchStrategy, min_size: int = 0, max_size=None,
          unique: bool = False) -> SearchStrategy:
    def draw(rnd):
        hi = (min_size + 20) if max_size is None else max_size
        n = rnd.randint(min_size, hi)
        out, seen, attempts = [], set(), 0
        while len(out) < n and attempts < 20 * n + 50:
            attempts += 1
            v = elements.example(rnd)
            if unique:
                key = v if not isinstance(v, list) else tuple(v)
                if key in seen:
                    continue
                seen.add(key)
            out.append(v)
        return out
    return SearchStrategy(draw)


def tuples(*strats: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda rnd: tuple(s.example(rnd) for s in strats))


def sampled_from(seq) -> SearchStrategy:
    seq = list(seq)
    return SearchStrategy(lambda rnd: rnd.choice(seq))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rnd: rnd.random() < 0.5)


def given(*pos_strats, **kw_strats):
    def deco(fn):
        sig = inspect.signature(fn)
        names = list(sig.parameters)
        # positional strategies bind the RIGHTMOST unbound parameters,
        # matching real hypothesis
        free = [n for n in names if n not in kw_strats]
        pos_names = free[len(free) - len(pos_strats):] if pos_strats else []
        bound = set(pos_names) | set(kw_strats)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n_ex = getattr(wrapper, "_hyp_max_examples", DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            for i in range(n_ex):
                rnd = random.Random((seed + i) & 0xFFFFFFFF)
                drawn = {n: s.example(rnd)
                         for n, s in zip(pos_names, pos_strats)}
                for n, s in kw_strats.items():
                    drawn[n] = s.example(rnd)
                fn(*args, **{**kwargs, **drawn})

        # hide the strategy-bound params so pytest doesn't see fixtures
        wrapper.__signature__ = sig.replace(
            parameters=[p for n, p in sig.parameters.items()
                        if n not in bound])
        wrapper.is_hypothesis_test = True
        return wrapper
    return deco


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    def deco(fn):
        fn._hyp_max_examples = max_examples
        return fn
    return deco


def install() -> bool:
    """Register the stub as ``hypothesis`` iff the real one is absent."""
    try:
        import hypothesis  # noqa: F401
        return False
    except ImportError:
        pass
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.SearchStrategy = SearchStrategy
    mod.__is_repro_stub__ = True
    st = types.ModuleType("hypothesis.strategies")
    for f in (integers, floats, lists, tuples, sampled_from, booleans):
        setattr(st, f.__name__, f)
    st.SearchStrategy = SearchStrategy
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
    return True

"""Pure-stdlib fallbacks for optional test/runtime dependencies."""

"""Benchmark driver: one section per paper table/figure + engine + roofline.

  PYTHONPATH=src python -m benchmarks.run [--quick]
"""

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)

    t0 = time.time()
    print("=" * 72)
    print("1. paper Fig. 6 — JSON store queries (static vs dynamic)")
    print("=" * 72)
    from benchmarks import json_queries
    json_queries.run(scale=0.5 if args.quick else 1.0)

    print()
    print("=" * 72)
    print("2. paper Fig. 7 — concurrent readers/writers over evolving index")
    print("=" * 72)
    from benchmarks import concurrent_trec
    concurrent_trec.run(n_years=2 if args.quick else 3,
                        files_per_year=4 if args.quick else 6)

    print()
    print("=" * 72)
    print("3. paper §4 — index build throughput")
    print("=" * 72)
    from benchmarks import build_throughput
    build_throughput.run(n_docs=600 if args.quick else 1500)

    print()
    print("=" * 72)
    print("4. query engines: lazy host vs vectorized vs Pallas")
    print("=" * 72)
    from benchmarks import engine_compare
    if args.quick:
        engine_compare.bench_joins(sizes=(1000, 10_000))
        engine_compare.bench_bm25(n_docs=50_000, postings=5_000)
    else:
        engine_compare.run()

    print()
    print("=" * 72)
    print("5. roofline from the multi-pod dry-run")
    print("=" * 72)
    from benchmarks import roofline
    roofline.main()

    print(f"\ntotal benchmark time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    sys.exit(main())

"""Paper Fig. 7 analogue: evolving collection with concurrent readers,
writers, and a deleter — MAP tracked live over "years".

Recapitulates the shape of the TREC-4→7 experiment with a synthetic
collection: appender threads ingest per-year document files (one transaction
per file), add term statistics and relevance judgments in *separate*
transactions; query threads run BM25 + PRF and compute AP from judgments
read back out of the index; a deletion thread erases old years so the
collection evolves.  Reports MAP per year and aggregate throughput.
"""

import threading
import time

import numpy as np

from repro.core import (DynamicIndex, Warren, average_precision,
                        collection_stats, expand_query, index_document,
                        ingest_documents, score_bm25)
from repro.data.synth import doc_generator


def scatter_gather_bench(warren, queries, rounds: int = 25,
                         extra_docs: int = 0, smoke: bool = False):
    """Same corpus, same query stream, three servings of a ShardedWarren:

      legacy        the pre-async serving path: every term list is k-way
                    merged across groups on the caller thread and scored in
                    one global device block (ShardedWarren as "one index")
      native/seq    scatter once per group per micro-batch, per-group
                    device top-k, global merge — groups visited in a
                    sequential caller-thread loop
      native/async  the same pipeline with the per-group fan-out on the
                    ScatterGather worker pool

    Prints ms/query + the scatter/score/merge breakdown for each, verifies
    all three return identical rankings, and reports the native/async
    speedup over the legacy sequential scatter."""
    from repro.train.serve import BatcherConfig, RetrievalServer

    if extra_docs:                       # give each group real work
        ingest_documents(warren, doc_generator(999, extra_docs), batch=256)
        warren.index.merge_segments()    # serving cost, not merge state
    qs = queries * rounds
    results, times = {}, {}
    for mode in ("legacy", "native/seq", "native/async"):
        warren.set_async_scatter(mode == "native/async")
        server = RetrievalServer(
            warren, k=10, batcher=BatcherConfig(max_batch=16, max_wait_ms=4),
            sharded_native=mode != "legacy")
        for i in (1, 2, 4, 8, 16):               # warm every batch bucket
            server._handle(qs[:i])
        server.timings.reset()
        t0 = time.time()
        handles = [server.batcher.submit(q) for q in qs]
        results[mode] = [h.get(timeout=120) for h in handles]
        times[mode] = time.time() - t0
        print(f"  serving [{mode:>12}]: {1e3 * times[mode] / len(qs):7.2f} "
              f"ms/query wall — {server.timings.summary()}")
        server.close()
    same = all(
        [(d, round(s, 9)) for d, s in a] == [(d, round(s, 9)) for d, s in b]
        for mode in ("native/seq", "native/async")
        for a, b in zip(results["legacy"], results[mode]))
    # the per-query search path must also agree between scatter modes
    for enabled in (False, True):
        warren.set_async_scatter(enabled)
        with warren:
            hits = [warren.search(q, k=10) for q in queries]
        same = same and (hits == results.setdefault("_search", hits))
    speedup = times["legacy"] / times["native/async"]
    note = (" (smoke-sized corpus: parity check only, speedup needs the "
            "full run)" if smoke else "")
    print(f"  all paths identical: {same}; native/async speedup over the "
          f"legacy sequential scatter: {speedup:.2f}x{note}")
    if not same:
        raise SystemExit("serving paths diverged on the same corpus")
    return speedup


def run(n_years: int = 3, files_per_year: int = 6, docs_per_file: int = 20,
        n_queries: int = 12, n_writers: int = 4, shards: int = 1,
        replicas: int = 1, async_scatter: bool = False, smoke: bool = False):
    if smoke:
        n_years, files_per_year, docs_per_file = 2, 2, 10
        n_queries, n_writers = 4, 2
    if shards > 1 or replicas > 1:
        from repro.dist.shard_router import ShardedWarren
        warren = ShardedWarren(n_shards=shards, replicas=replicas,
                               async_scatter=async_scatter)
    else:
        warren = Warren(DynamicIndex())
    rng = np.random.default_rng(0)
    queries = {}
    for y in range(n_years):
        for qi in range(n_queries // n_years):
            qid = f"y{y}q{qi}"
            queries[qid] = {"year": y, "text": None, "rel": set()}

    files = []
    for y in range(n_years):
        for f in range(files_per_year):
            docs = list(doc_generator(y * 100 + f, docs_per_file))
            files.append((y, f, docs))

    # assign relevance: each query gets terms from docs of its year
    for qid, q in queries.items():
        y = q["year"]
        _, text = files[y * files_per_year][2][hash(qid) % docs_per_file]
        words = text.split()
        q["text"] = " ".join(words[:4])
        for (fy, _, docs) in files:
            if fy == y:
                for docid, d in docs:
                    if sum(w in d for w in words[:4]) >= 2:
                        q["rel"].add(docid)

    ap_log = []
    log_lock = threading.Lock()
    stop = threading.Event()
    n_txn = [0]

    def appender(files_slice):
        wc = warren.clone()
        for (y, f, docs) in files_slice:
            # txn 1: append the file
            with wc:
                wc.transaction()
                for docid, text in docs:
                    index_document(wc, text, docid=docid)
                    wc.annotate(f"year:{y}", 0, 0)  # marker (see txn 3)
                wc.commit()
            # txn 2: re-read documents, write extra statistics
            with wc:
                wc.transaction()
                roots = wc.annotations(":")
                wc.annotate(f"stats:file:{y}:{f}", int(roots.starts[-1]),
                            int(roots.ends[-1]), float(len(roots)))
                wc.commit()
            # txn 3: relevance annotations
            with wc:
                wc.transaction()
                for docid, text in docs:
                    for qid, q in queries.items():
                        if docid in q["rel"]:
                            lst = wc.annotations("docid:" + docid)
                            if len(lst):
                                wc.annotate("rel:" + qid, int(lst.starts[0]),
                                            int(lst.ends[0]))
                wc.commit()
            n_txn[0] += 3

    def querier(qid):
        wc = warren.clone()
        q = queries[qid]
        while not stop.is_set():
            with wc:
                stats = collection_stats(wc)
                if stats.n_docs < 10:
                    time.sleep(0.01)
                    continue
                weights = expand_query(wc, q["text"], fb_docs=5, fb_terms=6,
                                       stats=stats)
                top = score_bm25(wc, "", k=50, weights=weights, stats=stats)
                # resolve doc addresses -> docids via judgments in the index
                rel_addrs = {int(s) for s in
                             wc.annotations("rel:" + qid).starts}
                ranked_rel = [d for d, _ in top]
                ap = average_precision(ranked_rel, rel_addrs
                                       ) if rel_addrs else 0.0
            with log_lock:
                ap_log.append((time.time(), qid, ap))

    def deleter():
        wc = warren.clone()
        while not stop.is_set():
            time.sleep(0.5)
            with wc:
                docs = wc.annotations(":")
                if len(docs) > (n_years - 1) * files_per_year * docs_per_file:
                    wc.transaction()
                    for i in range(docs_per_file):
                        wc.erase(int(docs.starts[i]), int(docs.ends[i]))
                    wc.commit()
                    n_txn[0] += 1

    t0 = time.time()
    per = max(len(files) // n_writers, 1)
    writers = [threading.Thread(target=appender,
                                args=(files[i * per:(i + 1) * per],))
               for i in range(n_writers)]
    readers = [threading.Thread(target=querier, args=(qid,))
               for qid in queries]
    d = threading.Thread(target=deleter)
    for t in writers + readers + [d]:
        t.start()
    for t in writers:
        t.join()
    time.sleep(0.5)        # let queries see the final state
    stop.set()
    for t in readers + [d]:
        t.join()
    wall = time.time() - t0
    warren.index.merge_segments()

    by_year = {}
    for ts, qid, ap in ap_log:
        y = queries[qid]["year"]
        by_year.setdefault(y, []).append(ap)
    print(f"# {len(files)} files, {n_txn[0]} transactions, "
          f"{len(ap_log)} query executions in {wall:.1f}s "
          f"({len(ap_log) / wall:.0f} q/s) — "
          f"{len(warren.index._segments)} subindexes after merge")
    for y in sorted(by_year):
        aps = by_year[y]
        print(f"  year {y}: final MAP {np.mean(aps[-len(aps)//4 or 1:]):.3f} "
              f"over {len(aps)} runs")
    if shards > 1:
        # sequential vs pooled scatter over the evolved corpus (plus extra
        # synthetic docs so each group does non-trivial per-query work)
        print("# scatter-gather serving (same corpus, fixed query set):")
        scatter_gather_bench(
            warren, [q["text"] for q in queries.values()],
            rounds=2 if smoke else 25,
            extra_docs=200 if smoke else 8000, smoke=smoke)
        warren.close()
    return ap_log


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=1,
                    help="partition the index over N shards (ShardedWarren)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="replicas per shard group (quorum commits)")
    ap.add_argument("--async-scatter", action="store_true",
                    help="fan per-group reads out on the ScatterGather "
                         "worker pool (repro.dist.parallel)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny corpus + few rounds: CI-sized sanity run "
                         "that still checks async == sequential results")
    ap.add_argument("--years", type=int, default=3)
    ap.add_argument("--writers", type=int, default=4)
    args = ap.parse_args()
    run(n_years=args.years, n_writers=args.writers, shards=args.shards,
        replicas=args.replicas, async_scatter=args.async_scatter,
        smoke=args.smoke)

"""Paper Fig. 7 analogue: evolving collection with concurrent readers,
writers, and a deleter — MAP tracked live over "years".

Recapitulates the shape of the TREC-4→7 experiment with a synthetic
collection: appender threads ingest per-year document files (one transaction
per file), add term statistics and relevance judgments in *separate*
transactions; query threads run BM25 + PRF and compute AP from judgments
read back out of the index; a deletion thread erases old years so the
collection evolves.  Reports MAP per year and aggregate throughput.
"""

import threading
import time

import numpy as np

from repro.core import (DynamicIndex, Warren, average_precision,
                        collection_stats, expand_query, index_document,
                        score_bm25)
from repro.data.synth import doc_generator


def run(n_years: int = 3, files_per_year: int = 6, docs_per_file: int = 20,
        n_queries: int = 12, n_writers: int = 4, shards: int = 1,
        replicas: int = 1):
    if shards > 1 or replicas > 1:
        from repro.dist.shard_router import ShardedWarren
        warren = ShardedWarren(n_shards=shards, replicas=replicas)
    else:
        warren = Warren(DynamicIndex())
    rng = np.random.default_rng(0)
    queries = {}
    for y in range(n_years):
        for qi in range(n_queries // n_years):
            qid = f"y{y}q{qi}"
            queries[qid] = {"year": y, "text": None, "rel": set()}

    files = []
    for y in range(n_years):
        for f in range(files_per_year):
            docs = list(doc_generator(y * 100 + f, docs_per_file))
            files.append((y, f, docs))

    # assign relevance: each query gets terms from docs of its year
    for qid, q in queries.items():
        y = q["year"]
        _, text = files[y * files_per_year][2][hash(qid) % docs_per_file]
        words = text.split()
        q["text"] = " ".join(words[:4])
        for (fy, _, docs) in files:
            if fy == y:
                for docid, d in docs:
                    if sum(w in d for w in words[:4]) >= 2:
                        q["rel"].add(docid)

    ap_log = []
    log_lock = threading.Lock()
    stop = threading.Event()
    n_txn = [0]

    def appender(files_slice):
        wc = warren.clone()
        for (y, f, docs) in files_slice:
            # txn 1: append the file
            with wc:
                wc.transaction()
                for docid, text in docs:
                    index_document(wc, text, docid=docid)
                    wc.annotate(f"year:{y}", 0, 0)  # marker (see txn 3)
                wc.commit()
            # txn 2: re-read documents, write extra statistics
            with wc:
                wc.transaction()
                roots = wc.annotations(":")
                wc.annotate(f"stats:file:{y}:{f}", int(roots.starts[-1]),
                            int(roots.ends[-1]), float(len(roots)))
                wc.commit()
            # txn 3: relevance annotations
            with wc:
                wc.transaction()
                for docid, text in docs:
                    for qid, q in queries.items():
                        if docid in q["rel"]:
                            lst = wc.annotations("docid:" + docid)
                            if len(lst):
                                wc.annotate("rel:" + qid, int(lst.starts[0]),
                                            int(lst.ends[0]))
                wc.commit()
            n_txn[0] += 3

    def querier(qid):
        wc = warren.clone()
        q = queries[qid]
        while not stop.is_set():
            with wc:
                stats = collection_stats(wc)
                if stats.n_docs < 10:
                    time.sleep(0.01)
                    continue
                weights = expand_query(wc, q["text"], fb_docs=5, fb_terms=6,
                                       stats=stats)
                top = score_bm25(wc, "", k=50, weights=weights, stats=stats)
                # resolve doc addresses -> docids via judgments in the index
                rel_addrs = {int(s) for s in
                             wc.annotations("rel:" + qid).starts}
                ranked_rel = [d for d, _ in top]
                ap = average_precision(ranked_rel, rel_addrs
                                       ) if rel_addrs else 0.0
            with log_lock:
                ap_log.append((time.time(), qid, ap))

    def deleter():
        wc = warren.clone()
        while not stop.is_set():
            time.sleep(0.5)
            with wc:
                docs = wc.annotations(":")
                if len(docs) > (n_years - 1) * files_per_year * docs_per_file:
                    wc.transaction()
                    for i in range(docs_per_file):
                        wc.erase(int(docs.starts[i]), int(docs.ends[i]))
                    wc.commit()
                    n_txn[0] += 1

    t0 = time.time()
    per = max(len(files) // n_writers, 1)
    writers = [threading.Thread(target=appender,
                                args=(files[i * per:(i + 1) * per],))
               for i in range(n_writers)]
    readers = [threading.Thread(target=querier, args=(qid,))
               for qid in queries]
    d = threading.Thread(target=deleter)
    for t in writers + readers + [d]:
        t.start()
    for t in writers:
        t.join()
    time.sleep(0.5)        # let queries see the final state
    stop.set()
    for t in readers + [d]:
        t.join()
    wall = time.time() - t0
    warren.index.merge_segments()

    by_year = {}
    for ts, qid, ap in ap_log:
        y = queries[qid]["year"]
        by_year.setdefault(y, []).append(ap)
    print(f"# {len(files)} files, {n_txn[0]} transactions, "
          f"{len(ap_log)} query executions in {wall:.1f}s "
          f"({len(ap_log) / wall:.0f} q/s) — "
          f"{len(warren.index._segments)} subindexes after merge")
    for y in sorted(by_year):
        aps = by_year[y]
        print(f"  year {y}: final MAP {np.mean(aps[-len(aps)//4 or 1:]):.3f} "
              f"over {len(aps)} runs")
    return ap_log


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=1,
                    help="partition the index over N shards (ShardedWarren)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="replicas per shard group (quorum commits)")
    ap.add_argument("--years", type=int, default=3)
    ap.add_argument("--writers", type=int, default=4)
    args = ap.parse_args()
    run(n_years=args.years, n_writers=args.writers, shards=args.shards,
        replicas=args.replicas)

"""Paper Fig. 7 analogue: evolving collection with concurrent readers,
writers, and a deleter — MAP tracked live over "years".

Recapitulates the shape of the TREC-4→7 experiment with a synthetic
collection: appender threads ingest per-year document files (one transaction
per file), add term statistics and relevance judgments in *separate*
transactions; query threads run BM25 + PRF and compute AP from judgments
read back out of the index; a deletion thread erases old years so the
collection evolves.  Reports MAP per year and aggregate throughput.
"""

import threading
import time

import numpy as np

from repro.core import (DynamicIndex, Warren, average_precision,
                        collection_stats, expand_query, index_document,
                        ingest_documents, score_bm25)
from repro.data.synth import doc_generator


def scatter_gather_bench(warren, queries, rounds: int = 25,
                         extra_docs: int = 0, smoke: bool = False):
    """Same corpus, same query stream, three servings of a ShardedWarren:

      legacy        the pre-async serving path: every term list is k-way
                    merged across groups on the caller thread and scored in
                    one global device block (ShardedWarren as "one index")
      native/seq    scatter once per group per micro-batch, per-group
                    device top-k, global merge — groups visited in a
                    sequential caller-thread loop
      native/async  the same pipeline with the per-group fan-out on the
                    ScatterGather worker pool

    Prints ms/query + the scatter/score/merge breakdown for each, verifies
    all three return identical rankings, and reports the native/async
    speedup over the legacy sequential scatter."""
    from repro.train.serve import BatcherConfig, RetrievalServer

    if extra_docs:                       # give each group real work
        ingest_documents(warren, doc_generator(999, extra_docs), batch=256)
        warren.index.merge_segments()    # serving cost, not merge state
    qs = queries * rounds
    results, times = {}, {}
    for mode in ("legacy", "native/seq", "native/async"):
        warren.set_async_scatter(mode == "native/async")
        server = RetrievalServer(
            warren, k=10, batcher=BatcherConfig(max_batch=16, max_wait_ms=4),
            sharded_native=mode != "legacy")
        for i in (1, 2, 4, 8, 16):               # warm every batch bucket
            server._handle(qs[:i])
        server.timings.reset()
        t0 = time.time()
        handles = [server.batcher.submit(q) for q in qs]
        results[mode] = [h.get(timeout=120) for h in handles]
        times[mode] = time.time() - t0
        print(f"  serving [{mode:>12}]: {1e3 * times[mode] / len(qs):7.2f} "
              f"ms/query wall — {server.timings.summary()}")
        server.close()
    same = all(
        [(d, round(s, 9)) for d, s in a] == [(d, round(s, 9)) for d, s in b]
        for mode in ("native/seq", "native/async")
        for a, b in zip(results["legacy"], results[mode]))
    # the per-query search path must also agree between scatter modes
    for enabled in (False, True):
        warren.set_async_scatter(enabled)
        with warren:
            hits = [warren.search(q, k=10) for q in queries]
        same = same and (hits == results.setdefault("_search", hits))
    speedup = times["legacy"] / times["native/async"]
    note = (" (smoke-sized corpus: parity check only, speedup needs the "
            "full run)" if smoke else "")
    print(f"  all paths identical: {same}; native/async speedup over the "
          f"legacy sequential scatter: {speedup:.2f}x{note}")
    if not same:
        raise SystemExit("serving paths diverged on the same corpus")
    return speedup


def rebalance_bench(shards: int = 3, replicas: int = 2,
                    smoke: bool = False) -> None:
    """Search latency impact of a LIVE split (and merge) under load.

    Writers keep committing and searchers keep querying while group 0 is
    split in two and the new group is merged back — all through
    ``repro.dist.rebalance.Rebalancer``.  Reports per-phase search latency
    (before / during / after the split), the measured writer stall (the
    routing-table swap window, the only moment writers block), verifies
    ZERO aborted reader transactions, and checks the final state is
    bit-identical to a single index holding exactly the committed docs.
    """
    from repro.dist.rebalance import Rebalancer
    from repro.dist.shard_router import ShardedWarren

    base_docs = 300 if smoke else 2500
    extra_per_writer = 40 if smoke else 250
    n_writers, n_searchers = (2, 2) if smoke else (3, 3)
    queries = ["school education student", "government law state",
               "stock money business", "vibration conductor wind"]

    warren = ShardedWarren(n_shards=shards, replicas=replicas)
    corpus = list(doc_generator(7, base_docs, mean_len=40))
    # small batches: every transaction's appends land on ONE group (hash of
    # the first doc), so fine batching is what spreads mass across groups
    ingest_documents(warren, corpus, batch=8)

    errors: list = []
    committed: list = []
    lat: list = []                       # (timestamp, seconds)
    stop = threading.Event()
    lock = threading.Lock()

    def writer(wid: int) -> None:
        wc = warren.clone()
        for i in range(extra_per_writer):
            docid, text = f"x{wid}-{i}", corpus[(wid * 31 + i) % len(corpus)][1]
            try:
                with wc:
                    wc.transaction()
                    index_document(wc, text, docid=docid)
                    wc.commit()
                with lock:
                    committed.append((docid, text))
            except Exception as e:        # noqa: BLE001 — must not happen
                errors.append(f"writer {docid}: {type(e).__name__}: {e}")
                return

    def searcher(sid: int) -> None:
        wc = warren.clone()
        i = 0
        while not stop.is_set():
            q = queries[(sid + i) % len(queries)]
            i += 1
            try:
                t0 = time.time()
                with wc:
                    wc.search(q, k=10)
                with lock:
                    lat.append((t0, time.time() - t0))
            except Exception as e:        # noqa: BLE001 — zero reader aborts
                errors.append(f"searcher: {type(e).__name__}: {e}")
                return

    threads = [threading.Thread(target=writer, args=(w,))
               for w in range(n_writers)]
    threads += [threading.Thread(target=searcher, args=(s,))
                for s in range(n_searchers)]
    for t in threads:
        t.start()
    try:
        time.sleep(0.3 if smoke else 1.0)    # a "before" latency window
        # split the busiest group (whole-txn append batches skew the hash)
        def _docs_of(g):
            grp = warren.groups[g]
            idx = grp.replicas[grp.first_alive()]
            return sum(len(s.content.records()) for s in idx._segments)
        source = max(range(warren.n_shards), key=_docs_of)
        rb = Rebalancer(warren)
        split_t0 = time.time()
        new_gid = rb.split_group(source)
        split_t1 = time.time()
        split_stats = rb.last_stats
        time.sleep(0.2 if smoke else 0.5)
        rb.merge_groups(source, new_gid)
        merge_stats = rb.last_stats
        for t in threads[:n_writers]:
            t.join(timeout=300)
        time.sleep(0.2)
    finally:
        stop.set()
    for t in threads[n_writers:]:
        t.join(timeout=30)

    if errors:
        raise SystemExit(f"rebalance bench saw reader/writer failures: "
                         f"{errors[:5]}")

    def pct(xs, p):
        if not xs:
            return float("nan")
        xs = sorted(xs)
        return 1e3 * xs[min(len(xs) - 1, int(p * len(xs)))]

    before = [d for ts, d in lat if ts < split_t0]
    during = [d for ts, d in lat if split_t0 <= ts <= split_t1]
    after = [d for ts, d in lat if ts > split_t1]
    print(f"# live rebalance under load: {shards}x{replicas} groups, "
          f"{len(committed)} concurrent commits, {len(lat)} searches, "
          f"0 aborted reader transactions")
    print(f"  split : {split_stats.summary()}")
    print(f"  merge : {merge_stats.summary()}")
    print(f"  search latency ms (p50/p95): "
          f"before {pct(before, .5):.2f}/{pct(before, .95):.2f}  "
          f"during-split {pct(during, .5):.2f}/{pct(during, .95):.2f} "
          f"({len(during)} queries)  "
          f"after {pct(after, .5):.2f}/{pct(after, .95):.2f}")
    print(f"  writer stall = swap window only: split "
          f"{1e3 * split_stats.swap_s:.2f} ms, merge "
          f"{1e3 * merge_stats.swap_s:.2f} ms")

    # parity: bit-identical to one index over exactly the committed docs
    single = Warren(DynamicIndex())
    ingest_documents(single, corpus, batch=128)
    ingest_documents(single, sorted(committed), batch=1)
    ok = True
    with warren, single:
        n_s = len(warren.annotations(":"))
        n_1 = len(single.annotations(":"))
        ok = ok and n_s == n_1
        for q in queries:
            got = sorted(round(s, 9) for _, s in warren.search(q, k=10))
            ref = sorted(round(s, 9) for _, s in score_bm25(single, q, k=10))
            ok = ok and got == ref
    print(f"  parity with single-index oracle over {n_s} docs: {ok}")
    if not ok:
        raise SystemExit("rebalanced warren diverged from the oracle")


def _emit_serving_bench(path: str, warren, queries, extra: dict) -> None:
    """Write a schema-versioned BENCH_serving.json from the obs registry.

    When nothing above ran a RetrievalServer (single-shard runs score via
    ``score_bm25``), a short native serving pass feeds the three
    ``serve_*_latency_ms`` histograms first, so the emitted file always
    carries scatter/score/merge percentiles.  Also prints the measured
    per-op cost of a disabled-registry observation — the "instrumentation
    left compiled in" overhead figure."""
    import timeit

    from repro import obs
    from repro.obs import bench as obs_bench
    from repro.train.serve import BatcherConfig, RetrievalServer

    reg = obs.registry()
    h = reg.histogram("serve_scatter_latency_ms", site="server")
    if h.count == 0:
        server = RetrievalServer(
            warren, k=10, batcher=BatcherConfig(max_batch=8, max_wait_ms=2))
        for q in queries * 3:
            server.query(q)
        server.close()

    n = 200_000
    t_on = timeit.timeit(lambda: h.observe(1.0), number=n) / n
    reg.disable()
    try:
        t_off = timeit.timeit(lambda: h.observe(1.0), number=n) / n
    finally:
        reg.enable()
    print(f"  metric overhead/op: enabled {1e9 * t_on:.0f} ns, "
          f"disabled {1e9 * t_off:.0f} ns")

    doc = obs_bench.emit(path, "serving", extra={"bench": extra})
    print(f"  wrote {path} ({doc['schema']}, kind=serving)")


def run(n_years: int = 3, files_per_year: int = 6, docs_per_file: int = 20,
        n_queries: int = 12, n_writers: int = 4, shards: int = 1,
        replicas: int = 1, async_scatter: bool = False, smoke: bool = False,
        emit_bench: str = None):
    if smoke:
        n_years, files_per_year, docs_per_file = 2, 2, 10
        n_queries, n_writers = 4, 2
    if shards > 1 or replicas > 1:
        from repro.dist.shard_router import ShardedWarren
        warren = ShardedWarren(n_shards=shards, replicas=replicas,
                               async_scatter=async_scatter)
    else:
        warren = Warren(DynamicIndex())
    rng = np.random.default_rng(0)
    queries = {}
    for y in range(n_years):
        for qi in range(n_queries // n_years):
            qid = f"y{y}q{qi}"
            queries[qid] = {"year": y, "text": None, "rel": set()}

    files = []
    for y in range(n_years):
        for f in range(files_per_year):
            docs = list(doc_generator(y * 100 + f, docs_per_file))
            files.append((y, f, docs))

    # assign relevance: each query gets terms from docs of its year
    for qid, q in queries.items():
        y = q["year"]
        _, text = files[y * files_per_year][2][hash(qid) % docs_per_file]
        words = text.split()
        q["text"] = " ".join(words[:4])
        for (fy, _, docs) in files:
            if fy == y:
                for docid, d in docs:
                    if sum(w in d for w in words[:4]) >= 2:
                        q["rel"].add(docid)

    ap_log = []
    log_lock = threading.Lock()
    stop = threading.Event()
    n_txn = [0]

    def appender(files_slice):
        wc = warren.clone()
        for (y, f, docs) in files_slice:
            # txn 1: append the file
            with wc:
                wc.transaction()
                for docid, text in docs:
                    index_document(wc, text, docid=docid)
                    wc.annotate(f"year:{y}", 0, 0)  # marker (see txn 3)
                wc.commit()
            # txn 2: re-read documents, write extra statistics
            with wc:
                wc.transaction()
                roots = wc.annotations(":")
                wc.annotate(f"stats:file:{y}:{f}", int(roots.starts[-1]),
                            int(roots.ends[-1]), float(len(roots)))
                wc.commit()
            # txn 3: relevance annotations
            with wc:
                wc.transaction()
                for docid, text in docs:
                    for qid, q in queries.items():
                        if docid in q["rel"]:
                            lst = wc.annotations("docid:" + docid)
                            if len(lst):
                                wc.annotate("rel:" + qid, int(lst.starts[0]),
                                            int(lst.ends[0]))
                wc.commit()
            n_txn[0] += 3

    def querier(qid):
        wc = warren.clone()
        q = queries[qid]
        while not stop.is_set():
            with wc:
                stats = collection_stats(wc)
                if stats.n_docs < 10:
                    time.sleep(0.01)
                    continue
                weights = expand_query(wc, q["text"], fb_docs=5, fb_terms=6,
                                       stats=stats)
                top = score_bm25(wc, "", k=50, weights=weights, stats=stats)
                # resolve doc addresses -> docids via judgments in the index
                rel_addrs = {int(s) for s in
                             wc.annotations("rel:" + qid).starts}
                ranked_rel = [d for d, _ in top]
                ap = average_precision(ranked_rel, rel_addrs
                                       ) if rel_addrs else 0.0
            with log_lock:
                ap_log.append((time.time(), qid, ap))

    def deleter():
        wc = warren.clone()
        while not stop.is_set():
            time.sleep(0.5)
            with wc:
                docs = wc.annotations(":")
                if len(docs) > (n_years - 1) * files_per_year * docs_per_file:
                    wc.transaction()
                    for i in range(docs_per_file):
                        wc.erase(int(docs.starts[i]), int(docs.ends[i]))
                    wc.commit()
                    n_txn[0] += 1

    t0 = time.time()
    per = max(len(files) // n_writers, 1)
    writers = [threading.Thread(target=appender,
                                args=(files[i * per:(i + 1) * per],))
               for i in range(n_writers)]
    readers = [threading.Thread(target=querier, args=(qid,))
               for qid in queries]
    d = threading.Thread(target=deleter)
    for t in writers + readers + [d]:
        t.start()
    for t in writers:
        t.join()
    time.sleep(0.5)        # let queries see the final state
    stop.set()
    for t in readers + [d]:
        t.join()
    wall = time.time() - t0
    warren.index.merge_segments()

    by_year = {}
    for ts, qid, ap in ap_log:
        y = queries[qid]["year"]
        by_year.setdefault(y, []).append(ap)
    print(f"# {len(files)} files, {n_txn[0]} transactions, "
          f"{len(ap_log)} query executions in {wall:.1f}s "
          f"({len(ap_log) / wall:.0f} q/s) — "
          f"{len(warren.index._segments)} subindexes after merge")
    for y in sorted(by_year):
        aps = by_year[y]
        print(f"  year {y}: final MAP {np.mean(aps[-len(aps)//4 or 1:]):.3f} "
              f"over {len(aps)} runs")
    if shards > 1:
        # sequential vs pooled scatter over the evolved corpus (plus extra
        # synthetic docs so each group does non-trivial per-query work)
        print("# scatter-gather serving (same corpus, fixed query set):")
        scatter_gather_bench(
            warren, [q["text"] for q in queries.values()],
            rounds=2 if smoke else 25,
            extra_docs=200 if smoke else 8000, smoke=smoke)
    if emit_bench:
        _emit_serving_bench(
            emit_bench, warren, [q["text"] for q in queries.values()],
            extra={"smoke": smoke, "shards": shards, "replicas": replicas,
                   "async_scatter": async_scatter, "wall_s": wall,
                   "query_executions": len(ap_log)})
    if shards > 1:
        warren.close()
    return ap_log


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=1,
                    help="partition the index over N shards (ShardedWarren)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="replicas per shard group (quorum commits)")
    ap.add_argument("--async-scatter", action="store_true",
                    help="fan per-group reads out on the ScatterGather "
                         "worker pool (repro.dist.parallel)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny corpus + few rounds: CI-sized sanity run "
                         "that still checks async == sequential results")
    ap.add_argument("--rebalance-mid-run", action="store_true",
                    help="run the live-rebalance benchmark instead: split + "
                         "merge a replica group while writers and searchers "
                         "run, report per-phase search latency, the writer "
                         "stall (swap window), and oracle parity")
    ap.add_argument("--years", type=int, default=3)
    ap.add_argument("--writers", type=int, default=4)
    ap.add_argument("--emit-bench", metavar="PATH", default=None,
                    help="write a schema-versioned BENCH_serving.json from "
                         "the obs registry snapshot (repro.obs.bench)")
    args = ap.parse_args()
    if args.rebalance_mid_run:
        rebalance_bench(shards=max(args.shards, 2), replicas=args.replicas,
                        smoke=args.smoke)
    else:
        run(n_years=args.years, n_writers=args.writers, shards=args.shards,
            replicas=args.replicas, async_scatter=args.async_scatter,
            smoke=args.smoke, emit_bench=args.emit_bench)

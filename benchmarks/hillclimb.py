import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb: hypothesis → change → re-lower → measure, for the three
chosen (arch × shape) pairs.  Each variant is lowered at scan-unroll 1 and 2
(two-point correction, see benchmarks/roofline.py) and the corrected
roofline terms are appended to experiments/perf_iterations.jsonl.

  PYTHONPATH=src python benchmarks/hillclimb.py [--pair N]
"""

import argparse
import dataclasses
import json
import sys
import time

import jax

from repro.configs import get_arch
from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_production_mesh
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.roofline import analyze, correct_scan_once  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                   "perf_iterations.jsonl")


def measure(arch, shape, mesh, label, fsdp="auto"):
    r1 = run_cell(arch, shape, mesh, "pod16x16", fsdp_mode=fsdp, unroll=1)
    if not r1["ok"]:
        return {"ok": False, "label": label, "error": r1["error"]}
    r2 = run_cell(arch, shape, mesh, "pod16x16", fsdp_mode=fsdp, unroll=2)
    rec = analyze(correct_scan_once(r1, r2 if r2["ok"] else None))
    return {"ok": True, "label": label, "arch": arch, "shape": shape,
            "terms": rec["terms"], "bound": rec["bound"],
            "mem_gib": rec.get("memory", {}).get("peak_bytes", 0) / 2**30,
            "useful_ratio": rec.get("useful_ratio"),
            "collectives": {k: v["bytes"] for k, v in
                            rec.get("collectives", {}).items()}}


def log(rec, hypothesis=""):
    rec["hypothesis"] = hypothesis
    with open(OUT, "a") as fh:
        fh.write(json.dumps(rec) + "\n")
    if rec["ok"]:
        t = rec["terms"]
        print(f"  [{rec['label']}] compute {t['compute_s']:.3e}s  "
              f"memory {t['memory_s']:.3e}s  coll {t['collective_s']:.3e}s  "
              f"bound={rec['bound']}  mem/dev={rec['mem_gib']:.1f}GiB",
              flush=True)
    else:
        print(f"  [{rec['label']}] FAILED: {rec['error'][:200]}", flush=True)


def with_config(arch, **replacements):
    """Temporarily replace the registered full config."""
    spec = get_arch(arch)
    original = spec.config
    spec.config = dataclasses.replace(original, **replacements)
    return original


def pair1(mesh):
    """qwen2.5-14b × prefill_32k — collective+memory bound.

    H1: the [B,H,G,S,S] attention scores (34 GiB/dev at S=32k) dominate the
    memory term and force GSPMD to reshard giant activations (the collective
    term).  Blocked flash-style attention (q_chunk × kv_chunk tiles) should
    cut the memory term by ~S/q_chunk on the attention part and remove the
    reshards.  Predicted: memory term ↓ 5-10×, collective ↓ 2×+."""
    arch, shape = "qwen2.5-14b", "prefill_32k"
    print(f"== pair 1: {arch} × {shape}")
    log(measure(arch, shape, mesh, "baseline"),
        "paper-agnostic baseline: full-matrix causal attention")
    orig = with_config(arch, attn_chunk_q=512, attn_chunk_kv=1024)
    try:
        log(measure(arch, shape, mesh, "it1-chunked-attn-512x1024"),
            "H1: blocked attention kills O(S^2) scores memory + reshards")
        get_arch(arch).config = dataclasses.replace(
            orig, attn_chunk_q=2048, attn_chunk_kv=4096)
        log(measure(arch, shape, mesh, "it2-chunked-attn-2048x4096"),
            "H2: bigger tiles amortize scan overhead; memory term still "
            "bounded, fewer loop iterations -> less per-step overhead")
    finally:
        get_arch(arch).config = orig


def pair2(mesh):
    """qwen3-moe-235b × train_4k — worst roofline fraction, memory bound.

    H1: GSPMD materializes the [E,C,D] dispatch buffers replicated (or
    gathers x to all experts) because nothing pins their layout; explicit
    with_sharding_constraint (E on 'model', C on 'data') turns dispatch into
    an all-to-all and shrinks the memory term several ×.
    H2: on top, blocked attention removes the S=4k score matrices."""
    arch, shape = "qwen3-moe-235b-a22b", "train_4k"
    print(f"== pair 2: {arch} × {shape}")
    log(measure(arch, shape, mesh, "baseline"),
        "baseline: unconstrained MoE dispatch layout")
    orig = with_config(arch, moe_shard="all")
    try:
        log(measure(arch, shape, mesh, "it1-moe-sharding-constraints"),
            "H1: pin [E,C,D] to ('model','data') -> a2a dispatch")
        get_arch(arch).config = dataclasses.replace(
            orig, moe_shard="all", attn_chunk_q=1024, attn_chunk_kv=2048)
        log(measure(arch, shape, mesh, "it2-+chunked-attn"),
            "H2: 4k scores matrices also big at 64 heads; chunk them")
    finally:
        get_arch(arch).config = orig


def pair3(mesh):
    """two-tower × train_batch — paper-representative (retrieval), collective
    bound.

    H1: the in-batch softmax materializes a [65536, 65536] f32 logits matrix
    (17 GiB) that GSPMD must reshard between the two tower shardings — the
    entire collective term.  Streaming the log-normalizer over item chunks
    (never materializing [B,B]) should collapse both memory and collective
    terms.  Predicted: collective ↓ ~10×, memory ↓ ~3×."""
    arch, shape = "two-tower-retrieval", "train_batch"
    print(f"== pair 3: {arch} × {shape}")
    log(measure(arch, shape, mesh, "baseline"),
        "baseline: full [B,B] in-batch softmax")
    orig = with_config(arch, loss_chunk=4096)
    try:
        log(measure(arch, shape, mesh, "it1-streamed-softmax-4096"),
            "H1: stream logsumexp over 4096-item chunks, no [B,B] matrix")
        get_arch(arch).config = dataclasses.replace(orig, loss_chunk=16384)
        log(measure(arch, shape, mesh, "it2-streamed-softmax-16384"),
            "H2: larger chunks -> fewer scan steps, better matmul shapes")
    finally:
        get_arch(arch).config = orig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", type=int, default=0, help="0 = all")
    args = ap.parse_args()
    mesh = make_production_mesh()
    pairs = {1: pair1, 2: pair2, 3: pair3}
    for i, fn in pairs.items():
        if args.pair in (0, i):
            fn(mesh)


if __name__ == "__main__":
    main()

"""Paper Fig. 6 analogue: the 9 example queries over the heterogeneous JSON
collection, timed on both static and dynamic indexes.

Offline stand-in for Özler's MongoDB collection (DESIGN §9.3): matched
schema heterogeneity, scaled by --scale.
"""

import tempfile
import time

import numpy as np

from repro.core import (DynamicIndex, StaticIndex, Warren, add_json,
                        annotate_dates, write_static)
from repro.core.gcl import BothOf, ContainedIn, Containing, OneOf, Phrase, Term
from repro.data.synth import json_collection


def build_dynamic(scale: float):
    w = Warren(DynamicIndex())
    data = json_collection(seed=0, scale=scale)
    t0 = time.time()
    with w:
        w.transaction()
        for name, objs in data.items():
            for obj in objs:
                add_json(w, obj, collection=f"Files/{name}.json")
        w.commit()
    with w:
        w.transaction()
        annotate_dates(w, [":created:", ":created_at:$date:", ":date:"])
        w.commit()
    build_s = time.time() - t0
    n = sum(len(v) for v in data.values())
    return w, n, build_s


def _phrase(reader, text):
    words = text.split()
    terms = [Term(reader.annotations(t)) for t in words]
    return terms[0] if len(terms) == 1 else Phrase(terms)


def queries(reader):
    """9 queries; each returns a count or aggregate (reader = warren-like)."""
    def h(f):
        return Term(reader.annotations(f))

    def q1():
        vals = [v for _, _, v in ContainedIn(
            h(":rating:"), h("Files/restaurant.json")).solutions()]
        return (min(vals), sum(vals) / len(vals), max(vals))

    def q2():
        return len(ContainedIn(Containing(h(":city:"),
                                          _phrase(reader, "new york")),
                               h("Files/zips.json")).solutions())

    def q3():
        node = ContainedIn(
            h(":name:"),
            Containing(h("Files/companies.json"),
                       ContainedIn(Containing(h(":category_code:"),
                                              _phrase(reader, "nanotech")),
                                   h("Files/companies.json"))))
        return len(node.solutions())

    def q4():
        return len(ContainedIn(OneOf(h(":title:"), h(":authors:")),
                               h("Files/books.json")).solutions())

    def q5():
        return len(ContainedIn(h(":"), h("Files/trades.json")).solutions())

    def q6():
        # GROUP BY result over inspections (translate + aggregate)
        from repro.core.json_store import value_of
        groups = {}
        for p, q, _ in ContainedIn(h(":result:"),
                                   h("Files/city_inspections.json")).solutions():
            toks = reader.tokens(int(p), int(q))
            key = " ".join(t for t in toks if len(t) > 1) if toks else "?"
            groups[key] = groups.get(key, 0) + 1
        return len(groups)

    def q7():
        return len(reader.annotations(":"))

    def q8():
        return len(ContainedIn(h(":title:"),
                               Containing(h("Files/books.json"),
                                          h("year=2008"))).solutions())

    def q9():
        return len(Containing(h(":"), BothOf(h("year=2008"),
                                             h("month=06"))).solutions())

    return [("1 restaurant rating stats", q1),
            ("2 zips in New York", q2),
            ("3 nanotech company names", q3),
            ("4 book titles+authors", q4),
            ("5 count trades", q5),
            ("6 inspections GROUP BY result", q6),
            ("7 count all objects", q7),
            ("8 books published 2008", q8),
            ("9 objects created 2008-06", q9)]


def run(scale: float = 1.0, repeats: int = 3):
    w, n, build_dyn = build_dynamic(scale)
    with tempfile.TemporaryDirectory() as td:
        t0 = time.time()
        write_static(w.index, td + "/static")
        build_static = time.time() - t0
        static = StaticIndex(td + "/static")

        rows = []
        with w:
            for name, fn in queries(w):
                t0 = time.time()
                for _ in range(repeats):
                    result = fn()
                dyn_ms = (time.time() - t0) / repeats * 1e3
                rows.append([name, result, dyn_ms])
        for row, (name, fn) in zip(rows, queries(static)):
            t0 = time.time()
            for _ in range(repeats):
                result = fn()
            row.append((time.time() - t0) / repeats * 1e3)
            assert row[1] == result or isinstance(result, tuple), \
                f"static/dynamic disagree on {name}"
        static.close()
    print(f"# {n} objects; build: dynamic {build_dyn:.2f}s, "
          f"static {build_static:.2f}s")
    print(f"{'query':35s} {'result':>18s} {'dynamic':>10s} {'static':>10s}")
    for name, result, dyn_ms, st_ms in rows:
        r = (f"{result[1]:.2f}" if isinstance(result, tuple) else str(result))
        print(f"{name:35s} {r:>18s} {dyn_ms:9.2f}ms {st_ms:9.2f}ms")
    return rows


if __name__ == "__main__":
    run()

"""Query engines compared: lazy host GCL vs vectorized JAX vs Pallas kernel.

Covers (a) structural containment joins and (b) BM25 top-k — the two hot
query paths — at increasing list sizes.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gcl
from repro.core.annotation import reduce_minimal
from repro.core.vectorized import bm25_topk, contained_in_mask, pack
from repro.kernels import bm25_blockmax_topk, interval_join


def random_gc(rng, n, span):
    s = np.sort(rng.choice(span, size=min(n, span), replace=False))
    e = s + rng.integers(0, 30, size=len(s))
    return reduce_minimal(s, e, np.zeros(len(s)))


def bench_joins(sizes=(1000, 10_000, 100_000), repeats=5):
    print("## containment join A ⊲ B (|B| = |A|/10)")
    print(f"{'|A|':>9s} {'lazy host':>12s} {'vector jnp':>12s} "
          f"{'pallas':>12s}")
    rng = np.random.default_rng(0)
    for n in sizes:
        A = random_gc(rng, n, n * 20)
        B = random_gc(rng, n // 10, n * 20)
        t0 = time.time()
        node = gcl.ContainedIn(gcl.Term(A), gcl.Term(B))
        lazy = node.solutions()
        t_lazy = time.time() - t0

        a_s, a_e, _ = pack(A.starts, A.ends)
        b_s, b_e, _ = pack(B.starts, B.ends)
        f = jax.jit(contained_in_mask)
        f(a_s, a_e, b_s, b_e).block_until_ready()
        t0 = time.time()
        for _ in range(repeats):
            mask = f(a_s, a_e, b_s, b_e).block_until_ready()
        t_vec = (time.time() - t0) / repeats
        assert int(np.asarray(mask).sum()) == len(lazy)

        interval_join(a_s, a_e, b_s, b_e)  # warm
        t0 = time.time()
        m2 = interval_join(a_s, a_e, b_s, b_e)
        jax.block_until_ready(m2)
        t_pl = time.time() - t0
        print(f"{n:9d} {1e3 * t_lazy:10.2f}ms {1e3 * t_vec:10.2f}ms "
              f"{1e3 * t_pl:10.2f}ms")


def bench_bm25(n_docs=200_000, n_terms=4, postings=20_000, repeats=3):
    print(f"\n## BM25 top-10, {n_docs} docs, {n_terms} terms × {postings} "
          f"postings")
    rng = np.random.default_rng(1)
    doc_idx = np.stack([np.sort(rng.choice(n_docs, size=postings,
                                           replace=False))
                        for _ in range(n_terms)]).astype(np.int32)
    impacts = rng.random((n_terms, postings)).astype(np.float32) * 3

    # host numpy
    t0 = time.time()
    for _ in range(repeats):
        acc = np.zeros(n_docs, np.float32)
        for t in range(n_terms):
            np.add.at(acc, doc_idx[t], impacts[t])
        top = np.argpartition(-acc, 10)[:10]
    t_host = (time.time() - t0) / repeats

    # vectorized device scatter-add
    di = jnp.asarray(doc_idx)[None]
    im = jnp.asarray(impacts)[None]
    qm = jnp.ones((1, n_terms), jnp.float32)
    bm25_topk(di, im, qm, n_docs=n_docs, k=10)  # warm
    t0 = time.time()
    for _ in range(repeats):
        s, i = bm25_topk(di, im, qm, n_docs=n_docs, k=10)
        jax.block_until_ready(s)
    t_vec = (time.time() - t0) / repeats

    # block-impact + pallas blockmax
    bs = 256
    nb = -(-n_docs // bs)
    blocked = np.zeros((n_terms, nb, bs), np.float32)
    blocked[np.arange(n_terms)[:, None], doc_idx // bs, doc_idx % bs] = impacts
    bmax = blocked.max(axis=2)
    jb, jm = jnp.asarray(blocked), jnp.asarray(bmax)
    bm25_blockmax_topk(jb, jm, k=10)  # warm
    t0 = time.time()
    s2, i2 = bm25_blockmax_topk(jb, jm, k=10)
    jax.block_until_ready(s2)
    t_kernel = time.time() - t0

    np.testing.assert_allclose(np.sort(np.asarray(s)[0])[::-1][:10],
                               np.sort(np.asarray(s2))[::-1][:10], rtol=1e-5)
    print(f"host numpy        {1e3 * t_host:10.2f}ms")
    print(f"vector device     {1e3 * t_vec:10.2f}ms")
    print(f"pallas block-max  {1e3 * t_kernel:10.2f}ms (interpret mode)")


def run():
    bench_joins()
    bench_bm25()


if __name__ == "__main__":
    run()

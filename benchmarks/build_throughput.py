"""Index-construction throughput (paper §4 build-time discussion):
single-writer vs multi-writer dynamic build, static freeze, and — with
``--tiered`` — hot-tier build rate under background LSM compaction, with
the compaction pause time (the only reader/writer-visible stall) reported
per run so regressions show up per-PR in the CI smoke job.

``--mmap`` is the larger-than-memory serving benchmark: it freezes the
corpus into a v2 block run, then serves BM25 + translate through an
mmap'd :class:`StaticIndex` behind a block cache sized at <= 1/10 of the
run, asserting (in ``--smoke``) bit-identical answers to the resident
dynamic oracle, exact cache byte accounting, and a serving-phase heap
peak below the on-disk corpus size — i.e. the corpus never goes
resident."""

import argparse
import tempfile
import threading
import time

from repro.core import DynamicIndex, Warren, index_document, write_static
from repro.data.synth import doc_generator


def run(n_docs: int = 1500, n_writers: int = 4):
    # single writer
    w = Warren(DynamicIndex())
    docs = list(doc_generator(0, n_docs))
    t0 = time.time()
    with w:
        w.transaction()
        for docid, text in docs:
            index_document(w, text, docid=docid)
        w.commit()
    single_s = time.time() - t0

    # multi writer (one txn per chunk per thread)
    w2 = Warren(DynamicIndex())
    per = n_docs // n_writers
    t0 = time.time()

    def worker(tid):
        wc = w2.clone()
        chunk = docs[tid * per:(tid + 1) * per]
        for i in range(0, len(chunk), 64):
            with wc:
                wc.transaction()
                for docid, text in chunk[i:i + 64]:
                    index_document(wc, text, docid=docid)
                wc.commit()

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_writers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    multi_s = time.time() - t0
    w2.index.merge_segments()

    with tempfile.TemporaryDirectory() as td:
        t0 = time.time()
        write_static(w.index, td + "/s")
        static_s = time.time() - t0

    tok = sum(len(t.split()) for _, t in docs)
    print(f"# {n_docs} docs, ~{tok} words")
    print(f"single-writer dynamic: {single_s:6.2f}s "
          f"({n_docs / single_s:7.0f} docs/s)")
    print(f"{n_writers}-writer dynamic:     {multi_s:6.2f}s "
          f"({n_docs / multi_s:7.0f} docs/s)")
    print(f"static freeze:         {static_s:6.2f}s")
    _gauge_build(n_docs, single_s, multi_s, static_s)
    return {"single_s": single_s, "multi_s": multi_s, "static_s": static_s}


def _gauge_build(n_docs, single_s, multi_s, static_s=None) -> None:
    from repro import obs

    reg = obs.registry()
    reg.gauge("build_docs_per_s", "dynamic build throughput",
              mode="single").set(n_docs / single_s)
    if multi_s is not None:
        reg.gauge("build_docs_per_s", mode="multi").set(n_docs / multi_s)
    if static_s is not None:
        reg.gauge("build_static_freeze_s",
                  "wall time to freeze the build into a static run"
                  ).set(static_s)


def _emit_build_bench(path: str, extra: dict) -> None:
    from repro.obs import bench as obs_bench

    doc = obs_bench.emit(path, "build", extra={"bench": extra})
    print(f"  wrote {path} ({doc['schema']}, kind=build)")


def run_tiered(n_docs: int = 1500, batch: int = 64,
               freeze_segments: int = 4, max_runs: int = 3,
               smoke: bool = False):
    """Hot-tier build rate with the background compactor freezing and
    merging concurrently; reports run counts and compaction pause times."""
    from repro.core import score_bm25
    from repro.tiered import Compactor, TieredStore

    docs = list(doc_generator(0, n_docs))
    with tempfile.TemporaryDirectory() as td:
        store = TieredStore(td + "/tiered", auto_merge_threshold=8)
        compactor = Compactor(store, freeze_segments=freeze_segments,
                              max_runs=max_runs, interval_s=0.01).start()
        w = store.warren()
        t0 = time.time()
        for i in range(0, len(docs), batch):
            with w:
                w.transaction()
                for docid, text in docs[i:i + batch]:
                    index_document(w, text, docid=docid)
                w.commit()
        build_s = time.time() - t0
        compactor.stop(drain=True)
        m = store.metrics
        with w:
            n_indexed = len(w.annotations(":"))
            top = score_bm25(w, "school education student", k=10)
        ok = n_indexed == n_docs
        print(f"# tiered build: {n_docs} docs, batch {batch}")
        print(f"hot-tier build:        {build_s:6.2f}s "
              f"({n_docs / build_s:7.0f} docs/s)")
        print(f"compaction:            {m.summary()}")
        print(f"state:                 {store.n_runs} runs, "
              f"{len(store.hot._segments)} hot segments, "
              f"manifest v{store.manifest.version}")
        print(f"post-compaction reads: {n_indexed}/{n_docs} docs visible, "
              f"top-10 len {len(top)} -> {'OK' if ok else 'MISMATCH'}")
        store.close()
        if smoke and not ok:
            raise SystemExit("tiered smoke: indexed-doc count mismatch")
        if smoke and m.n_freezes == 0:
            raise SystemExit("tiered smoke: compactor never froze the "
                             "hot tier")
        _gauge_build(n_docs, build_s, None)
        return {"build_s": build_s, "n_freezes": m.n_freezes,
                "n_merges": m.n_merges, "total_pause_s": m.total_pause_s,
                "max_pause_s": m.max_pause_s}


def run_mmap(n_docs: int = 1500, rounds: int = 3, smoke: bool = False):
    """Freeze ``n_docs`` into one v2 block run, then serve it through an
    mmap'd StaticIndex whose block cache holds <= 1/10 of the run bytes.
    Returns serving percentiles + cache stats; ``smoke`` turns the
    invariants (parity, accounting, ratio, bounded heap) into hard
    failures for CI."""
    import gc
    import tracemalloc

    import numpy as np

    from repro.core import score_bm25
    from repro.core.runfile import DEFAULT_BLOCK_SIZE
    from repro.core.static import LazyContentStore, StaticIndex, run_bytes
    from repro.tiered.cache import BlockCache

    queries = ["school education student", "government law state",
               "money business company", "water room house"]
    docs = list(doc_generator(0, n_docs))
    with tempfile.TemporaryDirectory() as td:
        w = Warren(DynamicIndex())
        t0 = time.time()
        with w:
            w.transaction()
            for docid, text in docs:
                index_document(w, text, docid=docid)
            w.commit()
        build_s = time.time() - t0
        d = td + "/run"
        write_static(w.index, d)
        corpus_bytes = run_bytes(d)

        # reference answers from the RESIDENT dynamic oracle (the repo's
        # invariant: static layout is bit-identical to the dynamic index
        # holding the same committed transactions)
        with w:
            ref_scores = {q: score_bm25(w, q, k=10) for q in queries}
            sample = [f"docid:doc0_{i}" for i in range(0, n_docs,
                                                       max(1, n_docs // 37))]
            ref_texts = {}
            for f in sample:
                lst = w.annotations(f)
                ref_texts[f] = w.translate(int(lst.starts[0]),
                                           int(lst.ends[0]))
        del w
        gc.collect()

        capacity = max(8 * DEFAULT_BLOCK_SIZE, corpus_bytes // 16)
        ratio = corpus_bytes / capacity
        cache = BlockCache(capacity_bytes=capacity)

        tracemalloc.start()
        si = StaticIndex(d, block_cache=cache)
        assert isinstance(si.content, LazyContentStore)
        lat = []
        parity_ok = True
        for _ in range(rounds):
            for q in queries:
                t0 = time.time()
                got = score_bm25(si, q, k=10)
                lat.append(time.time() - t0)
                ref = ref_scores[q]
                if [g for g, _ in got] != [r for r, _ in ref] or \
                        not np.allclose([s for _, s in got],
                                        [s for _, s in ref], rtol=1e-12):
                    parity_ok = False
            for f, want in ref_texts.items():
                lst = si.annotations(f)
                if si.translate(int(lst.starts[0]),
                                int(lst.ends[0])) != want:
                    parity_ok = False
        _, heap_peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()

        stats = cache.stats()
        cache.check_accounting()
        si.close()
        lat.sort()
        p95 = lat[int(0.95 * (len(lat) - 1))]

        reg = None
        from repro import obs
        reg = obs.registry()
        reg.gauge("mmap_serve_p95_ms",
                  "p95 query latency serving a v2 run via mmap + block "
                  "cache").set(1e3 * p95)
        reg.gauge("mmap_corpus_over_cache",
                  "on-disk run bytes over block-cache capacity (>=10 "
                  "proves larger-than-memory serving)").set(ratio)

        print(f"# mmap serve: {n_docs} docs, run {corpus_bytes} B, "
              f"cache {capacity} B ({ratio:.1f}x)")
        print(f"dynamic build:         {build_s:6.2f}s "
              f"({n_docs / build_s:7.0f} docs/s)")
        print(f"serve p95:             {1e3 * p95:6.2f} ms over "
              f"{len(lat)} queries")
        print(f"cache:                 {stats['hits']} hits / "
              f"{stats['misses']} misses / {stats['evictions']} evictions, "
              f"{stats['bytes']}/{capacity} B resident")
        print(f"serving heap peak:     {heap_peak} B "
              f"({'OK' if heap_peak < corpus_bytes else 'UNBOUNDED'} vs "
              f"corpus {corpus_bytes} B)")
        print(f"parity vs oracle:      {'OK' if parity_ok else 'MISMATCH'}")
        if smoke:
            if not parity_ok:
                raise SystemExit("mmap smoke: answers diverge from the "
                                 "resident oracle")
            if ratio < 10:
                raise SystemExit(f"mmap smoke: corpus only {ratio:.1f}x "
                                 "cache capacity (need >= 10x)")
            if stats["bytes"] > capacity:
                raise SystemExit("mmap smoke: cache over capacity")
            if stats["evictions"] == 0:
                raise SystemExit("mmap smoke: cache never evicted — "
                                 "corpus fit in memory, gate proved "
                                 "nothing")
            if heap_peak >= corpus_bytes:
                raise SystemExit(f"mmap smoke: serving heap peak "
                                 f"{heap_peak} B not bounded below the "
                                 f"{corpus_bytes} B corpus")
        _gauge_build(n_docs, build_s, None)
        return {"build_s": build_s, "serve_p95_ms": 1e3 * p95,
                "corpus_bytes": corpus_bytes, "cache_capacity": capacity,
                "corpus_over_cache": ratio, "heap_peak": heap_peak,
                "cache_hits": stats["hits"], "cache_misses": stats["misses"],
                "cache_evictions": stats["evictions"],
                "parity_ok": parity_ok}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=1500)
    ap.add_argument("--writers", type=int, default=4)
    ap.add_argument("--tiered", action="store_true",
                    help="benchmark the tiered engine (hot build rate + "
                         "compaction pause time)")
    ap.add_argument("--mmap", action="store_true",
                    help="benchmark larger-than-memory serving: mmap v2 "
                         "run + admission-controlled block cache")
    ap.add_argument("--smoke", action="store_true",
                    help="fail loudly on lost docs, an idle compactor, or "
                         "a broken mmap-serving invariant (CI guard)")
    ap.add_argument("--emit-bench", metavar="PATH", default=None,
                    help="write a schema-versioned BENCH_build.json from "
                         "the obs registry snapshot (repro.obs.bench)")
    args = ap.parse_args()
    if args.tiered:
        res = run_tiered(args.docs, smoke=args.smoke)
    elif args.mmap:
        res = run_mmap(args.docs, smoke=args.smoke)
    else:
        res = run(args.docs, args.writers)
    if args.emit_bench:
        _emit_build_bench(args.emit_bench,
                          extra={"docs": args.docs, "tiered": args.tiered,
                                 "mmap": args.mmap, "smoke": args.smoke,
                                 **res})

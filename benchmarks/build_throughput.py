"""Index-construction throughput (paper §4 build-time discussion):
single-writer vs multi-writer dynamic build, and static freeze."""

import tempfile
import threading
import time

from repro.core import DynamicIndex, Warren, index_document, write_static
from repro.data.synth import doc_generator


def run(n_docs: int = 1500, n_writers: int = 4):
    # single writer
    w = Warren(DynamicIndex())
    docs = list(doc_generator(0, n_docs))
    t0 = time.time()
    with w:
        w.transaction()
        for docid, text in docs:
            index_document(w, text, docid=docid)
        w.commit()
    single_s = time.time() - t0

    # multi writer (one txn per chunk per thread)
    w2 = Warren(DynamicIndex())
    per = n_docs // n_writers
    t0 = time.time()

    def worker(tid):
        wc = w2.clone()
        chunk = docs[tid * per:(tid + 1) * per]
        for i in range(0, len(chunk), 64):
            with wc:
                wc.transaction()
                for docid, text in chunk[i:i + 64]:
                    index_document(wc, text, docid=docid)
                wc.commit()

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_writers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    multi_s = time.time() - t0
    w2.index.merge_segments()

    with tempfile.TemporaryDirectory() as td:
        t0 = time.time()
        write_static(w.index, td + "/s")
        static_s = time.time() - t0

    tok = sum(len(t.split()) for _, t in docs)
    print(f"# {n_docs} docs, ~{tok} words")
    print(f"single-writer dynamic: {single_s:6.2f}s "
          f"({n_docs / single_s:7.0f} docs/s)")
    print(f"{n_writers}-writer dynamic:     {multi_s:6.2f}s "
          f"({n_docs / multi_s:7.0f} docs/s)")
    print(f"static freeze:         {static_s:6.2f}s")
    return {"single_s": single_s, "multi_s": multi_s, "static_s": static_s}


if __name__ == "__main__":
    run()

"""Roofline analysis from the dry-run artifacts (assignment §Roofline).

TPU v5e per chip: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

For every (arch × shape × mesh) record in experiments/dryrun_*.jsonl:
  compute term    = HLO_FLOPs_per_device / 197e12            [s]
  memory term     = HLO_bytes_per_device / 819e9             [s]
  collective term = collective_bytes_per_device / 50e9       [s]
(cost_analysis on the SPMD-partitioned module is per-device, so dividing by
per-chip peaks gives the same number as global/(chips × peak).)

Also: MODEL_FLOPS = 6·N·D (train) / 2·N·D (serve) with N = active params,
D = processed tokens/examples — and the usefulness ratio MODEL/HLO that
catches remat/redundancy waste.
"""

from __future__ import annotations

import json
import os
import sys
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_LM_TOKENS = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32_768,
              "decode_32k": 128, "long_500k": 1}


def model_flops(arch: str, shape: str) -> Optional[float]:
    """Analytic MODEL_FLOPS per step (6·N·D dense-train convention)."""
    from repro.configs import get_arch
    spec = get_arch(arch)
    if spec.family == "lm":
        cfg = spec.config
        n = cfg.active_param_count()
        d = _LM_TOKENS[shape]
        if shape == "train_4k":
            return 6.0 * n * d
        return 2.0 * n * d          # forward-only serving
    if spec.family == "gnn":
        return None                  # segment/gather dominated; no 6ND analogue
    # recsys: dense-compute params × examples (tables are lookups, ~0 flops)
    import jax
    import numpy as np
    cfg = spec.config
    params = spec.abstract_params()
    dense = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        p = "/".join(getattr(k, "key", str(k)) for k in path)
        if any(t in p for t in ("table", "embed", "linear/")):
            continue
        dense += int(np.prod(leaf.shape))
    b = {"train_batch": 65_536, "serve_p99": 512, "serve_bulk": 262_144,
         "retrieval_cand": 1_000_000}[shape]
    mult = 6.0 if shape == "train_batch" else 2.0
    return mult * dense * b


_SCAN_TRIPS = {"qwen2.5-14b": 48, "yi-9b": 48, "internlm2-1.8b": 24,
               "qwen3-moe-235b-a22b": 94, "qwen2-moe-a2.7b": 24,
               "nequip": 5, "sasrec": 2}


def correct_scan_once(r1: Dict, r2: Optional[Dict]) -> Dict:
    """XLA cost_analysis counts a while-loop body ONCE regardless of trip
    count.  Two-point probe: lowering the same cell with scan unroll=1 vs
    unroll=2 differs by exactly one layer's cost, so

        true = u1 + (L - 1) · (u2 - u1)

    for FLOPs, bytes and collective bytes alike (the unrolled body contains
    two copies of the layer's collectives)."""
    L = _SCAN_TRIPS.get(r1["arch"], 1)
    if L <= 1 or r2 is None or not r2.get("ok"):
        return r1
    out = dict(r1)
    c1, c2 = dict(r1.get("cost", {})), r2.get("cost", {})
    for key in ("flops", "bytes accessed"):
        if key in c1 and key in c2:
            per_layer = max(c2[key] - c1[key], 0.0)
            c1[key] = c1[key] + (L - 1) * per_layer
    out["cost"] = c1
    coll1 = {k: dict(v) for k, v in r1.get("collectives", {}).items()}
    coll2 = r2.get("collectives", {})
    for k in set(coll1) | set(coll2):
        b1 = coll1.get(k, {"bytes": 0.0, "count": 0})
        b2 = coll2.get(k, {"bytes": 0.0, "count": 0})
        per_layer = max(b2["bytes"] - b1["bytes"], 0.0)
        b1["bytes"] = b1["bytes"] + (L - 1) * per_layer
        coll1[k] = b1
    out["collectives"] = coll1
    out["scan_corrected"] = True
    return out


def analyze(record: Dict) -> Dict:
    cost = record.get("cost", {})
    flops = cost.get("flops", 0.0)
    nbytes = cost.get("bytes accessed", 0.0)
    coll = sum(v["bytes"] for v in record.get("collectives", {}).values())
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": nbytes / HBM_BW,
        "collective_s": coll / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(record["arch"], record["shape"])
    n_dev = record.get("n_devices", 256)
    ratio = (mf / (flops * n_dev)) if (mf and flops) else None
    bound = {"compute_s": "compute", "memory_s": "memory",
             "collective_s": "collective"}[dominant]
    suggestion = {
        "compute": "raise MXU efficiency: fuse elementwise chains, bf16 "
                   "matmuls, avoid remat recompute",
        "memory": "cut HBM traffic: block/flash attention, fused scans, "
                  "smaller activation dtypes, better layouts",
        "collective": "reshard to reduce resharding collectives, overlap "
                      "collectives with compute, hierarchical/compressed "
                      "reduction",
    }[bound]
    return {**record, "terms": terms, "bound": bound, "model_flops": mf,
            "useful_ratio": ratio, "suggestion": suggestion,
            "collective_bytes": coll}


def load(path: str, u2_path: str = None):
    out = []
    if not os.path.exists(path):
        return out
    probes = {}
    if u2_path and os.path.exists(u2_path):
        with open(u2_path) as fh:
            for line in fh:
                r = json.loads(line)
                probes[(r["arch"], r["shape"])] = r
    with open(path) as fh:
        for line in fh:
            r = json.loads(line)
            if r.get("ok"):
                r = correct_scan_once(r, probes.get((r["arch"], r["shape"])))
                out.append(analyze(r))
    return out


def table(records, title: str) -> str:
    lines = [f"### {title}", "",
             "| arch | shape | compute s | memory s | coll s | bound | "
             "mem GiB/dev | MODEL/HLO | roofline frac |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in records:
        t = r["terms"]
        peak = max(t.values())
        # roofline fraction: time the dominant term says vs time an ideal
        # compute-only execution would take
        frac = t["compute_s"] / peak if peak > 0 else 0.0
        mem = r.get("memory", {}).get("peak_bytes", 0) / 2**30
        ur = f"{r['useful_ratio']:.2f}" if r.get("useful_ratio") else "-"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.2e} | "
            f"{t['memory_s']:.2e} | {t['collective_s']:.2e} | {r['bound']} | "
            f"{mem:.1f} | {ur} | {frac:.2f} |")
    return "\n".join(lines)


# --------------------------------------------------------------------- #
# Kernel benches: achieved vs roofline for the two Pallas kernels        #
# --------------------------------------------------------------------- #

def _time_op(fn, *, warmup: int = 1, reps: int = 3) -> float:
    """Median wall seconds per call; blocks on the result each rep."""
    import time as _time

    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn())
    samples = []
    for _ in range(reps):
        t0 = _time.perf_counter()
        jax.block_until_ready(fn())
        samples.append(_time.perf_counter() - t0)
    samples.sort()
    return samples[len(samples) // 2]


def _phase_attribution(kernel: str, host_arrays, compute_fn,
                       reps: int = 3) -> None:
    """DMA-vs-compute attribution: time host->device staging of the
    kernel's inputs separately from compute on already-resident arrays,
    into the ``kernel_phase_ms{kernel,phase}`` histograms — the split
    that tells you whether a slow kernel is data-starved or MXU-bound."""
    import jax

    from repro import obs
    for _ in range(reps):
        with obs.phase_timer(kernel, "dma"):
            dev = [jax.block_until_ready(jax.device_put(a))
                   for a in host_arrays]
        with obs.phase_timer(kernel, "compute"):
            jax.block_until_ready(compute_fn(*dev))


def kernel_bench(smoke: bool = False):
    """Time ``bm25_blockmax_topk`` and ``interval_join`` at a few sizes and
    report achieved GFLOP/s against the roofline bound (min of the compute
    and HBM ceilings for each kernel's FLOP/byte mix).  Results land in the
    obs registry as ``kernel_achieved_gflops{kernel,size}``,
    ``kernel_roofline_frac{kernel,size}`` and the per-phase
    ``kernel_phase_ms{kernel,phase}`` (DMA staging vs resident compute)
    so ``--emit-bench`` can persist them as the BENCH_kernels.json
    trajectory point."""
    import jax.numpy as jnp
    import numpy as np

    from repro import obs
    from repro.kernels.bm25_blockmax.ops import bm25_blockmax_topk
    from repro.kernels.interval_join.ops import interval_join

    reg = obs.registry()
    rng = np.random.default_rng(0)
    rows = []

    bm25_sizes = [(8, 32, 64)] if smoke else [(8, 32, 64), (16, 128, 64)]
    for t, nb, bs in bm25_sizes:
        imp_np = np.asarray(
            rng.random((t, nb, bs), dtype=np.float32) *
            (rng.random((t, nb, bs)) < 0.3), dtype=np.float32)
        bmax_np = imp_np.max(axis=2)
        impacts, bmax = jnp.asarray(imp_np), jnp.asarray(bmax_np)
        fn = lambda: bm25_blockmax_topk(impacts, bmax, k=10)  # noqa: E731
        secs = _time_op(fn)
        _phase_attribution(
            "bm25_blockmax", [imp_np, bmax_np],
            lambda i, b: bm25_blockmax_topk(i, b, k=10))
        # per-doc score = sum over T term impacts -> ~T adds per (block, slot)
        flops = float(t * nb * bs)
        nbytes = 4.0 * (t * nb * bs + t * nb)        # impacts + block maxima
        rows.append(("bm25_blockmax", f"{t}x{nb}x{bs}", secs, flops, nbytes))

    join_sizes = [1024] if smoke else [1024, 4096]
    for n in join_sizes:
        a_s_np = rng.integers(0, 1 << 20, n).astype(np.int32)
        a_e_np = a_s_np + rng.integers(1, 64, n).astype(np.int32)
        b_s_np = rng.integers(0, 1 << 20, n).astype(np.int32)
        b_e_np = b_s_np + rng.integers(64, 4096, n).astype(np.int32)
        a_s, a_e = jnp.asarray(a_s_np), jnp.asarray(a_e_np)
        b_s, b_e = jnp.asarray(b_s_np), jnp.asarray(b_e_np)
        fn = lambda: interval_join(a_s, a_e, b_s, b_e)  # noqa: E731
        secs = _time_op(fn)
        _phase_attribution(
            "interval_join", [a_s_np, a_e_np, b_s_np, b_e_np],
            interval_join)
        flops = 3.0 * n * n                     # 2 compares + OR-combine/pair
        nbytes = 4.0 * (4 * n + n)              # four int32 inputs + mask out
        rows.append(("interval_join", f"{n}x{n}", secs, flops, nbytes))

    print("| kernel | size | wall ms | achieved GFLOP/s | roofline frac |")
    print("|---|---|---|---|---|")
    for kernel, size, secs, flops, nbytes in rows:
        achieved = flops / secs / 1e9
        bound_s = max(flops / PEAK_FLOPS, nbytes / HBM_BW)
        frac = bound_s / secs if secs > 0 else 0.0
        reg.gauge("kernel_achieved_gflops",
                  "measured kernel throughput (median of 3 reps)",
                  kernel=kernel, size=size).set(achieved)
        reg.gauge("kernel_roofline_frac",
                  "achieved / roofline-bound time (1.0 = at the ceiling)",
                  kernel=kernel, size=size).set(frac)
        print(f"| {kernel} | {size} | {1e3 * secs:.2f} | {achieved:.3f} | "
              f"{frac:.2e} |")
    print()
    print("| kernel | phase | p50 ms | samples |")
    print("|---|---|---|---|")
    for kernel in dict.fromkeys(k for k, *_ in rows):
        for ph in ("dma", "compute"):
            h = reg.histogram("kernel_phase_ms",
                              "per-phase kernel wall time",
                              kernel=kernel, phase=ph)
            if h.count:
                print(f"| {kernel} | {ph} | {h.percentile(0.5):.3f} | "
                      f"{h.count} |")
    return rows


def _emit_kernel_bench(path: str, extra: dict) -> None:
    from repro.obs import bench as obs_bench

    doc = obs_bench.emit(path, "kernels", extra={"bench": extra})
    print(f"wrote {path} ({doc['schema']}, kind=kernels)")


def main():
    base = os.path.join(os.path.dirname(__file__), "..", "experiments")
    for mesh in ["pod16x16", "pod2x16x16"]:
        recs = load(os.path.join(base, f"dryrun_{mesh}.jsonl"),
                    os.path.join(base, f"dryrun_{mesh}_u2.jsonl"))
        if not recs:
            print(f"(no records for {mesh})")
            continue
        print(table(recs, f"Roofline — {mesh} ({len(recs)} cells)"))
        print()
        with open(os.path.join(base, f"roofline_{mesh}.md"), "w") as fh:
            fh.write(table(recs, f"Roofline — {mesh}") + "\n")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--kernels", action="store_true",
                    help="time the Pallas kernels (bm25_blockmax, "
                         "interval_join) instead of analyzing dry-run "
                         "artifacts")
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes only (CI)")
    ap.add_argument("--emit-bench", metavar="PATH", default=None,
                    help="write a schema-versioned BENCH_kernels.json from "
                         "the obs registry snapshot (implies --kernels)")
    args = ap.parse_args()
    if args.kernels or args.emit_bench:
        rows = kernel_bench(smoke=args.smoke)
        if args.emit_bench:
            _emit_kernel_bench(
                args.emit_bench,
                extra={"smoke": args.smoke,
                       "rows": [{"kernel": k, "size": s, "wall_s": secs}
                                for k, s, secs, _, _ in rows]})
        sys.exit(0)
    sys.exit(main())

"""A day in the life of an autopiloted warren: closed loop vs no policy.

Three passes, one report:

1. **Simulated day** (deterministic, seeded).  A ``DriftingWorkload``
   (Zipf-over-topics traffic whose hot spot migrates each phase) drives a
   ``SimCluster`` for N ticks, twice: once with the autopilot
   ``Controller`` closing the loop, once with no policy.  The headline
   figure is worst-group p95 over time: the controller must keep it
   within ``--flatness`` (default 1.5x) of its starting value while the
   no-policy baseline degrades more — the run FAILS (non-zero exit) if
   either half of that claim breaks.  Fully reproducible per seed.

1b. **Burn-driven day**.  The same drifting traffic, but the raw p95
   split trigger is disabled and the controller acts only on the serving
   SLO's *sustained burn rate*: the sim cluster feeds its modeled
   latencies into the real ``scatter_latency_ms{group}`` histograms, an
   ``obs.SLOMonitor`` (on the sim clock, tick-denominated windows)
   computes multi-window ``slo_burn_rate``, and
   ``HotSplitPolicy.burn_hot`` fires the splits.  The run FAILS unless
   at least one burn-attributed split is applied.

2. **Real-warren pass**.  A live ``ShardedWarren`` under the controller
   (real ``WarrenSignals``/``WarrenActuator``, fake clock): traffic heats
   the groups, the controller splits, a replica is killed and
   anti-entropy resurrects it, traffic stops and the collection demotes —
   with served rankings checked bit-identical to a single-index oracle
   after every action.

``--smoke`` shrinks all passes to CI size; ``--emit-bench PATH`` writes
a schema-versioned ``BENCH_autopilot.json`` (repro.bench/v1) carrying the
``autopilot_*`` and ``slo_burn_rate`` metric families plus the p95
trajectories.
"""

import math
import time

from repro import obs
from repro.dist.autopilot import (AntiEntropyPolicy, AutopilotConfig,
                                  ColdPolicy, Controller, Hysteresis,
                                  HotSplitPolicy)
from repro.dist.simharness import DriftingWorkload, SimClock, SimCluster

QUERIES = ["school education student", "government law state",
           "stock money business", "vibration conductor wind"]


# ------------------------------------------------------------------ #
# pass 1: the simulated day
# ------------------------------------------------------------------ #
def _sim_config(max_groups: int) -> AutopilotConfig:
    return AutopilotConfig(
        split=HotSplitPolicy(p95_hot_ms=40.0, sustain_ticks=3, min_docs=64,
                             max_groups=max_groups),
        cold=ColdPolicy(demote_after_ticks=15, merge_after_ticks=40,
                        min_groups=2),
        hysteresis=Hysteresis(cooldown_ticks=4, min_dwell_ticks=1,
                              window_ticks=30, max_actions_per_window=6),
        pool=None)


def _run_sim_day(seed: int, ticks: int, controlled: bool,
                 max_groups: int = 8):
    clock = SimClock()
    cluster = SimCluster(docs=1200, base_ms=2.0, ms_per_doc=0.05)
    wl = DriftingWorkload(seed=seed, topics=48, reads_per_tick=120,
                          writes_per_tick=8, phase_ticks=max(ticks // 3, 10))
    ctl = Controller(cluster, cluster, config=_sim_config(max_groups),
                     clock=clock)
    worst = []
    for _ in range(ticks):
        reads, writes = wl.tick_keys()
        cluster.route(reads)
        cluster.ingest(writes)
        if controlled:
            ctl.tick()
        else:
            cluster.collect()            # same signal drain, no policy
        clock.advance()
        worst.append(max(cluster.base_ms + cluster.ms_per_doc * g.docs
                         for g in cluster.active()))
    return ctl, cluster, worst


def sim_day(seed: int, ticks: int, flatness: float) -> dict:
    t0 = time.time()
    ctl, cluster, worst_ctl = _run_sim_day(seed, ticks, controlled=True)
    _, _, worst_base = _run_sim_day(seed, ticks, controlled=False)
    wall = time.time() - t0

    settle = max(ticks // 8, 5)          # the loop needs a few sustains
    start = worst_ctl[0]
    peak_ctl = max(worst_ctl[settle:])
    peak_base = max(worst_base)
    by_outcome: dict = {}
    for d in ctl.decisions:
        key = f"{d.kind}/{d.outcome}"
        by_outcome[key] = by_outcome.get(key, 0) + 1

    print(f"# simulated day: seed {seed}, {ticks} ticks, "
          f"{len(cluster.active())} active groups at close ({wall:.2f}s)")
    print(f"  decisions: {by_outcome or 'none'}")
    print(f"  worst-group p95 ms: start {start:.1f} -> controller peak "
          f"{peak_ctl:.1f} ({peak_ctl / start:.2f}x), no-policy peak "
          f"{peak_base:.1f} ({peak_base / start:.2f}x)")
    ok_flat = peak_ctl <= flatness * start
    ok_beats = peak_base > peak_ctl
    print(f"  flatness (controller <= {flatness:.2f}x start): "
          f"{'PASS' if ok_flat else 'FAIL'}; controller beats baseline: "
          f"{'PASS' if ok_beats else 'FAIL'}")
    if not (ok_flat and ok_beats):
        raise SystemExit("day-in-the-life flatness check failed")
    return {"seed": seed, "ticks": ticks, "p95_start_ms": start,
            "p95_peak_controller_ms": peak_ctl,
            "p95_peak_baseline_ms": peak_base,
            "flatness_bound": flatness,
            "decisions": by_outcome,
            "p95_trajectory_controller_ms": [round(x, 3) for x in worst_ctl],
            "p95_trajectory_baseline_ms": [round(x, 3) for x in worst_base]}


# ------------------------------------------------------------------ #
# pass 1b: the burn-driven day — autopilot acting on slo_burn_rate
# ------------------------------------------------------------------ #
def burn_day(seed: int, ticks: int) -> dict:
    clock = SimClock()
    cluster = SimCluster(docs=1200, base_ms=2.0, ms_per_doc=0.05,
                         observe_latency=True)
    wl = DriftingWorkload(seed=seed, topics=48, reads_per_tick=120,
                          writes_per_tick=8,
                          phase_ticks=max(ticks // 3, 10))
    monitor = obs.SLOMonitor(
        slos=[obs.SLO(name="serving_p95", kind="latency", objective=0.95,
                      metric="scatter_latency_ms", threshold_ms=40.0)],
        windows=(("short", 5.0), ("long", 20.0)), clock=clock)
    cfg = AutopilotConfig(
        # raw p95 and skew triggers OFF: only sustained burn splits
        split=HotSplitPolicy(p95_hot_ms=math.inf, skew_ratio=math.inf,
                             min_docs=64, sustain_ticks=3, max_groups=8,
                             burn_hot=1.0),
        cold=ColdPolicy(demote_after_ticks=15, merge_after_ticks=40,
                        min_groups=2),
        hysteresis=Hysteresis(cooldown_ticks=4, min_dwell_ticks=1,
                              window_ticks=30, max_actions_per_window=6),
        pool=None)
    ctl = Controller(obs.SLOSignalSource(cluster, monitor), cluster,
                     config=cfg, clock=clock)
    t0 = time.time()
    for _ in range(ticks):
        reads, writes = wl.tick_keys()
        cluster.route(reads)
        cluster.ingest(writes)
        ctl.tick()
        clock.advance()
    wall = time.time() - t0

    burn_splits = [d for d in ctl.decisions
                   if d.kind == "split" and d.outcome == "applied"
                   and "burn" in d.reason]
    print(f"# burn-driven day: seed {seed}, {ticks} ticks, "
          f"{len(cluster.active())} active groups at close, "
          f"{len(burn_splits)} burn-driven splits ({wall:.2f}s)")
    if burn_splits:
        print(f"  first: {burn_splits[0].summary()}")
    print(f"  sustained serving burn at close: "
          f"{monitor.burn('serving_p95'):.2f}")
    ok = len(burn_splits) > 0
    print(f"  autopilot acted on slo_burn_rate: "
          f"{'PASS' if ok else 'FAIL'}")
    if not ok:
        raise SystemExit("burn-driven day produced no burn-driven split")
    return {"seed": seed, "ticks": ticks,
            "burn_splits": len(burn_splits),
            "first_burn_split": burn_splits[0].to_record(),
            "closing_burn": monitor.burn("serving_p95"),
            "groups_at_close": len(cluster.active())}


# ------------------------------------------------------------------ #
# pass 2: the real warren under the controller, parity-checked
# ------------------------------------------------------------------ #
def real_warren_pass(smoke: bool, static_dir: str) -> dict:
    import numpy as np

    from repro.core import DynamicIndex, Warren, score_bm25
    from repro.data.synth import doc_generator
    from repro.core import ingest_documents
    from repro.dist.shard_router import ShardedWarren

    n_docs = 200 if smoke else 1500
    warren = ShardedWarren(n_shards=2, replicas=2, static_dir=static_dir)
    single = Warren(DynamicIndex())
    corpus = list(doc_generator(7, n_docs, mean_len=30))
    ingest_documents(warren, corpus, batch=8)
    ingest_documents(single, corpus, batch=128)

    clock = SimClock()
    cfg = AutopilotConfig(
        split=HotSplitPolicy(p95_hot_ms=0.0, sustain_ticks=2, min_docs=1,
                             max_groups=3),
        cold=ColdPolicy(demote_after_ticks=2, merge_after_ticks=10 ** 6,
                        min_groups=1),
        anti_entropy=AntiEntropyPolicy(max_seq_lag=0, sustain_ticks=2),
        hysteresis=Hysteresis(cooldown_ticks=1, min_dwell_ticks=0,
                              window_ticks=50, max_actions_per_window=50),
        pool=None)
    ctl = Controller.for_warren(warren, config=cfg, clock=clock)

    parity_checks = [0]

    def assert_parity():
        with warren, single:
            for q in QUERIES:
                got = [s for _, s in warren.search(q, k=10)]
                ref = [s for _, s in score_bm25(single, q, k=10)]
                np.testing.assert_allclose(got, ref, rtol=1e-9)
        parity_checks[0] += 1

    def serve(rounds=1):
        with warren:
            for _ in range(rounds):
                for q in QUERIES:
                    warren.search(q, k=10)

    t0 = time.time()
    # hot traffic -> controller split (capped at max_groups)
    for _ in range(3):
        serve()
        ctl.tick()
        clock.advance()
        assert_parity()
    # replica loss -> anti-entropy resurrection
    warren.groups[0].mark_failed(1)
    for _ in range(4):
        serve()
        ctl.tick()
        clock.advance()
    assert_parity()
    # traffic stops -> demotion to the static tier
    for _ in range(4):
        ctl.tick()
        clock.advance()
    assert_parity()
    wall = time.time() - t0

    kinds = sorted({(d.kind, d.outcome) for d in ctl.decisions})
    n_demoted = sum(1 for d in warren.demoted() if d is not None)
    all_alive = all(all(a) for a in warren.health())
    print(f"# real warren under the controller: {n_docs} docs, "
          f"{warren.n_shards} groups after split, {n_demoted} demoted, "
          f"{parity_checks[0]} oracle parity checks ({wall:.2f}s)")
    print(f"  decision kinds: {kinds}")
    ok = (warren.n_shards == 3 and all_alive and n_demoted > 0
          and any(d.kind == "split" and d.outcome == "applied"
                  for d in ctl.decisions)
          and any(d.kind == "resync" and d.outcome == "applied"
                  for d in ctl.decisions))
    print(f"  split + resync + demote all applied, every replica live: "
          f"{'PASS' if ok else 'FAIL'}")
    warren.close()
    if not ok:
        raise SystemExit("real-warren controller pass failed")
    return {"docs": n_docs, "groups_after": 3, "demoted": n_demoted,
            "parity_checks": parity_checks[0], "wall_s": wall,
            "decisions": [d.to_record() for d in ctl.decisions]}


def witness_pass(smoke: bool, baseline_wall: float) -> dict:
    """Re-run the real-warren pass with the LockWitness installed:
    proves the whole day's acquisition orders against
    analysis/lock_hierarchy.toml and reports the witness overhead vs the
    un-witnessed pass that just ran."""
    import os
    import tempfile

    from repro import obs

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    hierarchy = os.path.join(root, "analysis", "lock_hierarchy.toml")
    w = obs.install_witness(obs.LockWitness.from_hierarchy(hierarchy))
    try:
        with tempfile.TemporaryDirectory(prefix="ditl-witness-") as d:
            real = real_warren_pass(smoke, d)
        w.check()          # any observed inversion fails the bench
        edges = w.edges()
    finally:
        obs.uninstall_witness()
    overhead = ((real["wall_s"] - baseline_wall) / baseline_wall * 100
                if baseline_wall else 0.0)
    print(f"# lock witness: {len(edges)} acquisition edges observed, "
          f"0 violations, overhead {overhead:+.1f}% vs un-witnessed pass")
    return {"edges": len(edges), "violations": 0,
            "wall_s": real["wall_s"], "overhead_pct": overhead}


def run(seed: int = 11, ticks: int = 400, flatness: float = 1.5,
        smoke: bool = False, emit_bench: str = None,
        lock_witness: bool = False):
    if smoke:
        ticks = min(ticks, 150)
    sim = sim_day(seed, ticks, flatness)
    burn = burn_day(seed, ticks)
    import tempfile

    with tempfile.TemporaryDirectory(prefix="ditl-static-") as d:
        real = real_warren_pass(smoke, d)
    if lock_witness:
        real["witness"] = witness_pass(smoke, real["wall_s"])
    if emit_bench:
        from repro.obs import bench as obs_bench

        doc = obs_bench.emit(emit_bench, "autopilot",
                             extra={"bench": {"smoke": smoke, "sim": sim,
                                              "burn": burn, "real": real}})
        print(f"  wrote {emit_bench} ({doc['schema']}, kind=autopilot)")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=11)
    ap.add_argument("--ticks", type=int, default=400,
                    help="length of the simulated day")
    ap.add_argument("--flatness", type=float, default=1.5,
                    help="controller p95 must stay within this factor of "
                         "its starting value")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: short sim day + tiny real corpus "
                         "(same checks, same determinism)")
    ap.add_argument("--emit-bench", metavar="PATH", default=None,
                    help="write a schema-versioned BENCH_autopilot.json "
                         "from the obs registry snapshot (repro.obs.bench)")
    ap.add_argument("--lock-witness", action="store_true",
                    help="re-run the real-warren pass with the runtime "
                         "LockWitness installed (analysis/lock_hierarchy"
                         ".toml); fails on any observed lock-order "
                         "violation and reports the witness overhead")
    args = ap.parse_args()
    run(seed=args.seed, ticks=args.ticks, flatness=args.flatness,
        smoke=args.smoke, emit_bench=args.emit_bench,
        lock_witness=args.lock_witness)

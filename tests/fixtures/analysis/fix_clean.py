"""Analyzer fixture: disciplined locking and metrics — zero findings.

Locks nest strictly outer→inner, the blocking I/O happens outside the
lock, and the metric is declared and guarded.
"""

import os
import threading

from repro import obs


class Clean:
    def __init__(self):
        self._outer = threading.Lock()
        self._inner = threading.Lock()
        self._fd = -1
        self.state = {}

    def step(self, key):
        with self._outer:
            with self._inner:
                self.state[key] = self.state.get(key, 0) + 1
        os.fsync(self._fd)
        reg = obs.registry()
        if reg.enabled:
            reg.counter("fixture_ops_total", op="step").inc()

"""Analyzer fixture: metric-contract violations.

``record`` emits a metric that is not in the catalog; ``count`` uses a
declared name with the wrong label set; ``fine`` is fully declared.
"""

from repro import obs


class Meter:
    def record(self, ms):
        obs.registry().histogram("fixture_undeclared_ms").observe(ms)

    def count(self):
        obs.registry().counter("fixture_ops_total", region="x").inc()

    def fine(self):
        obs.registry().counter("fixture_ops_total", op="read").inc()

"""Analyzer fixture: blocking I/O while a hot lock is held.

``flush`` fsyncs under the (declared-hot) lock directly; ``save`` does
it transitively through ``_write``.
"""

import os
import threading


class HotPath:
    def __init__(self):
        self._lock = threading.Lock()
        self._fd = -1

    def _write(self, data):
        os.write(self._fd, data)
        os.fsync(self._fd)

    def flush(self):
        with self._lock:
            os.fsync(self._fd)

    def save(self, data):
        with self._lock:
            self._write(data)

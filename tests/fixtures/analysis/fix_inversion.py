"""Analyzer fixture: a classic AB/BA lock-order inversion.

``ping`` nests beta inside alpha; ``pong`` nests alpha inside beta —
the acquisition graph has the 2-cycle alpha→beta→alpha.
"""

import threading


class Inverted:
    def __init__(self):
        self._alpha = threading.Lock()
        self._beta = threading.Lock()
        self.n = 0

    def ping(self):
        with self._alpha:
            with self._beta:
                self.n += 1

    def pong(self):
        with self._beta:
            with self._alpha:
                self.n -= 1

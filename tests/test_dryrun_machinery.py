"""Dry-run machinery under test: a reduced mesh in a subprocess (the forced
device count must be set before jax init, so this runs out of process)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro.launch.dryrun import run_cell, collective_stats
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rec = run_cell("internlm2-1.8b", "train_4k", mesh, "test4x2")
    print(json.dumps({k: rec[k] for k in
                      ("ok", "cost", "collectives", "memory")
                      if k in rec}))
""")


@pytest.mark.slow
def test_dryrun_cell_on_small_mesh():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"]
    assert rec["cost"]["flops"] > 0
    assert rec["memory"]["peak_bytes"] > 0
    # a DP+TP train step must produce gradient/activation collectives
    assert rec["collectives"], "no collectives found in SPMD HLO"
    total = sum(v["bytes"] for v in rec["collectives"].values())
    assert total > 0


def test_collective_parser():
    from repro.launch.dryrun import collective_stats
    hlo = """
      %all-reduce.1 = f32[1024,512]{1,0} all-reduce(f32[1024,512]{1,0} %x)
      %ag = bf16[64]{0} all-gather(bf16[32]{0} %y), dim=0
      %t = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(f32[8,8]{1,0} %a, f32[8,8]{1,0} %b)
      %other = f32[2,2]{1,0} add(f32[2,2]{1,0} %p, f32[2,2]{1,0} %q)
    """
    stats = collective_stats(hlo)
    assert stats["all-reduce"]["bytes"] == 1024 * 512 * 4
    assert stats["all-gather"]["bytes"] == 64 * 2
    assert stats["all-to-all"]["count"] == 1
    assert "collective-permute" not in stats


def test_roofline_correction_math():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.roofline import correct_scan_once
    r1 = {"arch": "internlm2-1.8b", "shape": "train_4k", "ok": True,
          "cost": {"flops": 100.0, "bytes accessed": 50.0},
          "collectives": {"all-reduce": {"bytes": 10.0, "count": 2}}}
    r2 = {"arch": "internlm2-1.8b", "shape": "train_4k", "ok": True,
          "cost": {"flops": 104.0, "bytes accessed": 52.0},
          "collectives": {"all-reduce": {"bytes": 11.0, "count": 3}}}
    out = correct_scan_once(r1, r2)
    # L = 24: true = 100 + 23 * 4
    assert out["cost"]["flops"] == 100.0 + 23 * 4.0
    assert out["cost"]["bytes accessed"] == 50.0 + 23 * 2.0
    assert out["collectives"]["all-reduce"]["bytes"] == 10.0 + 23 * 1.0

"""Vectorized (device) GCL engine vs the lazy reference engine."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import gcl
from repro.core.annotation import AnnotationList, reduce_minimal
from repro.core import vectorized as V


gc_strategy = st.lists(
    st.tuples(st.integers(0, 60), st.integers(0, 10)).map(lambda t: (t[0], t[0] + t[1])),
    max_size=16,
)


def make(ivs):
    if not ivs:
        return AnnotationList.empty()
    s = np.array([i[0] for i in ivs], dtype=np.int64)
    e = np.array([i[1] for i in ivs], dtype=np.int64)
    return reduce_minimal(s, e, np.zeros(len(ivs)))


def lazy_solutions(node):
    return [(p, q) for p, q, _ in node.solutions()]


OPS = {
    "contained_in": (gcl.ContainedIn, lambda A, B: V.contained_in(
        *V.pack(A.starts, A.ends, A.values), *V.pack(B.starts, B.ends)[:2])[:2]),
    "containing": (gcl.Containing, lambda A, B: V.containing(
        *V.pack(A.starts, A.ends, A.values), *V.pack(B.starts, B.ends)[:2])[:2]),
    "not_contained_in": (gcl.NotContainedIn, lambda A, B: V.not_contained_in(
        *V.pack(A.starts, A.ends, A.values), *V.pack(B.starts, B.ends)[:2])[:2]),
    "not_containing": (gcl.NotContaining, lambda A, B: V.not_containing(
        *V.pack(A.starts, A.ends, A.values), *V.pack(B.starts, B.ends)[:2])[:2]),
    "both_of": (gcl.BothOf, lambda A, B: V.both_of(
        *V.pack(A.starts, A.ends)[:2], *V.pack(B.starts, B.ends)[:2])),
    "one_of": (gcl.OneOf, lambda A, B: V.one_of(
        *V.pack(A.starts, A.ends)[:2], *V.pack(B.starts, B.ends)[:2])),
    "followed_by": (gcl.FollowedBy, lambda A, B: V.followed_by(
        *V.pack(A.starts, A.ends)[:2], *V.pack(B.starts, B.ends)[:2])),
}


@pytest.mark.parametrize("name", list(OPS))
@settings(max_examples=80, deadline=None)
@given(a=gc_strategy, b=gc_strategy)
def test_vectorized_matches_lazy(name, a, b):
    A, B = make(a), make(b)
    node_cls, vec = OPS[name]
    want = lazy_solutions(node_cls(gcl.Term(A), gcl.Term(B)))
    s, e = vec(A, B)
    got_s, got_e, _ = V.unpack(s, e)
    got = sorted(zip(got_s.tolist(), got_e.tolist()))
    assert got == want, f"{name}: {got} != {want}"


@settings(max_examples=40, deadline=None)
@given(a=gc_strategy)
def test_tau_rho_batched(a):
    A = make(a)
    s, e, _ = V.pack(A.starts, A.ends)
    ks = np.arange(-2, 75)
    ts, te = V.tau(s, e, ks)
    rs, re = V.rho(s, e, ks)
    term = gcl.Term(A)
    for i, k in enumerate(ks):
        want_t = term.tau(int(k))
        want_r = term.rho(int(k))
        if want_t[1] >= gcl.INF:
            assert int(ts[i]) == V.PAD
        else:
            assert (int(ts[i]), int(te[i])) == want_t[:2]
        if want_r[1] >= gcl.INF:
            assert int(rs[i]) == V.PAD
        else:
            assert (int(rs[i]), int(re[i])) == want_r[:2]


def test_bm25_topk_batched():
    rng = np.random.default_rng(3)
    n_docs, q, t, l, k = 500, 4, 3, 40, 10
    doc_idx = rng.integers(0, n_docs, size=(q, t, l)).astype(np.int32)
    impacts = rng.random((q, t, l)).astype(np.float32)
    # pad some entries
    padmask = rng.random((q, t, l)) < 0.3
    doc_idx[padmask] = n_docs  # drop
    impacts[padmask] = 0.0
    qmask = np.ones((q, t), np.float32)
    scores, ids = V.bm25_topk(doc_idx, impacts, qmask, n_docs=n_docs, k=k)
    # oracle per query
    for qi in range(q):
        acc = np.zeros(n_docs)
        for ti in range(t):
            for li in range(l):
                d = doc_idx[qi, ti, li]
                if d < n_docs:
                    acc[d] += impacts[qi, ti, li]
        order = np.argsort(-acc, kind="stable")[:k]
        np.testing.assert_allclose(np.sort(np.asarray(scores[qi]))[::-1],
                                   np.sort(acc[order])[::-1], rtol=1e-5)

"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
asserting output shapes and no NaNs (assignment requirement f)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.train.optimizer import AdamWConfig, init_opt_state, make_train_step

ARCH_NAMES = sorted(ARCHS)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_smoke(name):
    spec = get_arch(name)
    cfg = spec.smoke_config
    params = spec.init_fn(cfg, jax.random.PRNGKey(0))
    batch = spec.smoke_batch(cfg, "train", seed=1)
    batch = {k: jnp.asarray(v) if not np.isscalar(v) else v
             for k, v in batch.items()}

    step = jax.jit(make_train_step(lambda p, b: spec.loss_fn(p, cfg, b),
                                   AdamWConfig(warmup_steps=2, total_steps=10)))
    opt = init_opt_state(params)
    p1, opt1, m1 = step(params, opt, batch)
    assert np.isfinite(float(m1["loss"])), f"{name}: loss not finite"
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)))
    assert moved, f"{name}: train step did not update params"
    # second step: loss finite again (no NaN propagation)
    _, _, m2 = step(p1, opt1, batch)
    assert np.isfinite(float(m2["loss"]))


@pytest.mark.parametrize("name", [n for n in ARCH_NAMES
                                  if ARCHS[n].serve_fn is not None])
def test_serve_smoke(name):
    spec = get_arch(name)
    cfg = spec.smoke_config
    params = spec.init_fn(cfg, jax.random.PRNGKey(0))
    batch = spec.smoke_batch(cfg, "serve", seed=2)
    batch = {k: jnp.asarray(v) if not np.isscalar(v) else v
             for k, v in batch.items()}
    out = jax.jit(lambda p, b: spec.serve_fn(p, cfg, b))(params, batch)
    assert np.all(np.isfinite(np.asarray(out, np.float32)))


@pytest.mark.parametrize("name", ["qwen2.5-14b", "qwen3-moe-235b-a22b",
                                  "qwen2-moe-a2.7b"])
def test_lm_decode_smoke(name):
    """Decode path: prefill-free incremental decoding with a KV cache."""
    from repro.models import transformer as T
    spec = get_arch(name)
    cfg = spec.smoke_config
    params = spec.init_fn(cfg, jax.random.PRNGKey(0))
    cache = T.init_cache(cfg, batch=2, seq_len=32)
    step = jax.jit(lambda p, c, t: T.decode_step(p, c, t, cfg))
    toks = jnp.asarray([1, 2], jnp.int32)
    for i in range(4):
        logits, cache = step(params, cache, toks)
        assert logits.shape == (2, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
    assert int(cache["length"][0]) == 4


def test_lm_decode_matches_forward():
    """Incremental decode logits == full forward logits (causal consistency)."""
    from repro.models import transformer as T
    spec = get_arch("internlm2-1.8b")
    cfg = dataclasses.replace(spec.smoke_config, dtype="float32")
    params = spec.init_fn(cfg, jax.random.PRNGKey(3))
    toks = np.array([[5, 9, 2, 7, 4, 1]], dtype=np.int32)
    full_logits = T.forward(params, jnp.asarray(toks), cfg)  # [1, S, V]

    cache = T.init_cache(cfg, batch=1, seq_len=8)
    dec = []
    for i in range(toks.shape[1]):
        logits, cache = T.decode_step(params, cache, jnp.asarray(toks[:, i]), cfg)
        dec.append(np.asarray(logits))
    dec = np.stack(dec, axis=1)
    np.testing.assert_allclose(np.asarray(full_logits), dec, rtol=2e-4,
                               atol=2e-4)


def test_nequip_equivariance():
    """E invariant, F equivariant under random rotations (the E(3) property)."""
    from repro.models import nequip as NQ
    from repro.data import synth
    cfg = dataclasses.replace(get_arch("nequip").smoke_config, d_feat=0,
                              n_classes=0)
    params = NQ.init_params(cfg, jax.random.PRNGKey(1))
    b = synth.molecule_batch(0, batch=2, n_nodes=6, n_edges=14)
    pos = jnp.asarray(b["positions"])
    args = (jnp.asarray(b["species"]), jnp.asarray(b["senders"]),
            jnp.asarray(b["receivers"]), jnp.asarray(b["graph_ids"]), 2)

    e0, f0 = NQ.energy_and_forces(params, cfg, pos, *args)
    # random rotation (QR of a gaussian)
    q, _ = np.linalg.qr(np.random.default_rng(0).standard_normal((3, 3)))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    R = jnp.asarray(q, jnp.float32)
    e1, f1 = NQ.energy_and_forces(params, cfg, pos @ R.T, *args)
    np.testing.assert_allclose(np.asarray(e0), np.asarray(e1), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(f0 @ R.T), np.asarray(f1),
                               rtol=1e-3, atol=1e-4)


def test_neighbor_sampler_fanout():
    from repro.data.synth import NeighborSampler, random_graph
    g = random_graph(0, 500, 4000)
    s = NeighborSampler(500, g["senders"], g["receivers"])
    rng = np.random.default_rng(0)
    seeds = rng.choice(500, 32, replace=False)
    sub = s.sample(seeds, [5, 3], rng)
    assert sub["senders"].max() < len(sub["nodes"])
    assert sub["receivers"].max() < len(sub["nodes"])
    assert len(sub["senders"]) == 32 * 5 + len(np.unique(sub["senders"])) * 0 + \
        (len(sub["senders"]) - 32 * 5)  # trivially consistent sizes
    # seed nodes map into the subgraph
    assert np.all(sub["nodes"][sub["seed_local"]] == seeds)

"""vByte codec, static index, graph store."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (DynamicIndex, GraphStore, StaticIndex, Warren,
                        add_json, index_document, score_bm25, write_static)
from repro.core import vbyte


@settings(max_examples=80, deadline=None)
@given(st.lists(st.integers(0, 2**48), max_size=200))
def test_vbyte_roundtrip(values):
    arr = np.array(values, dtype=np.int64)
    enc = vbyte.encode(arr)
    dec = vbyte.decode(enc, len(arr))
    assert np.array_equal(dec, arr)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(-2**40, 2**40), max_size=100))
def test_zigzag_roundtrip(values):
    arr = np.array(values, dtype=np.int64)
    assert np.array_equal(vbyte.unzigzag(vbyte.zigzag(arr)), arr)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 2**32), min_size=1, max_size=100, unique=True))
def test_gap_roundtrip(values):
    arr = np.sort(np.array(values, dtype=np.int64))
    enc = vbyte.encode_gaps(arr)
    assert np.array_equal(vbyte.decode_gaps(enc, len(arr)), arr)


def test_static_index_roundtrip(tmp_path):
    idx = DynamicIndex()
    w = Warren(idx)
    with w:
        w.transaction()
        for i in range(10):
            index_document(w, f"static document {i} with shared words fox")
        w.commit()
    d = str(tmp_path / "static")
    write_static(idx, d)
    si = StaticIndex(d)
    assert len(si.annotations(":")) == 10
    assert len(si.annotations("fox")) == 10
    # ranking works against the static index too (same read surface)
    top = score_bm25(si, "fox shared", k=3)
    assert len(top) == 3
    # translate round trip
    doc0 = si.annotations(":")
    t = si.translate(int(doc0.starts[0]), int(doc0.ends[0]))
    assert t.startswith("static document 0")
    si.close()


def test_static_roundtrip_forced_zlib_fallback(tmp_path, monkeypatch):
    """write_static of a committed snapshot, re-read with the zlib codec
    path forced (as if zstandard were not installed): every blob must be
    self-describing and the erased state must survive the round trip."""
    from repro.core import codec

    monkeypatch.setattr(codec, "_zstd", None)
    monkeypatch.setattr(codec, "_zstd_c", None)
    monkeypatch.setattr(codec, "_zstd_d", None)

    idx = DynamicIndex()
    w = Warren(idx)
    with w:
        w.transaction()
        for i in range(8):
            index_document(w, f"fallback document {i} shared fox",
                           docid=f"d{i}")
        w.commit()
    with w:
        lst = w.annotations("docid:d3")
        victim = (int(lst.starts[0]), int(lst.ends[0]))
    with w:
        w.transaction()
        w.erase(*victim)
        w.commit()

    d = str(tmp_path / "static")
    write_static(idx, d)
    si0 = StaticIndex(d)
    from repro.core.codec import ZLIB
    # the fallback really engaged: v2 content payloads are codec-tagged
    assert si0.content.raw_payload(0)[0] == ZLIB
    si0.close()

    si = StaticIndex(d)
    assert len(si.annotations(":")) == 7      # erased doc is gone
    assert len(si.annotations("docid:d3")) == 0
    # regression: erased CONTENT must not leak back through the static
    # layout — dynamic and static agree that the span is unreadable
    with w:
        assert w.translate(*victim) is None
    assert si.translate(*victim) is None
    assert si.tokens(*victim) is None
    # a partial overlap with the erased interval is unreadable too
    assert si.translate(victim[0] + 1, victim[1] + 1) is None
    surviving = si.annotations("docid:d0")
    t = si.translate(int(surviving.starts[0]), int(surviving.ends[0]))
    assert t == "fallback document 0 shared fox"
    top = score_bm25(si, "fox shared", k=3)
    assert len(top) == 3
    si.close()


def test_static_snapshot_parity_hopper_phrase_over_erased(tmp_path):
    """StaticIndex and Snapshot must agree on hopper access methods and
    phrase solutions when erased intervals cut through the collection:
    full-document erases, a partial mid-document erase, and probes that
    straddle an erased boundary."""
    idx = DynamicIndex()
    w = Warren(idx)
    with w:
        w.transaction()
        for i in range(12):
            index_document(w, f"quick brown fox number {i} jumps high",
                           docid=f"d{i}")
        w.commit()
    spans = {}
    with w:
        for i in range(12):
            lst = w.annotations(f"docid:d{i}")
            spans[i] = (int(lst.starts[0]), int(lst.ends[0]))
    with w:
        w.transaction()
        w.erase(*spans[4])                       # full doc
        w.erase(spans[7][0] + 1, spans[7][0] + 3)  # partial, mid-doc
        w.commit()

    d = str(tmp_path / "static")
    write_static(idx, d)
    si = StaticIndex(d)
    snap = idx.snapshot()

    for feature in ("quick", "brown", "fox", "jumps", ":", "dl:",
                    "docid:d4", "docid:d7"):
        fval = idx.featurizer.featurize(feature)
        assert si.annotations(feature) == snap.annotations(fval), feature

    # hopper access methods probed across the erased boundaries
    fval = idx.featurizer.featurize("fox")
    h_static, h_dyn = si.hopper("fox"), snap.hopper(fval)
    probes = [spans[4][0] - 1, spans[4][0], spans[4][1],
              spans[4][1] + 1, spans[7][0], spans[7][0] + 2, spans[7][1]]
    for k in probes:
        assert h_static.tau(k) == h_dyn.tau(k), k
        assert h_static.rho(k) == h_dyn.rho(k), k

    # phrase solutions: erased docs drop out identically on both sides
    w_static, w_dyn = si.phrase("quick brown fox"), None
    with w:
        w_dyn = w.phrase("quick brown fox")
        assert w_static.solutions() == w_dyn.solutions()
        assert len(w_static.solutions()) == 10   # d4 gone; d7 phrase cut
    # translate/tokens straddling the erased boundary: None on both sides
    for p, q in [(spans[4][0] - 1, spans[4][0]), (spans[7][0], spans[7][1]),
                 (spans[7][0] + 3, spans[7][0] + 4)]:
        with w:
            assert si.translate(p, q) is None
            assert si.translate(p, q) == w.translate(p, q)
            assert si.tokens(p, q) == w.tokens(p, q)
    si.close()


def test_static_legacy_meta_without_erased_fields(tmp_path):
    """Directories written before the erased list existed (no er_* keys in
    meta.msgpack) must load with nothing hidden."""
    import msgpack

    idx = DynamicIndex()
    w = Warren(idx)
    with w:
        w.transaction()
        index_document(w, "legacy layout doc", docid="d0")
        w.commit()
    from repro.core.static import _write_static_v1

    d = str(tmp_path / "static")
    _write_static_v1(idx, d)
    with open(d + "/meta.msgpack", "rb") as fh:
        meta = msgpack.unpackb(fh.read(), raw=False)
    for k in ("er_n", "er_s", "er_e"):
        meta.pop(k)
    with open(d + "/meta.msgpack", "wb") as fh:
        fh.write(msgpack.packb(meta))
    si = StaticIndex(d)
    docs = si.annotations(":")
    assert len(docs) == 1
    assert si.translate(int(docs.starts[0]),
                        int(docs.ends[0])) == "legacy layout doc"
    si.close()


def test_codec_legacy_raw_zstd_frame_without_zstd(monkeypatch):
    """A pre-codec-byte blob (raw zstd frame) read in a zlib-only
    environment must fail loudly naming the missing codec — never be
    misparsed as an unknown codec byte."""
    from repro.core import codec

    monkeypatch.setattr(codec, "_zstd", None)
    monkeypatch.setattr(codec, "_zstd_d", None)
    legacy = b"\x28\xb5\x2f\xfd" + b"\x00" * 16   # zstd magic + frame bytes
    with np.testing.assert_raises(RuntimeError):
        codec.decompress(legacy)
    try:
        codec.decompress(legacy)
    except RuntimeError as e:
        assert "zstandard" in str(e)
    # zlib-tagged blobs always decode, zstd or not
    blob = codec.compress(b"fallback payload" * 10)
    assert blob[0] == codec.ZLIB
    assert codec.decompress(blob) == b"fallback payload" * 10


def test_graph_store_friends():
    w = Warren(DynamicIndex())
    g = GraphStore(w)
    with w:
        w.transaction()
        people = {}
        for name in ["Alice", "Bob", "Carol", "Dave"]:
            people[name] = g.add_node({"name": name})
        edges = {"Alice": ["Bob", "Carol", "Dave"], "Bob": ["Alice", "Dave"],
                 "Carol": ["Alice"], "Dave": ["Bob", "Alice"]}
        for src, dsts in edges.items():
            for dst in dsts:
                g.add_edge("@friend", people[src][0], people[dst][0])
        remap = w.commit()
    people = {k: (remap(lo), remap(hi)) for k, (lo, hi) in people.items()}
    with w:
        nbrs = g.neighbors("@friend", *people["Alice"])
        assert sorted(nbrs) == sorted([people[n][0] for n in ["Bob", "Carol", "Dave"]])
        # resolve a target address back to its containing object
        obj = g.containing_object(nbrs[0])
        assert obj in people.values()
        # BFS reaches everyone from Carol
        reached = list(g.bfs("@friend", people["Carol"]))
        assert len(reached) == 4


def test_graph_store_triples():
    w = Warren(DynamicIndex())
    g = GraphStore(w)
    with w:
        w.transaction()
        streep = g.add_node({"name": "Meryl Streep"})
        oscar = g.add_node({"name": "Best Actress"})
        g.add_triple(streep[0], "won_award", oscar[0])
        remap = w.commit()
    streep = (remap(streep[0]), remap(streep[1]))
    oscar = (remap(oscar[0]), remap(oscar[1]))
    with w:
        objs = g.objects_of(streep, "won_award")
        assert objs == [oscar[0]]


# ------------------------------------------------------------------ #
# v2 lazy decode: mmap blocks, erased unions, promotion parity
# ------------------------------------------------------------------ #
def test_lazy_content_multi_block_record_roundtrip(tmp_path):
    """A record bigger than several 4 KiB blocks reassembles exactly
    through the block reader (extent pinning across block boundaries)."""
    from repro.core.static import LazyContentStore

    idx = DynamicIndex()
    w = Warren(idx)
    long_text = " ".join(f"tok{i}" for i in range(4000))     # ~30 KiB
    with w:
        w.transaction()
        index_document(w, "tiny doc before", docid="small0")
        index_document(w, long_text, docid="big")
        index_document(w, "tiny doc after", docid="small1")
        w.commit()
    d = str(tmp_path / "static")
    write_static(idx, d)
    si = StaticIndex(d)
    assert isinstance(si.content, LazyContentStore)
    lst = si.annotations("docid:big")
    p, q = int(lst.starts[0]), int(lst.ends[0])
    assert si.translate(p, q) == long_text
    assert si.tokens(p, q) == long_text.split()
    # and only the touched records were decoded (the corpus stays cold)
    assert len(si.content._lru) <= 2
    si.close()


def test_erased_union_through_mmap_blocks(tmp_path):
    """Tombstones recorded across separate transactions coalesce into one
    union that filters lazily decoded content — including an erased span
    that covers a record straddling block boundaries."""
    idx = DynamicIndex()
    w = Warren(idx)
    texts = {f"d{i}": (" ".join(f"w{i}_{j}" for j in range(600))
                       if i in (2, 3) else f"short doc {i} keyword")
             for i in range(8)}
    with w:
        w.transaction()
        for docid, text in texts.items():
            index_document(w, text, docid=docid)
        w.commit()
    # erase two ADJACENT docs (union must coalesce) + the big straddler
    spans = {}
    with w:
        for docid in ("d2", "d3", "d6"):
            lst = w.annotations("docid:" + docid)
            spans[docid] = (int(lst.starts[0]), int(lst.ends[0]))
    for docid in ("d2", "d3", "d6"):
        with w:
            w.transaction()
            w.erase(*spans[docid])
            w.commit()
    d = str(tmp_path / "static")
    write_static(idx, d)
    si = StaticIndex(d)
    snap = idx.snapshot()
    # adjacent tombstones coalesced into one interval in the static union
    assert len(si.erased) == len(snap.erased)
    np.testing.assert_array_equal(si.erased.starts, snap.erased.starts)
    np.testing.assert_array_equal(si.erased.ends, snap.erased.ends)
    for docid, (p, q) in spans.items():
        assert si.translate(p, q) is None, docid
        assert len(si.annotations("docid:" + docid)) == 0
    # survivors read exactly, straight through the same blocks
    for docid in ("d0", "d1", "d4", "d5", "d7"):
        lst = si.annotations("docid:" + docid)
        assert si.translate(int(lst.starts[0]),
                            int(lst.ends[0])) == texts[docid]
    si.close()


def test_to_segment_materializes_lazy_content(tmp_path):
    """Promotion (going hot) is the one deliberately non-lazy read: the
    segment gets a RESIDENT content store bit-identical to lazy decode."""
    from repro.core.static import LazyContentStore
    from repro.core.txt import ContentStore

    idx = DynamicIndex()
    w = Warren(idx)
    with w:
        w.transaction()
        for i in range(9):
            index_document(w, f"promote me {i} please", docid=f"d{i}")
        w.commit()
    d = str(tmp_path / "static")
    write_static(idx, d)
    si = StaticIndex(d)
    seg = si.to_segment()
    assert isinstance(si.content, LazyContentStore)
    assert isinstance(seg.content, ContentStore)
    assert len(seg.content.records()) == len(si.content)
    for i, rec in enumerate(seg.content.records()):
        lazy = si.content.decode(i)
        assert (rec.lo, rec.hi, rec.text, rec.tokens) == \
            (lazy.lo, lazy.hi, lazy.text, lazy.tokens)
        np.testing.assert_array_equal(rec.offsets, lazy.offsets)
    si.close()


def test_lazy_content_store_refuses_writes(tmp_path):
    import pytest

    idx = DynamicIndex()
    w = Warren(idx)
    with w:
        w.transaction()
        index_document(w, "immutable content", docid="d0")
        w.commit()
    d = str(tmp_path / "static")
    write_static(idx, d)
    si = StaticIndex(d)
    with pytest.raises(TypeError):
        si.content.add(si.content.decode(0))
    si.close()

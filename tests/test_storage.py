"""vByte codec, static index, graph store."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (DynamicIndex, GraphStore, StaticIndex, Warren,
                        add_json, index_document, score_bm25, write_static)
from repro.core import vbyte


@settings(max_examples=80, deadline=None)
@given(st.lists(st.integers(0, 2**48), max_size=200))
def test_vbyte_roundtrip(values):
    arr = np.array(values, dtype=np.int64)
    enc = vbyte.encode(arr)
    dec = vbyte.decode(enc, len(arr))
    assert np.array_equal(dec, arr)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(-2**40, 2**40), max_size=100))
def test_zigzag_roundtrip(values):
    arr = np.array(values, dtype=np.int64)
    assert np.array_equal(vbyte.unzigzag(vbyte.zigzag(arr)), arr)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 2**32), min_size=1, max_size=100, unique=True))
def test_gap_roundtrip(values):
    arr = np.sort(np.array(values, dtype=np.int64))
    enc = vbyte.encode_gaps(arr)
    assert np.array_equal(vbyte.decode_gaps(enc, len(arr)), arr)


def test_static_index_roundtrip(tmp_path):
    idx = DynamicIndex()
    w = Warren(idx)
    with w:
        w.transaction()
        for i in range(10):
            index_document(w, f"static document {i} with shared words fox")
        w.commit()
    d = str(tmp_path / "static")
    write_static(idx, d)
    si = StaticIndex(d)
    assert len(si.annotations(":")) == 10
    assert len(si.annotations("fox")) == 10
    # ranking works against the static index too (same read surface)
    top = score_bm25(si, "fox shared", k=3)
    assert len(top) == 3
    # translate round trip
    doc0 = si.annotations(":")
    t = si.translate(int(doc0.starts[0]), int(doc0.ends[0]))
    assert t.startswith("static document 0")
    si.close()


def test_graph_store_friends():
    w = Warren(DynamicIndex())
    g = GraphStore(w)
    with w:
        w.transaction()
        people = {}
        for name in ["Alice", "Bob", "Carol", "Dave"]:
            people[name] = g.add_node({"name": name})
        edges = {"Alice": ["Bob", "Carol", "Dave"], "Bob": ["Alice", "Dave"],
                 "Carol": ["Alice"], "Dave": ["Bob", "Alice"]}
        for src, dsts in edges.items():
            for dst in dsts:
                g.add_edge("@friend", people[src][0], people[dst][0])
        remap = w.commit()
    people = {k: (remap(lo), remap(hi)) for k, (lo, hi) in people.items()}
    with w:
        nbrs = g.neighbors("@friend", *people["Alice"])
        assert sorted(nbrs) == sorted([people[n][0] for n in ["Bob", "Carol", "Dave"]])
        # resolve a target address back to its containing object
        obj = g.containing_object(nbrs[0])
        assert obj in people.values()
        # BFS reaches everyone from Carol
        reached = list(g.bfs("@friend", people["Carol"]))
        assert len(reached) == 4


def test_graph_store_triples():
    w = Warren(DynamicIndex())
    g = GraphStore(w)
    with w:
        w.transaction()
        streep = g.add_node({"name": "Meryl Streep"})
        oscar = g.add_node({"name": "Best Actress"})
        g.add_triple(streep[0], "won_award", oscar[0])
        remap = w.commit()
    streep = (remap(streep[0]), remap(streep[1]))
    oscar = (remap(oscar[0]), remap(oscar[1]))
    with w:
        objs = g.objects_of(streep, "won_award")
        assert objs == [oscar[0]]

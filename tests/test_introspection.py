"""The live introspection plane: admin server, profiling, SLO burn.

Tier-1 here covers the introspection issue's acceptance criteria: every
admin endpoint answers against a *live* ShardedWarren while a rebalance
is in flight and writers keep committing (the admin plane never takes a
write lock), the sampling profiler returns non-empty collapsed stacks,
ProfiledLock records contention without changing lock semantics (RLock
reentrancy included), RotatingJsonl bounds its disk use, and the SLO
monitor's multi-window burn rates — computed on a fake clock,
deterministically — drive the autopilot's hot-split policy through
``HotSplitPolicy.burn_hot``.
"""

import json
import math
import threading
import time
import urllib.request

import pytest

from repro import obs
from repro.obs import (SLO, AdminServer, MetricsRegistry, ProfiledLock,
                       RotatingJsonl, SamplingProfiler, SLOMonitor,
                       SLOSignalSource)
from repro.dist.autopilot import (AutopilotConfig, ColdPolicy, Controller,
                                  HotSplitPolicy, Hysteresis)
from repro.dist.simharness import SimClock, SimCluster

from tests.test_rebalance import QUERIES, _ingest, _pair


@pytest.fixture(autouse=True)
def _clean_global_obs():
    obs.enable()
    obs.registry().reset()
    obs.tracer().reset()
    obs.tracer().set_slow_dump(None, None)
    yield
    obs.enable()
    obs.tracer().set_slow_dump(None, None)


# --------------------------------------------------------------------- #
# RotatingJsonl                                                         #
# --------------------------------------------------------------------- #

def test_rotating_jsonl_caps_disk_use(tmp_path):
    p = tmp_path / "log.jsonl"
    sink = RotatingJsonl(str(p), max_bytes=300, backups=2)
    for i in range(100):
        sink.write({"i": i, "pad": "x" * 40})
    files = sink.files()
    assert str(p) in files and len(files) == 3        # live + 2 backups
    import os
    total = sum(os.path.getsize(f) for f in files)
    assert total <= 3 * 300 + 100                      # bounded disk use
    # live file holds whole lines, newest records last
    last = [json.loads(line) for line in p.read_text().splitlines()]
    assert last[-1]["i"] == 99
    # an oversized single record still lands rather than being dropped
    sink.write({"huge": "y" * 1000})
    assert json.loads(p.read_text().splitlines()[-1])["huge"] == "y" * 1000


def test_rotating_jsonl_zero_backups(tmp_path):
    p = tmp_path / "log.jsonl"
    sink = RotatingJsonl(str(p), max_bytes=200, backups=0)
    for i in range(50):
        sink.write({"i": i})
    assert sink.files() == [str(p)]
    import os
    assert os.path.getsize(str(p)) <= 250


def test_controller_decision_log_rotates(tmp_path):
    clock = SimClock()
    cluster = SimCluster(docs=500)
    log = tmp_path / "decisions.jsonl"
    cfg = AutopilotConfig(
        split=HotSplitPolicy(p95_hot_ms=0.0, sustain_ticks=1, min_docs=1,
                             max_groups=64),
        cold=ColdPolicy(demote_after_ticks=10 ** 6,
                        merge_after_ticks=10 ** 6),
        hysteresis=Hysteresis(cooldown_ticks=0, min_dwell_ticks=0,
                              window_ticks=1, max_actions_per_window=10),
        pool=None)
    ctl = Controller(cluster, cluster, config=cfg, clock=clock,
                     decision_log=str(log))
    ctl._log_sink = RotatingJsonl(str(log), max_bytes=400, backups=1)
    for _ in range(60):
        cluster.route([0.01, 0.51])
        ctl.tick()
        clock.advance()
    assert ctl.decisions, "controller made no decisions"
    import os
    assert os.path.getsize(str(log)) <= 500
    # every line in the live log is a valid Decision record
    for line in log.read_text().splitlines():
        rec = json.loads(line)
        assert {"tick", "kind", "group", "outcome"} <= set(rec)


# --------------------------------------------------------------------- #
# ProfiledLock                                                          #
# --------------------------------------------------------------------- #

def test_profiled_lock_records_contention_only():
    lk = ProfiledLock("t_uncontended")
    with lk:
        pass
    h = obs.registry().histogram("lock_wait_ms", lock="t_uncontended")
    assert h.count == 0                     # fast path: no observation

    lk2 = ProfiledLock("t_contended")
    lk2.acquire()
    waited = threading.Event()

    def taker():
        with lk2:
            waited.set()

    t = threading.Thread(target=taker)
    t.start()
    time.sleep(0.02)
    lk2.release()
    t.join(timeout=5.0)
    assert waited.is_set()
    h2 = obs.registry().histogram("lock_wait_ms", lock="t_contended")
    assert h2.count == 1
    assert h2.percentile(0.5) >= 1.0        # waited >= the sleep, roughly
    c = obs.registry().counter("lock_contended_total", lock="t_contended")
    assert c.value == 1


def test_profiled_lock_rlock_reentrancy_and_protocol():
    lk = ProfiledLock("t_rlock", threading.RLock())
    with lk:
        with lk:                            # reentrant: must not deadlock
            assert lk.acquire(blocking=False)
            lk.release()
    assert lk.acquire(blocking=True, timeout=1.0)
    lk.release()
    # non-blocking failure path returns False without metrics explosions
    plain = ProfiledLock("t_plain")
    plain.acquire()
    hold = threading.Event()
    done = threading.Event()

    def other():
        assert not plain.acquire(blocking=False)
        done.set()

    threading.Thread(target=other).start()
    assert done.wait(timeout=5.0)
    plain.release()
    hold.set()


# --------------------------------------------------------------------- #
# SamplingProfiler                                                      #
# --------------------------------------------------------------------- #

def _spin(stop: threading.Event) -> None:
    x = 0
    while not stop.is_set():
        x += 1


def test_sampling_profiler_collapsed_stacks():
    stop = threading.Event()
    t = threading.Thread(target=_spin, args=(stop,), name="spinner")
    t.start()
    try:
        prof = SamplingProfiler(interval_s=0.002)
        prof.start()
        time.sleep(0.15)
        prof.stop()
    finally:
        stop.set()
        t.join()
    assert prof.samples > 0
    text = prof.collapsed()
    assert text, "no collapsed stacks collected"
    for line in text.splitlines():
        stack, _, count = line.rpartition(" ")
        assert stack and int(count) >= 1
    assert "_spin" in text                  # the busy thread is visible
    assert "spinner" in text                # tagged with its thread name


def test_profile_for_one_shot():
    out = obs.profile_for(0.05, interval_s=0.002)
    assert isinstance(out, str)


# --------------------------------------------------------------------- #
# SLO burn rates on a fake clock                                        #
# --------------------------------------------------------------------- #

def test_latency_slo_burn_multiwindow():
    reg = MetricsRegistry()
    clk = SimClock(step=1.0)
    slo = SLO(name="p95", kind="latency", objective=0.9,
              metric="lat_ms", threshold_ms=10.0)
    mon = SLOMonitor(slos=[slo], windows=(("short", 2.0), ("long", 6.0)),
                     reg=reg, clock=clk)
    h = reg.histogram("lat_ms", group=0)
    # healthy traffic: all good, burn 0 in every window
    for _ in range(4):
        for _ in range(10):
            h.observe(1.0)
        mon.tick()
        clk.advance()
    assert mon.burn("p95") == 0.0
    # sustained badness: every observation over threshold -> bad
    # fraction 1.0, burn = 1.0 / 0.1 = 10 in both windows
    for _ in range(8):
        for _ in range(10):
            h.observe(100.0)
        mon.tick()
        clk.advance()
    assert mon.burn("p95", "short") == pytest.approx(10.0)
    assert mon.burn("p95") == pytest.approx(10.0, rel=0.35)
    assert mon.group_burns("p95")["0"] > 1.0
    # the gauges were exported
    snap = reg.snapshot()["slo_burn_rate"]
    labels = {tuple(sorted(s["labels"].items())) for s in snap["series"]}
    assert (("slo", "p95"), ("window", "short")) in labels
    assert (("slo", "p95"), ("window", "long")) in labels


def test_latency_slo_short_window_recovers_first():
    reg = MetricsRegistry()
    clk = SimClock(step=1.0)
    slo = SLO(name="p95", kind="latency", objective=0.9,
              metric="lat_ms", threshold_ms=10.0)
    mon = SLOMonitor(slos=[slo], windows=(("short", 2.0), ("long", 8.0)),
                     reg=reg, clock=clk)
    h = reg.histogram("lat_ms")
    for _ in range(6):                       # bad spell
        h.observe(100.0)
        mon.tick()
        clk.advance()
    for _ in range(3):                       # recovery
        for _ in range(20):
            h.observe(1.0)
        mon.tick()
        clk.advance()
    short, long_ = mon.burn("p95", "short"), mon.burn("p95", "long")
    assert short < long_                     # short window forgets first
    assert mon.burn("p95") == short          # sustained = min across windows


def test_ratio_slo_burn():
    reg = MetricsRegistry()
    clk = SimClock(step=1.0)
    slo = SLO(name="commit", kind="ratio", objective=0.9,
              good_metric="ok_total", bad_metric="fail_total")
    mon = SLOMonitor(slos=[slo], windows=(("w", 4.0),), reg=reg, clock=clk)
    ok, fail = reg.counter("ok_total"), reg.counter("fail_total")
    mon.tick()
    clk.advance()
    ok.inc(90)
    fail.inc(10)                             # 10% bad = exactly at budget
    mon.tick()
    assert mon.burn("commit") == pytest.approx(1.0)
    ok.inc(100)                              # dilute: 10/200 bad
    mon.tick()
    assert mon.burn("commit") == pytest.approx(0.5)


def test_empty_window_burns_zero_and_nan_before_first_tick():
    reg = MetricsRegistry()
    mon = SLOMonitor(slos=[SLO(name="p", kind="latency", objective=0.99,
                               metric="nothing_ms", threshold_ms=1.0)],
                     reg=reg, clock=SimClock())
    assert math.isnan(mon.burn("p"))
    mon.tick()
    assert mon.burn("p") == 0.0              # no traffic is not an outage


def test_slo_signal_source_drives_burn_hot_split():
    clk = SimClock(step=1.0)
    cluster = SimCluster(docs=64, ms_per_doc=1.0, observe_latency=True)
    mon = SLOMonitor(
        slos=[SLO(name="serving_p95", kind="latency", objective=0.95,
                  metric="scatter_latency_ms", threshold_ms=20.0)],
        windows=(("short", 3.0), ("long", 9.0)), clock=clk)
    cfg = AutopilotConfig(
        # p95/skew triggers disabled: only burn can split
        split=HotSplitPolicy(p95_hot_ms=math.inf, skew_ratio=math.inf,
                             min_docs=8, sustain_ticks=2, max_groups=4,
                             burn_hot=1.0),
        pool=None)
    ctl = Controller(SLOSignalSource(cluster, mon), cluster,
                     config=cfg, clock=clk)
    for _ in range(10):
        cluster.route([0.1] * 20)
        ctl.tick()
        clk.advance()
    splits = [d for d in ctl.decisions
              if d.kind == "split" and d.outcome == "applied"]
    assert splits, "sustained burn did not trigger a split"
    assert "burn" in splits[0].reason
    assert len(cluster.active()) > 1


def test_slo_signal_source_rejects_unknown_slo():
    mon = SLOMonitor(reg=MetricsRegistry())
    with pytest.raises(ValueError, match="no SLO named"):
        SLOSignalSource(SimCluster(), mon, slo_name="nonsense")


# --------------------------------------------------------------------- #
# AdminServer                                                           #
# --------------------------------------------------------------------- #

def _get(url, timeout=10.0):
    try:
        with urllib.request.urlopen(url, timeout=timeout) as r:
            return r.status, r.read().decode()
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode()


def test_admin_endpoints_and_scrapes_mid_rebalance(tmp_path):
    from repro.dist.rebalance import Rebalancer

    sharded, _ = _pair(n_docs=140)
    clock = SimClock()
    ctl = Controller.for_warren(
        sharded, config=AutopilotConfig(pool=None), clock=clock)
    mon = SLOMonitor()
    with sharded:
        sharded.search(QUERIES[0], k=5)     # seed a trace + latency metrics
    ctl.tick()
    mon.tick()

    with AdminServer(warren=sharded, controller=ctl, slo=mon) as srv:
        # -- every endpoint answers -------------------------------------- #
        code, body = _get(srv.url("/healthz"))
        assert code == 200 and json.loads(body)["ok"] is True
        code, body = _get(srv.url("/readyz"))
        assert code == 200 and json.loads(body)["ready"] is True
        code, body = _get(srv.url("/metrics"))
        assert code == 200 and "# TYPE" in body
        assert "scatter_latency_ms_bucket" in body
        code, body = _get(srv.url("/metrics.json"))
        assert code == 200 and "scatter_latency_ms" in json.loads(
            body)["metrics"]
        code, body = _get(srv.url("/routing"))
        routing = json.loads(body)
        assert code == 200 and routing["n_groups"] == sharded.n_shards
        for g in routing["groups"].values():
            assert g["alive"] and g["ranges"]
        code, body = _get(srv.url("/autopilot/decisions?n=5"))
        assert code == 200 and "decisions" in json.loads(body)
        code, body = _get(srv.url("/slo"))
        assert code == 200
        names = [s["name"] for s in json.loads(body)["slos"]]
        assert "serving_p95" in names
        code, body = _get(srv.url("/tiered/runs"))
        assert code == 200 and "demoted_groups" in json.loads(body)
        code, body = _get(srv.url("/traces"))
        traces = json.loads(body)["traces"]
        assert code == 200 and traces
        tid = traces[-1]["trace_id"]
        code, body = _get(srv.url(f"/traces/{tid}"))
        assert code == 200 and json.loads(body)["tree"]["name"]
        # error paths stay JSON
        assert _get(srv.url("/traces/notanid"))[0] == 400
        assert _get(srv.url("/traces/999999999"))[0] == 404
        assert _get(srv.url("/nonsense"))[0] == 404
        code, body = _get(srv.url("/profile/cpu?seconds=0.05"))
        assert code == 200

        # -- scrape storm while a split runs and writers commit ----------- #
        errors = []
        stop = threading.Event()

        def scraper():
            paths = ["/metrics", "/routing", "/traces", "/healthz",
                     "/autopilot/decisions", "/slo"]
            i = 0
            while not stop.is_set():
                c, _ = _get(srv.url(paths[i % len(paths)]))
                if c != 200:
                    errors.append((paths[i % len(paths)], c))
                i += 1

        def writer():
            try:
                _ingest(sharded, range(1000, 1040), batch=8)
            except Exception as e:          # pragma: no cover
                errors.append(("writer", repr(e)))

        threads = [threading.Thread(target=scraper) for _ in range(3)]
        wt = threading.Thread(target=writer)
        for t in threads:
            t.start()
        wt.start()
        new_gid = Rebalancer(sharded).split_group(0)
        wt.join(timeout=60.0)
        assert not wt.is_alive(), "writer blocked during scraped rebalance"
        stop.set()
        for t in threads:
            t.join(timeout=10.0)
        assert not errors, f"admin-plane failures: {errors[:5]}"

        # post-split routing reflects the new epoch and group
        code, body = _get(srv.url("/routing"))
        routing = json.loads(body)
        assert str(new_gid) in routing["groups"]
        assert routing["epoch"] >= 1
    sharded.close()


def test_admin_server_without_attachments():
    with AdminServer() as srv:
        assert _get(srv.url("/healthz"))[0] == 200
        code, body = _get(srv.url("/readyz"))
        assert code == 200 and json.loads(body)["warren"] is None
        assert _get(srv.url("/routing"))[0] == 404
        assert _get(srv.url("/autopilot/decisions"))[0] == 404
        assert _get(srv.url("/tiered/runs"))[0] == 404
        assert _get(srv.url("/slo"))[0] == 404


def test_admin_tiered_runs_with_store(tmp_path):
    from repro.core import index_document
    from repro.tiered.store import TieredStore

    store = TieredStore(str(tmp_path))
    with store.warren() as w:
        w.transaction()
        index_document(w, "school education student", docid="t0")
        w.commit()
    info = store.freeze()
    assert info is not None
    with AdminServer(tiered=store) as srv:
        code, body = _get(srv.url("/tiered/runs"))
        doc = json.loads(body)
        assert code == 200
        assert doc["n_runs"] == 1
        assert doc["runs"][0]["n_records"] > 0
        assert doc["manifest"]["frozen_upto"] >= 0
    store.close()


def test_registry_series_view_concurrent_with_scrape():
    reg = obs.registry()
    stop = threading.Event()
    errs = []

    def churn():
        i = 0
        while not stop.is_set():
            reg.histogram("churn_ms", group=i % 50).observe(float(i % 90))
            i += 1

    def scrape():
        try:
            while not stop.is_set():
                text = reg.to_prometheus()
                assert "churn_ms" in text or text is not None
                reg.series("churn_ms")
        except Exception as e:              # pragma: no cover
            errs.append(repr(e))

    ts = [threading.Thread(target=churn) for _ in range(4)] + \
         [threading.Thread(target=scrape) for _ in range(2)]
    for t in ts:
        t.start()
    time.sleep(0.3)
    stop.set()
    for t in ts:
        t.join(timeout=10.0)
    assert not errs

"""Fault tolerance: checkpoint/restart determinism, injected failures,
straggler mitigation, gradient compression, elastic resharding."""

import itertools
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synth
from repro.dist.checkpoint import CheckpointManager
from repro.dist.compression import (compress_with_feedback, decompress,
                                    init_residual)
from repro.dist.elastic import reshard, shrink_mesh
from repro.train.optimizer import AdamWConfig, init_opt_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig, run_with_restarts


def tiny_loss(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def tiny_params(key):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (8, 4)), "b": jnp.zeros((4,))}


def data_stream(seed, start=0):
    def gen():
        step = start
        while True:
            rng = np.random.default_rng(hash((seed, step)) % 2**32)
            yield {"x": rng.standard_normal((16, 8)).astype(np.float32),
                   "y": rng.standard_normal((16, 4)).astype(np.float32),
                   "step": step}
            step += 1
    return gen()


class ResumableStream:
    def __init__(self, seed):
        self.seed = seed
        self.step = 0

    def __iter__(self):
        return self

    def __next__(self):
        rng = np.random.default_rng(hash((self.seed, self.step)) % 2**32)
        b = {"x": rng.standard_normal((16, 8)).astype(np.float32),
             "y": rng.standard_normal((16, 4)).astype(np.float32)}
        self.step += 1
        b["_state"] = {"step": self.step}   # state AFTER producing this batch
        return b

    def state(self):
        return {"step": self.step}

    def restore(self, s):
        self.step = int(s["step"])   # checkpoint round-trip yields arrays


def make_trainer(ckpt_dir, total=30, stream=None, **kw):
    stream = stream or ResumableStream(0)
    cfg = TrainerConfig(total_steps=total, ckpt_every=5, ckpt_dir=ckpt_dir,
                        log_every=1, opt=AdamWConfig(warmup_steps=2,
                                                     total_steps=total), **kw)
    return Trainer(tiny_loss, tiny_params(jax.random.PRNGKey(0)), cfg,
                   stream, data_state_fn=stream.state,
                   data_restore_fn=stream.restore)


def test_checkpoint_restart_bitwise_identical(tmp_path):
    """Uninterrupted run == crash-at-17-and-restart run, bit for bit."""
    t_ref = make_trainer(str(tmp_path / "ref"), total=30)
    t_ref.train()
    ref_params = t_ref.params

    t2 = run_with_restarts(
        lambda: make_trainer(str(tmp_path / "crash"), total=30,
                             stream=ResumableStream(0)),
        fail_at=17)
    assert t2.step == 30
    for a, b in zip(jax.tree.leaves(ref_params), jax.tree.leaves(t2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_manager_keeps_and_gc(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in [5, 10, 15, 20]:
        cm.save(s, {"w": np.arange(4.0), "step": s})
    assert cm.all_steps() == [15, 20]
    assert cm.latest_step() == 20
    got = cm.restore(20, {"w": np.zeros(4), "step": 0})
    np.testing.assert_array_equal(got["w"], np.arange(4.0))
    assert got["step"] == 20


def test_checkpoint_bf16_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_write=False)
    state = {"p": jnp.asarray(np.random.randn(6, 3), jnp.bfloat16)}
    cm.save(1, state)
    got = cm.restore(1, state)
    np.testing.assert_array_equal(np.asarray(got["p"], np.float32),
                                  np.asarray(state["p"], np.float32))
    assert got["p"].dtype == jnp.bfloat16


def test_straggler_skip():
    """A slow batch is skipped and the loop continues with the next one."""
    class SlowStream(ResumableStream):
        def __next__(self):
            if self.step == 3:
                self.step += 1
                time.sleep(1.0)   # straggler
                return super().__next__()
            return super().__next__()

    stream = SlowStream(0)
    cfg = TrainerConfig(total_steps=10, ckpt_every=100, ckpt_dir=None,
                        straggler_timeout_s=0.25,
                        opt=AdamWConfig(warmup_steps=1, total_steps=10))
    t = Trainer(tiny_loss, tiny_params(jax.random.PRNGKey(0)), cfg, stream,
                data_state_fn=stream.state, data_restore_fn=stream.restore)
    out = t.train()
    assert out["step"] == 10
    assert out["skipped"] >= 1


def test_gradient_compression_error_feedback():
    """Quantization error is carried, so the *averaged* update converges:
    the residual keeps the compressed stream unbiased over steps."""
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.standard_normal((32, 32)) * 1e-3)}
    residual = init_residual(g_true)
    acc = jnp.zeros((32, 32))
    n = 50
    for _ in range(n):
        q, s, residual = compress_with_feedback(g_true, residual)
        acc = acc + decompress(q, s)["w"]
    mean_err = np.abs(np.asarray(acc / n - g_true["w"])).max()
    # error feedback drives the time-averaged error well below one
    # quantization step (|g|_max/127 ≈ 3e-5 here)
    assert mean_err < float(jnp.abs(g_true["w"]).max()) / 127 / 2


def test_compression_reduces_bytes():
    g = {"w": jnp.ones((1024, 1024), jnp.float32)}
    q, s, _ = compress_with_feedback(g, init_residual(g))
    assert q["w"].dtype == jnp.int8
    ratio = (q["w"].size * 1 + 4) / (g["w"].size * 4)
    assert ratio < 0.26


def test_elastic_reshard_and_shrink():
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    params = {"w": jnp.arange(16.0).reshape(4, 4)}
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    moved = reshard(params, sh)
    assert moved["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(moved["w"]),
                                  np.asarray(params["w"]))
    # mesh shrink policy: lose 16 devices from (2,16,16) → halve data axis
    sizes = shrink_mesh({"pod": 2, "data": 16, "model": 16}, lost_devices=16)
    assert sizes["model"] == 16          # TP width preserved
    assert sizes["data"] * sizes["pod"] * sizes["model"] <= 512 - 16


def test_index_backed_pipeline_resumable():
    from repro.core import DynamicIndex, Warren
    from repro.data.pipeline import (IndexedCorpusLoader, ingest,
                                     mark_duplicates, segment)
    w = Warren(DynamicIndex())
    docs = list(synth.doc_generator(0, 30, mean_len=60))
    docs.append(docs[0])  # exact duplicate
    assert ingest(w, docs) == 31
    assert mark_duplicates(w) == 1
    n_segs = segment(w, window=32, stride=16)
    assert n_segs > 30
    loader = IndexedCorpusLoader(w, vocab=1000, batch=4, seq_len=32)
    b1 = next(loader)
    state = loader.state()
    b2 = next(loader)
    # restore and replay: identical batch
    loader2 = IndexedCorpusLoader(w, vocab=1000, batch=4, seq_len=32)
    loader2.restore(state)
    b2_replay = next(loader2)
    np.testing.assert_array_equal(b2["tokens"], b2_replay["tokens"])
    assert b1["tokens"].shape == (4, 32)
    assert b1["tokens"].max() < 1000

"""Structural query language: text syntax → GCL trees → solutions."""

import pytest

from repro.core import DynamicIndex, Warren, add_json
from repro.core.query import QueryError, parse_query, solve
from repro.data.synth import json_collection


@pytest.fixture(scope="module")
def warren():
    w = Warren(DynamicIndex())
    data = json_collection(seed=0, scale=0.4)
    with w:
        w.transaction()
        for name, objs in data.items():
            for obj in objs:
                add_json(w, obj, collection=f"Files/{name}.json")
        w.commit()
    return w


def test_containment_query(warren):
    with warren:
        got = solve('[:city:] >> "new york" << [Files/zips.json]', warren)
        # oracle: direct GCL construction
        from repro.core.gcl import ContainedIn, Containing
        want = ContainedIn(Containing(warren.hopper(":city:"),
                                      warren.phrase("new york")),
                           warren.hopper("Files/zips.json")).solutions()
        assert got == want
        assert len(got) > 0


def test_or_and_precedence(warren):
    with warren:
        q = "[:title:] | [:authors:] << [Files/books.json]"
        got = solve(q, warren)
        # << binds tighter than |
        from repro.core.gcl import ContainedIn, OneOf
        want = OneOf(warren.hopper(":title:"),
                     ContainedIn(warren.hopper(":authors:"),
                                 warren.hopper("Files/books.json"))).solutions()
        assert got == want


def test_parens_and_both(warren):
    with warren:
        got = solve("([:name:] & [:cuisine:]) << [Files/restaurant.json]",
                    warren)
        assert len(got) > 0


def test_followed_by(warren):
    with warren:
        got = solve('"company" ... "nanotech"', warren)
        # every witness starts at a "company" token and ends at a later
        # "nanotech" token
        for p, q, _ in got:
            assert p < q


def test_not_contained(warren):
    with warren:
        all_names = solve("[:name:]", warren)
        not_rest = solve("[:name:] !<< [Files/restaurant.json]", warren)
        in_rest = solve("[:name:] << [Files/restaurant.json]", warren)
        assert len(not_rest) + len(in_rest) == len(all_names)


def test_word_atom_and_errors(warren):
    with warren:
        assert solve("nanotech", warren)
        with pytest.raises(QueryError):
            parse_query("[:a:] <<", warren)
        with pytest.raises(QueryError):
            parse_query("(unclosed", warren)
        with pytest.raises(QueryError):
            parse_query('"unclosed phrase', warren)

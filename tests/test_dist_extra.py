"""repro.dist beyond the seed tests: compression round-trips (property),
ShardedWarren == single Warren, sharded checkpoints, codec fallback."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (DynamicIndex, Warren, collection_stats,
                        index_document, score_bm25)
from repro.core import codec
from repro.core.query import solve
from repro.data.synth import doc_generator
from repro.dist.checkpoint import (CheckpointCorrupt, CheckpointManager,
                                   CheckpointShapeMismatch)
from repro.dist.compression import (compress_with_feedback, compression_ratio,
                                    decompress, init_residual)
from repro.dist.elastic import repartition_shards, shrink_mesh
from repro.dist.shard_router import STRIPE, ShardedWarren, shard_of


# ------------------------------------------------------------------ #
# dist.compression: property round-trips
# ------------------------------------------------------------------ #
@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-100.0, 100.0), min_size=1, max_size=64))
def test_quantize_dequantize_error_bound(xs):
    g = {"w": jnp.asarray(np.array(xs, np.float32))}
    r = init_residual(g)
    q, s, new_r = compress_with_feedback(g, r)
    deq = decompress(q, s)
    step = float(np.abs(np.asarray(g["w"])).max()) / 127.0
    err = np.asarray(deq["w"]) - np.asarray(g["w"])
    assert np.abs(err).max() <= step + 1e-6
    # the residual is exactly the negated rounding error
    np.testing.assert_allclose(np.asarray(new_r["w"]), -err,
                               rtol=1e-5, atol=1e-6)
    assert q["w"].dtype == jnp.int8


@settings(max_examples=15, deadline=None)
@given(st.lists(st.floats(-1.0, 1.0), min_size=4, max_size=32))
def test_residual_carry_is_unbiased(xs):
    """Across repeated sends of the same gradient the carried residual
    keeps the stream unbiased: cumulative dequantized mass tracks n*g."""
    g = {"w": jnp.asarray(np.array(xs, np.float32))}
    r = init_residual(g)
    acc = np.zeros(len(xs), np.float64)
    n = 25
    for _ in range(n):
        q, s, r = compress_with_feedback(g, r)
        acc += np.asarray(decompress(q, s)["w"], np.float64)
    scale = float(np.abs(np.asarray(g["w"])).max()) / 127.0
    err = np.abs(acc / n - np.asarray(g["w"], np.float64)).max()
    assert err <= scale  # a plain (no-feedback) quantizer only gives n*scale


def test_compression_ratio_helper():
    g = {"a": jnp.ones((256, 256)), "b": jnp.ones((128,))}
    assert compression_ratio(g) < 0.26


# ------------------------------------------------------------------ #
# core.codec: zlib fallback
# ------------------------------------------------------------------ #
def test_codec_roundtrip_and_tagging():
    blob = codec.compress(b"annotative indexing" * 100)
    assert blob[0] in (codec.ZSTD, codec.ZLIB)
    assert codec.decompress(blob) == b"annotative indexing" * 100
    with pytest.raises(ValueError):
        codec.decompress(bytes([99]) + blob[1:])


# ------------------------------------------------------------------ #
# dist.shard_router: sharded == single-index retrieval
# ------------------------------------------------------------------ #
def _ingest(w, docs, batch=32):
    it = iter(docs)
    while True:
        chunk = [d for _, d in zip(range(batch), it)]
        if not chunk:
            return
        with w:
            w.transaction()
            for docid, text in chunk:
                index_document(w, text, docid=docid)
            w.commit()


@pytest.fixture(scope="module")
def corpus():
    return list(doc_generator(42, 240, mean_len=50))


@pytest.fixture(scope="module")
def single(corpus):
    w = Warren(DynamicIndex())
    _ingest(w, corpus)
    return w


@pytest.fixture(scope="module")
def sharded(corpus):
    sw = ShardedWarren(n_shards=4)
    _ingest(sw, corpus)
    return sw


QUERIES = ["vibration conductor wind", "school education student",
           "government law state", "stock money business"]


def _texts(w, results):
    stats = collection_stats(w)
    ends = {int(s): int(e) for s, e in zip(stats.doc_starts, stats.doc_ends)}
    return [w.translate(d, ends[d]) for d, _ in results]


def test_sharded_topk_equals_single(single, sharded):
    assert len({shard_of(s._next_addr) for s in sharded.shards}) == 4
    for q in QUERIES:
        with single:
            ref = score_bm25(single, q, k=10)
            ref_texts = _texts(single, ref)
        with sharded:
            merged = score_bm25(sharded, q, k=10)      # zero-change surface
            fast = sharded.search(q, k=10)             # scatter-gather path
            merged_texts = _texts(sharded, merged)
            fast_texts = _texts(sharded, fast)
        np.testing.assert_allclose([s for _, s in merged],
                                   [s for _, s in ref], rtol=1e-9)
        np.testing.assert_allclose([s for _, s in fast],
                                   [s for _, s in ref], rtol=1e-9)
        # identical documents modulo equal-score ties
        for got in (merged_texts, fast_texts):
            i = 0
            ref_scores = [round(s, 9) for _, s in ref]
            while i < len(ref):
                j = i
                while j < len(ref) and ref_scores[j] == ref_scores[i]:
                    j += 1
                assert set(got[i:j]) == set(ref_texts[i:j])
                i = j


def test_sharded_gcl_solutions_match(single, sharded):
    with single:
        ref = solve("school", single, limit=10_000)
    with sharded:
        got = sharded.search_gcl("school", limit=10_000)
    assert len(got) == len(ref) > 0


def test_sharded_erase_visible_through_merged_reads(sharded):
    with sharded:
        docs = sharded.annotations(":")
        n0 = len(docs)
        victim = (int(docs.starts[0]), int(docs.ends[0]))
    with sharded:
        sharded.transaction()
        sharded.erase(*victim)
        sharded.commit()
    with sharded:
        assert len(sharded.annotations(":")) == n0 - 1
        assert sharded.translate(*victim) is None


def test_sharded_cross_shard_transaction(sharded):
    """One transaction annotating committed docs on several shards."""
    with sharded:
        docs = sharded.annotations(":")
        picks = [(int(docs.starts[i]), int(docs.ends[i]))
                 for i in range(1, len(docs), max(len(docs) // 6, 1))]
    with sharded:
        sharded.transaction()
        for p, q in picks:
            sharded.annotate("audit:", p, q, 1.0)
        sharded.commit()
    assert len({shard_of(p) for p, _ in picks}) > 1
    with sharded:
        assert len(sharded.annotations("audit:")) == len(picks)


def test_sharded_checkpoint_roundtrip(tmp_path, sharded):
    cm = CheckpointManager(str(tmp_path), async_write=False)
    sharded.checkpoint(cm, 7)
    restored = ShardedWarren.restore(cm, 7)
    assert restored.n_shards == sharded.n_shards
    q = QUERIES[0]
    with sharded:
        ref = sharded.search(q, k=10)
    with restored:
        got = restored.search(q, k=10)
    assert [(d, round(s, 9)) for d, s in got] == \
        [(d, round(s, 9)) for d, s in ref]
    # restored shards keep allocating inside their stripe
    for i, s in enumerate(restored.shards):
        assert shard_of(s._next_addr) == i


# ------------------------------------------------------------------ #
# dist.checkpoint: corruption tolerance
# ------------------------------------------------------------------ #
def test_restore_latest_good_skips_corrupt(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_write=False)
    cm.save(1, {"w": np.arange(3.0)})
    cm.save(2, {"w": np.arange(3.0) * 2})
    with open(os.path.join(str(tmp_path), "step_00000002",
                           "state.msgpack"), "r+b") as fh:
        fh.seek(10)
        fh.write(b"\xff\xff\xff")
    with pytest.raises(CheckpointCorrupt):
        cm.restore(2, {"w": np.zeros(3)})
    step, state = cm.restore_latest_good({"w": np.zeros(3)})
    assert step == 1
    np.testing.assert_array_equal(state["w"], np.arange(3.0))


def test_torn_shard_snapshot_refuses_restore(tmp_path):
    """A missing middle shard must be an error, not a truncated warren."""
    sw = ShardedWarren(n_shards=3)
    _ingest(sw, list(doc_generator(5, 60, mean_len=30)))
    cm = CheckpointManager(str(tmp_path), async_write=False)
    sw.checkpoint(cm, 3)
    os.unlink(os.path.join(str(tmp_path), "shard01_00000003.log"))
    with pytest.raises(CheckpointCorrupt, match="missing shard"):
        ShardedWarren.restore(cm, 3)


def test_shape_mismatch_is_loud_not_skipped(tmp_path):
    """A config change must not silently restart training from step 0."""
    cm = CheckpointManager(str(tmp_path), async_write=False)
    cm.save(1, {"w": np.zeros(4), "step": 1})
    bigger = {"w": np.zeros(4), "extra": np.zeros(2), "step": 0}
    with pytest.raises(CheckpointShapeMismatch):
        cm.restore(1, bigger)
    with pytest.raises(CheckpointShapeMismatch):
        cm.restore_latest_good(bigger)


def test_async_write_failure_surfaces_on_wait(tmp_path):
    cm = CheckpointManager(str(tmp_path / "ck"), async_write=True)
    cm.save(1, {"w": np.zeros(4)}, block=True)          # healthy write
    broken = tmp_path / "not_a_dir"
    broken.write_text("occupied")                       # mkdir will fail
    cm.directory = str(broken)
    with pytest.raises(RuntimeError, match="checkpoint write failed"):
        cm.save(2, {"w": np.zeros(4)}, block=True)
    cm.directory = str(tmp_path / "ck")                 # error is one-shot
    cm.save(3, {"w": np.zeros(4)}, block=True)
    assert cm.all_steps() == [1, 3]


def test_index_checkpoint_roundtrip(tmp_path):
    w = Warren(DynamicIndex())
    _ingest(w, list(doc_generator(3, 40, mean_len=30)))
    cm = CheckpointManager(str(tmp_path), async_write=False)
    cm.save_index(5, w.index)
    assert cm.index_steps() == [5]
    idx2 = cm.restore_index(5)
    w2 = Warren(idx2)
    with w, w2:
        assert score_bm25(w, "school education", k=5) == \
            score_bm25(w2, "school education", k=5)


# ------------------------------------------------------------------ #
# dist.elastic + trainer integration
# ------------------------------------------------------------------ #
def test_shrink_mesh_edge_cases():
    with pytest.raises(ValueError):
        shrink_mesh({"data": 4, "model": 4}, lost_devices=16)
    with pytest.raises(ValueError):
        shrink_mesh({"data": 1, "model": 8}, lost_devices=4)
    out = shrink_mesh({"pod": 4, "data": 8, "model": 4}, lost_devices=100)
    assert out["model"] == 4 and out["pod"] * out["data"] * 4 <= 28


def test_repartition_shards_covers_all_items():
    shards = [[f"doc{i}" for i in range(20)], [f"doc{i}" for i in range(20, 50)]]
    out = repartition_shards(shards, 3)
    assert sorted(x for s in out for x in s) == sorted(x for s in shards for x in s)
    assert sum(bool(s) for s in out) == 3


def test_trainer_with_compressed_grads():
    from repro.train.optimizer import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig

    def loss(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2)

    rng = np.random.default_rng(0)
    w_true = rng.standard_normal((8, 4)).astype(np.float32)

    class Stream:
        def __init__(self):
            self.step = 0

        def __iter__(self):
            return self

        def __next__(self):
            r = np.random.default_rng(self.step)
            x = r.standard_normal((32, 8)).astype(np.float32)
            self.step += 1
            return {"x": x, "y": x @ w_true}

    params = {"w": jnp.zeros((8, 4), jnp.float32)}
    cfg = TrainerConfig(total_steps=40, ckpt_every=1000, ckpt_dir=None,
                        compress_grads=True,
                        opt=AdamWConfig(lr=3e-2, warmup_steps=2,
                                        total_steps=40))
    t = Trainer(loss, params, cfg, Stream())
    out = t.train()
    assert out["step"] == 40
    assert "ef" in t.opt_state            # residual rides in the opt state
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0] * 0.5   # converges despite int8 grads

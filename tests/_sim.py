"""Canned autopilot scenarios on top of repro.dist.simharness.

The harness proper (SimClock / SimCluster / DriftingWorkload /
ScriptedSignals) lives in ``src/repro/dist/simharness.py`` so the
benchmarks import it too; this module is the thin test-side layer:
signal shorthand, a recording actuator with failure injection, tight
deterministic controller configs, and the drive loop the tier-1 tests
and the property test share.  Nothing here reads the wall clock.
"""

import math

from repro.dist.autopilot import (AntiEntropyPolicy, AutopilotConfig,
                                  ColdPolicy, Controller, GroupSignal,
                                  Hysteresis, HotSplitPolicy, RetryPolicy,
                                  ScriptedSignals)
from repro.dist.rebalance import RebalanceAborted
from repro.dist.simharness import SimClock


def sig(group, docs=100, p95=math.nan, reads=10, writes=0,
        demoted=False, retired=False, seqs=(5, 5), alive=(True, True)):
    """GroupSignal shorthand for scripted scenarios."""
    return GroupSignal(group=group, docs=docs, p95_ms=p95, reads=reads,
                       writes=writes, demoted=demoted, retired=retired,
                       replica_seqs=tuple(seqs), alive=tuple(alive))


class RecordingActuator:
    """Pure actuator: records calls, applies no mechanism.  ``split``
    hands out fresh group ids; ``fail_kinds`` makes those action kinds
    raise RebalanceAborted (always, or the next N times via
    ``fail_budget``) to exercise the backoff path."""

    def __init__(self, next_gid=1, fail_kinds=(), fail_budget=None):
        self.calls = []
        self._next_gid = next_gid
        self.fail_kinds = set(fail_kinds)
        self.fail_budget = fail_budget

    def _maybe_fail(self, kind):
        if kind in self.fail_kinds:
            if self.fail_budget is None:
                raise RebalanceAborted(f"injected {kind} abort")
            if self.fail_budget > 0:
                self.fail_budget -= 1
                raise RebalanceAborted(f"injected {kind} abort")

    def split(self, group):
        self.calls.append(("split", group))
        self._maybe_fail("split")
        gid = self._next_gid
        self._next_gid += 1
        return gid

    def merge(self, dest, source):
        self.calls.append(("merge", dest, source))
        self._maybe_fail("merge")

    def demote(self, group):
        self.calls.append(("demote", group))
        self._maybe_fail("demote")

    def resync(self, group, replica):
        self.calls.append(("resync", group, replica))
        self._maybe_fail("resync")

    @property
    def applied(self):
        return list(self.calls)


def tight_config(**overrides):
    """The deterministic scenario config every canned test shares: short
    sustains and cooldowns so sequences resolve in a handful of ticks."""
    kw = dict(
        split=HotSplitPolicy(p95_hot_ms=50.0, skew_ratio=3.0, min_docs=10,
                             sustain_ticks=3, max_groups=8),
        cold=ColdPolicy(idle_reads=0, demote_after_ticks=3,
                        merge_after_ticks=6, min_groups=2),
        anti_entropy=AntiEntropyPolicy(max_seq_lag=0, sustain_ticks=2),
        hysteresis=Hysteresis(cooldown_ticks=4, min_dwell_ticks=1,
                              window_ticks=20, max_actions_per_window=8),
        retry=RetryPolicy(base_ticks=1, cap_ticks=8),
        pool=None,
    )
    kw.update(overrides)
    return AutopilotConfig(**kw)


def run_scripted(ticks, config=None, actuator=None, n_ticks=None):
    """Drive a controller over a scripted signal schedule; returns
    (controller, actuator).  ``n_ticks`` defaults to the script length
    (the last tick's signals hold if you ask for more)."""
    clock = SimClock()
    act = actuator if actuator is not None else RecordingActuator(
        next_gid=max(s.group for t in ticks for s in t) + 1)
    ctl = Controller(ScriptedSignals(ticks), act,
                     config=config if config is not None else tight_config(),
                     clock=clock)
    for _ in range(n_ticks if n_ticks is not None else len(ticks)):
        ctl.tick()
        clock.advance()
    return ctl, act


def decision_seq(ctl):
    """The compact (tick, kind, group, target, outcome) sequence the
    exact-scenario tests assert against."""
    return [(d.tick, d.kind, d.group, d.target, d.outcome)
            for d in ctl.decisions]
